"""Quickstart: train the paper's cross-attention router and evaluate it.

    PYTHONPATH=src python examples/quickstart.py

Steps (all offline, deterministic):
  1. generate synthetic RouterBench traffic (11 models x 8 benchmarks),
  2. build training-free model embeddings (k-means cluster performance),
  3. train the dual attention predictors (quality + cost, MSE/Adam/cosine),
  4. sweep the user's willingness-to-pay and report AIQ vs KNN + oracle.
"""
import numpy as np

from repro.core import (
    DEFAULT_LAMBDA_GRID, build_model_embeddings, evaluate_sweep, oracle_sweep,
)
from repro.core.baselines import KNNRouter
from repro.core.router import PredictiveRouter
from repro.core import rewards
from repro.data import generate
from repro.training import train_dual_predictors


def main():
    print("== 1. data ==")
    data = generate(2000, seed=0)
    pool = data.pool("pool1")
    tr, va, te = pool.split()
    print(f"pool1 = {pool.model_names}; {len(tr)} train / {len(te)} test")

    print("== 2. model embeddings (training-free, k-means) ==")
    memb, _ = build_model_embeddings(pool.emb[tr], pool.quality[tr], seed=0)
    print(f"model embedding matrix: {memb.shape}")

    print("== 3. train dual attention predictors ==")
    qp, cp, scaler, hist = train_dual_predictors(
        "attn", "attn", pool.emb[tr], pool.quality[tr], pool.cost[tr], memb,
        q_emb_val=pool.emb[va], quality_val=pool.quality[va],
        cost_val=pool.cost[va], epochs=200, seed=0,
    )
    print(f"quality MSE {hist['quality']['train_loss'][0]:.4f} -> "
          f"{hist['quality']['train_loss'][-1]:.4f}")

    print("== 4. evaluate ==")
    router = PredictiveRouter("attn", "attn", qp, cp, memb, reward="R2",
                              cost_scaler=scaler)
    ch = router.sweep(pool.emb[te], DEFAULT_LAMBDA_GRID)
    m = evaluate_sweep(ch, pool.quality[te], pool.cost[te])

    knn = KNNRouter(pool.emb[tr], pool.quality[tr], pool.cost[tr], k=20)
    s_hat, c_hat = knn.predict(pool.emb[te])
    ch_knn = np.stack([np.asarray(rewards.route("R2", s_hat, c_hat, lam))
                       for lam in DEFAULT_LAMBDA_GRID])
    mk = evaluate_sweep(ch_knn, pool.quality[te], pool.cost[te])

    mo = evaluate_sweep(
        oracle_sweep(pool.quality[te], pool.cost[te], DEFAULT_LAMBDA_GRID, "R2"),
        pool.quality[te], pool.cost[te])

    print(f"{'router':<22}{'AIQ':>8}{'Perf_max':>10}")
    print(f"{'attention (paper)':<22}{m['aiq']:>8.4f}{m['perf_max']:>10.4f}")
    print(f"{'KNN (k=20)':<22}{mk['aiq']:>8.4f}{mk['perf_max']:>10.4f}")
    print(f"{'oracle R2':<22}{mo['aiq']:>8.4f}{mo['perf_max']:>10.4f}")


if __name__ == "__main__":
    main()
