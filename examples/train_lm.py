"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the synthetic Markov corpus, then greedy-decode from it.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the qwen3 family at ~100M scale (12L, d_model=512), the framework's
Adam + cosine schedule, remat, and the checkpoint layer. Loss must drop
well below uniform (ln 4096 ~ 8.3) into the corpus' structural entropy.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import LayerSpec, ATTN, MLP
from repro.launch.train import train_loop
from repro.models import lm as lm_mod


def lm_100m():
    base = get_config("qwen3-0.6b")
    return dataclasses.replace(
        base,
        name="qwen3-100m-example",
        n_layers=12,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        d_ff=1536,
        vocab_size=4096,
        pattern=(LayerSpec(mixer=ATTN, ffn=MLP),),
        n_repeats=12,
    )


def main():
    ap = argparse.ArgumentParser()
    # Defaults sized for the 1-core CPU container (~2-4 s/step); raise them
    # freely on real hardware.
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint", default="reports/lm100m.npz")
    args = ap.parse_args()

    cfg = lm_100m()
    n_params = cfg.param_count()
    print(f"training {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")
    params, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=6e-4, checkpoint_path=args.checkpoint, log_every=25,
    )
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(uniform would be {jnp.log(cfg.vocab_size):.3f})")

    prompt = jnp.zeros((1, 8), jnp.int32)
    out = lm_mod.greedy_generate(cfg, params, prompt, max_new=16)
    print("greedy sample:", out[0].tolist())


if __name__ == "__main__":
    main()
