"""Streaming routed serving: the paper's router fronting the
assigned-architecture pool under simulated open-loop traffic.

    PYTHONPATH=src python examples/routed_serving.py

Builds three pool members (reduced configs on CPU: a dense, an MoE, and a
second dense family member), trains the attention router on synthetic
RouterBench traffic mapped onto them, then drives the continuous
micro-batching scheduler three ways:

  1. steady Poisson traffic at the nominal willingness-to-pay;
  2. the same traffic at a near-zero lambda (everything routes cheap);
  3. a bursty trace under a tight rolling budget — the governor shifts
     traffic toward cheaper members as the window overspends.
"""
from repro.configs import get_config
from repro.launch.serve import build_routed_engine
from repro.serving import (
    BudgetGovernor,
    MicroBatchScheduler,
    SchedulerConfig,
    TraceConfig,
    default_service_model,
    make_trace,
)

POOL = ["qwen3-0.6b", "granite-moe-1b-a400m", "granite-3-8b"]
SEED = 0


def run(engine, data, te, *, kind, lam, budget=None, n=48, score_batch=32):
    trace = make_trace(
        TraceConfig(kind=kind, n_requests=n, rate=400.0, seed=SEED,
                    max_new=2, prompt_len_max=24, vocab=64),
        texts=[data.texts[i] for i in te],
        benchmarks=[data.benchmark[i] for i in te],
    )
    engine.lam = lam
    governor = None
    if budget is not None:
        governor = BudgetGovernor(budget, window_s=0.2, lam0=lam)
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=score_batch, max_batch=8),
        governor=governor, service_time=default_service_model())
    summary = sched.run_trace(trace)
    label = f"{kind} lam={lam:g}" + (f" budget=${budget:g}" if budget else "")
    print(f"--- {label}")
    print("   routed:", summary["per_member_counts"],
          f" spend ${summary['total_spend']:.6f}")
    if governor is not None:
        print(f"   governor: final lambda {governor.lam:.3g} "
              f"(tightened x{governor.tightened})")
    return summary


def main():
    engine, data, te = build_routed_engine(POOL, seed=SEED, epochs=150)
    for m in engine.pool:
        full = get_config(m.name)
        print(f"member {m.name:24s} cost ${m.cost_rate:.6f}/request "
              f"({full.active_param_count()/1e9:.2f}B active params full-size)")

    run(engine, data, te, kind="poisson", lam=1.0)
    run(engine, data, te, kind="poisson", lam=1e-7)
    # Small score batches -> more dispatch rounds -> the governor gets
    # enough controller steps to visibly shift traffic mid-trace.
    run(engine, data, te, kind="bursty", lam=1.0, budget=5e-4, n=96,
        score_batch=12)


if __name__ == "__main__":
    main()
