"""Routed serving: the paper's router fronting the assigned-architecture
pool, end to end.

    PYTHONPATH=src python examples/routed_serving.py

Builds three pool members (reduced configs on CPU: a dense, an MoE, and an
SSM family member), maps synthetic RouterBench traffic onto them with
FLOPs-derived cost rates, trains the attention router, and serves a request
batch at three willingness-to-pay levels — showing traffic shift from the
cheap member to the expensive one as lambda grows.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import build_model_embeddings
from repro.core.router import PredictiveRouter
from repro.launch.serve import build_pool, synthetic_pool_traffic
from repro.serving import RoutedEngine
from repro.training import train_dual_predictors

POOL = ["qwen3-0.6b", "granite-moe-1b-a400m", "granite-3-8b"]


def main():
    from repro.configs import get_config
    pool = build_pool(POOL)
    for m in pool:
        full = get_config(m.name)
        print(f"member {m.name:24s} cost ${m.cost_rate:.6f}/request "
              f"({full.active_param_count()/1e9:.2f}B active params full-size)")

    data, quality, cost = synthetic_pool_traffic(pool, n=1200)
    tr, va, te = data.split()
    memb, _ = build_model_embeddings(data.emb[tr], quality[tr], seed=0)
    qp, cp, scaler, _ = train_dual_predictors(
        "attn", "attn", data.emb[tr], quality[tr], cost[tr], memb,
        q_emb_val=data.emb[va], quality_val=quality[va], cost_val=cost[va],
        epochs=150,
    )
    router = PredictiveRouter("attn", "attn", qp, cp, memb, reward="R2",
                              cost_scaler=scaler)

    texts = [data.texts[i] for i in te[:32]]
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, 256, size=(32, 12)), jnp.int32)

    for lam in (1e-7, 3e-6, 1.0):
        engine = RoutedEngine(router=router, pool=pool, lam=lam)
        res = engine.serve(texts, prompts, max_new=4)
        counts = dict(zip(POOL, res["per_member_counts"].tolist()))
        print(f"lambda={lam:g}: routed {counts}  "
              f"total ${res['total_cost']:.6f}  {res['latency_s']:.1f}s")


if __name__ == "__main__":
    main()
