"""Reward-function study (paper §6 + Appendix A): R1 vs R2 oracle routers.

    PYTHONPATH=src python examples/reward_analysis.py

Reproduces the paper's argument for the exponential reward: comparable AIQ,
drastically lower lambda-sensitivity, bounded reward values, and the query
distribution concentrated on cheap models.
"""
import numpy as np

from repro.core import (
    DEFAULT_LAMBDA_GRID, evaluate_sweep, oracle_sweep, reward_exponential,
    reward_linear,
)
from repro.data import generate


def main():
    data = generate(2000, seed=0)
    print(f"{'pool':<8}{'reward':<8}{'AIQ':>9}{'sens_perf':>11}"
          f"{'sens_cost':>12}{'maxGPT4':>9}")
    for pool_name in ("pool1", "pool2", "pool3", "pool4"):
        pool = data.pool(pool_name)
        _, _, te = pool.split()
        q, c = pool.quality[te], pool.cost[te]
        for reward in ("R1", "R2"):
            m = evaluate_sweep(oracle_sweep(q, c, DEFAULT_LAMBDA_GRID, reward),
                               q, c)
            print(f"{pool_name:<8}{reward:<8}{m['aiq']:>9.4f}"
                  f"{m['lam_sens_perf']:>11.4f}{m['lam_sens_cost']:>12.2e}"
                  f"{m['max_calls_expensive']:>9.3f}")

    print("\nboundedness (s=0.9): R1 vs R2 as cost grows at lambda=0.01")
    for cost in (0.0, 0.01, 0.1, 1.0):
        r1 = float(reward_linear(0.9, cost, 0.01))
        r2 = float(reward_exponential(0.9, cost, 0.01))
        print(f"  c={cost:<6} R1={r1:>10.3f}   R2={r2:>8.5f}")

    print("\nquery distribution at mid-lambda (pool1, R2 oracle):")
    pool = data.pool("pool1")
    _, _, te = pool.split()
    ch = oracle_sweep(pool.quality[te], pool.cost[te], DEFAULT_LAMBDA_GRID, "R2")
    mid = ch[len(DEFAULT_LAMBDA_GRID) // 2]
    for i, name in enumerate(pool.model_names):
        bar = "#" * int(40 * (mid == i).mean())
        print(f"  {name:<26}{(mid == i).mean():>6.1%} {bar}")


if __name__ == "__main__":
    main()
