"""Cascade routing benchmark (ours): multi-leg escalation vs. single-shot.

RouterBench's central observation is that *cascading* — run a cheap model,
escalate only when the response looks inadequate — reaches parts of the
cost-quality plane no single irrevocable choice can. This benchmark pits
the `repro.cascade` policy against the paper's single-shot router on the
seeded synthetic RouterBench pool, with **cumulative (all-leg) cost
accounting**: every leg a cascade runs is charged, exactly as the serving
plane's budget ledger charges it.

Setup: pool1 (5 API models, mistral-7b -> gpt-4), the deep-ensemble
cross-attention quality head (``attn-ens``: shared trunk, 4 bootstrap
heads) + the standard attention cost head. The cascade seeds leg 1 at the
*cheapest* ladder rung (the canonical cascade shape) and then asks
:class:`~repro.cascade.CascadePolicy` after every leg whether the expected
marginal reward of the next rung justifies another call, using observed
leg quality (RouterBench logs responses, so post-hoc quality is available)
plus the ensemble's predictive mean/std for untried rungs.

Acceptance gates (the PR's bar):
  * the cascade's nondecreasing-quality frontier weakly dominates the
    single-shot router's realized operating points at >= 3 of the 5
    lambda points;
  * the escalation rate is nonzero overall and monotone nondecreasing in
    lambda (more willingness-to-pay -> more escalation, never less).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, gate, load_data, pool_splits, trained_router
from repro.cascade import CascadeConfig, CascadePolicy, cost_ladder
from repro.core.metrics import frontier_dominance, pareto_frontier
from repro.core.rewards import REWARDS, cascade_outcome

POOL = "pool1"
REWARD = "R2"
# Willingness-to-pay points spanning cheap-only -> quality-dominated for
# pool1's $/query scale (mistral ~2e-4, gpt-4 ~4e-2).
LAM_POINTS = np.logspace(-2.5, 0.0, 5)
# Extra sweep lambdas anchoring the cascade frontier's cheap end (the
# never-escalate regime is part of the cascade policy family — lam -> 0
# degenerates to "cheapest rung only"). The dominance gate is still scored
# at the 5 LAM_POINTS; these only shape the hull.
ANCHOR_LAMS = (1e-4, 10.0)
MIN_DOMINATED = 3


def single_shot_points(router, pool, te):
    """Realized (mean cost, mean quality) of the one-shot router per lam."""
    choices = router.sweep(pool.emb[te], LAM_POINTS)
    b = np.arange(len(te))
    costs = [float(pool.cost[te][b, ch].mean()) for ch in choices]
    perfs = [float(pool.quality[te][b, ch].mean()) for ch in choices]
    return np.asarray(costs), np.asarray(perfs)


def run_cascade(router, pool, te, lam, config: CascadeConfig):
    """Simulate the cascade over the held-out queries at one lambda.

    Leg quality is *observed* (truth table lookup — the RouterBench
    setting); rung predictions come from the ensemble router. Returns
    (mean cum cost, mean final quality, escalation rate, mean legs).
    """
    ladder = cost_ladder(router)
    policy = CascadePolicy(ladder, config, reward=REWARD)
    s_hat, s_std, c_hat = router.predict_with_uncertainty(pool.emb[te])
    quality = pool.quality[te]
    cost = pool.cost[te]
    cum_costs, finals, n_legs = [], [], []
    for i in range(len(te)):
        member = int(ladder[0])                  # canonical cascade: cheap first
        leg_q, leg_c, tried = [], [], []
        best_q = -np.inf
        while True:
            leg_q.append(float(quality[i, member]))
            leg_c.append(float(cost[i, member]))
            tried.append(member)
            best_q = max(best_q, leg_q[-1])
            decision = policy.decide(
                s_cur=best_q, s_std_cur=0.0,
                s_hat=s_hat[i], s_std=s_std[i], c_hat=c_hat[i],
                cum_cost=float(np.sum(leg_c)), tried=tried, lam=lam,
                observed=True,
            )
            if not decision.escalate:
                break
            member = decision.next_member
        q, c = cascade_outcome(leg_q, leg_c, keep_best=True)
        finals.append(q)
        cum_costs.append(c)
        n_legs.append(len(tried))
    n_legs = np.asarray(n_legs)
    return (float(np.mean(cum_costs)), float(np.mean(finals)),
            float(np.mean(n_legs > 1)), float(n_legs.mean()))


def main() -> None:
    data = load_data()
    pool, tr, va, te = pool_splits(data, POOL)
    router = trained_router(pool, tr, va, POOL, "attn-ens", "attn",
                            reward=REWARD)

    ss_costs, ss_perfs = single_shot_points(router, pool, te)
    config = CascadeConfig(max_legs=3, beta=1.0, margin=0.0)
    casc_costs, casc_perfs, esc_rates, legs = [], [], [], []
    for lam in LAM_POINTS:
        c, q, esc, mean_legs = run_cascade(router, pool, te, float(lam),
                                           config)
        casc_costs.append(c)
        casc_perfs.append(q)
        esc_rates.append(esc)
        legs.append(mean_legs)
        emit(f"cascade/lam_{lam:.4g}", 0.0,
             f"cost=${c:.6f};quality={q:.4f};esc_rate={esc:.3f}"
             f";mean_legs={mean_legs:.2f}")
    for lam, c, q in zip(LAM_POINTS, ss_costs, ss_perfs):
        emit(f"single_shot/lam_{lam:.4g}", 0.0,
             f"cost=${c:.6f};quality={q:.4f}")

    front_costs, front_perfs = list(casc_costs), list(casc_perfs)
    for lam in ANCHOR_LAMS:
        c, q, _, _ = run_cascade(router, pool, te, float(lam), config)
        front_costs.append(c)
        front_perfs.append(q)
    casc_costs = np.asarray(casc_costs)
    casc_perfs = np.asarray(casc_perfs)
    dominated = frontier_dominance(np.asarray(front_costs),
                                   np.asarray(front_perfs),
                                   ss_costs, ss_perfs, tol=1e-6)
    hx, hy = pareto_frontier(np.asarray(front_costs),
                             np.asarray(front_perfs))
    emit("cascade/frontier", 0.0,
         "points=" + "|".join(f"({x:.6f},{y:.4f})" for x, y in zip(hx, hy)))
    emit("cascade/dominated_points", 0.0,
         f"{int(dominated.sum())}/{len(dominated)}")

    # Realized mean cascade reward with cumulative-cost accounting, for
    # the record (the gate is on the frontier, not on raw reward).
    for lam, c, q in zip(LAM_POINTS, casc_costs, casc_perfs):
        r = float(REWARDS[REWARD](q, c, float(lam)))
        emit(f"cascade/reward_lam_{lam:.4g}", 0.0, f"reward={r:.4f}")

    rates = np.asarray(esc_rates)
    monotone = bool(np.all(np.diff(rates) >= -1e-9))
    emit("cascade/escalation_rates", 0.0,
         "|".join(f"{r:.3f}" for r in rates)
         + f";monotone={monotone};nonzero={bool(rates.max() > 0)}")

    if not gate("cascade/frontier_dominance",
                int(dominated.sum()) >= MIN_DOMINATED,
                f"dominates {int(dominated.sum())}/{len(dominated)} "
                f"lambda points (need >= {MIN_DOMINATED})"):
        raise SystemExit(
            f"cascade frontier dominates only {int(dominated.sum())}/"
            f"{len(dominated)} single-shot lambda points "
            f"(need >= {MIN_DOMINATED})")
    if not gate("cascade/escalation_nonzero", rates.max() > 0,
                f"max rate {rates.max():.3f}"):
        raise SystemExit("cascade never escalated at any lambda point")
    if not gate("cascade/escalation_monotone", monotone,
                "|".join(f"{r:.3f}" for r in rates)):
        raise SystemExit(
            "escalation rate is not monotone in lambda: "
            + "|".join(f"{r:.3f}" for r in rates))


if __name__ == "__main__":
    main()
