"""Paper Figures 4-5 (and 8-9): dataset-wise and domain-wise results.

AIQ of the predictor-based routers per benchmark dataset (Fig 4) and per
MMLU domain (Fig 5) on pool 1, for both rewards (Figs 8-9 = R1 variants).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    LAMS, emit, load_data, pool_splits, trained_router,
)
from repro.core import evaluate_sweep, rewards
from repro.data.routerbench import BENCHMARKS, MMLU_DOMAINS

ROUTERS = ["reg", "2fcn", "attn"]


def main() -> None:
    data = load_data()
    pool, tr, va, te = pool_splits(data, "pool1")

    routers = {}
    for kind in ROUTERS:
        routers[kind] = trained_router(pool, tr, va, "pool1", kind, kind)

    for reward in ("R2", "R1"):
        fig = "fig4_5" if reward == "R2" else "fig8_9"
        for kind, router in routers.items():
            s_hat, c_hat = router.predict(pool.emb[te])
            choices = np.stack([
                np.asarray(rewards.route(reward, s_hat, c_hat, lam))
                for lam in LAMS
            ])
            # Dataset-wise (Fig 4 / 8).
            for bench in BENCHMARKS:
                mask = pool.benchmark[te] == bench
                if mask.sum() < 20:
                    continue
                m = evaluate_sweep(choices[:, mask], pool.quality[te][mask],
                                   pool.cost[te][mask], LAMS)
                emit(f"{fig}/{reward}/dataset={bench}/{kind}/aiq", 0.0,
                     round(m["aiq"], 5))
            # Domain-wise over MMLU sub-domains (Fig 5 / 9).
            for dom in MMLU_DOMAINS:
                mask = pool.domain[te] == dom
                if mask.sum() < 10:
                    continue
                m = evaluate_sweep(choices[:, mask], pool.quality[te][mask],
                                   pool.cost[te][mask], LAMS)
                emit(f"{fig}/{reward}/domain={dom}/{kind}/aiq", 0.0,
                     round(m["aiq"], 5))


if __name__ == "__main__":
    main()
