"""Paper Tables 3-6 (Appendix C): quality-predictor x cost-predictor grid.

AIQ and Perf_max for every (quality, cost) predictor pair on pool 1, for
both reward functions. "oracle" rows/columns use the true values for that
role (the paper's Oracle R1/R2 row/col).
"""
from __future__ import annotations

import itertools
import os

import numpy as np

from benchmarks.common import (
    EPOCHS, LAMS, emit, load_data, model_embeddings, pool_splits,
    trained_router,
)
from repro.core import evaluate_sweep, rewards

KINDS = ["reg", "2fcn", "3fcn", "reg-emb", "2fcn-emb", "3fcn-emb", "attn"]
GRID_KINDS = os.environ.get("REPRO_ABLATION_KINDS", ",".join(KINDS)).split(",")


def main() -> None:
    data = load_data()
    pool, tr, va, te = pool_splits(data, "pool1")
    q_true, c_true = pool.quality[te], pool.cost[te]

    # Train each predictor once per role (routers share cached params).
    preds_q, preds_c = {}, {}
    for kind in GRID_KINDS:
        router = trained_router(pool, tr, va, "pool1", kind, kind)
        s_hat, c_hat = router.predict(pool.emb[te])
        preds_q[kind] = s_hat
        preds_c[kind] = c_hat
    preds_q["oracle"] = q_true
    preds_c["oracle"] = c_true

    for reward in ("R1", "R2"):
        for qk, ck in itertools.product(
            ["oracle"] + GRID_KINDS, ["oracle"] + GRID_KINDS
        ):
            choices = np.stack([
                np.asarray(rewards.route(reward, preds_q[qk], preds_c[ck], lam))
                for lam in LAMS
            ])
            m = evaluate_sweep(choices, q_true, c_true, LAMS)
            tag = f"table{'3_4' if reward == 'R1' else '5_6'}/{reward}/q={qk}/c={ck}"
            emit(f"{tag}/aiq", 0.0, round(m["aiq"], 5))
            emit(f"{tag}/perf_max", 0.0, round(m["perf_max"], 5))


if __name__ == "__main__":
    main()
