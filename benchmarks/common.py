"""Shared benchmark harness.

Data generation + predictor training are cached under reports/cache so the
individual tables can be re-run cheaply. Every benchmark prints
``name,us_per_call,derived`` CSV rows (us_per_call = router scoring latency
per query; derived = the table's metric).

Machine-readable summaries: ``benchmarks.run`` installs a
:class:`BenchReport` per suite; :func:`emit` mirrors every CSV row into it
and :func:`headline` / :func:`gate` record the suite's headline metric and
pass/fail acceptance gates. The runner writes the result as
``reports/bench/BENCH_<suite>.json``.
"""
from __future__ import annotations

import json
import os
import pickle
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    DEFAULT_LAMBDA_GRID, build_model_embeddings, evaluate_sweep, oracle_sweep,
)
from repro.core.router import PredictiveRouter
from repro.data import generate
from repro.training import train_dual_predictors

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "reports/cache")
N_QUERIES = int(os.environ.get("REPRO_BENCH_QUERIES", "4000"))
# The paper trains 1000 epochs; the synthetic benchmark converges by ~300.
EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "300"))
LAMS = DEFAULT_LAMBDA_GRID


def _cache(name: str):
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, name + ".pkl")


def load_data():
    path = _cache(f"routerbench_{N_QUERIES}")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    data = generate(N_QUERIES, seed=0)
    with open(path, "wb") as f:
        pickle.dump(data, f)
    return data


def pool_splits(data, pool_name: str):
    pool = data.pool(pool_name)
    tr, va, te = pool.split(seed=0)
    return pool, tr, va, te


def model_embeddings(pool, tr, pool_name: str):
    path = _cache(f"memb_{pool_name}_{N_QUERIES}")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    memb, cents = build_model_embeddings(pool.emb[tr], pool.quality[tr], seed=0)
    with open(path, "wb") as f:
        pickle.dump((memb, cents), f)
    return memb, cents


def trained_router(
    pool, tr, va, pool_name: str, quality_kind: str, cost_kind: str,
    reward: str = "R2", epochs: Optional[int] = None,
) -> PredictiveRouter:
    epochs = epochs or EPOCHS
    memb, _ = model_embeddings(pool, tr, pool_name)
    path = _cache(f"router_{pool_name}_{quality_kind}_{cost_kind}_{epochs}_{N_QUERIES}")
    if os.path.exists(path):
        with open(path, "rb") as f:
            qp, cp, scaler = pickle.load(f)
    else:
        qp, cp, scaler, _ = train_dual_predictors(
            quality_kind, cost_kind, pool.emb[tr], pool.quality[tr],
            pool.cost[tr], memb,
            q_emb_val=pool.emb[va], quality_val=pool.quality[va],
            cost_val=pool.cost[va], epochs=epochs, seed=0,
        )
        with open(path, "wb") as f:
            pickle.dump((qp, cp, scaler), f)
    return PredictiveRouter(quality_kind, cost_kind, qp, cp, memb,
                            reward=reward, cost_scaler=scaler)


def eval_router_sweep(router, pool, te) -> Tuple[Dict, float]:
    """Returns (metrics, us_per_query for one scoring pass)."""
    t0 = time.perf_counter()
    s_hat, c_hat = router.predict(pool.emb[te])
    dt = time.perf_counter() - t0
    choices = router.sweep(pool.emb[te], LAMS)
    metrics = evaluate_sweep(choices, pool.quality[te], pool.cost[te], LAMS)
    return metrics, dt / len(te) * 1e6


def eval_oracle(pool, te, reward: str) -> Dict:
    ch = oracle_sweep(pool.quality[te], pool.cost[te], LAMS, reward)
    return evaluate_sweep(ch, pool.quality[te], pool.cost[te], LAMS)


class BenchReport:
    """Machine-readable summary of one benchmark suite run.

    Collects the suite's emitted CSV rows, an optional explicit headline
    metric (falls back to the first emitted row), and named pass/fail
    gates. Serialized as ``BENCH_<suite>.json`` by ``benchmarks.run``.
    """

    def __init__(self, suite: str):
        self.suite = suite
        self.rows: List[Dict] = []
        self._headline: Optional[Dict] = None
        self.gates: List[Dict] = []
        self.wall_s: float = 0.0
        self.error: Optional[str] = None

    def set_headline(self, metric: str, value: float, unit: str = "",
                     direction: Optional[str] = None) -> None:
        """``direction`` declares which way is better ("higher"/"lower");
        ``tools/bench_diff.py`` only treats a headline move as a
        regression when a direction is declared."""
        if direction not in (None, "higher", "lower"):
            raise ValueError(f"direction must be higher/lower, "
                             f"got {direction!r}")
        self._headline = {"metric": metric, "value": float(value),
                          "unit": unit, "direction": direction}

    def add_gate(self, name: str, passed: bool, detail: str = "") -> None:
        self.gates.append({"name": name, "passed": bool(passed),
                           "detail": detail})

    @property
    def headline(self) -> Optional[Dict]:
        if self._headline is not None:
            return self._headline
        if self.rows:
            r = self.rows[0]
            return {"metric": r["name"], "value": r["us_per_call"],
                    "unit": "us_per_call", "direction": None}
        return None

    def to_dict(self) -> Dict:
        return {
            "suite": self.suite,
            "headline": self.headline,
            "gates": self.gates,
            "gates_passed": all(g["passed"] for g in self.gates),
            "wall_s": round(self.wall_s, 3),
            "rows": self.rows,
            "error": self.error,
        }

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")


# Suite report installed by benchmarks.run around each suite's main().
_ACTIVE_REPORT: Optional[BenchReport] = None


def set_active_report(report: Optional[BenchReport]) -> None:
    global _ACTIVE_REPORT
    _ACTIVE_REPORT = report


def active_report() -> Optional[BenchReport]:
    return _ACTIVE_REPORT


def headline(metric: str, value: float, unit: str = "",
             direction: Optional[str] = None) -> None:
    """Declare the suite's headline metric (latest call wins).
    ``direction`` ("higher"/"lower" = better) arms the bench-trajectory
    regression check in ``tools/bench_diff.py``."""
    if _ACTIVE_REPORT is not None:
        _ACTIVE_REPORT.set_headline(metric, value, unit, direction)


def gate(name: str, passed: bool, detail: str = "") -> bool:
    """Record a named pass/fail acceptance gate; returns ``passed``."""
    if _ACTIVE_REPORT is not None:
        _ACTIVE_REPORT.add_gate(name, passed, detail)
    status = "PASS" if passed else "FAIL"
    print(f"# gate {name}: {status}  {detail}")
    return passed


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
    if _ACTIVE_REPORT is not None:
        _ACTIVE_REPORT.rows.append({
            "name": name, "us_per_call": round(float(us_per_call), 2),
            "derived": str(derived)})
