"""Shared benchmark harness.

Data generation + predictor training are cached under reports/cache so the
individual tables can be re-run cheaply. Every benchmark prints
``name,us_per_call,derived`` CSV rows (us_per_call = router scoring latency
per query; derived = the table's metric).
"""
from __future__ import annotations

import os
import pickle
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import (
    DEFAULT_LAMBDA_GRID, build_model_embeddings, evaluate_sweep, oracle_sweep,
)
from repro.core.router import PredictiveRouter
from repro.data import generate
from repro.training import train_dual_predictors

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "reports/cache")
N_QUERIES = int(os.environ.get("REPRO_BENCH_QUERIES", "4000"))
# The paper trains 1000 epochs; the synthetic benchmark converges by ~300.
EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "300"))
LAMS = DEFAULT_LAMBDA_GRID


def _cache(name: str):
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, name + ".pkl")


def load_data():
    path = _cache(f"routerbench_{N_QUERIES}")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    data = generate(N_QUERIES, seed=0)
    with open(path, "wb") as f:
        pickle.dump(data, f)
    return data


def pool_splits(data, pool_name: str):
    pool = data.pool(pool_name)
    tr, va, te = pool.split(seed=0)
    return pool, tr, va, te


def model_embeddings(pool, tr, pool_name: str):
    path = _cache(f"memb_{pool_name}_{N_QUERIES}")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    memb, cents = build_model_embeddings(pool.emb[tr], pool.quality[tr], seed=0)
    with open(path, "wb") as f:
        pickle.dump((memb, cents), f)
    return memb, cents


def trained_router(
    pool, tr, va, pool_name: str, quality_kind: str, cost_kind: str,
    reward: str = "R2", epochs: Optional[int] = None,
) -> PredictiveRouter:
    epochs = epochs or EPOCHS
    memb, _ = model_embeddings(pool, tr, pool_name)
    path = _cache(f"router_{pool_name}_{quality_kind}_{cost_kind}_{epochs}_{N_QUERIES}")
    if os.path.exists(path):
        with open(path, "rb") as f:
            qp, cp, scaler = pickle.load(f)
    else:
        qp, cp, scaler, _ = train_dual_predictors(
            quality_kind, cost_kind, pool.emb[tr], pool.quality[tr],
            pool.cost[tr], memb,
            q_emb_val=pool.emb[va], quality_val=pool.quality[va],
            cost_val=pool.cost[va], epochs=epochs, seed=0,
        )
        with open(path, "wb") as f:
            pickle.dump((qp, cp, scaler), f)
    return PredictiveRouter(quality_kind, cost_kind, qp, cp, memb,
                            reward=reward, cost_scaler=scaler)


def eval_router_sweep(router, pool, te) -> Tuple[Dict, float]:
    """Returns (metrics, us_per_query for one scoring pass)."""
    t0 = time.perf_counter()
    s_hat, c_hat = router.predict(pool.emb[te])
    dt = time.perf_counter() - t0
    choices = router.sweep(pool.emb[te], LAMS)
    metrics = evaluate_sweep(choices, pool.quality[te], pool.cost[te], LAMS)
    return metrics, dt / len(te) * 1e6


def eval_oracle(pool, te, reward: str) -> Dict:
    ch = oracle_sweep(pool.quality[te], pool.cost[te], LAMS, reward)
    return evaluate_sweep(ch, pool.quality[te], pool.cost[te], LAMS)


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
