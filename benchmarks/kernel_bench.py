"""Kernel microbenchmarks (ours, not a paper table): router_xattn and
pairwise_l2 wall-time per call vs the jnp reference path.

On this CPU container the Pallas kernels run in interpret mode (slower —
they exist to be lowered on real TPUs); the jnp reference numbers are the
meaningful CPU timings. Derived column = max |kernel - ref| (correctness).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def main() -> None:
    key = jax.random.key(0)
    ks = jax.random.split(key, 8)
    for b in (256, 1024, 4096):
        dq, k, dm, d = 768, 11, 20, 20
        q = jax.random.normal(ks[0], (b, dq))
        m_emb = jax.random.normal(ks[1], (k, dm))
        wq = jax.random.normal(ks[2], (dq, d)) * 0.05
        wk = jax.random.normal(ks[3], (dm, d)) * 0.3
        wv = jax.random.normal(ks[4], (dm, d)) * 0.3
        wo = jax.random.normal(ks[5], (d, k)) * 0.3
        bo = jnp.zeros((k,))

        ref_fn = jax.jit(ref.router_xattn_ref)
        us_ref, out_ref = _time(ref_fn, q, wq, wk, wv, wo, bo, m_emb)
        us_pal, out_pal = _time(
            lambda *a: ops.router_xattn(*a), q, wq, wk, wv, wo, bo, m_emb,
            iters=2)
        err = float(jnp.abs(out_pal - out_ref).max())
        emit(f"kernel/router_xattn/b={b}/jnp_ref", us_ref, f"err={err:.2e}")
        emit(f"kernel/router_xattn/b={b}/pallas_interpret", us_pal,
             f"err={err:.2e}")

    for n, c in ((1024, 20), (4096, 256)):
        x = jax.random.normal(ks[6], (n, 768))
        cc = jax.random.normal(ks[7], (c, 768))
        ref_fn = jax.jit(ref.pairwise_l2_ref)
        us_ref, out_ref = _time(ref_fn, x, cc)
        us_pal, out_pal = _time(lambda *a: ops.pairwise_l2(*a), x, cc, iters=2)
        err = float(jnp.abs(out_pal - out_ref).max())
        emit(f"kernel/pairwise_l2/n={n}x{c}/jnp_ref", us_ref, f"err={err:.2e}")
        emit(f"kernel/pairwise_l2/n={n}x{c}/pallas_interpret", us_pal,
             f"err={err:.2e}")


if __name__ == "__main__":
    main()
