"""Paper Table 1: R1 vs R2 oracle routers on pools 1-4.

Columns: AIQ (up), lambda-sensitivity_perf (down), lambda-sensitivity_cost
(down), max calls to the strongest model (down).
"""
from __future__ import annotations

from benchmarks.common import emit, eval_oracle, load_data, pool_splits


def main() -> None:
    data = load_data()
    for pool_name in ("pool1", "pool2", "pool3", "pool4"):
        pool, tr, va, te = pool_splits(data, pool_name)
        for reward in ("R1", "R2"):
            m = eval_oracle(pool, te, reward)
            tag = f"table1/{pool_name}/{reward}"
            emit(f"{tag}/aiq", 0.0, round(m["aiq"], 5))
            emit(f"{tag}/lam_sens_perf", 0.0, round(m["lam_sens_perf"], 5))
            emit(f"{tag}/lam_sens_cost", 0.0, f"{m['lam_sens_cost']:.3e}")
            emit(f"{tag}/max_calls_expensive", 0.0,
                 round(m["max_calls_expensive"], 5))


if __name__ == "__main__":
    main()
