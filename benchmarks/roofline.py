"""Roofline analysis (assignment deliverable g).

For every dry-run baseline (reports/dryrun/*.json) derive the three terms:

    compute    = HLO_FLOPs / peak_FLOPs              [per device]
    memory     = HLO_bytes / HBM_bw                  [per device]
    collective = collective_bytes / ICI link bw      [per device]

HLO numbers come from probe extrapolation when probe files exist:
``total = probe1 + (n_repeats - 1) * (probe2 - probe1)`` with ALL loops
unrolled in the probes (see models/runtime_flags.py), which removes XLA
cost-analysis' scan-body undercount exactly. The sLSTM time scan (never
unrolled) gets an analytic correction. Falls back to the raw (undercounted)
full-model numbers when probes are missing, flagged in the output.

Also reports MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (inference) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from benchmarks.common import emit
from repro.configs import get_config
from repro.configs.base import SLSTM
from repro.configs.shapes import SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "reports/dryrun")


def _load(tag: str) -> Optional[Dict]:
    path = os.path.join(DRYRUN_DIR, tag + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    return r if r.get("status") == "ok" else None


def _extrapolate(base: Dict, p1: Dict, p2: Dict, n_repeats: int) -> Dict:
    """Per-repeat delta from the two probes -> full-depth totals."""
    out = dict(base)
    for key in ("flops", "bytes_accessed", "collective_bytes_per_device"):
        delta = p2[key] - p1[key]
        out[key] = p1[key] + (n_repeats - 1) * delta
    out["probe_corrected"] = True
    return out


def slstm_flops_correction(cfg, shape, chips: int) -> float:
    """Analytic per-device FLOPs of the sLSTM time scan (never unrolled)."""
    n_slstm = sum(1 for s in cfg.layer_plan() if s.mixer == SLSTM)
    if n_slstm == 0:
        return 0.0
    d = cfg.d_model
    nh = cfg.xlstm_n_heads
    dh = d // nh
    # Batch shards over the 16-way 'data' axis on the single-pod mesh.
    if shape.kind == "train":
        tokens_per_dev = shape.global_batch / 16 * shape.seq_len
        mult = 3.0   # fwd + bwd
    elif shape.kind == "prefill":
        tokens_per_dev = max(shape.global_batch / 16, 1) * shape.seq_len
        mult = 1.0
    else:
        tokens_per_dev = max(shape.global_batch / 16, 1)
        mult = 1.0
    # Per token: 4 gates x block-diag R (H*dh*dh MACs) + ~24d elementwise.
    per_token = 4 * nh * dh * dh * 2 + 24 * d
    return mult * n_slstm * tokens_per_dev * per_token


def _head_overhead_flops(cfg, shape, chips: int) -> float:
    """Per-device FLOPs of embedding + LM head (+ loss), outside the layer
    scan. Train: fwd+bwd (3x) on the head matmul; inference: fwd only."""
    v, d = cfg.padded_vocab, cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch / 16 * shape.seq_len   # per-device
        return 3 * 2.0 * tokens * d * v / 16               # head sharded 16-way
    if shape.kind == "prefill":
        tokens = max(shape.global_batch / 16, 1) * 1       # last-token logits
        return 2.0 * tokens * d * v / 16
    tokens = max(shape.global_batch / 16, 1)
    return 2.0 * tokens * d * v / 16


def model_flops(cfg, shape, chips: int) -> float:
    """Reference useful FLOPs per device (6ND train / 2ND inference)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:
        total = 2.0 * n * shape.global_batch
    return total / chips


_FWD_FRACTION_CACHE: Dict[str, float] = {}


def _forward_fraction(mesh_tag: str) -> float:
    """prefill/train FLOP ratio measured on fully-probed dense archs."""
    if mesh_tag in _FWD_FRACTION_CACHE:
        return _FWD_FRACTION_CACHE[mesh_tag]
    ratios = []
    for arch in ("qwen3-0.6b", "granite-3-8b", "qwen1.5-4b"):
        tr = analyze(arch, "train_4k", mesh_tag)
        pf = analyze(arch, "prefill_32k", mesh_tag)
        if tr and pf and tr["probe_corrected"] is True and pf["probe_corrected"] is True:
            ratios.append(pf["hlo_flops_per_dev"] / tr["hlo_flops_per_dev"])
    frac = sum(ratios) / len(ratios) if ratios else 0.25
    _FWD_FRACTION_CACHE[mesh_tag] = frac
    return frac


def analyze(arch: str, shape_name: str, mesh_tag: str = "16x16") -> Optional[Dict]:
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    base = _load(tag)
    if base is None:
        return None
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = base["chips"]

    p1 = _load(tag + "__probe1")
    p2 = _load(tag + "__probe2")
    rec = dict(base)
    rec["probe_corrected"] = False
    if p1 and p2 and cfg.n_repeats >= 2:
        rec = _extrapolate(base, p1, p2, cfg.n_repeats)
    elif p1 and cfg.n_repeats >= 2:
        # probe1-only fallback: the non-repeated overhead (embedding + LM
        # head + loss) is one analytically-known matmul; per-repeat cost =
        # probe1 - overhead. Exact for FLOPs, approximate for bytes/coll
        # (same linear split applied).
        head = _head_overhead_flops(cfg, shape, chips)
        body = max(p1["flops"] - head, 0.0)
        rec["flops"] = head + cfg.n_repeats * body
        scale = rec["flops"] / max(p1["flops"], 1.0)
        for key in ("bytes_accessed", "collective_bytes_per_device"):
            rec[key] = p1[key] * scale
        rec["probe_corrected"] = "probe1+analytic-head"
    elif shape_name == "prefill_32k":
        # SSM-heavy prefill probes are prohibitive to unroll (128+ chunk
        # bodies); derive from the probe-corrected TRAIN numbers instead.
        # train_4k and prefill_32k run the same 1,048,576 global tokens, so
        # prefill ~= train * (forward fraction), with the fraction measured
        # on archs that have both probes (qwen3/granite-3: ~0.25 with remat).
        tr = analyze(arch, "train_4k", mesh_tag)
        if tr is not None and tr["probe_corrected"]:
            frac = _forward_fraction(mesh_tag)
            rec["flops"] = tr["hlo_flops_per_dev"] * frac
            rec["probe_corrected"] = "derived-from-train"
    rec["flops"] += slstm_flops_correction(cfg, shape, chips)

    compute_s = rec["flops"] / PEAK_FLOPS_BF16
    memory_s = rec["bytes_accessed"] / HBM_BW
    collective_s = rec["collective_bytes_per_device"] / ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, chips)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": rec["flops"],
        "useful_ratio": mf / rec["flops"] if rec["flops"] else float("nan"),
        "peak_gib": base["peak_bytes_per_device"] / 2**30,
        "probe_corrected": rec["probe_corrected"],
    }


def main() -> None:
    rows = []
    seen = set()
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__16x16.json"))):
        tag = os.path.basename(path)[: -len(".json")]
        arch, shape_name, _ = tag.split("__")
        if (arch, shape_name) in seen:
            continue
        seen.add((arch, shape_name))
        r = analyze(arch, shape_name)
        if r is None:
            continue
        rows.append(r)
        base = f"roofline/{arch}/{shape_name}"
        emit(f"{base}/compute_s", 0.0, f"{r['compute_s']:.4e}")
        emit(f"{base}/memory_s", 0.0, f"{r['memory_s']:.4e}")
        emit(f"{base}/collective_s", 0.0, f"{r['collective_s']:.4e}")
        emit(f"{base}/dominant", 0.0, r["dominant"])
        emit(f"{base}/useful_ratio", 0.0, f"{r['useful_ratio']:.3f}")
        emit(f"{base}/peak_gib", 0.0, f"{r['peak_gib']:.2f}")
        emit(f"{base}/probe_corrected", 0.0, r["probe_corrected"])

    if rows:
        os.makedirs("reports", exist_ok=True)
        with open("reports/roofline.json", "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
