"""Multi-worker serving-plane benchmark (ours): N-worker reward parity
with the single-worker online adapter under drift, plus the decode-path
MoE no-drop audit.

Same scenario as benchmarks/online_bench.py — the trace's content drifts
across benchmark mixtures while the pool's relative strengths reverse on
the drifted domain — but the adapted run is replayed twice:

  * **solo**  — one scheduler + one OnlineAdapter (the PR-2 loop);
  * **plane** — 4 workers with follower adapters, the coordinator running
    the replay-merge -> leader-update -> broadcast cycle
    (repro.distributed).

Acceptance gates (ISSUE 4):
  * plane back-half mean realized reward within 0.02 of solo;
  * every worker converges to the same router version;
  * zero decode-path MoE token drops across the whole run (the pool
    includes the MoE member; ``moe.DECODE_DROP_LOG`` records per-call
    dropped-token counts from inside the dispatch).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, gate
from repro.core.rewards import reward_exponential
from repro.distributed import (
    Coordinator,
    ServingPlane,
    SyncConfig,
    WorkerNode,
)
from repro.launch.serve import build_routed_engine, pool_quality_columns
from repro.models import moe as moe_mod
from repro.online import (
    DriftDetector,
    ExplorationConfig,
    OnlineAdapter,
    OnlineUpdateConfig,
)
from repro.serving import (
    MicroBatchScheduler,
    RoutedEngine,
    SchedulerConfig,
    TraceConfig,
    default_service_model,
    make_trace,
)
from repro.serving.scheduler import SimClock

POOL = ["qwen3-0.6b", "granite-moe-1b-a400m", "granite-3-8b"]
N_REQUESTS = 192
N_WORKERS = 4
LAM = 2e-3              # on the pool's $/request scale (see online_bench)
SEED = 0
PARITY = 0.02           # allowed back-half reward deficit vs. solo


def _serving_truth(engine, data):
    """Per-text realized quality under the POST-change regime (group-B
    benchmarks get their pool quality columns reversed — the offline
    snapshot's world no longer holds there)."""
    quality = data.quality[:, pool_quality_columns(engine.pool, data)]
    names = sorted(set(data.benchmark.tolist()))
    group_b = np.isin(data.benchmark, names[len(names) // 2:])
    truth = quality.copy()
    truth[group_b] = truth[group_b][:, ::-1]
    return {data.texts[i]: truth[i] for i in range(len(data.texts))}


def _make_trace(engine, data, te):
    return make_trace(
        TraceConfig(kind="drift", n_requests=N_REQUESTS, rate=800.0,
                    seed=SEED, max_new=2, prompt_len_max=24,
                    vocab=min(m.cfg.vocab_size for m in engine.pool)),
        texts=[data.texts[i] for i in te],
        benchmarks=[data.benchmark[i] for i in te],
    )


def _score(trace, engine, truth):
    order = sorted(trace, key=lambda r: r.arrival_s)
    cost_rates = np.asarray([m.cost_rate for m in engine.pool])
    rewards = []
    for r in order:
        per_member = np.asarray(reward_exponential(
            np.asarray(truth[r.text]), cost_rates, LAM))
        rewards.append(float(per_member[r.member]))
    half = len(order) // 2
    return {
        "mean_reward_back": float(np.mean(rewards[half:])),
        "mean_reward_full": float(np.mean(rewards)),
    }


def _run_solo(engine, data, te, truth):
    tr, _, _ = data.split(seed=SEED)
    adapter = OnlineAdapter(
        engine,
        lambda req: float(truth[req.text][req.member]),
        config=OnlineUpdateConfig(update_every=16, steps_per_update=16,
                                  burst_steps=48, batch_size=64),
        exploration=ExplorationConfig(epsilon=0.1, seed=SEED),
        drift=DriftDetector(window=48, threshold=3.0).fit(
            data.emb[tr], engine.router.centroids),
        seed=SEED,
    )
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=32, max_batch=8),
        service_time=default_service_model(), adapter=adapter)
    trace = _make_trace(engine, data, te)
    sched.run_trace(trace)
    return {**_score(trace, engine, truth), "adapter": adapter}


def _run_plane(base_engine, data, te, truth):
    tr, _, _ = data.split(seed=SEED)
    workers = []
    for wid in range(N_WORKERS):
        weng = RoutedEngine(router=base_engine.router, pool=base_engine.pool,
                            lam=LAM)
        wseed = SEED + 101 * wid + 1
        adapter = OnlineAdapter(
            weng,
            lambda req: float(truth[req.text][req.member]),
            config=OnlineUpdateConfig(batch_size=64),
            exploration=ExplorationConfig(epsilon=0.1, seed=wseed),
            drift=DriftDetector(window=16, threshold=3.0).fit(
                data.emb[tr], base_engine.router.centroids),
            defer_updates=True, seed=wseed,
        )
        sched = MicroBatchScheduler(
            weng, SchedulerConfig(score_batch=32, max_batch=8),
            clock=SimClock(), service_time=default_service_model(),
            adapter=adapter)
        workers.append(WorkerNode(wid, weng, sched, adapter))
    # Budgeted so leader training work tracks the solo adapter's: solo runs
    # ~12 updates x 16 steps over the trace; the plane reaches min_buffer a
    # couple of sync boundaries later (distinct-outcome guard), so each of
    # its ~9 rounds runs proportionally more steps on the merged buffer.
    coord = Coordinator(workers, SyncConfig(
        sync_every_s=0.02, merge_per_worker=48, steps_per_sync=32,
        burst_steps=48, seed=SEED,
        update=OnlineUpdateConfig(batch_size=64)))
    plane = ServingPlane(workers, coord)
    trace = _make_trace(base_engine, data, te)
    plane.run_trace(trace)
    versions = sorted({w.router_version for w in workers})
    return {**_score(trace, base_engine, truth),
            "versions": versions, "plane": plane, "coord": coord}


def main() -> None:
    # Count every decode-path MoE drop across BOTH runs — the no-drop
    # guarantee must hold under real micro-batched serving traffic.
    moe_mod.DECODE_DROP_LOG = []
    try:
        solo_eng, data, te = build_routed_engine(
            POOL, seed=SEED, epochs=60, n_traffic=900, lam=LAM)
        plane_eng = RoutedEngine(router=solo_eng.router, pool=solo_eng.pool,
                                 lam=LAM)
        truth = _serving_truth(solo_eng, data)

        solo = _run_solo(solo_eng, data, te, truth)
        plane = _run_plane(plane_eng, data, te, truth)
        drops = int(sum(moe_mod.DECODE_DROP_LOG))
        decode_calls = len(moe_mod.DECODE_DROP_LOG)
    finally:
        moe_mod.DECODE_DROP_LOG = None

    emit("distributed/solo/back_half_reward", 0.0,
         f"reward={solo['mean_reward_back']:.4f}")
    emit("distributed/plane/back_half_reward", 0.0,
         f"reward={plane['mean_reward_back']:.4f}")
    delta = plane["mean_reward_back"] - solo["mean_reward_back"]
    emit("distributed/parity/back_half_reward", 0.0, f"delta={delta:+.4f}")
    emit("distributed/plane/router_versions", 0.0,
         "versions=" + "|".join(str(v) for v in plane["versions"]))
    c = plane["coord"].stats
    emit("distributed/plane/sync", 0.0,
         f"syncs={c['syncs']};merged={c['merged']};updates={c['updates']}"
         f";bursts={c['bursts']};stale_rejected={c['stale_rejected']}")
    emit("distributed/moe/decode_drops", 0.0,
         f"drops={drops};decode_calls={decode_calls}")

    if not gate("distributed/reward_parity", delta >= -PARITY,
                f"back-half reward delta={delta:+.4f} (floor -{PARITY})"):
        raise SystemExit(
            f"multi-worker plane lost more than {PARITY} back-half reward "
            f"vs the single-worker adapter (delta={delta:+.4f})")
    if not gate("distributed/version_convergence",
                len(plane["versions"]) == 1,
                f"versions={sorted(plane['versions'])}"):
        raise SystemExit(
            f"workers did not converge to one router version: "
            f"{plane['versions']}")
    if not gate("distributed/moe_decode_no_drop",
                decode_calls > 0 and drops == 0,
                f"drops={drops} over {decode_calls} decode calls"):
        if decode_calls == 0:
            raise SystemExit(
                "decode-drop audit recorded zero MoE decode calls — the "
                "no-drop gate would be vacuous (DECODE_DROP_LOG must be set "
                "before the decode path is first traced)")
        raise SystemExit(
            f"decode-path MoE dropped {drops} tokens "
            f"(over {decode_calls} decode calls)")


if __name__ == "__main__":
    main()
