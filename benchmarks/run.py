"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table2     # one table

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py). Env:
REPRO_BENCH_QUERIES (default 4000), REPRO_BENCH_EPOCHS (default 300; paper
uses 1000), REPRO_BENCH_CACHE.
"""
from __future__ import annotations

import sys
import time

from benchmarks import (
    cascade_bench,
    distributed_bench,
    fig4_5_domains,
    fig6_distribution,
    kernel_bench,
    online_bench,
    roofline,
    serving_bench,
    table1_rewards,
    table2_routers,
    table3_6_ablation,
)

SUITES = {
    "table1": table1_rewards.main,
    "table2": table2_routers.main,
    "table3_6": table3_6_ablation.main,
    "fig4_5": fig4_5_domains.main,
    "fig6": fig6_distribution.main,
    "kernels": kernel_bench.main,
    "roofline": roofline.main,
    "serving": serving_bench.main,
    "online": online_bench.main,
    "distributed": distributed_bench.main,
    "cascade": cascade_bench.main,
}


def main() -> None:
    selected = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for name in selected:
        if name not in SUITES:
            raise SystemExit(f"unknown suite {name!r}; choose from {list(SUITES)}")
        t0 = time.time()
        SUITES[name]()
        print(f"# suite {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
