"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table2     # one table

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py) and
writes a machine-readable ``BENCH_<suite>.json`` per suite (headline
metric, gate pass/fail, wall time, every emitted row) under
``$REPRO_BENCH_OUT`` (default reports/bench). A suite that raises still
gets its JSON (with the ``error`` field set) before the runner exits
non-zero. Env: REPRO_BENCH_QUERIES (default 4000), REPRO_BENCH_EPOCHS
(default 300; paper uses 1000), REPRO_BENCH_CACHE, REPRO_BENCH_OUT.
"""
from __future__ import annotations

import os
import sys
import time

from benchmarks.common import BenchReport, set_active_report

from benchmarks import (
    cascade_bench,
    distributed_bench,
    fig4_5_domains,
    fig6_distribution,
    kernel_bench,
    online_bench,
    roofline,
    serving_bench,
    table1_rewards,
    table2_routers,
    table3_6_ablation,
)

SUITES = {
    "table1": table1_rewards.main,
    "table2": table2_routers.main,
    "table3_6": table3_6_ablation.main,
    "fig4_5": fig4_5_domains.main,
    "fig6": fig6_distribution.main,
    "kernels": kernel_bench.main,
    "roofline": roofline.main,
    "serving": serving_bench.main,
    "online": online_bench.main,
    "distributed": distributed_bench.main,
    "cascade": cascade_bench.main,
}


OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "reports/bench")


def main() -> None:
    selected = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        if name not in SUITES:
            raise SystemExit(f"unknown suite {name!r}; choose from {list(SUITES)}")
        report = BenchReport(name)
        set_active_report(report)
        t0 = time.time()
        try:
            SUITES[name]()
        except Exception as e:  # noqa: BLE001 — recorded, then re-raised
            report.error = f"{type(e).__name__}: {e}"
            failures.append(name)
        finally:
            report.wall_s = time.time() - t0
            set_active_report(None)
            report.save(os.path.join(OUT_DIR, f"BENCH_{name}.json"))
        if report.error is None and any(
                not g["passed"] for g in report.gates):
            failures.append(name)
        print(f"# suite {name} done in {report.wall_s:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures in: {sorted(set(failures))}")


if __name__ == "__main__":
    main()
