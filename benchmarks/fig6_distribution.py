"""Paper Figure 6 / Appendix A: distribution of queries per model under the
oracle routers — verifies the cost-efficiency story (<= ~20% to GPT-4 at the
paper's operating points, most queries to cheap models)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import LAMS, emit, load_data, pool_splits
from repro.core import oracle_sweep


def main() -> None:
    data = load_data()
    pool, tr, va, te = pool_splits(data, "pool1")
    for reward in ("R1", "R2"):
        choices = oracle_sweep(pool.quality[te], pool.cost[te], LAMS, reward)
        # Mid-lambda operating point (the paper's plots) + the max over grid.
        mid = choices[len(LAMS) // 2]
        for mi, name in enumerate(pool.model_names):
            frac = float((mid == mi).mean())
            emit(f"fig6/{reward}/mid_lambda/{name}", 0.0, round(frac, 4))
        exp_idx = int(np.argmax(pool.cost[te].mean(0)))
        max_frac = float((choices == exp_idx).mean(axis=1).max())
        emit(f"fig6/{reward}/max_calls_gpt4", 0.0, round(max_frac, 4))


if __name__ == "__main__":
    main()
