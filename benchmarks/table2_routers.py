"""Paper Table 2 / Figure 3: attention router vs KNN / MLP / SVM / Blender.

AIQ and Perf_max on pools 1-3 (paper table), with the oracle as the upper
bound. The MLP baseline is RouterBench's (same role as 2-FCN predictor);
KNN uses k=20, SVM margin=0, as in the paper.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    LAMS, emit, eval_oracle, eval_router_sweep, load_data, pool_splits,
    trained_router,
)
from repro.core import evaluate_sweep, rewards
from repro.core.baselines import KNNRouter, SVMRouter, llm_blender_eval


def _sweep_from_predictions(s_hat, c_hat):
    return np.stack([
        np.asarray(rewards.route("R2", s_hat, c_hat, lam)) for lam in LAMS
    ])


def main() -> None:
    data = load_data()
    for pool_name in ("pool1", "pool2", "pool3"):
        pool, tr, va, te = pool_splits(data, pool_name)
        tag = f"table2/{pool_name}"

        # Attention router (R2) — the paper's method.
        router = trained_router(pool, tr, va, pool_name, "attn", "attn")
        m, us = eval_router_sweep(router, pool, te)
        emit(f"{tag}/attn/aiq", us, round(m["aiq"], 5))
        emit(f"{tag}/attn/perf_max", us, round(m["perf_max"], 5))

        # KNN (k=20).
        t0 = time.perf_counter()
        knn = KNNRouter(pool.emb[tr], pool.quality[tr], pool.cost[tr], k=20)
        s_hat, c_hat = knn.predict(pool.emb[te])
        us_knn = (time.perf_counter() - t0) / len(te) * 1e6
        mk = evaluate_sweep(_sweep_from_predictions(s_hat, c_hat),
                            pool.quality[te], pool.cost[te], LAMS)
        emit(f"{tag}/knn/aiq", us_knn, round(mk["aiq"], 5))
        emit(f"{tag}/knn/perf_max", us_knn, round(mk["perf_max"], 5))

        # MLP router (RouterBench baseline == 2-FCN predictors).
        mlp = trained_router(pool, tr, va, pool_name, "2fcn", "2fcn")
        mm, us_mlp = eval_router_sweep(mlp, pool, te)
        emit(f"{tag}/mlp/aiq", us_mlp, round(mm["aiq"], 5))
        emit(f"{tag}/mlp/perf_max", us_mlp, round(mm["perf_max"], 5))

        # SVM router (margin=0).
        t0 = time.perf_counter()
        svm = SVMRouter.fit(pool.emb[tr], pool.quality[tr], pool.cost[tr])
        s_hat, c_hat = svm.predict(pool.emb[te])
        us_svm = (time.perf_counter() - t0) / len(te) * 1e6
        ms = evaluate_sweep(_sweep_from_predictions(s_hat, c_hat),
                            pool.quality[te], pool.cost[te], LAMS)
        emit(f"{tag}/svm/aiq", us_svm, round(ms["aiq"], 5))
        emit(f"{tag}/svm/perf_max", us_svm, round(ms["perf_max"], 5))

        # LLM-Blender: post-generation, queries every model (no AIQ — single
        # operating point whose cost is the sum of all model costs).
        perf, total_cost = llm_blender_eval(pool.quality[te], pool.cost[te])
        emit(f"{tag}/blender/perf_max", 0.0, round(perf, 5))
        emit(f"{tag}/blender/cost_per_query", 0.0, f"{total_cost:.6f}")

        # Oracle upper bound.
        mo = eval_oracle(pool, te, "R2")
        emit(f"{tag}/oracle/aiq", 0.0, round(mo["aiq"], 5))
        emit(f"{tag}/oracle/perf_max", 0.0, round(mo["perf_max"], 5))


if __name__ == "__main__":
    main()
