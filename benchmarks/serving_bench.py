"""Serving-runtime benchmark (ours, not a paper table): sustained simulated
traffic through the admission queue + continuous micro-batching scheduler.

Reports requests/sec of the full pipeline (scoring + generation on the
reduced CPU pool) and p50/p99 *routing* latency per score batch — the
paper's "router adds microseconds, not milliseconds" serving claim, here
measured under open-loop load instead of a single offline batch.

Also runs the observability overhead gate: the same trace served with the
trace recorder installed must keep its p50 per-dispatch wall latency
within 5% of the tracing-off run (best-of-N reps each, so jit warm-up and
scheduler noise don't decide the gate). Tracing is a handful of tuple
appends per request — if this gate fails, an emission site grew a real
cost.

CPU-sized: 2 pool members, small trace. On TPU the scoring path drops into
the fused Pallas router_xattn kernel with pool-side K~/V~ reuse.
"""
from __future__ import annotations

import time
from collections import deque

import numpy as np

from benchmarks.common import emit, gate, headline
from repro.launch.serve import build_routed_engine
from repro.obs import TraceRecorder
from repro.serving import (
    MicroBatchScheduler,
    SchedulerConfig,
    TraceConfig,
    make_trace,
)

POOL = ["qwen3-0.6b", "granite-3-8b"]
N_REQUESTS = 96
OVERHEAD_REPS = 3          # best-of reps per tracing config
OVERHEAD_BUDGET = 1.05     # tracing-on p50 must stay within 5%


def _make_bench_trace(data, te, seed: int = 0):
    return make_trace(
        TraceConfig(kind="poisson", n_requests=N_REQUESTS, rate=1000.0,
                    seed=seed, max_new=2, prompt_len_max=24, vocab=64),
        texts=[data.texts[i] for i in te],
        benchmarks=[data.benchmark[i] for i in te],
    )


def _dispatch_p50_us(engine, data, te, *, tracing: bool) -> float:
    """p50 wall microseconds per scheduler dispatch over one full trace.

    Drives the run_trace event loop by hand so only the dispatch() calls
    (scoring + routing + generation bookkeeping — every traced code path)
    land in the timed window, not trace construction or queue idling.
    """
    tracer = TraceRecorder(label="overhead").scoped(0) if tracing else None
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=32, max_batch=8), tracer=tracer)
    pending = deque(sorted(_make_bench_trace(data, te),
                           key=lambda r: r.arrival_s))
    times = []
    while pending or sched.queue.depth:
        while pending and pending[0].arrival_s <= sched.clock.now:
            sched.queue.offer(pending.popleft(), sched.clock.now)
        if sched.should_dispatch(flush=not pending):
            t0 = time.perf_counter()
            sched.dispatch()
            times.append(time.perf_counter() - t0)
            continue
        nxt = []
        if pending:
            nxt.append(pending[0].arrival_s)
        if sched.queue.depth:
            head = sched.queue.peek_all()[0]
            nxt.append(head.admitted_s + sched.config.max_wait_s)
        nxt_t = min(nxt)
        if nxt_t <= sched.clock.now:
            t0 = time.perf_counter()
            sched.dispatch()
            times.append(time.perf_counter() - t0)
            continue
        sched.clock.advance_to(nxt_t)
    return float(np.percentile(times, 50)) * 1e6


def overhead_gate(engine, data, te) -> None:
    """Tracing-on p50 dispatch latency within OVERHEAD_BUDGET of off."""
    _dispatch_p50_us(engine, data, te, tracing=True)   # jit/cache warm-up
    p50_off = min(_dispatch_p50_us(engine, data, te, tracing=False)
                  for _ in range(OVERHEAD_REPS))
    p50_on = min(_dispatch_p50_us(engine, data, te, tracing=True)
                 for _ in range(OVERHEAD_REPS))
    ratio = p50_on / p50_off if p50_off > 0 else float("inf")
    emit("serving/trace_overhead/p50_off", p50_off, f"us={p50_off:.1f}")
    emit("serving/trace_overhead/p50_on", p50_on, f"us={p50_on:.1f}")
    emit("serving/trace_overhead/ratio", p50_on, f"ratio={ratio:.4f}")
    headline("trace_overhead_p50_ratio", ratio, "on/off")
    gate("serving/trace_overhead_p50", ratio <= OVERHEAD_BUDGET,
         f"p50 on {p50_on:.1f}us / off {p50_off:.1f}us = {ratio:.4f} "
         f"(budget {OVERHEAD_BUDGET})")


def main() -> None:
    engine, data, te = build_routed_engine(
        POOL, seed=0, epochs=40, n_traffic=600)

    for kind in ("poisson", "bursty"):
        trace = make_trace(
            TraceConfig(kind=kind, n_requests=N_REQUESTS, rate=1000.0,
                        seed=0, max_new=2, prompt_len_max=24, vocab=64),
            texts=[data.texts[i] for i in te],
            benchmarks=[data.benchmark[i] for i in te],
        )
        sched = MicroBatchScheduler(
            engine, SchedulerConfig(score_batch=32, max_batch=8))
        t0 = time.perf_counter()
        summary = sched.run_trace(trace)
        wall = time.perf_counter() - t0
        tel = sched.telemetry
        rps = summary["completed"] / wall
        us_routing = tel.routing_latency.mean / max(
            tel.scored_requests / tel.score_batches, 1) * 1e6
        emit(f"serving/{kind}/throughput", us_routing,
             f"rps={rps:.1f}")
        emit(f"serving/{kind}/routing_p50", us_routing,
             f"p50_ms={summary['routing_p50_ms']:.2f}")
        emit(f"serving/{kind}/routing_p99", us_routing,
             f"p99_ms={summary['routing_p99_ms']:.2f}")
        emit(f"serving/{kind}/mean_generate_batch", us_routing,
             f"batch={summary['mean_generate_batch']:.1f}")

    overhead_gate(engine, data, te)


if __name__ == "__main__":
    main()
