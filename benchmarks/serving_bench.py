"""Serving-runtime benchmark (ours, not a paper table): sustained simulated
traffic through the admission queue + continuous micro-batching scheduler.

Reports requests/sec of the full pipeline (scoring + generation on the
reduced CPU pool) and p50/p99 *routing* latency per score batch — the
paper's "router adds microseconds, not milliseconds" serving claim, here
measured under open-loop load instead of a single offline batch.

Also runs the observability overhead gates: the same trace served with the
trace recorder installed must keep its p50 per-dispatch wall latency
within 5% of the tracing-off run — and again with the full streaming
stack on (deterministic sampling + per-worker cap + periodic segment
flushes to disk). The gate runs on a *stub* scoring/generation engine so
a dispatch is pure scheduler+tracer code (~100s of us): against the real
pool, LM compute is seconds per dispatch with multi-percent variance,
which drowns the tuple-appends the gate is actually about. Best-of-N reps
each, so warm-up and scheduler noise don't decide the gates. If a gate
fails, an emission site grew a real cost.

CPU-sized: 2 pool members, small trace. On TPU the scoring path drops into
the fused Pallas router_xattn kernel with pool-side K~/V~ reuse.
"""
from __future__ import annotations

import tempfile
import time
from collections import deque

import numpy as np

from benchmarks.common import emit, gate, headline
from repro.launch.serve import build_routed_engine
from repro.obs import ObsFlusher, TraceRecorder, TraceSampler
from repro.serving import (
    MicroBatchScheduler,
    SchedulerConfig,
    TraceConfig,
    make_trace,
)

POOL = ["qwen3-0.6b", "granite-3-8b"]
N_REQUESTS = 96
OVERHEAD_REPS = 5          # best-of reps per tracing config
OVERHEAD_BUDGET = 1.05     # tracing-on p50 must stay within 5%


class _StubMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate


class _StubEngine:
    """Fixed-cost engine for the overhead gate: static scores, and every
    score/generate call burns a deterministic numpy matmul payload (a few
    ms — the scale of one micro-batch step on an accelerator). A dispatch
    is therefore scheduler + tracer code over a *stable* compute floor;
    against the real reduced CPU pool a dispatch is seconds of LM compute
    whose multi-percent wall variance both drowns the us-scale emission
    cost the gate is about and flaps the ratio."""

    def __init__(self, cost_rates=(1.0, 10.0), quality=(0.5, 1.0),
                 payload_dim=384, payload_reps=4):
        self.pool = [_StubMember(f"m{i}", c)
                     for i, c in enumerate(cost_rates)]
        self.quality = np.asarray(quality, np.float64)
        self.lam = 100.0
        self._payload = np.random.default_rng(0).standard_normal(
            (payload_dim, payload_dim)).astype(np.float32)
        self._payload_reps = payload_reps

    def _burn(self) -> None:
        for _ in range(self._payload_reps):
            self._payload @ self._payload

    def score_texts(self, texts):
        self._burn()
        b = len(texts)
        s = np.tile(self.quality, (b, 1))
        c = np.tile([m.cost_rate for m in self.pool], (b, 1))
        return s, c

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8):
        self._burn()
        outs = [np.zeros(max_new, np.int32) for _ in prompts]
        return outs, self.pool[mi].cost_rate * len(prompts)


def _make_bench_trace(data, te, seed: int = 0):
    return make_trace(
        TraceConfig(kind="poisson", n_requests=N_REQUESTS, rate=1000.0,
                    seed=seed, max_new=2, prompt_len_max=24, vocab=64),
        texts=[data.texts[i] for i in te],
        benchmarks=[data.benchmark[i] for i in te],
    )


def _dispatch_p50_us(engine, data, te, *, mode: str,
                     obs_dir: str = None) -> float:
    """p50 wall microseconds per scheduler dispatch over one full trace.

    ``mode``: "off" = no tracer; "on" = plain recorder (PR-6 tracing);
    "stream" = the full streaming stack — sampling (rate 0.25), a
    per-worker cap, and periodic segment flushes to ``obs_dir``.

    Drives the run_trace event loop by hand so only the dispatch() calls
    (scoring + routing + generation bookkeeping — every traced code path)
    land in the timed window, not trace construction or queue idling.
    Flusher ticks are included in the timed window for "stream": segment
    writes land on the dispatches that cross a scrape boundary, so the
    p50 is the steady-state per-dispatch cost with the streaming stack
    installed, while a flush regression still shows up in the tail and in
    the rep minimum. Micro-batches are smaller than the throughput suites'
    so one trace yields ~30 dispatch samples for a stable p50.
    """
    tracer = flusher = None
    if mode == "on":
        tracer = TraceRecorder(label="overhead").scoped(0)
    elif mode == "stream":
        rec = TraceRecorder(label="overhead",
                            sampler=TraceSampler(0.25, seed=0),
                            max_buffered_per_worker=4096)
        tracer = rec.scoped(0)
        flusher = ObsFlusher(obs_dir, recorder=rec, scrape_every_s=0.01,
                             label="overhead")
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=8, max_batch=4), tracer=tracer,
        flusher=flusher, service_time=lambda kind, n_, wall: 1e-3)
    pending = deque(sorted(_make_bench_trace(data, te),
                           key=lambda r: r.arrival_s))
    times = []
    while pending or sched.queue.depth:
        while pending and pending[0].arrival_s <= sched.clock.now:
            sched.queue.offer(pending.popleft(), sched.clock.now)
        if sched.should_dispatch(flush=not pending):
            t0 = time.perf_counter()
            sched.dispatch()
            if flusher is not None:
                flusher.maybe_flush(sched.clock.now)
            times.append(time.perf_counter() - t0)
            continue
        nxt = []
        if pending:
            nxt.append(pending[0].arrival_s)
        if sched.queue.depth:
            head = sched.queue.peek_all()[0]
            nxt.append(head.admitted_s + sched.config.max_wait_s)
        nxt_t = min(nxt)
        if nxt_t <= sched.clock.now:
            t0 = time.perf_counter()
            sched.dispatch()
            if flusher is not None:
                flusher.maybe_flush(sched.clock.now)
            times.append(time.perf_counter() - t0)
            continue
        sched.clock.advance_to(nxt_t)
    if flusher is not None:
        flusher.finalize(sched.clock.now)
    return float(np.percentile(times, 50)) * 1e6


def overhead_gate(data, te) -> None:
    """Tracing-on and streaming-on p50 dispatch latency within
    OVERHEAD_BUDGET of tracing-off (stub engine: see module docstring)."""
    engine = _StubEngine()
    _dispatch_p50_us(engine, data, te, mode="on")   # cache/allocator warm-up
    p50_off = min(_dispatch_p50_us(engine, data, te, mode="off")
                  for _ in range(OVERHEAD_REPS))
    p50_on = min(_dispatch_p50_us(engine, data, te, mode="on")
                 for _ in range(OVERHEAD_REPS))
    with tempfile.TemporaryDirectory() as tmp:
        p50_stream = min(
            _dispatch_p50_us(engine, data, te, mode="stream",
                             obs_dir=f"{tmp}/rep{i}")
            for i in range(OVERHEAD_REPS))
    ratio = p50_on / p50_off if p50_off > 0 else float("inf")
    s_ratio = p50_stream / p50_off if p50_off > 0 else float("inf")
    emit("serving/trace_overhead/p50_off", p50_off, f"us={p50_off:.1f}")
    emit("serving/trace_overhead/p50_on", p50_on, f"us={p50_on:.1f}")
    emit("serving/trace_overhead/p50_stream", p50_stream,
         f"us={p50_stream:.1f}")
    emit("serving/trace_overhead/ratio", p50_on, f"ratio={ratio:.4f}")
    emit("serving/trace_overhead/stream_ratio", p50_stream,
         f"ratio={s_ratio:.4f}")
    headline("trace_overhead_p50_ratio", ratio, "on/off",
             direction="lower")
    gate("serving/trace_overhead_p50", ratio <= OVERHEAD_BUDGET,
         f"p50 on {p50_on:.1f}us / off {p50_off:.1f}us = {ratio:.4f} "
         f"(budget {OVERHEAD_BUDGET})")
    gate("serving/stream_overhead_p50", s_ratio <= OVERHEAD_BUDGET,
         f"p50 stream {p50_stream:.1f}us / off {p50_off:.1f}us = "
         f"{s_ratio:.4f} (budget {OVERHEAD_BUDGET}, sampling 0.25 + "
         f"cap 4096 + flush every 0.01 virtual s)")


def main() -> None:
    engine, data, te = build_routed_engine(
        POOL, seed=0, epochs=40, n_traffic=600)

    for kind in ("poisson", "bursty"):
        trace = make_trace(
            TraceConfig(kind=kind, n_requests=N_REQUESTS, rate=1000.0,
                        seed=0, max_new=2, prompt_len_max=24, vocab=64),
            texts=[data.texts[i] for i in te],
            benchmarks=[data.benchmark[i] for i in te],
        )
        sched = MicroBatchScheduler(
            engine, SchedulerConfig(score_batch=32, max_batch=8))
        t0 = time.perf_counter()
        summary = sched.run_trace(trace)
        wall = time.perf_counter() - t0
        tel = sched.telemetry
        rps = summary["completed"] / wall
        us_routing = tel.routing_latency.mean / max(
            tel.scored_requests / tel.score_batches, 1) * 1e6
        emit(f"serving/{kind}/throughput", us_routing,
             f"rps={rps:.1f}")
        emit(f"serving/{kind}/routing_p50", us_routing,
             f"p50_ms={summary['routing_p50_ms']:.2f}")
        emit(f"serving/{kind}/routing_p99", us_routing,
             f"p99_ms={summary['routing_p99_ms']:.2f}")
        emit(f"serving/{kind}/mean_generate_batch", us_routing,
             f"batch={summary['mean_generate_batch']:.1f}")

    overhead_gate(data, te)


if __name__ == "__main__":
    main()
