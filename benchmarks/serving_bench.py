"""Serving-runtime benchmark (ours, not a paper table): sustained simulated
traffic through the admission queue + continuous micro-batching scheduler.

Reports requests/sec of the full pipeline (scoring + generation on the
reduced CPU pool) and p50/p99 *routing* latency per score batch — the
paper's "router adds microseconds, not milliseconds" serving claim, here
measured under open-loop load instead of a single offline batch.

CPU-sized: 2 pool members, small trace. On TPU the scoring path drops into
the fused Pallas router_xattn kernel with pool-side K~/V~ reuse.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.launch.serve import build_routed_engine
from repro.serving import (
    MicroBatchScheduler,
    SchedulerConfig,
    TraceConfig,
    make_trace,
)

POOL = ["qwen3-0.6b", "granite-3-8b"]
N_REQUESTS = 96


def main() -> None:
    engine, data, te = build_routed_engine(
        POOL, seed=0, epochs=40, n_traffic=600)

    for kind in ("poisson", "bursty"):
        trace = make_trace(
            TraceConfig(kind=kind, n_requests=N_REQUESTS, rate=1000.0,
                        seed=0, max_new=2, prompt_len_max=24, vocab=64),
            texts=[data.texts[i] for i in te],
            benchmarks=[data.benchmark[i] for i in te],
        )
        sched = MicroBatchScheduler(
            engine, SchedulerConfig(score_batch=32, max_batch=8))
        t0 = time.perf_counter()
        summary = sched.run_trace(trace)
        wall = time.perf_counter() - t0
        tel = sched.telemetry
        rps = summary["completed"] / wall
        us_routing = tel.routing_latency.mean / max(
            tel.scored_requests / tel.score_batches, 1) * 1e6
        emit(f"serving/{kind}/throughput", us_routing,
             f"rps={rps:.1f}")
        emit(f"serving/{kind}/routing_p50", us_routing,
             f"p50_ms={summary['routing_p50_ms']:.2f}")
        emit(f"serving/{kind}/routing_p99", us_routing,
             f"p99_ms={summary['routing_p99_ms']:.2f}")
        emit(f"serving/{kind}/mean_generate_batch", us_routing,
             f"batch={summary['mean_generate_batch']:.1f}")


if __name__ == "__main__":
    main()
