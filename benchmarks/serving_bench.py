"""Serving-runtime benchmark (ours, not a paper table): sustained simulated
traffic through the admission queue + continuous micro-batching scheduler.

Reports requests/sec of the full pipeline (scoring + generation on the
reduced CPU pool) and p50/p99 *routing* latency per score batch — the
paper's "router adds microseconds, not milliseconds" serving claim, here
measured under open-loop load instead of a single offline batch.

Also runs the observability overhead gates: the same trace served with the
trace recorder installed must keep its p50 per-dispatch wall latency
within 5% of the tracing-off run — again with the full streaming
stack on (deterministic sampling + per-worker cap + periodic segment
flushes to disk), and again for RPC tracing: remote GENERATE dispatch
over a LocalTransport with the trace-context/span/RpcStats stack on must
stay within 5% of the same topology bare. The gate runs on a *stub* scoring/generation engine so
a dispatch is pure scheduler+tracer code (~100s of us): against the real
pool, LM compute is seconds per dispatch with multi-percent variance,
which drowns the tuple-appends the gate is actually about. Best-of-N reps
each, so warm-up and scheduler noise don't decide the gates. If a gate
fails, an emission site grew a real cost.

CPU-sized: 2 pool members, small trace. On TPU the scoring path drops into
the fused Pallas router_xattn kernel with pool-side K~/V~ reuse.
"""
from __future__ import annotations

import tempfile
import time
from collections import deque

import numpy as np

from benchmarks.common import emit, gate, headline
from repro.cascade import CascadeConfig, CascadeCoordinator, CascadePolicy
from repro.launch.serve import build_routed_engine
from repro.obs import ObsFlusher, TraceRecorder, TraceSampler
from repro.online import DriftDetector
from repro.serving import (
    MicroBatchScheduler,
    Request,
    SchedulerConfig,
    SemanticCache,
    TraceConfig,
    make_trace,
)

POOL = ["qwen3-0.6b", "granite-3-8b"]
N_REQUESTS = 96
OVERHEAD_REPS = 5          # best-of reps per tracing config
OVERHEAD_BUDGET = 1.05     # tracing-on p50 must stay within 5%


class _StubMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate


class _StubEngine:
    """Fixed-cost engine for the overhead gate: static scores, and every
    score/generate call burns a deterministic numpy matmul payload (a few
    ms — the scale of one micro-batch step on an accelerator). A dispatch
    is therefore scheduler + tracer code over a *stable* compute floor;
    against the real reduced CPU pool a dispatch is seconds of LM compute
    whose multi-percent wall variance both drowns the us-scale emission
    cost the gate is about and flaps the ratio."""

    def __init__(self, cost_rates=(1.0, 10.0), quality=(0.5, 1.0),
                 payload_dim=384, payload_reps=4):
        self.pool = [_StubMember(f"m{i}", c)
                     for i, c in enumerate(cost_rates)]
        self.quality = np.asarray(quality, np.float64)
        self.lam = 100.0
        self._payload = np.random.default_rng(0).standard_normal(
            (payload_dim, payload_dim)).astype(np.float32)
        self._payload_reps = payload_reps
        self._n_embedded = 0

    def _burn(self) -> None:
        for _ in range(self._payload_reps):
            self._payload @ self._payload

    def score_texts(self, texts):
        self._burn()
        b = len(texts)
        s = np.tile(self.quality, (b, 1))
        c = np.tile([m.cost_rate for m in self.pool], (b, 1))
        return s, c

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8, max_new_per_req=None):
        self._burn()
        outs = [np.zeros(max_new, np.int32) for _ in prompts]
        return outs, self.pool[mi].cost_rate * len(prompts)

    # Embedding surface for the semcache overhead mode: embed() burns the
    # same payload score_texts() does (the real engine embeds once for
    # scoring either way), score_emb() is free — so mode "cache" measures
    # exactly the cache rung's marginal cost over the same compute floor.
    def embed(self, texts):
        self._burn()
        b = len(texts)
        out = np.zeros((b, 8), np.float32)
        # Every query embedding distinct: all-miss worst case — each
        # lookup scans the buffer and each outcome is a fresh admission.
        out[:, 0] = np.arange(b) + self._n_embedded
        self._n_embedded += b
        return out

    def score_emb(self, q_emb):
        b = len(q_emb)
        s = np.tile(self.quality, (b, 1))
        c = np.tile([m.cost_rate for m in self.pool], (b, 1))
        return s, c


def _make_bench_trace(data, te, seed: int = 0):
    return make_trace(
        TraceConfig(kind="poisson", n_requests=N_REQUESTS, rate=1000.0,
                    seed=seed, max_new=2, prompt_len_max=24, vocab=64),
        texts=[data.texts[i] for i in te],
        benchmarks=[data.benchmark[i] for i in te],
    )


def _dispatch_p50_us(engine, data, te, *, mode: str,
                     obs_dir: str = None) -> float:
    """p50 wall microseconds per scheduler dispatch over one full trace.

    ``mode``: "off" = no tracer; "on" = plain recorder (PR-6 tracing);
    "stream" = the full streaming stack — sampling (rate 0.25), a
    per-worker cap, and periodic segment flushes to ``obs_dir``;
    "rpc-off"/"rpc" = remote-generate topology (a PoolDispatcher over a
    LocalTransport where the always-chosen member lives on a bound peer,
    so every generate micro-batch is one GENERATE request) without / with
    the RPC tracing stack (trace-context stamping + client span + server
    span + transport RpcStats latency accounting).

    Drives the run_trace event loop by hand so only the dispatch() calls
    (scoring + routing + generation bookkeeping — every traced code path)
    land in the timed window, not trace construction or queue idling.
    Flusher ticks are included in the timed window for "stream": segment
    writes land on the dispatches that cross a scrape boundary, so the
    p50 is the steady-state per-dispatch cost with the streaming stack
    installed, while a flush regression still shows up in the tail and in
    the rep minimum. Micro-batches are smaller than the throughput suites'
    so one trace yields ~30 dispatch samples for a stable p50.
    """
    tracer = flusher = semcache = dispatcher = None
    if mode == "on":
        tracer = TraceRecorder(label="overhead").scoped(0)
    elif mode in ("rpc", "rpc-off"):
        # lam=100 always routes to member 1 (owner_of(1, 2) == 1 != wid 0),
        # so EVERY generate micro-batch ships as a GENERATE request to the
        # bound peer. "rpc-off" times the bare topology; "rpc" layers the
        # RPC tracing stack on top — the gate's paired ratio isolates the
        # tracing cost from the transport cost.
        from repro.distributed.shard import PoolDispatcher
        from repro.distributed.transport import LocalTransport

        transport = LocalTransport()
        srv = None
        if mode == "rpc":
            rec = TraceRecorder(label="overhead")
            tracer = rec.scoped(0)
            srv = rec.scoped(1)
            transport.tracer = rec

        def _peer(msg):
            p = msg.payload
            t0 = time.perf_counter()
            outs, costs = engine.generate_member(
                p["member"], p["prompts"], max_new=p["max_new"])
            if srv is not None:   # the worker-side rpc span (worker.handle)
                srv.span("rpc", "rpc", t0, time.perf_counter(),
                         args={"rpc": msg.seq, "kind": msg.kind,
                               "side": "server", "peer": int(msg.src)})
            return {"outs": outs, "costs": costs}

        transport.bind(1, _peer)
        dispatcher = PoolDispatcher(0, 2, engine, transport)
    elif mode == "stream":
        rec = TraceRecorder(label="overhead",
                            sampler=TraceSampler(0.25, seed=0),
                            max_buffered_per_worker=4096)
        tracer = rec.scoped(0)
        flusher = ObsFlusher(obs_dir, recorder=rec, scrape_every_s=0.01,
                             label="overhead")
    elif mode == "cache":
        # Tiny radius + all-distinct embeddings (see _StubEngine.embed):
        # every lookup misses against a filling buffer and every outcome
        # is admitted — the cache rung's worst case, zero serves to
        # flatter the ratio with skipped generates.
        semcache = SemanticCache(1e-6, cap=256, query_bucket=8)
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=8, max_batch=4), tracer=tracer,
        flusher=flusher, semcache=semcache, dispatcher=dispatcher,
        service_time=lambda kind, n_, wall: 1e-3)
    pending = deque(sorted(_make_bench_trace(data, te),
                           key=lambda r: r.arrival_s))
    times = []
    while pending or sched.queue.depth:
        while pending and pending[0].arrival_s <= sched.clock.now:
            sched.queue.offer(pending.popleft(), sched.clock.now)
        if sched.should_dispatch(flush=not pending):
            t0 = time.perf_counter()
            sched.dispatch()
            if flusher is not None:
                flusher.maybe_flush(sched.clock.now)
            times.append(time.perf_counter() - t0)
            continue
        nxt = []
        if pending:
            nxt.append(pending[0].arrival_s)
        if sched.queue.depth:
            head = sched.queue.peek_all()[0]
            nxt.append(head.admitted_s + sched.config.max_wait_s)
        nxt_t = min(nxt)
        if nxt_t <= sched.clock.now:
            t0 = time.perf_counter()
            sched.dispatch()
            if flusher is not None:
                flusher.maybe_flush(sched.clock.now)
            times.append(time.perf_counter() - t0)
            continue
        sched.clock.advance_to(nxt_t)
    if flusher is not None:
        flusher.finalize(sched.clock.now)
    return float(np.percentile(times, 50)) * 1e6


def overhead_gate(data, te) -> None:
    """Tracing-on and streaming-on p50 dispatch latency within
    OVERHEAD_BUDGET of tracing-off (stub engine: see module docstring)."""
    engine = _StubEngine()
    _dispatch_p50_us(engine, data, te, mode="on")   # cache/allocator warm-up
    _dispatch_p50_us(engine, data, te, mode="cache")  # jit-compile warm-up
    _dispatch_p50_us(engine, data, te, mode="rpc")  # dispatcher warm-up
    # Interleave the modes rep by rep and compare each mode against an
    # "off" run measured IMMEDIATELY before it, then take the median
    # paired ratio. Block-ordered best-of-N reps let slow machine-load
    # drift bias whichever mode ran during the noisy window; adjacent
    # pairing cancels the drift (each pair shares its noise regime, so
    # e.g. the stream rep's segment-flush IO can't land between a mode
    # and its pair-mate) and the median rejects the odd cycle a
    # background tick lands in. The reported p50s stay best-of-reps for
    # absolute scale.
    offs, ons, caches, streams = [], [], [], []
    rpc_offs, rpcs = [], []
    c_ratios, o_ratios, s_ratios, r_ratios = [], [], [], []
    with tempfile.TemporaryDirectory() as tmp:
        for i in range(OVERHEAD_REPS):
            off_c = _dispatch_p50_us(engine, data, te, mode="off")
            caches.append(_dispatch_p50_us(engine, data, te, mode="cache"))
            off_o = _dispatch_p50_us(engine, data, te, mode="off")
            ons.append(_dispatch_p50_us(engine, data, te, mode="on"))
            off_s = _dispatch_p50_us(engine, data, te, mode="off")
            streams.append(_dispatch_p50_us(engine, data, te, mode="stream",
                                            obs_dir=f"{tmp}/rep{i}"))
            # The rpc pair baselines against "rpc-off" (same remote-generate
            # topology, tracing absent), so the ratio is the RPC tracing
            # stack's marginal cost — not the transport's.
            off_r = _dispatch_p50_us(engine, data, te, mode="rpc-off")
            rpc_offs.append(off_r)
            rpcs.append(_dispatch_p50_us(engine, data, te, mode="rpc"))
            offs.extend((off_c, off_o, off_s))
            c_ratios.append(caches[-1] / off_c)
            o_ratios.append(ons[-1] / off_o)
            s_ratios.append(streams[-1] / off_s)
            r_ratios.append(rpcs[-1] / off_r)
    p50_off, p50_on = min(offs), min(ons)
    p50_cache, p50_stream = min(caches), min(streams)
    p50_rpc_off, p50_rpc = min(rpc_offs), min(rpcs)
    ratio = float(np.median(o_ratios))
    s_ratio = float(np.median(s_ratios))
    c_ratio = float(np.median(c_ratios))
    r_ratio = float(np.median(r_ratios))
    emit("serving/trace_overhead/p50_off", p50_off, f"us={p50_off:.1f}")
    emit("serving/trace_overhead/p50_on", p50_on, f"us={p50_on:.1f}")
    emit("serving/trace_overhead/p50_stream", p50_stream,
         f"us={p50_stream:.1f}")
    emit("serving/trace_overhead/ratio", p50_on, f"ratio={ratio:.4f}")
    emit("serving/trace_overhead/stream_ratio", p50_stream,
         f"ratio={s_ratio:.4f}")
    headline("trace_overhead_p50_ratio", ratio, "on/off",
             direction="lower")
    gate("serving/trace_overhead_p50", ratio <= OVERHEAD_BUDGET,
         f"p50 on {p50_on:.1f}us / off {p50_off:.1f}us, median paired "
         f"ratio {ratio:.4f} (budget {OVERHEAD_BUDGET})")
    gate("serving/stream_overhead_p50", s_ratio <= OVERHEAD_BUDGET,
         f"p50 stream {p50_stream:.1f}us / off {p50_off:.1f}us, median "
         f"paired ratio {s_ratio:.4f} (budget {OVERHEAD_BUDGET}, sampling 0.25 + "
         f"cap 4096 + flush every 0.01 virtual s)")
    emit("serving/trace_overhead/p50_cache", p50_cache,
         f"us={p50_cache:.1f}")
    emit("serving/trace_overhead/cache_ratio", p50_cache,
         f"ratio={c_ratio:.4f}")
    gate("serving/cache_overhead_p50", c_ratio <= OVERHEAD_BUDGET,
         f"p50 cache {p50_cache:.1f}us / off {p50_off:.1f}us, median "
         f"paired ratio {c_ratio:.4f} (budget {OVERHEAD_BUDGET}, all-miss worst case: "
         f"every dispatch pays lookup + admission)")
    emit("serving/trace_overhead/p50_rpc_off", p50_rpc_off,
         f"us={p50_rpc_off:.1f}")
    emit("serving/trace_overhead/p50_rpc", p50_rpc, f"us={p50_rpc:.1f}")
    emit("serving/trace_overhead/rpc_ratio", p50_rpc,
         f"ratio={r_ratio:.4f}")
    gate("serving/rpc_overhead_p50", r_ratio <= OVERHEAD_BUDGET,
         f"p50 rpc-traced {p50_rpc:.1f}us / rpc-bare {p50_rpc_off:.1f}us, "
         f"median paired ratio {r_ratio:.4f} (budget {OVERHEAD_BUDGET}; every "
         f"generate is a remote GENERATE with client+server spans + "
         f"RpcStats)")


# ---------------------------------------------------------------------------
# Semantic-cache scenario: near-duplicate traffic through the cascade with
# the cache as rung 0. A controlled embedding geometry (clustered queries,
# jittered near-dup variants, an injected post-drift shift) makes three
# things measurable deterministically: the hit rate cached traffic earns,
# whether the cached frontier weakly dominates the no-cache cascade at the
# tested lambdas (same quality, strictly less spend), and whether drift
# invalidation prevents the stale-cache quality cliff.
# ---------------------------------------------------------------------------

SEM_COSTS = (0.2, 1.0, 3.0)
SEM_D = 16                    # embedding dim
SEM_CLUSTERS = 8              # hot regions; one cache entry serves each
SEM_VARIANTS = 16             # near-dup phrasings per region
SEM_EPS = 0.05                # intra-region embedding jitter
SEM_DELTA = 0.8               # post-drift shift (within the cache radius!)
SEM_RADIUS = 1.4              # serve radius: spans drifted near-dups too
SEM_STALE_Q = 0.15            # realized quality of an outdated answer


class _SemCacheEngine:
    """Cascade scoring surface over an explicit embedding geometry.

    Predictions come from per-text tables (the router is assumed
    calibrated — online adaptation is benchmarked elsewhere); generated
    tokens encode (member, phase) so realized answer quality can be
    evaluated after the run, including cached answers served across the
    drift boundary."""

    def __init__(self, emb_of, pred_of, lam=10.0, std=0.05):
        self.pool = [_StubMember(f"m{i}", c)
                     for i, c in enumerate(SEM_COSTS)]
        self.lam = lam
        self.emb_of = emb_of
        self.pred_of = pred_of
        self.std = float(std)

    def embed(self, texts):
        out = np.stack([self.emb_of[t] for t in texts])
        # The scheduler scores a SUBSET of the embedded batch (the cache
        # rung serves some rows first): recover texts from the rows.
        self._text_of = {row.tobytes(): t for row, t in zip(out, texts)}
        return out

    def score_emb_uncertainty(self, q_emb):
        texts = [self._text_of[np.asarray(row, np.float32).tobytes()]
                 for row in q_emb]
        s = np.stack([self.pred_of[t] for t in texts])
        return (s, np.full_like(s, self.std),
                np.tile(SEM_COSTS, (len(s), 1)))

    def score_emb(self, q_emb):
        s, _, c = self.score_emb_uncertainty(q_emb)
        return s, c

    def score_texts(self, texts):
        return self.score_emb(self.embed(texts))

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8, max_new_per_req=None):
        caps = (max_new_per_req if max_new_per_req is not None
                else [max_new] * len(prompts))
        # Token value encodes member + generation phase (the prompt's
        # first token carries the request's phase).
        outs = [np.full(int(c), mi + 10 * int(p[0]), np.int32)
                for p, c in zip(prompts, caps)]
        return outs, np.full(len(prompts), self.pool[mi].cost_rate,
                             np.float64)


def _sem_corpus(seed=0):
    """(emb_of, pred_of, truth_of, centers): clustered near-dup corpus.

    Text ``c{j}.p{phase}.v{k}`` = variant k of region j in drift phase
    ``phase``; phase-1 embeddings shift by SEM_DELTA in a fixed direction
    (still within the cache radius of phase-0 entries — exactly the case
    where only invalidation prevents stale serves). Even regions are easy
    (the cheap member suffices), odd regions need the strong member."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((SEM_CLUSTERS, SEM_D)).astype(np.float32)
    shift = np.zeros(SEM_D, np.float32)
    shift[0] = SEM_DELTA
    emb_of, pred_of, truth_of = {}, {}, {}
    for j in range(SEM_CLUSTERS):
        q = (np.array([0.85, 0.90, 0.95]) if j % 2 == 0
             else np.array([0.30, 0.55, 0.95]))
        for phase in (0, 1):
            for k in range(SEM_VARIANTS):
                e = (centers[j] + SEM_EPS
                     * rng.standard_normal(SEM_D).astype(np.float32))
                if phase:
                    e = e + shift
                t = f"c{j}.p{phase}.v{k}"
                emb_of[t] = e.astype(np.float32)
                pred_of[t] = q
                truth_of[t] = q
    return emb_of, pred_of, truth_of, centers


def _sem_requests(seed, n, phase, t0=0.0, rate=400.0):
    """Near-dup arrivals: Zipf-weighted region picks, uniform variants."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, SEM_CLUSTERS + 1)
    w /= w.sum()
    reqs = []
    for i in range(n):
        j = int(rng.choice(SEM_CLUSTERS, p=w))
        k = int(rng.integers(SEM_VARIANTS))
        reqs.append(Request(
            text=f"c{j}.p{phase}.v{k}",
            prompt=np.full(4, phase, np.int32),
            max_new=4, arrival_s=t0 + i / rate))
    return reqs


def _sem_realized(r, truth_of):
    """Realized answer quality: an answer generated in another drift phase
    is outdated content regardless of who generated it."""
    tok = int(np.asarray(r.output)[0])
    member, gen_phase = tok % 10, tok // 10
    req_phase = 1 if ".p1." in r.text else 0
    if gen_phase != req_phase:
        return SEM_STALE_Q
    return float(truth_of[r.text][member])


def _run_semcache(corpus, reqs, lam, *, cache, drift=None):
    emb_of, pred_of, truth_of, _ = corpus
    eng = _SemCacheEngine(emb_of, pred_of, lam=lam)
    policy = CascadePolicy([0, 1, 2], CascadeConfig(max_legs=3),
                           reward="R2")
    coord = CascadeCoordinator(
        policy,
        observed_quality=lambda r: float(truth_of[r.text][r.member]))
    semcache = (SemanticCache(SEM_RADIUS, cap=64, policy=policy,
                              drift=drift) if cache else None)
    sched = MicroBatchScheduler(
        eng, SchedulerConfig(score_batch=16, max_batch=16),
        cascade=coord, semcache=semcache,
        service_time=lambda kind, n_, wall: 1e-3)
    sched.run_trace(reqs)
    quals = np.asarray([_sem_realized(r, truth_of) for r in reqs])
    p1 = np.asarray([".p1." in r.text for r in reqs])
    return {
        "quality": float(quals.mean()),
        "quality_p1": float(quals[p1].mean()) if p1.any() else float("nan"),
        "cost": float(sum(r.cum_cost for r in reqs)),
        "hit_rate": semcache.report()["hit_rate"] if cache else 0.0,
        "cache": semcache,
    }


def semcache_scenario() -> None:
    corpus = _sem_corpus(seed=0)
    emb_of, _, _, centers = corpus

    # -- frontier: cache-on must weakly dominate cache-off per lambda ------
    frontier_ok = True
    hit_rate_10 = 0.0
    for lam in (4.0, 10.0, 25.0):
        off = _run_semcache(corpus, _sem_requests(1, 160, 0), lam,
                            cache=False)
        on = _run_semcache(corpus, _sem_requests(1, 160, 0), lam,
                           cache=True)
        dom = (on["quality"] >= off["quality"] - 0.02
               and on["cost"] <= off["cost"] + 1e-6)
        frontier_ok &= dom
        if lam == 10.0:
            hit_rate_10 = on["hit_rate"]
        emit(f"serving/semcache/lam{lam:g}", on["hit_rate"] * 100,
             f"q_on={on['quality']:.3f} q_off={off['quality']:.3f} "
             f"cost_on={on['cost']:.1f} cost_off={off['cost']:.1f} "
             f"hit={on['hit_rate']:.2f}")
    gate("serving/semcache_hit_rate", hit_rate_10 >= 0.25,
         f"near-dup traffic served from cache: {hit_rate_10:.2f} "
         f"(floor 0.25, lam=10)")
    gate("serving/semcache_frontier", frontier_ok,
         "cache-on weakly dominates cache-off at every tested lambda "
         "(quality within 0.02, spend never higher)")

    # -- drift segment: invalidation must prevent the stale-cache cliff ---
    ref = np.stack([emb_of[f"c{j}.p0.v{k}"] for j in range(SEM_CLUSTERS)
                    for k in range(SEM_VARIANTS)])
    def drift_reqs():
        return (_sem_requests(2, 120, 0)
                + _sem_requests(3, 120, 1, t0=1.0))
    base = _run_semcache(corpus, drift_reqs(), 10.0, cache=False)
    inval = _run_semcache(
        corpus, drift_reqs(), 10.0, cache=True,
        drift=DriftDetector(window=8, patience=1).fit(ref, centers))
    stale = _run_semcache(corpus, drift_reqs(), 10.0, cache=True)
    emit("serving/semcache/drift", inval["quality_p1"],
         f"post-drift q: no-cache={base['quality_p1']:.3f} "
         f"invalidating={inval['quality_p1']:.3f} "
         f"stale={stale['quality_p1']:.3f} "
         f"(alarms={inval['cache'].drift.alarms}, "
         f"invalidated={inval['cache'].stats['invalidations']})")
    gate("serving/semcache_drift_recovery",
         inval["quality_p1"] >= base["quality_p1"] - 0.05,
         f"post-drift quality with invalidation {inval['quality_p1']:.3f} "
         f"within 0.05 of no-cache {base['quality_p1']:.3f}")
    gate("serving/semcache_stale_cliff",
         base["quality_p1"] - stale["quality_p1"] > 0.05,
         f"without invalidation the stale cache costs "
         f"{base['quality_p1'] - stale['quality_p1']:.3f} post-drift "
         f"quality — the cliff the detector hook prevents")
    headline("semcache_hit_rate", hit_rate_10, "served/lookups",
             direction="higher")


def main() -> None:
    engine, data, te = build_routed_engine(
        POOL, seed=0, epochs=40, n_traffic=600)

    for kind in ("poisson", "bursty"):
        trace = make_trace(
            TraceConfig(kind=kind, n_requests=N_REQUESTS, rate=1000.0,
                        seed=0, max_new=2, prompt_len_max=24, vocab=64),
            texts=[data.texts[i] for i in te],
            benchmarks=[data.benchmark[i] for i in te],
        )
        sched = MicroBatchScheduler(
            engine, SchedulerConfig(score_batch=32, max_batch=8))
        t0 = time.perf_counter()
        summary = sched.run_trace(trace)
        wall = time.perf_counter() - t0
        tel = sched.telemetry
        rps = summary["completed"] / wall
        us_routing = tel.routing_latency.mean / max(
            tel.scored_requests / tel.score_batches, 1) * 1e6
        emit(f"serving/{kind}/throughput", us_routing,
             f"rps={rps:.1f}")
        emit(f"serving/{kind}/routing_p50", us_routing,
             f"p50_ms={summary['routing_p50_ms']:.2f}")
        emit(f"serving/{kind}/routing_p99", us_routing,
             f"p99_ms={summary['routing_p99_ms']:.2f}")
        emit(f"serving/{kind}/mean_generate_batch", us_routing,
             f"batch={summary['mean_generate_batch']:.1f}")

    overhead_gate(data, te)
    semcache_scenario()


if __name__ == "__main__":
    main()
