"""Online adaptation benchmark (ours): regret vs. a frozen router under
domain drift with a pool-regime change.

Scenario: the serving trace's *content* drifts from one benchmark mixture
to another (`serving/traffic.py` drift), and on the drifted domain the
pool's relative strengths are **reversed** relative to what the offline
RouterBench snapshot taught (the cheap member is the strong one there) —
the RouteLLM argument that a frozen snapshot misprices a moving pool,
distilled to its sharpest case.

Both runs replay the identical seeded trace through the full queue ->
scheduler -> engine pipeline:

  * **frozen**  — the PR-1 static router, exactly as trained offline;
  * **online**  — same starting router + the `repro.online` adapter
    (replay buffer, drift detection, exploration, incremental updates).

Reported per run: mean *realized* reward R2(s_true, c_true; lam) over the
back half of the trace (the drifted regime), and the regret vs. the
realized-reward oracle. The acceptance gate is online > frozen on
back-half mean reward.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, gate
from repro.core.rewards import reward_exponential
from repro.launch.serve import build_routed_engine, pool_quality_columns
from repro.online import (
    DriftDetector,
    ExplorationConfig,
    OnlineAdapter,
    OnlineUpdateConfig,
)
from repro.serving import (
    MicroBatchScheduler,
    RoutedEngine,
    SchedulerConfig,
    TraceConfig,
    default_service_model,
    make_trace,
)

POOL = ["qwen3-0.6b", "granite-3-8b"]
N_REQUESTS = 192
# Willingness-to-pay on the scale of the pool's $/request rates: the
# expensive member must genuinely earn its cost premium, so correcting its
# overestimated quality on the drifted domain flips routing (with lam far
# above the cost scale, R2 degenerates to quality-argmax and only massive
# exploration could flip it).
LAM = 2e-3
SEED = 0


def _serving_truth(engine, data):
    """Per-text realized quality under the POST-change regime.

    Group-B benchmarks (the drift trace's late mixture — second half of
    the sorted benchmark names, mirroring traffic._drift_order) get their
    pool quality columns reversed: the world the router was trained on no
    longer holds there.
    """
    quality = data.quality[:, pool_quality_columns(engine.pool, data)]
    names = sorted(set(data.benchmark.tolist()))
    group_b = np.isin(data.benchmark, names[len(names) // 2:])
    truth = quality.copy()
    truth[group_b] = truth[group_b][:, ::-1]
    return {data.texts[i]: truth[i] for i in range(len(data.texts))}


def _run(engine, data, te, truth, *, online: bool):
    trace = make_trace(
        TraceConfig(kind="drift", n_requests=N_REQUESTS, rate=800.0,
                    seed=SEED, max_new=2, prompt_len_max=24,
                    vocab=min(m.cfg.vocab_size for m in engine.pool)),
        texts=[data.texts[i] for i in te],
        benchmarks=[data.benchmark[i] for i in te],
    )
    adapter = None
    if online:
        tr, _, _ = data.split(seed=SEED)
        adapter = OnlineAdapter(
            engine,
            lambda req: float(truth[req.text][req.member]),
            config=OnlineUpdateConfig(update_every=16, steps_per_update=16,
                                      burst_steps=48, batch_size=64),
            exploration=ExplorationConfig(epsilon=0.1, seed=SEED),
            drift=DriftDetector(window=48, threshold=3.0).fit(
                data.emb[tr], engine.router.centroids),
            seed=SEED,
        )
    sched = MicroBatchScheduler(
        engine, SchedulerConfig(score_batch=32, max_batch=8),
        service_time=default_service_model(), adapter=adapter)
    sched.run_trace(trace)

    order = sorted(trace, key=lambda r: r.arrival_s)
    cost_rates = np.asarray([m.cost_rate for m in engine.pool])
    rewards, regrets = [], []
    for r in order:
        s_row = truth[r.text]
        per_member = np.asarray(reward_exponential(
            np.asarray(s_row), cost_rates, LAM))
        achieved = float(per_member[r.member])
        rewards.append(achieved)
        regrets.append(float(per_member.max()) - achieved)
    half = len(order) // 2
    return {
        "mean_reward_back": float(np.mean(rewards[half:])),
        "mean_regret_back": float(np.mean(regrets[half:])),
        "mean_reward_full": float(np.mean(rewards)),
        "adapter": adapter,
    }


def main() -> None:
    # One offline training pays for both runs: routers are immutable and
    # online updates publish fresh trees via swap_router, so giving the
    # online engine the frozen engine's router object cannot leak mutated
    # state back into the frozen control (which also runs first).
    frozen_eng, data, te = build_routed_engine(
        POOL, seed=SEED, epochs=60, n_traffic=900, lam=LAM)
    online_eng = RoutedEngine(router=frozen_eng.router,
                              pool=frozen_eng.pool, lam=LAM)
    truth = _serving_truth(frozen_eng, data)

    frozen = _run(frozen_eng, data, te, truth, online=False)
    online = _run(online_eng, data, te, truth, online=True)

    emit("online/frozen/back_half_reward", 0.0,
         f"reward={frozen['mean_reward_back']:.4f}")
    emit("online/adapted/back_half_reward", 0.0,
         f"reward={online['mean_reward_back']:.4f}")
    emit("online/frozen/back_half_regret", 0.0,
         f"regret={frozen['mean_regret_back']:.4f}")
    emit("online/adapted/back_half_regret", 0.0,
         f"regret={online['mean_regret_back']:.4f}")
    ad = online["adapter"]
    emit("online/adapted/loop", 0.0,
         f"updates={int(ad.stats['updates'])}"
         f";alarms={int(ad.stats['drift_alarms'])}"
         f";router_version={ad.engine.router.version}")
    gain = online["mean_reward_back"] - frozen["mean_reward_back"]
    emit("online/gain/back_half_reward", 0.0, f"delta={gain:+.4f}")
    if not gate("online/adaptation_beats_frozen", gain > 0,
                f"back-half reward delta={gain:+.4f}"):
        raise SystemExit(
            "online adaptation failed to beat the frozen router "
            f"(delta={gain:+.4f})")


if __name__ == "__main__":
    main()
