"""Property-based tests for the serving telemetry ``Histogram`` (via the
``_hypothesis_compat`` shim: real hypothesis when installed, bounded
deterministic grid otherwise).

Property families:
  * percentile bounds and monotonicity — for any recorded stream,
    percentile(p) stays inside [min, max] and is non-decreasing in p;
  * under/overflow boundary behaviour — streams living entirely below
    edges[0] or above edges[-1] still span [min, max] across the
    percentile range instead of collapsing to one endpoint (the bug this
    file pins: the underflow bucket used to return ``min`` for every p,
    so an all-underflow histogram reported percentile(100) == min);
  * merge algebra — merging preserves count/total/min/max exactly,
    merging an empty histogram is an identity, and merge order doesn't
    change any percentile.
"""
import math

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.serving.telemetry import Histogram, exemplar_score


def _hist(values, **kw):
    h = Histogram(**kw)
    for v in values:
        h.record(float(v))
    return h


class TestPercentileInvariants:
    @given(st.lists(st.floats(1e-8, 1e5), min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_bounds(self, values):
        h = _hist(values)
        for p in (0, 1, 25, 50, 75, 99, 100):
            est = h.percentile(p)
            assert h.min <= est <= h.max

    @given(st.lists(st.floats(1e-8, 1e5), min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_monotone_in_p(self, values):
        h = _hist(values)
        ps = list(range(0, 101, 5))
        ests = [h.percentile(p) for p in ps]
        assert all(a <= b + 1e-12 for a, b in zip(ests, ests[1:]))

    @given(st.floats(1e-8, 1e5))
    @settings(max_examples=40, deadline=None)
    def test_single_value_is_exact(self, v):
        h = _hist([v])
        for p in (0, 50, 100):
            assert h.percentile(p) == v


class TestUnderOverflowBuckets:
    """Values outside [edges[0], edges[-1]] land in the open-ended
    under/overflow buckets, which interpolate against observed min/max."""

    def test_all_underflow_spans_min_max(self):
        # Every value below edges[0]=1e-6: percentile(100) must reach max.
        h = _hist([1e-9, 2e-9, 5e-8, 9e-7])
        assert h.percentile(100) == h.max == 9e-7
        assert h.percentile(0) == h.min == 1e-9
        assert h.min < h.percentile(50) <= h.max

    def test_all_overflow_spans_min_max(self):
        # Every value above edges[-1]=1e3.
        h = _hist([2e3, 5e3, 4e4, 9e5])
        assert h.percentile(0) == h.min == 2e3
        assert h.percentile(100) == h.max == 9e5
        assert h.min <= h.percentile(50) < h.max

    def test_nonpositive_values_underflow(self):
        # record() accepts any float; zero/negative values can only land
        # in the underflow bucket, where interpolation must fall back to
        # linear (log-interp needs positive bounds) and stay in bounds.
        h = _hist([-3.0, -1.0, 0.0, 0.5])
        for p in (0, 25, 50, 75, 100):
            assert h.min <= h.percentile(p) <= h.max
        assert h.percentile(0) == -3.0
        assert h.percentile(100) == 0.5

    @given(st.lists(st.floats(1e-9, 5e-7), min_size=1, max_size=40))
    @settings(max_examples=40, deadline=None)
    def test_underflow_monotone(self, values):
        h = _hist(values)
        ps = list(range(0, 101, 10))
        ests = [h.percentile(p) for p in ps]
        assert all(a <= b + 1e-20 for a, b in zip(ests, ests[1:]))
        assert ests[0] == h.min and ests[-1] == h.max


class TestMergeAlgebra:
    def test_empty_merge_identity(self):
        h = _hist([0.01, 0.2, 3.0])
        snap = (h.count, h.total, h.min, h.max, h.percentile(50))
        h.merge(Histogram())
        assert (h.count, h.total, h.min, h.max, h.percentile(50)) == snap

    def test_merge_into_empty(self):
        a, b = Histogram(), _hist([0.5, 0.7])
        a.merge(b)
        assert (a.count, a.min, a.max) == (2, 0.5, 0.7)
        # A merge of two empties keeps the empty sentinels and nan stats.
        e = Histogram()
        e.merge(Histogram())
        assert e.count == 0
        assert e.min == float("inf") and e.max == float("-inf")
        assert math.isnan(e.percentile(50)) and math.isnan(e.mean)

    @given(st.lists(st.floats(1e-7, 1e4), min_size=0, max_size=30),
           st.lists(st.floats(1e-7, 1e4), min_size=0, max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_merge_matches_combined_stream(self, xs, ys):
        a, b = _hist(xs), _hist(ys)
        a.merge(b)
        both = _hist(list(xs) + list(ys))
        assert a.count == both.count
        assert np.array_equal(a.counts, both.counts)
        assert a.min == both.min and a.max == both.max
        assert math.isclose(a.total, both.total, rel_tol=1e-12, abs_tol=1e-12)
        for p in (0, 50, 99, 100):
            pa, pb = a.percentile(p), both.percentile(p)
            assert (math.isnan(pa) and math.isnan(pb)) or pa == pb

    def test_merge_rejects_mismatched_edges(self):
        a = Histogram(n_buckets=10)
        b = Histogram(n_buckets=12)
        try:
            a.merge(b)
        except ValueError:
            return
        raise AssertionError("merge with different edges must raise")


def _hist_ex(pairs, **kw):
    h = Histogram(**kw)
    for v, k in pairs:
        h.record(float(v), exemplar=int(k))
    return h


class TestExemplars:
    """Prometheus-style bucket exemplars: the kept trace key per bucket is
    the one with the smallest deterministic min-hash score, so exemplar
    selection is a pure function of the recorded (value, key) SET —
    independent of arrival order and of how per-worker shards merge."""

    def test_score_is_pure_and_spread(self):
        for k in (0, 1, 7, 123456, 10**12):
            assert exemplar_score(k) == exemplar_score(k)
        assert len({exemplar_score(k) for k in range(256)}) == 256

    def test_min_score_wins_within_bucket(self):
        keys = list(range(16))
        best = min(keys, key=exemplar_score)
        h = Histogram()
        for k in keys:
            h.record(0.5, exemplar=k)     # one bucket, many candidates
        assert len(h.exemplars) == 1
        (_, kept, value), = h.exemplars.values()
        assert kept == best and value == 0.5

    def test_none_exemplar_records_nothing(self):
        h = Histogram()
        h.record(0.5)
        h.record(0.5, exemplar=None)
        assert h.exemplars == {} and h.count == 2

    @given(st.lists(st.tuples(st.floats(1e-8, 1e5), st.integers(0, 512)),
                    min_size=1, max_size=50))
    @settings(max_examples=50, deadline=None)
    def test_order_independent(self, pairs):
        a = _hist_ex(pairs)
        b = _hist_ex(list(reversed(pairs)))
        assert a.exemplars == b.exemplars

    @given(st.lists(st.tuples(st.floats(1e-8, 1e5), st.integers(0, 512)),
                    min_size=0, max_size=50),
           st.lists(st.tuples(st.floats(1e-8, 1e5), st.integers(0, 512)),
                    min_size=0, max_size=50))
    @settings(max_examples=40, deadline=None)
    def test_merge_matches_combined_stream(self, xs, ys):
        a, b = _hist_ex(xs), _hist_ex(ys)
        a.merge(b)
        both = _hist_ex(list(xs) + list(ys))
        assert a.exemplars == both.exemplars
        # and merge is commutative on the exemplar table
        c, d = _hist_ex(ys), _hist_ex(xs)
        c.merge(d)
        assert c.exemplars == a.exemplars

    def test_prometheus_emission(self):
        from repro.obs import MetricsRegistry

        h = _hist_ex([(0.5, 7), (2e4, 9)])   # interior + overflow bucket
        reg = MetricsRegistry()
        reg.histogram("e2e_latency_s", "end-to-end latency", hist=h)
        text = reg.prometheus()
        tagged = [ln for ln in text.splitlines() if "# {" in ln]
        assert any('trace_key="7"' in ln and "0.5" in ln for ln in tagged)
        # the overflow value rides the +Inf bucket line
        assert any('le="+Inf"' in ln and 'trace_key="9"' in ln
                   for ln in tagged)
        # exemplar-free buckets stay plain exposition lines
        assert any(ln.startswith("e2e_latency_s_bucket") and "#" not in ln
                   for ln in text.splitlines())
