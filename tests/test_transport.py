"""Transport layer: codec round-trips (property + adversarial), fault
injection vs. version fencing, partitions + converge repair, leader
crash re-election with fenced catch-up, the real socket transport across
threads (nested RPC, follower->follower forwarding, shutdown, lost
controller), and sharded-pool generate dispatch over the wire.

Workers reuse the stub-engine recipe from test_distributed (duplicated
here — tests are standalone modules, not a package), so everything is
CPU-fast; real-process socket coverage lives in tools/distributed_smoke.
"""
import dataclasses
import hashlib
import threading

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.predictors import PREDICTORS
from repro.core.router import PredictiveRouter
from repro.distributed import Coordinator, SyncConfig, WorkerNode
from repro.distributed import messages as M
from repro.distributed.messages import Message, decode, encode
from repro.distributed.shard import (
    PoolDispatcher,
    owned_members,
    owner_of,
)
from repro.distributed.transport import (
    FaultyTransport,
    LocalTransport,
    RpcStats,
    SocketTransport,
    TransportError,
)
from repro.obs import TraceRecorder, build_trace_doc, validate_span_tree
from repro.online import OnlineAdapter, OnlineUpdateConfig
from repro.serving import (
    MicroBatchScheduler,
    Request,
    RoutedEngine,
    SchedulerConfig,
    default_service_model,
)
from repro.serving.scheduler import SimClock
from repro.serving.telemetry import Telemetry

DQ, K, DM = 16, 2, 4
COSTS = (0.2, 1.0)


def _text_emb(text: str) -> np.ndarray:
    h = int.from_bytes(hashlib.blake2s(text.encode(), digest_size=4).digest(),
                       "little")
    e = np.random.default_rng(h).normal(0, 1, DQ).astype(np.float32)
    return e / np.linalg.norm(e)


@dataclasses.dataclass
class StubEngine(RoutedEngine):
    def embed(self, texts):
        return np.stack([_text_emb(t) for t in texts])


class StubGenMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate

    def generate(self, prompts, max_new=8, attn_mask=None):
        return np.zeros((int(np.asarray(prompts).shape[0]), max_new),
                        np.int32)


def _truth(text: str, member: int) -> float:
    h = int.from_bytes(
        hashlib.blake2s(f"{text}|{member}".encode(),
                        digest_size=4).digest(), "little")
    return (h % 1000) / 999.0


def make_router(seed=0):
    rng = np.random.default_rng(seed)
    memb = rng.random((K, DM)).astype(np.float32)
    qp = PREDICTORS["attn"].init(jax.random.key(seed), DQ, K, DM)
    cp = {"w": np.zeros((DQ, K), np.float32),
          "b": np.asarray(COSTS, np.float32)}
    return PredictiveRouter("attn", "reg", qp, cp, memb, reward="R2")


def make_workers(n_workers=3, seed=0):
    router = make_router(seed)
    pool = [StubGenMember(f"m{i}", c) for i, c in enumerate(COSTS)]
    workers = []
    for wid in range(n_workers):
        engine = StubEngine(router=router, pool=pool, lam=2.0)
        adapter = OnlineAdapter(
            engine, lambda req: _truth(req.text, req.member),
            config=OnlineUpdateConfig(min_buffer=8, batch_size=16),
            defer_updates=True, seed=seed + 7 * wid + 1)
        sched = MicroBatchScheduler(
            engine,
            SchedulerConfig(score_batch=8, max_batch=4, max_wait_s=0.005,
                            queue_capacity=64),
            clock=SimClock(), service_time=default_service_model(),
            adapter=adapter)
        workers.append(WorkerNode(wid, engine, sched, adapter))
    return workers


def feed_outcomes(worker, n=40, seed=0, now=0.0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        r = Request(text=f"direct {i}", prompt=np.zeros(1, np.int32))
        r.q_emb = rng.normal(0, 1, DQ).astype(np.float32)
        r.member = int(rng.integers(K))
        r.cost = COSTS[r.member]
        r.status = "done"
        reqs.append(r)
    worker.adapter.observe(reqs, now)


def roundtrip(payload, kind="PING"):
    msg = Message(kind=kind, dst=3, src=1, seq=42, payload=payload)
    return decode(encode(msg)).payload


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------


class TestCodecRoundTrip:
    @settings(max_examples=40)
    @given(st.integers(-2**70, 2**70))
    def test_ints(self, n):
        assert roundtrip({"v": n})["v"] == n

    @settings(max_examples=40)
    @given(st.floats(-1e300, 1e300))
    def test_floats(self, x):
        got = roundtrip({"v": x})["v"]
        assert got == x and isinstance(got, float)

    @settings(max_examples=40)
    @given(st.text(max_size=40))
    def test_text(self, s):
        assert roundtrip({"v": s})["v"] == s

    @settings(max_examples=20)
    @given(st.lists(st.tuples(st.integers(-100, 100),
                              st.floats(-10.0, 10.0)),
                    max_size=6))
    def test_nested_containers(self, items):
        payload = {"items": items, "meta": {"n": len(items),
                                            "tags": ("a", "b")}}
        got = roundtrip(payload)
        assert got["items"] == items          # tuples stay tuples
        assert got["meta"] == {"n": len(items), "tags": ("a", "b")}

    def test_special_floats_and_bytes(self):
        p = roundtrip({"nan": float("nan"), "inf": float("inf"),
                       "ninf": float("-inf"), "blob": b"\x00\xffraw"})
        assert np.isnan(p["nan"])
        assert p["inf"] == float("inf") and p["ninf"] == float("-inf")
        assert p["blob"] == b"\x00\xffraw"

    def test_bool_none_set(self):
        p = roundtrip({"t": True, "f": False, "n": None, "s": {3, 1, 2}})
        assert p["t"] is True and p["f"] is False and p["n"] is None
        assert p["s"] == {1, 2, 3} and isinstance(p["s"], set)

    @pytest.mark.parametrize("arr", [
        np.arange(12, dtype=np.float32).reshape(3, 4) / 7,
        np.asarray([-2**40, 0, 2**40], np.int64),
        np.asarray([True, False, True]),
        np.asarray([np.nan, np.inf, -np.inf, 1.5], np.float64),
        np.zeros((0, 3), np.float32),
    ])
    def test_ndarray_exact(self, arr):
        got = roundtrip({"a": arr})["a"]
        assert got.dtype == arr.dtype and got.shape == arr.shape
        np.testing.assert_array_equal(got, arr)

    def test_non_contiguous_array_roundtrips(self):
        arr = np.arange(24, dtype=np.float32).reshape(4, 6)[:, ::2]
        np.testing.assert_array_equal(roundtrip({"a": arr})["a"], arr)

    def test_jax_array_degrades_to_numpy(self):
        arr = jax.numpy.arange(6, dtype=jax.numpy.float32)
        got = roundtrip({"a": arr})["a"]
        assert isinstance(got, np.ndarray)
        np.testing.assert_array_equal(got, np.arange(6, dtype=np.float32))

    def test_object_dtype_rejected(self):
        with pytest.raises(TypeError):
            encode(Message(kind="X", dst=0,
                           payload={"a": np.asarray([object()])}))

    def test_message_fields(self):
        msg = Message(kind=M.SYNC_STATUS, dst=2, src=7, seq=9000001,
                      reply_to=13, expect_reply=True, payload={"k": 1})
        got = decode(encode(msg))
        assert (got.kind, got.dst, got.src, got.seq) == \
            (M.SYNC_STATUS, 2, 7, 9000001)
        assert got.reply_to == 13 and got.expect_reply is True
        assert got.payload == {"k": 1}

    def test_bad_magic_rejected(self):
        buf = bytearray(encode(Message(kind="X", dst=0)))
        buf[0] ^= 0xFF
        with pytest.raises(ValueError):
            decode(bytes(buf))

    def test_version_mismatch_rejected(self):
        buf = bytearray(encode(Message(kind="X", dst=0)))
        buf[len(M.MAGIC)] = M.PROTOCOL_VERSION + 1
        with pytest.raises(ValueError):
            decode(bytes(buf))

    def test_truncated_frame_rejected(self):
        buf = encode(Message(kind="X", dst=0, payload={"a": 1}))
        with pytest.raises(ValueError):
            decode(buf[:-2])

    def test_router_adapter_roundtrip(self):
        router = make_router(4)
        got = roundtrip({"router": router})["router"]
        assert got.version == router.version
        assert got.quality_kind == router.quality_kind
        jax.tree.map(np.testing.assert_array_equal,
                     jax.tree.map(np.asarray, got.quality_params),
                     jax.tree.map(np.asarray, router.quality_params))
        np.testing.assert_array_equal(np.asarray(got.model_emb),
                                      np.asarray(router.model_emb))

    def test_request_adapter_roundtrip(self):
        r = Request(text="hello", prompt=np.arange(5, dtype=np.int32),
                    max_new=3, arrival_s=0.25)
        r.member, r.cost, r.status = 1, 0.5, "done"
        got = roundtrip({"req": r})["req"]
        assert got.text == "hello" and got.member == 1
        assert got.cost == 0.5 and got.status == "done"
        np.testing.assert_array_equal(got.prompt, r.prompt)

    def test_telemetry_adapter_roundtrip(self):
        tel = Telemetry(["m0", "m1"])
        got = roundtrip({"tel": tel})["tel"]
        assert isinstance(got, Telemetry)
        assert got.member_names == tel.member_names


class TestTraceContextFrames:
    """Protocol v2: frames optionally carry (trace_key, parent_span)."""

    def test_trace_context_roundtrip(self):
        msg = Message(kind=M.GENERATE, dst=1, src=0, seq=77,
                      trace_key=123, parent_span=4, payload={"x": 1})
        got = decode(encode(msg))
        assert got.trace_key == 123 and got.parent_span == 4
        assert got.payload == {"x": 1}

    def test_absent_trace_context_decodes_to_none(self):
        got = decode(encode(Message(kind=M.STEP, dst=1)))
        assert got.trace_key is None and got.parent_span is None

    def test_version_bumped_to_two(self):
        # The trace-context fields rode a frame version bump: a v1 peer
        # fails the version check up front instead of mis-parsing the new
        # fields. Simulated symmetrically — a v1 frame against this (v2)
        # decoder is the same fencing the old decoder applies to ours.
        assert M.PROTOCOL_VERSION == 2
        buf = bytearray(encode(Message(kind="X", dst=0, trace_key=5)))
        buf[len(M.MAGIC)] = 1
        with pytest.raises(ValueError):
            decode(bytes(buf))

    def test_rpc_span_kind_policy(self):
        # Real request/reply protocol legs trace; the hot NEXT_ACTION poll
        # and the obs drains themselves stay unspanned (they would dwarf
        # and recursively observe the traffic they measure).
        for kind in (M.GENERATE, M.STEP, M.SYNC_STATUS, M.LEDGER_OP,
                     M.ASSIGN, M.TICK, M.FINALIZE):
            assert kind in M.RPC_SPAN_KINDS
        for kind in (M.NEXT_ACTION, M.TRACE_REQ, M.TELEMETRY_REQ,
                     M.METRICS_REQ, M.HELLO, M.SHUTDOWN):
            assert kind not in M.RPC_SPAN_KINDS


class TestRpcTelemetry:
    def test_request_counts_latency_and_client_span(self):
        lt = LocalTransport()
        lt.bind(1, lambda msg: {"ok": 1})
        rec = TraceRecorder()
        lt.tracer = rec
        lt.now = 2.5
        lt.request(Message(kind=M.STEP, dst=1, payload={"t": 0.1}))
        s = lt.stats
        assert s.requests == {M.STEP: 1}
        assert s.peer_requests == {1: 1}
        assert s.in_flight == 0 and s.unreachable == 0
        assert s.latency[M.STEP].count == 1
        assert s.merged_latency().count == 1
        spans = [e for e in rec.events if e[0] == "rpc"]
        assert len(spans) == 1
        name, cat, ph, ts, dur, wid, key, args = spans[0]
        assert (cat, ph, wid, key) == ("rpc", "X", 0, None)
        assert ts == 2.5                      # virtual stamp, not wall
        assert args["side"] == "client" and args["peer"] == 1
        assert args["kind"] == M.STEP and args["rpc"] == 1

    def test_unspanned_kind_counts_but_emits_no_span(self):
        lt = LocalTransport()
        lt.bind(1, lambda msg: {})
        rec = TraceRecorder()
        lt.tracer = rec
        lt.request(Message(kind=M.NEXT_ACTION, dst=1))
        assert lt.stats.requests == {M.NEXT_ACTION: 1}
        assert not [e for e in rec.events if e[0] == "rpc"]

    def test_unreachable_failure_counted_no_span(self):
        lt = LocalTransport()
        rec = TraceRecorder()
        lt.tracer = rec
        with pytest.raises(TransportError):
            lt.request(Message(kind=M.STEP, dst=9))
        assert lt.stats.unreachable == 1
        assert lt.stats.requests == {}        # only completed RPCs count
        assert not rec.events                 # no span for a failed call

    def test_failure_classification(self):
        s = RpcStats()
        s.note_failure(TransportError("request to w1 timed out"))
        s.note_failure(TransportError("remote handler failed: boom"))
        s.note_failure(TransportError("no endpoint bound for wid 9"))
        assert (s.timeouts, s.errors, s.unreachable) == (1, 1, 1)

    def test_server_span_pairs_with_client_span(self):
        w = make_workers(1)[0]
        lt = LocalTransport()
        w.bind(lt)
        rec = TraceRecorder()
        lt.tracer = rec
        lt.trace_wid = 5                      # a distinct client process
        lt.now = 1.0
        w.scheduler.tracer = rec.scoped(0)
        lt.request(Message(kind=M.SYNC_STATUS, dst=0, src=5))
        spans = [e for e in rec.events if e[0] == "rpc"]
        sides = {e[7]["side"]: e for e in spans}
        assert set(sides) == {"client", "server"}
        assert sides["client"][7]["rpc"] == sides["server"][7]["rpc"]
        assert sides["client"][5] == 5 and sides["server"][5] == 0
        doc = build_trace_doc(rec.events)
        assert validate_span_tree(doc) == []

    def test_dangling_client_link_fails_validation(self):
        rec = TraceRecorder()
        rec.span("rpc", "rpc", 0.0, 0.1, wid=1,
                 args={"rpc": 99, "kind": M.STEP, "side": "client",
                       "peer": 0})
        errs = validate_span_tree(build_trace_doc(rec.events))
        assert errs and any("rpc" in e for e in errs)
        # An unmatched SERVER span is fine (the reply can be lost in
        # transit after the handler ran) — only client links must pair.
        rec2 = TraceRecorder()
        rec2.span("rpc", "rpc", 0.0, 0.1, wid=0,
                  args={"rpc": 99, "kind": M.STEP, "side": "server",
                        "peer": 1})
        assert validate_span_tree(build_trace_doc(rec2.events)) == []


# ---------------------------------------------------------------------------
# Local + faulty transports vs. version fencing
# ---------------------------------------------------------------------------


class TestLocalTransport:
    def test_request_reaches_bound_handler(self):
        lt = LocalTransport()
        lt.bind(1, lambda msg: {"echo": msg.payload["x"] + 1})
        rep = lt.request(Message(kind="PING", dst=1, payload={"x": 41}))
        assert rep.kind == M.ACK and rep.payload == {"echo": 42}

    def test_unbound_destination_raises(self):
        with pytest.raises(TransportError):
            LocalTransport().request(Message(kind="PING", dst=9))

    def test_handler_exception_propagates_raw(self):
        lt = LocalTransport()

        def boom(msg):
            raise KeyError("inner detail")

        lt.bind(0, boom)
        with pytest.raises(KeyError):
            lt.request(Message(kind="PING", dst=0))


class TestFaultInjection:
    def _bound_worker(self, **faults):
        w = make_workers(1)[0]
        ft = FaultyTransport(LocalTransport(), **faults)
        w.bind(ft)
        return w, ft

    def test_dropped_broadcasts_are_tolerated(self):
        w, ft = self._bound_worker(seed=0, drop=1.0)
        r2 = dataclasses.replace(w.engine.router, version=2)
        ft.send(Message(kind=M.ROUTER_BCAST, dst=0, payload={"router": r2}))
        assert ft.stats["dropped"] == 1
        assert w.router_version == 0          # lost, not applied
        # The reliable request path still works — and fencing lets a later
        # newer broadcast repair the miss.
        rep = ft.request(Message(kind=M.ROUTER_BCAST, dst=0,
                                 payload={"router": r2}))
        assert rep.payload["accepted"] and w.router_version == 2

    def test_duplicate_broadcast_applies_once(self):
        w, ft = self._bound_worker(seed=1, dup=1.0)
        r2 = dataclasses.replace(w.engine.router, version=2)
        ft.send(Message(kind=M.ROUTER_BCAST, dst=0, payload={"router": r2}))
        assert ft.stats["duplicated"] == 1
        assert w.router_version == 2
        assert w.swaps_accepted == 1 and w.swaps_rejected == 1

    def test_reordered_broadcasts_never_roll_back(self):
        for seed in range(6):                 # both flush orders occur
            w, ft = self._bound_worker(seed=seed, reorder=1.0)
            r1 = dataclasses.replace(w.engine.router, version=1)
            r2 = dataclasses.replace(w.engine.router, version=2)
            ft.send(Message(kind=M.ROUTER_BCAST, dst=0,
                            payload={"router": r1}))
            ft.send(Message(kind=M.ROUTER_BCAST, dst=0,
                            payload={"router": r2}))
            assert w.router_version == 0      # both held
            ft.flush()
            assert w.router_version == 2      # fencing beats delivery order


# ---------------------------------------------------------------------------
# Partition, converge repair, leader crash re-election
# ---------------------------------------------------------------------------


class PartitionedTransport(LocalTransport):
    """LocalTransport where a set of wids is unreachable."""

    def __init__(self):
        super().__init__()
        self.blocked = set()

    def _deliver(self, msg):
        if msg.dst in self.blocked:
            raise TransportError(f"w{msg.dst} partitioned")
        return super()._deliver(msg)


class TestPartitionAndElection:
    def _fleet(self, n=3, seed=0):
        workers = make_workers(n, seed=seed)
        pt = PartitionedTransport()
        for w in workers:
            w.bind(pt)
        coord = Coordinator(workers, SyncConfig(
            merge_per_worker=16, steps_per_sync=4, min_buffer=8, seed=seed),
            transport=pt)
        return workers, pt, coord

    def test_partition_during_sync_counts_unreachable(self):
        workers, pt, coord = self._fleet()
        for w in workers:
            feed_outcomes(w, n=30, seed=30 + w.wid)
        pt.blocked = {2}
        router = coord.sync_round(0.1)
        assert router is not None
        assert coord.stats["unreachable"] > 0
        assert workers[0].router_version == router.version
        assert workers[1].router_version == router.version
        assert workers[2].router_version == 0          # behind the wall

    def test_heal_then_converge_repairs_versions(self):
        workers, pt, coord = self._fleet()
        for w in workers:
            feed_outcomes(w, n=30, seed=30 + w.wid)
        pt.blocked = {2}
        router = coord.sync_round(0.1)
        pt.blocked = set()
        coord.converge()
        assert {w.router_version for w in workers} == {router.version}

    def test_converge_is_version_fenced(self):
        """catch_up on an already-current worker must not re-broadcast."""
        workers, pt, coord = self._fleet()
        for w in workers:
            feed_outcomes(w, n=30, seed=30 + w.wid)
        coord.sync_round(0.1)
        before = [(w.swaps_accepted, w.swaps_rejected) for w in workers]
        coord.converge()
        # Nobody re-receives the router they already hold.
        assert [(w.swaps_accepted, w.swaps_rejected)
                for w in workers] == before

    def test_leader_crash_reelection_and_fenced_catch_up(self):
        workers, pt, coord = self._fleet()
        for w in workers:
            feed_outcomes(w, n=30, seed=30 + w.wid)
        r1 = coord.sync_round(0.1)
        assert coord.leader is workers[0]
        # Leader crashes AND partitions away mid-run.
        workers[0].alive = False
        pt.blocked = {0}
        for w in workers[1:]:
            feed_outcomes(w, n=20, seed=90 + w.wid, now=0.2)
        r2 = coord.sync_round(0.2)
        assert r2 is not None and r2.version > r1.version
        assert coord.leader is workers[1]
        assert coord.stats["leader_changes"] >= 1
        assert workers[0].router_version == r1.version  # missed the epoch
        # Heal + catch up before marking alive (the plane's rejoin order:
        # the surviving leader is still authoritative while the returning
        # worker is down). The catch-up is version-fenced: it lands
        # exactly on the leader's version, and repeating it is a no-op.
        pt.blocked = set()
        coord.catch_up(workers[0])
        assert workers[0].router_version == r2.version
        before = (workers[0].swaps_accepted, workers[0].swaps_rejected)
        coord.catch_up(workers[0])
        assert (workers[0].swaps_accepted,
                workers[0].swaps_rejected) == before
        workers[0].alive = True
        assert coord.leader is workers[0]     # lowest alive id leads again


# ---------------------------------------------------------------------------
# Socket transport across real OS threads
# ---------------------------------------------------------------------------


def _start_follower(wid, port, handler, errors):
    """Connect + serve a follower SocketTransport on its own thread."""
    t = SocketTransport(wid, timeout=20.0)
    t.bind(wid, handler)
    ready = threading.Event()

    def run():
        try:
            t.connect(port, hello_payload={"pid": 1000 + wid})
            ready.set()
            t.serve_forever()
        except TransportError as exc:
            errors[wid] = exc
        finally:
            ready.set()
            t.close()

    th = threading.Thread(target=run, daemon=True)
    th.start()
    return t, th, ready


class TestSocketTransport:
    def test_hello_rpc_forwarding_and_shutdown(self):
        ctrl = SocketTransport(0, timeout=20.0)
        port = ctrl.listen()
        state = {"ticks": 0}
        ctrl.bind(0, lambda msg: {"ctrl": msg.payload.get("x", 0) * 10})
        errors = {}

        def w1_handler(msg):
            if msg.kind == "RELAY":
                # Nested RPC mid-handling: w1 -> w2 hops through the
                # controller while the controller itself is blocked
                # waiting on this very reply.
                rep = t1.request(Message(kind="PING", dst=2,
                                         payload={"x": msg.payload["x"]}))
                return {"via": rep.payload["sq"]}
            if msg.kind == "ASKCTRL":
                rep = t1.request(Message(kind="PING", dst=0,
                                         payload={"x": 7}))
                return {"ctrl": rep.payload["ctrl"]}
            state["ticks"] += 1
            return {}

        def w2_handler(msg):
            return {"sq": msg.payload["x"] ** 2}

        t1, th1, _ = _start_follower(1, port, w1_handler, errors)
        t2, th2, _ = _start_follower(2, port, w2_handler, errors)
        try:
            hellos = ctrl.accept(2, timeout=20.0)
            assert {w: h["pid"] for w, h in hellos.items()} == \
                {1: 1001, 2: 1002}

            # Direct RPC controller -> follower.
            rep = ctrl.request(Message(kind="PING", dst=2, payload={"x": 6}))
            assert rep.payload == {"sq": 36}
            # Nested follower -> follower (forwarded by the controller).
            rep = ctrl.request(Message(kind="RELAY", dst=1, payload={"x": 5}))
            assert rep.payload == {"via": 25}
            # Nested follower -> controller (serviced mid-roundtrip).
            rep = ctrl.request(Message(kind="ASKCTRL", dst=1))
            assert rep.payload == {"ctrl": 70}

            # One-way send is fire-and-forget; confirm via a later request.
            ctrl.send(Message(kind=M.TICK, dst=1))
            ctrl.request(Message(kind=M.TICK, dst=1))
            assert state["ticks"] == 2
        finally:
            for wid in (1, 2):
                try:
                    ctrl.request(Message(kind=M.SHUTDOWN, dst=wid))
                except TransportError:
                    pass
            th1.join(timeout=10.0)
            th2.join(timeout=10.0)
            ctrl.close()
        assert not th1.is_alive() and not th2.is_alive()
        assert errors == {}                   # clean SHUTDOWN, no degrade

    def test_remote_handler_error_surfaces_as_transport_error(self):
        ctrl = SocketTransport(0, timeout=20.0)
        port = ctrl.listen()
        errors = {}

        def bad_handler(msg):
            if msg.kind == "BOOM":
                raise ValueError("follower exploded")
            return {}

        t1, th1, _ = _start_follower(1, port, bad_handler, errors)
        try:
            ctrl.accept(1, timeout=20.0)
            with pytest.raises(TransportError, match="follower exploded"):
                ctrl.request(Message(kind="BOOM", dst=1))
            # The connection survives an application error.
            assert ctrl.request(Message(kind="OK", dst=1)).kind == M.ACK
        finally:
            try:
                ctrl.request(Message(kind=M.SHUTDOWN, dst=1))
            except TransportError:
                pass
            th1.join(timeout=10.0)
            ctrl.close()

    def test_lost_controller_raises_in_serve_forever(self):
        ctrl = SocketTransport(0, timeout=20.0)
        port = ctrl.listen()
        errors = {}
        t1, th1, ready = _start_follower(1, port, lambda msg: {}, errors)
        try:
            ctrl.accept(1, timeout=20.0)
            ready.wait(timeout=10.0)
            ctrl.drop_connection(1)
            th1.join(timeout=10.0)
            assert not th1.is_alive()
            assert isinstance(errors.get(1), TransportError)
        finally:
            ctrl.close()

    def test_connect_refused_after_retries(self):
        t = SocketTransport(3, timeout=1.0)
        sacrificial = SocketTransport(0, timeout=1.0)
        port = sacrificial.listen()
        sacrificial.close()                   # nobody listening any more
        t.CONNECT_RETRIES = 2
        with pytest.raises(TransportError):
            t.connect(port)


# ---------------------------------------------------------------------------
# Sharded pool dispatch
# ---------------------------------------------------------------------------


class TestPoolDispatch:
    def test_owner_layout_round_robin(self):
        assert [owner_of(mi, 2) for mi in range(4)] == [0, 1, 0, 1]
        assert owned_members(0, 5, 2) == [0, 2, 4]
        assert owned_members(1, 5, 2) == [1, 3]
        assert owned_members(2, 2, 3) == []   # more workers than members

    def _pair(self):
        workers = make_workers(2, seed=6)
        lt = LocalTransport()
        for w in workers:
            w.bind(lt)
        disp = PoolDispatcher(0, 2, workers[0].engine, lt)
        prompts = [np.arange(4, dtype=np.int32),
                   np.arange(7, dtype=np.int32) % 9]
        return workers, disp, prompts

    def test_remote_generate_matches_local(self):
        workers, disp, prompts = self._pair()
        want_outs, want_costs = workers[1].engine.generate_member(
            1, prompts, max_new=4)
        outs, costs = disp.generate_member(1, prompts, max_new=4)
        assert disp.stats == {"local": 0, "remote": 1}
        np.testing.assert_array_equal(np.asarray(costs),
                                      np.asarray(want_costs))
        for got, want in zip(outs, want_outs):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_owned_member_stays_local(self):
        workers, disp, prompts = self._pair()
        outs, costs = disp.generate_member(0, prompts, max_new=4)
        assert disp.stats == {"local": 1, "remote": 0}
        assert len(outs) == len(prompts) and costs.shape == (2,)

    def test_per_request_caps_cross_the_wire(self):
        workers, disp, prompts = self._pair()
        want_outs, want_costs = workers[1].engine.generate_member(
            1, prompts, max_new=4, max_new_per_req=[1, 3])
        outs, costs = disp.generate_member(1, prompts, max_new=4,
                                           max_new_per_req=[1, 3])
        np.testing.assert_array_equal(np.asarray(costs),
                                      np.asarray(want_costs))
        for got, want in zip(outs, want_outs):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_unreachable_owner_raises_transport_error(self):
        workers = make_workers(2, seed=6)
        lt = LocalTransport()
        workers[0].bind(lt)                   # w1 never binds
        disp = PoolDispatcher(0, 2, workers[0].engine, lt)
        with pytest.raises(TransportError):
            disp.generate_member(1, [np.arange(3, dtype=np.int32)])
