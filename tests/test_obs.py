"""Observability plane tests: trace recorder + validators, metrics
registry + exporters, kernel profiler, and the traced serving scheduler
(single worker and shared-recorder multi-worker views).

The determinism contract under test everywhere: virtual-clock timestamps
and recorder-assigned trace keys only, wall-clock confined to WALL_CATS,
canonical JSON — so a seeded run's exported trace and deterministic
metrics snapshot are byte-identical across replays.
"""
import json

import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.obs import (
    KernelProfiler,
    MetricsRegistry,
    MetricsServer,
    TraceRecorder,
    WALL_CATS,
    register_scheduler_metrics,
    request_trees,
    trace_summary,
    validate_chrome_trace,
    validate_span_tree,
)
from repro.serving import MicroBatchScheduler, Request, SchedulerConfig


def req(text="q", arrival=0.0, deadline=None, n_prompt=4, max_new=2):
    return Request(text=text, prompt=np.zeros(n_prompt, np.int32),
                   max_new=max_new, arrival_s=arrival, deadline_s=deadline)


class FakeMember:
    def __init__(self, name, cost_rate):
        self.name = name
        self.cost_rate = cost_rate


class FakeEngine:
    """Static-score engine (no router) — exercises the tracer's stub-engine
    guards alongside the span plumbing."""

    def __init__(self, cost_rates=(1.0, 10.0), quality=(0.5, 1.0)):
        self.pool = [FakeMember(f"m{i}", c) for i, c in enumerate(cost_rates)]
        self.quality = np.asarray(quality, np.float64)
        self.lam = 100.0

    def score_texts(self, texts):
        b = len(texts)
        s = np.tile(self.quality, (b, 1))
        c = np.tile([m.cost_rate for m in self.pool], (b, 1))
        return s, c

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8):
        outs = [np.zeros(max_new, np.int32) for _ in prompts]
        return outs, self.pool[mi].cost_rate * len(prompts)


def run_traced_sched(n=12):
    rec = TraceRecorder(label="test")
    # Fixed virtual service times: with service_time=None the clock would
    # advance by measured wall time and the trace could not replay
    # bit-identically.
    sched = MicroBatchScheduler(
        FakeEngine(), SchedulerConfig(score_batch=4, max_batch=4),
        service_time=lambda kind, n_, wall: 1e-3,
        tracer=rec.scoped(0))
    reqs = [req(text=str(i), arrival=i * 1e-3) for i in range(n)]
    summary = sched.run_trace(reqs)
    return rec, sched, summary


# ---------------------------------------------------------------------------
# TraceRecorder
# ---------------------------------------------------------------------------

class TestTraceRecorder:
    def test_chrome_export_structure(self):
        rec = TraceRecorder(label="unit")
        rec.instant("admit", "queue", 0.001, key=rec.next_key())
        rec.span("request", "request", 0.001, 0.005, key=0,
                 args={"status": "done"})
        rec.span("score_batch", "sched", 0.002, 0.003)
        doc = rec.chrome_trace()
        assert validate_chrome_trace(doc) == []
        evs = [e for e in doc["traceEvents"] if e.get("ph") != "M"]
        meta = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
        assert len(evs) == 3 and len(meta) == 1
        # ts in microseconds; request-scoped events on tid key+1, runtime
        # scope on tid 0.
        admit = next(e for e in evs if e["name"] == "admit")
        assert admit["ts"] == pytest.approx(1000.0) and admit["tid"] == 1
        batch = next(e for e in evs if e["name"] == "score_batch")
        assert batch["tid"] == 0
        root = next(e for e in evs if e["name"] == "request")
        assert root["dur"] == pytest.approx(4000.0)

    def test_wall_categories_excluded_from_deterministic_export(self):
        rec = TraceRecorder()
        rec.span("kernel:pairwise_l2", "kernel", 0.0, 0.1)
        rec.instant("admit", "queue", 0.0, key=rec.next_key())
        assert "kernel" in WALL_CATS
        names = {e["name"] for e in rec.chrome_trace()["traceEvents"]
                 if e.get("ph") != "M"}
        assert names == {"admit"}
        names_wall = {e["name"]
                      for e in rec.chrome_trace(include_wall=True)
                      ["traceEvents"] if e.get("ph") != "M"}
        assert "kernel:pairwise_l2" in names_wall

    def test_ensure_key_dense_admission_order(self):
        rec = TraceRecorder()
        reqs = [req(text=str(i)) for i in range(3)]
        assert [rec.ensure_key(r) for r in reqs] == [0, 1, 2]
        # Idempotent on re-sight (cascade re-admission).
        assert rec.ensure_key(reqs[1]) == 1
        assert rec._next_key == 3

    def test_canonical_json_byte_stable(self):
        def build():
            rec = TraceRecorder(label="x")
            rec.instant("a", "queue", 0.25, key=rec.next_key(),
                        args={"depth": 3})
            rec.span("b", "sched", 0.25, 0.5)
            return rec.to_json()
        assert build() == build()

    def test_scoped_views_share_one_log(self):
        rec = TraceRecorder()
        w0, w1 = rec.scoped(0), rec.scoped(1)
        k = rec.next_key()
        w0.instant("admit", "queue", 0.0, key=k)
        w1.span("leg", "request", 0.1, 0.2, key=k)
        doc = rec.chrome_trace()
        pids = {e["pid"] for e in doc["traceEvents"] if e.get("ph") != "M"}
        assert pids == {0, 1}
        # Both workers' events land in one request tree (same tid).
        trees = request_trees(doc)
        assert len(trees) == 1 and len(trees[k + 1]["events"]) == 2
        # Process metadata for both workers.
        meta_pids = {e["pid"] for e in doc["traceEvents"]
                     if e.get("ph") == "M"}
        assert meta_pids == {0, 1}

    def test_merge_rebases_keys(self):
        a, b = TraceRecorder(), TraceRecorder()
        ra, rb = req(text="a"), req(text="b")
        a.instant("admit", "queue", 0.0, key=a.ensure_key(ra))
        b.instant("admit", "queue", 0.0, key=b.ensure_key(rb))
        a.merge(b)
        keys = sorted(e[6] for e in a.events)
        assert keys == [0, 1]
        assert a._next_key == 2


# ---------------------------------------------------------------------------
# Validators
# ---------------------------------------------------------------------------

class TestValidators:
    def test_schema_catches_malformed_events(self):
        doc = {"traceEvents": [
            {"name": "x", "cat": "c", "ph": "X", "ts": 0.0, "pid": 0,
             "tid": 1},                                   # X without dur
            {"name": "y", "cat": "c", "ph": "Z", "ts": 0.0, "pid": 0,
             "tid": 0},                                   # unknown ph
            {"cat": "c", "ph": "i", "ts": 0.0, "pid": 0, "tid": 0},  # no name
        ]}
        problems = validate_chrome_trace(doc)
        assert len(problems) >= 3

    def test_span_tree_catches_leg_outside_root(self):
        rec = TraceRecorder()
        k = rec.next_key()
        rec.instant("admit", "queue", 0.0, key=k)
        rec.span("request", "request", 0.0, 0.1, key=k,
                 args={"status": "done", "legs": 1})
        rec.span("queue_wait", "queue", 0.0, 0.01, key=k, args={"leg": 1})
        rec.span("leg", "request", 0.5, 0.6, key=k,
                 args={"leg": 1, "member": "m0"})   # outside the root span
        assert validate_span_tree(rec.chrome_trace())

    def test_span_tree_accepts_wellformed(self):
        rec = TraceRecorder()
        k = rec.next_key()
        rec.instant("admit", "queue", 0.0, key=k)
        rec.span("queue_wait", "queue", 0.0, 0.01, key=k, args={"leg": 1})
        rec.span("leg", "request", 0.01, 0.05, key=k,
                 args={"leg": 1, "member": "m0"})
        rec.span("request", "request", 0.0, 0.05, key=k,
                 args={"status": "done", "legs": 1})
        assert validate_span_tree(rec.chrome_trace()) == []


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_owned_and_callback_metrics(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs_total", "requests")
        c.inc()
        c.inc(2)
        g = reg.gauge("depth", "queue depth")
        g.set(7)
        state = {"v": 3}
        cb = reg.gauge("live", "callback", fn=lambda: state["v"])
        snap = reg.snapshot()
        assert snap["reqs_total"]["value"] == 3.0
        assert snap["depth"]["value"] == 7.0
        assert snap["live"]["value"] == 3.0
        state["v"] = 9   # callbacks read live state at export time
        assert reg.snapshot()["live"]["value"] == 9.0
        with pytest.raises(TypeError):
            cb.set(1)

    def test_duplicate_registration_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x", labels=(("worker", "0"),))
        reg.counter("x", labels=(("worker", "1"),))  # distinct labels: ok
        with pytest.raises(ValueError):
            reg.counter("x", labels=(("worker", "0"),))

    def test_deterministic_snapshot_excludes_wall(self):
        reg = MetricsRegistry()
        reg.counter("steady", "deterministic")
        reg.gauge("wall_g", "wall-clock", wall=True, fn=lambda: 1.0)
        full = reg.snapshot()
        det = reg.snapshot(deterministic=True)
        assert "wall_g" in full and "wall_g" not in det
        assert "steady" in det

    def test_histogram_snapshot_and_multigauge(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat_s", "latency")
        for v in (0.01, 0.02, 0.04):
            h.observe(v)
        reg.multi_gauge("rate_by_leg", "per-rung", "leg",
                        fn=lambda: {"1": 0.5, "2": 0.25})
        snap = reg.snapshot()
        hs = snap["lat_s"]
        assert hs["count"] == 3 and hs["min"] == 0.01 and hs["max"] == 0.04
        assert hs["min"] <= hs["p50"] <= hs["max"]
        assert snap['rate_by_leg{leg="1"}']["value"] == 0.5
        assert snap['rate_by_leg{leg="2"}']["value"] == 0.25

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("reqs_total", "served requests",
                    labels=(("worker", "0"),)).inc(5)
        h = reg.histogram("lat_s", "latency")
        h.observe(0.01)
        h.observe(0.5)
        text = reg.prometheus()
        assert "# TYPE reqs_total counter" in text
        assert "# HELP reqs_total served requests" in text
        assert 'reqs_total{worker="0"} 5' in text
        assert "# TYPE lat_s histogram" in text
        assert "lat_s_count 2" in text
        assert "lat_s_sum 0.51" in text
        assert 'le="+Inf"} 2' in text
        # Buckets cumulative and ending at the total count.
        bucket_counts = [int(line.rsplit(" ", 1)[1])
                         for line in text.splitlines()
                         if line.startswith("lat_s_bucket")]
        assert bucket_counts == sorted(bucket_counts)
        assert bucket_counts[-1] == 2


# ---------------------------------------------------------------------------
# Traced scheduler (single worker)
# ---------------------------------------------------------------------------

class TestTracedScheduler:
    def test_span_tree_covers_every_request(self):
        rec, sched, summary = run_traced_sched(n=12)
        doc = rec.chrome_trace()
        assert validate_chrome_trace(doc) == []
        assert validate_span_tree(doc) == []
        summ = trace_summary(doc)
        assert summ["requests"] == 12
        assert summ["finalized"] == summary["completed"] == 12
        for t in request_trees(doc).values():
            assert t["root"] is not None
            assert t["root"]["args"]["status"] == "done"
            assert len(t["legs"]) == 1 and len(t["admits"]) == 1

    def test_replay_bit_identity(self):
        j1 = run_traced_sched(n=12)[0].to_json()
        j2 = run_traced_sched(n=12)[0].to_json()
        assert j1 == j2

    def test_untraced_run_has_no_tracer_state(self):
        sched = MicroBatchScheduler(
            FakeEngine(), SchedulerConfig(score_batch=4, max_batch=4))
        assert sched.tracer is None and sched.queue.tracer is None
        summary = sched.run_trace([req(text=str(i), arrival=i * 1e-3)
                                   for i in range(6)])
        assert summary["completed"] == 6

    def test_reject_and_expire_traced(self):
        rec = TraceRecorder()
        sched = MicroBatchScheduler(
            FakeEngine(),
            SchedulerConfig(score_batch=4, max_batch=4, queue_capacity=2),
            tracer=rec.scoped(0))
        # Burst of simultaneous arrivals against a 2-deep queue.
        reqs = [req(text=str(i), arrival=0.0) for i in range(5)]
        sched.run_trace(reqs)
        names = [e[0] for e in rec.events]
        assert names.count("reject") == 3
        # Rejected requests have no root span but are visible in the tree
        # grouping as reject-only leaves.
        doc = rec.chrome_trace()
        assert validate_span_tree(doc) == []

    def test_scheduler_metrics_match_telemetry(self):
        rec = TraceRecorder()
        reg = MetricsRegistry()
        sched = MicroBatchScheduler(
            FakeEngine(), SchedulerConfig(score_batch=4, max_batch=4),
            tracer=rec.scoped(0))
        register_scheduler_metrics(reg, sched)
        sched.run_trace([req(text=str(i), arrival=i * 1e-3)
                         for i in range(10)])
        snap = reg.snapshot(deterministic=True)
        assert snap["requests_completed_total"]["value"] == 10
        assert snap["queue_admitted_total"]["value"] == 10
        assert snap["e2e_latency_s"]["count"] == 10
        assert snap["spend_total"]["value"] == pytest.approx(
            sched.telemetry.total_spend)
        # Deterministic snapshot is replay-stable as JSON.
        assert json.loads(reg.to_json(deterministic=True)) == snap


# ---------------------------------------------------------------------------
# Kernel profiler
# ---------------------------------------------------------------------------

class TestKernelProfiler:
    def test_profiler_hooks_pairwise_l2(self):
        rec = TraceRecorder()
        prof = KernelProfiler(tracer=rec)
        kops.set_kernel_profiler(prof)
        try:
            x = np.random.default_rng(0).normal(size=(8, 4)).astype(
                np.float32)
            c = np.random.default_rng(1).normal(size=(3, 4)).astype(
                np.float32)
            out = np.asarray(kops.pairwise_l2(x, c))
        finally:
            kops.set_kernel_profiler(None)
        assert out.shape == (8, 3)
        assert prof.calls["pairwise_l2"] == 1
        assert prof.elements["pairwise_l2"] == 8
        assert prof.hists["pairwise_l2"].count == 1
        # The span is wall-clock: kernel category, excluded by default.
        kernel_events = [e for e in rec.events if e[1] == "kernel"]
        assert len(kernel_events) == 1
        det = rec.chrome_trace()["traceEvents"]
        assert not any(e.get("cat") == "kernel" for e in det)
        summ = prof.summary()["pairwise_l2"]
        assert summ["calls"] == 1 and summ["p50_us"] > 0
        assert "pairwise_l2" in prof.report()

    def test_uninstalled_profiler_is_passthrough(self):
        assert kops.get_kernel_profiler() is None
        x = np.zeros((4, 4), np.float32)
        c = np.zeros((2, 4), np.float32)
        assert np.asarray(kops.pairwise_l2(x, c)).shape == (4, 2)

    def test_register_metrics(self):
        prof = KernelProfiler()
        with prof.annotate("router_xattn_pool", batch=64):
            pass
        reg = MetricsRegistry()
        prof.register_metrics(reg)
        snap = reg.snapshot()   # wall metrics: full snapshot only
        assert snap['kernel_calls_total{op="router_xattn_pool"}'][
            "value"] == 1
        assert snap['kernel_elements_total{op="router_xattn_pool"}'][
            "value"] == 64
        assert reg.snapshot(deterministic=True) == {}


class TestMetricsServer:
    """HTTP scrape endpoint over a live registry (ephemeral port)."""

    def _registry(self):
        reg = MetricsRegistry()
        reg.counter("reqs_total", "requests").inc(5)
        state = {"depth": 2}
        reg.gauge("queue_depth", "live depth", fn=lambda: state["depth"])
        return reg, state

    def _get(self, url):
        import urllib.request

        with urllib.request.urlopen(url, timeout=10.0) as resp:
            return resp.status, resp.headers.get("Content-Type"), \
                resp.read().decode()

    def test_prometheus_and_json_endpoints(self):
        reg, state = self._registry()
        with MetricsServer(reg) as srv:
            assert srv.port != 0          # ephemeral port was bound
            status, ctype, body = self._get(srv.url)
            assert status == 200 and ctype.startswith("text/plain")
            assert "# TYPE reqs_total counter" in body
            assert "reqs_total 5" in body
            # Gauges read their callbacks at scrape time.
            state["depth"] = 9
            _, _, body = self._get(srv.url)
            assert "queue_depth 9" in body
            status, ctype, body = self._get(
                f"http://127.0.0.1:{srv.port}/metrics.json")
            assert status == 200 and ctype == "application/json"
            assert json.loads(body)["reqs_total"]["value"] == 5.0
            assert srv.scrapes == 3

    def test_unknown_path_404(self):
        import urllib.error
        import urllib.request

        reg, _ = self._registry()
        with MetricsServer(reg) as srv:
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/nope", timeout=10.0)
            assert ei.value.code == 404
            assert srv.scrapes == 0

    def test_requires_registry_and_stop_idempotent(self):
        with pytest.raises(ValueError):
            MetricsServer(None)
        reg, _ = self._registry()
        srv = MetricsServer(reg)
        port = srv.start()
        assert srv.start() == port        # second start is a no-op
        srv.stop()
        srv.stop()                        # idempotent
