"""Predictor architectures: shapes, gradients, learnability, pool-freedom."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictors import ATTN_LATENT, PREDICTORS, attention_scores
from repro.training import TrainConfig, train_predictor

DQ, K, DM, B = 32, 5, 8, 64


@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(0)
    q = rng.standard_normal((400, DQ)).astype(np.float32)
    m = rng.standard_normal((K, DM)).astype(np.float32)
    w_true = rng.standard_normal((DQ, K)).astype(np.float32) * 0.3
    targets = np.tanh(q @ w_true) * 0.5 + 0.5
    return q, m, targets


@pytest.mark.parametrize("kind", list(PREDICTORS))
def test_shapes_and_finiteness(kind, toy):
    q, m, targets = toy
    pred = PREDICTORS[kind]
    params = pred.init(jax.random.key(0), DQ, K, DM)
    out = pred.apply(params, jnp.asarray(q[:B]), jnp.asarray(m))
    assert out.shape == (B, K)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("kind", list(PREDICTORS))
def test_gradients_flow(kind, toy):
    q, m, targets = toy
    pred = PREDICTORS[kind]
    params = pred.init(jax.random.key(0), DQ, K, DM)

    def loss(p):
        return jnp.mean((pred.apply(p, jnp.asarray(q[:B]), jnp.asarray(m))
                         - jnp.asarray(targets[:B])) ** 2)

    g = jax.grad(loss)(params)
    norms = [float(jnp.abs(x).sum()) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0.0


@pytest.mark.parametrize("kind", ["reg", "2fcn", "attn", "attn-dot", "reg-emb"])
def test_training_reduces_mse(kind, toy):
    q, m, targets = toy
    cfg = TrainConfig(lr=1e-2, epochs=60, batch_size=128, eval_every=5)
    params, hist = train_predictor(kind, q, targets, m, cfg,
                                   val=(q[:100], targets[:100]))
    assert hist["train_loss"][-1] < hist["train_loss"][0] * 0.8


def test_attention_weights_are_simplex():
    pred = PREDICTORS["attn"]
    params = pred.init(jax.random.key(1), DQ, K, DM)
    q = jnp.asarray(np.random.default_rng(1).standard_normal((B, DQ)), jnp.float32)
    m = jnp.asarray(np.random.default_rng(2).standard_normal((K, DM)), jnp.float32)
    _, alpha = attention_scores(params, q, m)
    assert alpha.shape == (B, K)
    assert np.allclose(np.asarray(alpha.sum(-1)), 1.0, atol=1e-5)
    assert float(alpha.min()) >= 0.0


def test_pool_free_predictors_accept_new_models():
    """emb/dot variants must score a GROWN pool without retraining."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((B, DQ)), jnp.float32)
    m5 = jnp.asarray(rng.standard_normal((5, DM)), jnp.float32)
    m7 = jnp.concatenate([m5, jnp.asarray(rng.standard_normal((2, DM)), jnp.float32)])
    for kind, pred in PREDICTORS.items():
        if not pred.pool_free:
            continue
        params = pred.init(jax.random.key(0), DQ, 5, DM)
        out5 = pred.apply(params, q, m5)
        out7 = pred.apply(params, q, m7)
        assert out7.shape == (B, 7)
        # attn variants renormalize over the pool; emb variants are exactly
        # consistent on the original columns.
        if kind.endswith("-emb"):
            assert np.allclose(np.asarray(out5), np.asarray(out7[:, :5]), atol=1e-5)


def test_attn_latent_dim_is_paper_value():
    assert ATTN_LATENT == 20
