"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates its REDUCED same-family config
(<=2 pattern repeats, d_model<=512, <=4 experts) and runs:
  * one forward/train step (loss + grads finite, shapes correct),
  * one prefill + one decode step, asserting decode == full-sequence logits
    (MoE archs use a generous capacity factor so capacity dispatch is exact).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import MOE
from repro.models import lm

B, S = 2, 16


def _setup(name):
    cfg = get_smoke_config(name)
    if cfg.has_ffn(MOE):
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = lm.init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    media = None
    if cfg.arch_type == "vlm":
        media = jax.random.normal(
            jax.random.key(2), (B, cfg.n_frontend_tokens, cfg.frontend_dim)
        )
    return cfg, params, tokens, media


@pytest.mark.parametrize("name", ARCH_IDS)
def test_reduced_config_bounds(name):
    cfg = get_smoke_config(name)
    assert cfg.d_model <= 512
    assert cfg.n_repeats <= 2
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    assert len(cfg.layer_plan()) == cfg.n_layers


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_and_train_step(name):
    cfg, params, tokens, media = _setup(name)
    logits, aux = lm.apply_lm_train(cfg, params, tokens, media=media)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(cfg, p, tokens, tokens, media=media)
    )(params)
    assert np.isfinite(float(loss))
    gsq = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
              for g in jax.tree.leaves(grads))
    assert np.isfinite(gsq) and gsq > 0.0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_decode_matches_full_forward(name):
    cfg, params, tokens, media = _setup(name)
    caches = lm.init_caches(cfg, B, S)
    _, caches = lm.apply_lm_prefill(cfg, params, tokens[:, : S - 1], caches,
                                    media=media)
    logits_dec, _ = lm.apply_lm_decode(
        cfg, params, tokens[:, S - 1 : S], caches, jnp.int32(S - 1)
    )
    logits_full, _ = lm.apply_lm_train(cfg, params, tokens, media=media)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
        rtol=2e-3, atol=2e-4,
    )


@pytest.mark.parametrize("name", ARCH_IDS)
def test_full_config_matches_assignment(name):
    """The full (dry-run) configs carry the exact published dimensions."""
    spec = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    }[name]
    cfg = get_config(name)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec
    moe = {
        "granite-moe-1b-a400m": (32, 8),
        "jamba-1.5-large-398b": (16, 2),
        "llama4-maverick-400b-a17b": (128, 1),
    }
    if name in moe:
        assert (cfg.n_experts, cfg.top_k) == moe[name]


def test_param_counts_are_plausible():
    """Sanity-check analytic parameter counts against the model names."""
    expected_range = {
        "xlstm-1.3b": (0.9e9, 2.4e9),
        "granite-moe-1b-a400m": (0.8e9, 1.8e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "gemma3-27b": (22e9, 34e9),
        "qwen1.5-4b": (3e9, 5.5e9),
        "qwen3-0.6b": (0.5e9, 1.0e9),
        "llama4-maverick-400b-a17b": (330e9, 480e9),
        "llama-3.2-vision-90b": (75e9, 100e9),
        "granite-3-8b": (7e9, 10e9),
    }
    for name, (lo, hi) in expected_range.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_active_params_below_total_for_moe():
    for name in ("granite-moe-1b-a400m", "jamba-1.5-large-398b",
                 "llama4-maverick-400b-a17b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < cfg.param_count()
