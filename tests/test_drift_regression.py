"""Deterministic regression pins for drift-detector calibration.

The bootstrap null is the detector's *threshold*: alarms compare window
statistics to (mean, std) estimated by resampling the reference. These
tests pin (a) that a fixed-seed ``fit`` reproduces the null bit-for-bit
across runs, (b) the calibrated values themselves against hardcoded
regression constants (any change to the bootstrap — rng flow, inflation
factor, statistic definitions — shows up here first), and (c) that a
known synthetic drift trace raises its first alarm at a pinned window
index with a pinned total alarm count.
"""
import numpy as np
import pytest

from repro.online.drift import DriftDetector

DQ = 16

# Regression constants: computed once from the fixed seeds below. These are
# environment-stable (float64 numpy ops under a seeded PCG64 generator);
# loosened only by the assert tolerances.
PINNED_NULL_SHIFT = (0.10667235674373693, 0.017437568260171257)
PINNED_NULL_DISPERSION = (0.5645120898261666, 0.018845105992954077)
PINNED_FIRST_ALARM_WINDOW = 7      # patience=2: windows 6,7 abnormal
PINNED_TOTAL_ALARMS = 4            # re-arms every `patience` shifted windows


def _emb(rng, n, sign=1.0):
    e = rng.normal(0, 0.4, size=(n, DQ)).astype(np.float32)
    e[:, : DQ // 2] += 0.8 * sign
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def _fit_detector():
    ref = _emb(np.random.default_rng(42), 300)
    return DriftDetector(window=32, threshold=3.0, patience=2,
                         n_bootstrap=64, seed=5).fit(ref)


class TestBootstrapNullStability:
    def test_fixed_seed_fit_is_bitwise_reproducible(self):
        d1, d2 = _fit_detector(), _fit_detector()
        assert d1.null_shift == d2.null_shift
        assert d1.null_dispersion == d2.null_dispersion
        np.testing.assert_array_equal(d1.ref_mean, d2.ref_mean)

    def test_null_matches_pinned_regression_values(self):
        det = _fit_detector()
        np.testing.assert_allclose(det.null_shift, PINNED_NULL_SHIFT,
                                   rtol=1e-6)
        np.testing.assert_allclose(det.null_dispersion,
                                   PINNED_NULL_DISPERSION, rtol=1e-6)

    def test_null_std_strictly_positive(self):
        det = _fit_detector()
        assert det.null_shift[1] > 0 and det.null_dispersion[1] > 0


class TestKnownTraceAlarmsAtPinnedStep:
    def _run_trace(self):
        det = _fit_detector()
        trace_rng = np.random.default_rng(7)
        fired = []
        for i in range(14):
            sign = 1.0 if i < 6 else -1.0          # drift begins at window 6
            fired.append(bool(det.observe(_emb(trace_rng, 32, sign),
                                          now=float(i))))
        return det, fired

    def test_first_alarm_and_total_count_pinned(self):
        det, fired = self._run_trace()
        assert fired.index(True) == PINNED_FIRST_ALARM_WINDOW
        assert det.alarms == PINNED_TOTAL_ALARMS
        assert not any(fired[:6])                  # no pre-drift false alarm

    def test_trace_replays_identically(self):
        d1, f1 = self._run_trace()
        d2, f2 = self._run_trace()
        assert f1 == f2
        assert d1.alarms == d2.alarms
        assert d1.last_stats == d2.last_stats

    def test_refit_recovers_from_pinned_trace(self):
        det, _ = self._run_trace()
        det.refit()                                # re-anchor to new regime
        trace_rng = np.random.default_rng(13)
        assert not det.observe(_emb(trace_rng, 128, -1.0))
