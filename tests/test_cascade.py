"""Cascade routing subsystem: escalation policy decisions (both reward
shapes), ensemble uncertainty, multi-leg scheduler lifecycle (re-admission,
cumulative cost, idempotent finalize), and a seeded escalation-rate
regression. Everything runs on stub engines — no LM generation.
"""
import jax
import numpy as np
import pytest

from repro.cascade import (
    CascadeConfig,
    CascadeCoordinator,
    CascadePolicy,
    cost_ladder,
)
from repro.core.metrics import frontier_dominance, frontier_value_at
from repro.core.predictors import ENSEMBLE_KINDS, PREDICTORS
from repro.core.rewards import cascade_outcome, cascade_reward
from repro.core.router import PredictiveRouter
from repro.serving import (
    DONE,
    MicroBatchScheduler,
    Request,
    SchedulerConfig,
    Telemetry,
)

# Three-member ladder: cheap / mid / strong.
COSTS = (0.1, 1.0, 5.0)
QUAL = (0.4, 0.7, 0.95)
STD = (0.05, 0.05, 0.05)


def make_policy(reward="R2", **cfg):
    return CascadePolicy([0, 1, 2], CascadeConfig(**cfg), reward=reward)


def decide(policy, *, s_cur, s_std_cur=0.0, tried=(0,), cum=0.1, lam=5.0,
           s_hat=QUAL, s_std=STD, c_hat=COSTS, observed=False, headroom=1.0):
    return policy.decide(
        s_cur=s_cur, s_std_cur=s_std_cur,
        s_hat=np.asarray(s_hat), s_std=np.asarray(s_std),
        c_hat=np.asarray(c_hat), cum_cost=cum, tried=list(tried),
        lam=lam, observed=observed, headroom=headroom)


class TestPolicyDecisions:
    @pytest.mark.parametrize("reward", ["R1", "R2"])
    def test_good_observed_answer_stops(self, reward):
        d = decide(make_policy(reward), s_cur=0.95, observed=True, lam=5.0)
        assert not d.escalate and d.next_member == -1

    @pytest.mark.parametrize("reward", ["R1", "R2"])
    def test_poor_observed_answer_escalates(self, reward):
        d = decide(make_policy(reward), s_cur=0.1, observed=True, lam=50.0)
        assert d.escalate and d.next_member in (1, 2)
        assert d.expected_gain > 0

    @pytest.mark.parametrize("reward", ["R1", "R2"])
    def test_escalation_monotone_in_lambda(self, reward):
        """Sweep a synthetic mean/std grid: once a lambda escalates a given
        state, every higher lambda escalates it too (the cost penalty only
        shrinks), so per-lambda escalation counts are nondecreasing."""
        policy = make_policy(reward)
        rng = np.random.default_rng(0)
        states = [(float(rng.uniform(0.05, 0.95)),
                   float(rng.uniform(0.0, 0.3))) for _ in range(40)]
        lams = [0.5, 2.0, 8.0, 32.0, 128.0]
        counts = []
        for lam in lams:
            n = sum(decide(policy, s_cur=s, s_std_cur=sd, lam=lam).escalate
                    for s, sd in states)
            counts.append(n)
        assert counts == sorted(counts)
        assert counts[-1] > counts[0]   # the sweep actually moves

    def test_disagreement_discount_flips_stop_to_escalate(self):
        """Same mean estimate: confident -> stop, high ensemble
        disagreement -> the stop value is discounted and the policy buys a
        second opinion."""
        policy = make_policy("R2", gamma=1.0)
        confident = decide(policy, s_cur=0.8, s_std_cur=0.0, lam=10.0)
        uncertain = decide(policy, s_cur=0.8, s_std_cur=0.35, lam=10.0)
        assert not confident.escalate
        assert uncertain.escalate

    def test_observed_quality_ignores_std(self):
        policy = make_policy("R2", gamma=1.0)
        d = decide(policy, s_cur=0.8, s_std_cur=0.35, observed=True,
                   lam=10.0)
        assert not d.escalate

    def test_max_legs_hard_stop(self):
        policy = make_policy("R2", max_legs=2)
        d = decide(policy, s_cur=0.1, tried=(0, 1), cum=1.1, lam=100.0)
        assert not d.escalate

    def test_headroom_gate_blocks_escalation(self):
        policy = make_policy("R2", min_headroom=0.25)
        base = dict(s_cur=0.1, observed=True, lam=100.0)
        assert decide(policy, headroom=1.0, **base).escalate
        assert not decide(policy, headroom=0.1, **base).escalate

    def test_margin_blocks_marginal_gains(self):
        lax = make_policy("R2", margin=0.0)
        strict = make_policy("R2", margin=10.0)
        base = dict(s_cur=0.1, observed=True, lam=100.0)
        assert decide(lax, **base).escalate
        assert not decide(strict, **base).escalate

    def test_candidates_climb_only(self):
        policy = make_policy("R2")
        assert policy.candidates([]) == [0, 1, 2]
        assert policy.candidates([0]) == [1, 2]
        assert policy.candidates([1]) == [2]      # below-top rungs skipped
        assert policy.candidates([0, 2]) == []

    def test_unknown_reward_rejected(self):
        with pytest.raises(ValueError):
            CascadePolicy([0, 1], reward="R9")


class TestCostLadder:
    def test_ladder_from_scaler(self):
        router = PredictiveRouter(
            "reg", "reg", {}, {}, np.zeros((3, 2), np.float32),
            cost_scaler={"mu": np.asarray([5.0, 0.1, 1.0]),
                         "sd": np.ones(3)})
        assert cost_ladder(router).tolist() == [1, 2, 0]

    def test_ladder_fallback_to_c_hat(self):
        router = PredictiveRouter(
            "reg", "reg", {}, {}, np.zeros((2, 2), np.float32),
            cost_scaler=None)
        c_hat = np.asarray([[3.0, 1.0], [3.0, 1.0]])
        assert cost_ladder(router, c_hat).tolist() == [1, 0]

    def test_ladder_requires_a_source(self):
        router = PredictiveRouter(
            "reg", "reg", {}, {}, np.zeros((2, 2), np.float32))
        with pytest.raises(ValueError):
            cost_ladder(router)


class TestLadderRefresh:
    """Hot pool mutation must re-derive the escalation ladder.

    Regression for the stale-ladder bug: after ``add_member`` the policy's
    ladder still ranked the old members, so the new member could never be
    escalated to (and after ``remove_member`` a dead rung stayed
    selectable)."""

    def _router(self, mu):
        k, dq = len(mu), 4
        params = {"w": np.zeros((dq, k), np.float32),
                  "b": np.zeros(k, np.float32)}
        return PredictiveRouter(
            "reg", "reg", params, dict(params),
            np.zeros((k, 2), np.float32),
            cost_scaler={"mu": np.asarray(mu, np.float64),
                         "sd": np.ones(k)})

    def test_refresh_noop_when_pool_unchanged(self):
        router = self._router([5.0, 0.1, 1.0])
        policy = CascadePolicy(cost_ladder(router))
        assert policy.refresh(router) is False
        assert policy.ladder == [1, 2, 0]

    def test_added_member_becomes_escalatable(self):
        router = self._router([0.1, 1.0])
        policy = CascadePolicy(cost_ladder(router))
        assert policy.ladder == [0, 1]
        grown = router.add_member()       # new member's mu = mean = 0.55
        # Stale ladder: the new member (index 2) is not a rung at all.
        assert 2 not in policy.candidates([0])
        assert policy.refresh(grown) is True
        assert policy.ladder == [0, 2, 1]
        assert 2 in policy.candidates([0])
        # And the decision rule can now actually pick it: a poor cheap leg
        # with a strong-looking new member escalates onto the new rung.
        d = policy.decide(
            s_cur=0.1, s_std_cur=0.0,
            s_hat=np.asarray([0.3, 0.5, 0.95]),
            s_std=np.asarray([0.05, 0.05, 0.05]),
            c_hat=np.asarray([0.1, 1.0, 0.55]),
            cum_cost=0.1, tried=[0], lam=100.0, observed=True)
        assert d.escalate and d.next_member == 2

    def test_removed_member_drops_its_rung(self):
        router = self._router([5.0, 0.1, 1.0])
        policy = CascadePolicy(cost_ladder(router))
        shrunk = router.remove_member(0)  # members above shift down
        assert policy.refresh(shrunk) is True
        assert policy.ladder == [0, 1]
        assert all(m in (0, 1) for m in policy.candidates([]))

    def test_stub_routers_left_alone(self):
        policy = CascadePolicy([0, 1, 2])
        router = PredictiveRouter(
            "reg", "reg", {}, {}, np.zeros((2, 2), np.float32),
            cost_scaler=None)
        assert policy.refresh(router) is False
        assert policy.ladder == [0, 1, 2]


class TestEnsemblePredictor:
    def test_heads_disagree_and_mean_matches(self):
        rng = np.random.default_rng(0)
        dq, k, dm = 8, 3, 4
        params = PREDICTORS["attn-ens"].init(jax.random.key(0), dq, k, dm)
        q = rng.normal(size=(5, dq)).astype(np.float32)
        m = rng.random((k, dm)).astype(np.float32)
        heads = np.asarray(ENSEMBLE_KINDS["attn-ens"](params, q, m))
        mean = np.asarray(PREDICTORS["attn-ens"].apply(params, q, m))
        assert heads.shape[0] >= 2 and heads.shape[1:] == (5, k)
        np.testing.assert_allclose(heads.mean(axis=0), mean, atol=1e-6)
        assert heads.std(axis=0).max() > 0   # fresh heads differ

    def test_router_uncertainty_and_pool_mutation(self):
        rng = np.random.default_rng(1)
        dq, k, dm = 8, 3, 4
        qp = PREDICTORS["attn-ens"].init(jax.random.key(1), dq, k, dm)
        cp = {"w": np.zeros((dq, k), np.float32),
              "b": np.asarray([0.1, 1.0, 5.0], np.float32)}
        router = PredictiveRouter("attn-ens", "reg", qp, cp,
                                  rng.random((k, dm)).astype(np.float32))
        q = rng.normal(size=(4, dq)).astype(np.float32)
        s, sd, c = router.predict_with_uncertainty(q)
        assert s.shape == sd.shape == c.shape == (4, k)
        assert (sd > 0).all()
        s2, c2 = router.predict(q)
        np.testing.assert_allclose(s, s2, atol=1e-6)
        grown = router.add_member()
        s3, sd3, _ = grown.predict_with_uncertainty(q)
        assert s3.shape == (4, k + 1) and (sd3 >= 0).all()
        shrunk = grown.remove_member(1)
        assert shrunk.predict_with_uncertainty(q)[0].shape == (4, k)

    def test_non_ensemble_router_reports_zero_std(self):
        rng = np.random.default_rng(2)
        dq, k, dm = 8, 2, 4
        qp = PREDICTORS["attn"].init(jax.random.key(2), dq, k, dm)
        cp = {"w": np.zeros((dq, k), np.float32),
              "b": np.ones(k, np.float32)}
        router = PredictiveRouter("attn", "reg", qp, cp,
                                  rng.random((k, dm)).astype(np.float32))
        _, sd, _ = router.predict_with_uncertainty(
            rng.normal(size=(3, dq)).astype(np.float32))
        assert (sd == 0).all()

    def test_bootstrap_training_fits_and_keeps_spread(self):
        from repro.training.predictor_trainer import TrainConfig, train_predictor

        rng = np.random.default_rng(3)
        n, dq, k, dm = 300, 12, 2, 4
        q = rng.normal(size=(n, dq)).astype(np.float32)
        w = rng.normal(size=(dq, k)).astype(np.float32)
        t = 1.0 / (1.0 + np.exp(-(q @ w)))
        memb = rng.random((k, dm)).astype(np.float32)
        params, hist = train_predictor(
            "attn-ens", q, t, memb, TrainConfig(epochs=40, batch_size=64))
        assert hist["train_loss"][-1] < hist["train_loss"][0] * 0.5
        heads = np.asarray(ENSEMBLE_KINDS["attn-ens"](
            params, q[:32], memb))
        assert heads.std(axis=0).mean() > 1e-4   # bootstrap kept diversity


# ---------------------------------------------------------------------------
# Multi-leg scheduler lifecycle
# ---------------------------------------------------------------------------


class FakeMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate


class FakeCascadeEngine:
    """Per-text quality tables + the cascade scoring surface.

    ``pred_of`` holds what the router *believes* (s_hat rows); it defaults
    to ``quality_of`` (perfect estimates) so most tests need only one
    table, while keep-best tests can split belief from truth.
    """

    def __init__(self, quality_of=None, pred_of=None, lam=10.0, std=STD):
        self.pool = [FakeMember(f"m{i}", c) for i, c in enumerate(COSTS)]
        self.lam = lam
        self.std = np.asarray(std, np.float64)
        self.quality_of = quality_of or {}
        self.pred_of = pred_of if pred_of is not None else self.quality_of
        self.generate_log = []

    def _rows(self, texts):
        return np.stack([
            np.asarray(self.pred_of.get(t, QUAL), np.float64)
            for t in texts])

    def embed(self, texts):
        self._last_texts = list(texts)
        return np.zeros((len(texts), 4), np.float32)

    def score_emb_uncertainty(self, q_emb):
        b = len(q_emb)
        s = self._rows(self._last_texts)
        return (s, np.tile(self.std, (b, 1)),
                np.tile(COSTS, (b, 1)))

    def score_emb(self, q_emb):
        s, _, c = self.score_emb_uncertainty(q_emb)
        return s, c

    def score_texts(self, texts):
        self.embed(texts)
        return self.score_emb(np.zeros((len(texts), 4), np.float32))

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8):
        self.generate_log.append((mi, len(prompts)))
        outs = [np.full(max_new, mi, np.int32) for _ in prompts]
        return outs, self.pool[mi].cost_rate * len(prompts)


def req(text="q", arrival=0.0, deadline=None, forced=-1):
    r = Request(text=text, prompt=np.zeros(4, np.int32), max_new=2,
                arrival_s=arrival, deadline_s=deadline)
    r.forced_member = forced
    if forced >= 0:
        r.forced_member_name = f"m{forced}"   # members resolve by NAME
    return r


def make_sched(eng, coordinator, **cfg):
    return MicroBatchScheduler(
        eng, SchedulerConfig(score_batch=16, max_batch=16, **cfg),
        cascade=coordinator, service_time=lambda kind, n, wall: 1e-3)


class TestMultiLegLifecycle:
    def test_escalation_readmits_at_queue_head_and_accumulates_cost(self):
        eng = FakeCascadeEngine(lam=10.0)
        coord = CascadeCoordinator(make_policy("R2"))
        sched = make_sched(eng, coord)
        # Force everyone to start at the cheapest rung (canonical cascade).
        for i in range(3):
            sched.queue.offer(req(text=str(i), forced=0), 0.0)
        served1 = sched.dispatch()
        # Leg 1 served nothing final: estimated q=0.4 with next-rung upside.
        assert served1 == []
        assert sched.queue.depth == 3 and sched.queue.readmitted == 3
        assert all(r.forced_member >= 1 for r in sched.queue.peek_all())
        served2 = sched.dispatch()
        escalated_twice = sched.queue.depth
        while sched.queue.depth:
            served2 += sched.dispatch()
        done = served1 + served2
        assert len(done) == 3
        for r in done:
            assert r.status == DONE and r.finalized
            assert len(r.tried) >= 2 and r.tried[0] == 0
            assert r.leg == len(r.tried) == len(r.leg_costs)
            assert r.cum_cost == pytest.approx(
                sum(COSTS[m] for m in r.tried))
            assert r.cum_cost > r.cost      # cumulative, not last-leg
        assert coord.stats["escalations"] >= 3 + escalated_twice

    def test_no_double_finalize_and_telemetry_split_by_leg(self):
        eng = FakeCascadeEngine(lam=10.0)
        coord = CascadeCoordinator(make_policy("R2"))
        sched = make_sched(eng, coord)
        trace = [req(text=str(i), arrival=i * 1e-3, forced=0)
                 for i in range(4)]
        summary = sched.run_trace(trace)
        assert summary["completed"] == 4
        assert summary["double_finalize_blocked"] == 0
        assert sum(summary["finalized_by_leg"]) == 4
        assert summary["escalations"] == coord.stats["escalations"] > 0
        # every leg shows up exactly once in the per-leg split
        assert sum(summary["legs_served"]) == sum(r.leg for r in trace)
        assert summary["legs_served"][0] == 4
        for r in trace:
            assert r.finalized

    def test_keep_best_answer_is_delivered(self):
        # Mid rung is the best ANSWER but the router's beliefs still climb
        # to the top (it predicts the top is better); keep-best must
        # deliver the mid rung's response while charging all three legs.
        quality_of = {"x": (0.2, 0.9, 0.5)}
        pred_of = {"x": (0.2, 0.9, 0.95)}
        eng = FakeCascadeEngine(quality_of=quality_of, pred_of=pred_of,
                                lam=50.0)
        coord = CascadeCoordinator(
            make_policy("R2"),
            observed_quality=lambda r: quality_of["x"][r.member])
        sched = make_sched(eng, coord)
        sched.queue.offer(req(text="x", forced=0), 0.0)
        done = []
        for _ in range(4):
            done += sched.dispatch()
            if done:
                break
        (r,) = done
        assert r.tried == [0, 1, 2]
        assert r.best_member == 1 and r.member == 1
        assert (r.output == 1).all()          # mid rung's tokens delivered
        assert r.best_q == pytest.approx(0.9)
        assert r.cum_cost == pytest.approx(sum(COSTS))

    def test_mixed_feedback_keeps_verified_answer_over_shaky_estimate(self):
        """Regression: when leg feedback is intermittent (staged/delayed),
        the best answer is compared on disagreement-discounted value and
        its observedness is tracked — a verified 0.7 beats an estimated
        0.75 the ensemble disagrees about, and the stop decision treats an
        estimated best as estimated (no phantom-confidence early stop)."""
        pred_of = {"x": (0.75, 0.70, 0.50)}
        truth = {1: 0.7}                        # only m1 feedback arrives
        eng = FakeCascadeEngine(pred_of=pred_of, lam=50.0,
                                std=(0.30, 0.01, 0.05))
        coord = CascadeCoordinator(
            make_policy("R2", gamma=1.0),
            observed_quality=lambda r: truth.get(r.member))
        sched = make_sched(eng, coord)
        r = req(text="x", forced=0)
        sched.queue.offer(r, 0.0)
        while sched.queue.depth:
            sched.dispatch()
        # Leg 1 (m0) had no feedback: estimated 0.75 with std 0.30 ->
        # effective 0.45, so the policy escalated despite the high mean.
        assert r.tried == [0, 1]
        assert coord.stats["estimated_legs"] == 1
        assert coord.stats["observed_legs"] == 1
        # The verified 0.7 displaced the shakier 0.75 estimate.
        assert r.best_member == 1 and r.best_observed
        assert r.best_q == pytest.approx(0.7)
        assert r.member == 1                    # delivered answer

    def test_estimated_best_survives_weak_observation_unobserved(self):
        """The estimated best can stay the best — but it must keep its
        estimated status (and std) for later stop decisions."""
        coord = CascadeCoordinator(make_policy("R2", gamma=1.0))
        r = req(text="x")
        r.s_pred = np.asarray([0.75, 0.3, 0.9])
        r.s_std_pred = np.asarray([0.10, 0.01, 0.05])
        r.c_pred = np.asarray(COSTS)
        r.member, r.output = 0, np.zeros(2, np.int32)
        r.tried, r.leg_costs, r.cum_cost = [0], [0.1], 0.1
        coord.on_leg_complete(r, lam=50.0, now=0.0)
        assert not r.best_observed
        assert r.best_q == pytest.approx(0.75)
        assert r.best_q_std == pytest.approx(0.10)

    def test_deadline_mid_cascade_delivers_best_so_far(self):
        eng = FakeCascadeEngine(lam=10.0)
        coord = CascadeCoordinator(make_policy("R2"))
        sched = make_sched(eng, coord)
        r = req(text="q", deadline=0.0005, forced=0)
        sched.queue.offer(r, 0.0)
        sched.dispatch()                      # leg 1 + re-admission
        assert sched.queue.depth == 1
        sched.clock.advance(1.0)              # deadline passes in queue
        served = sched.dispatch()
        assert served == [r]
        assert r.status == DONE and r.finalized
        assert r.output is not None and (r.output == 0).all()
        assert sched.queue.expired == 0       # rescued, not expired
        assert sched.telemetry.completed == 1
        # the rescue is accounted: coordinator finalized count tracks
        # telemetry completions, so the escalation rate stays honest
        assert coord.stats["finalized"] == 1

    def test_forced_member_beyond_pool_falls_back_to_free_routing(self):
        """A forced rung that no longer exists (hot pool shrink between
        the escalation decision and redispatch) must not lose the
        request — it routes freely instead."""
        eng = FakeCascadeEngine(lam=10.0)
        coord = CascadeCoordinator(make_policy("R2"))
        sched = make_sched(eng, coord)
        r = req(text="q", forced=len(COSTS) + 3)   # stale rung index
        sched.queue.offer(r, 0.0)
        done = []
        while sched.queue.depth:
            done += sched.dispatch()
        assert r in done and r.finalized
        assert all(0 <= m < len(COSTS) for m in r.tried)

    def test_forced_member_resolved_by_name_across_index_shift(self):
        """Escalation targets resolve by member NAME: a hot-pool removal
        that shifts indices down must not dispatch the escalated leg to
        whichever member slid into the old index."""
        eng = FakeCascadeEngine(lam=10.0)
        coord = CascadeCoordinator(make_policy("R2", max_legs=1))
        sched = make_sched(eng, coord)
        # The policy chose m2 while the pool was [m0, m1, m2]; before the
        # redispatch, m0 was removed and the pool is now [m1, m2] — the
        # old index 2 is out of range, but the NAME still resolves.
        del eng.pool[0]
        r = req(text="q", forced=2)
        r.forced_member_name = "m2"
        sched.queue.offer(r, 0.0)
        (done,) = sched.dispatch()
        assert done is r and r.tried == [1]        # m2's NEW index
        assert eng.generate_log == [(1, 1)]
        # ...and a name that vanished entirely falls back to free routing.
        eng2 = FakeCascadeEngine(lam=10.0)
        sched2 = make_sched(eng2, CascadeCoordinator(
            make_policy("R2", max_legs=1)))
        r2 = req(text="q", forced=0)
        r2.forced_member_name = "gone"
        sched2.queue.offer(r2, 0.0)
        (done2,) = sched2.dispatch()
        assert done2 is r2 and r2.finalized

    def test_headroom_blocked_counts_only_suppressed_escalations(self):
        """headroom_blocked must count legs the budget gate actually
        stopped, not every low-headroom completion."""
        from repro.serving import BudgetGovernor

        quality_of = {"poor": (0.1, 0.7, 0.95), "good": (0.95, 0.6, 0.7)}
        eng = FakeCascadeEngine(quality_of=quality_of, lam=30.0)
        gov = BudgetGovernor(1e-6, 1e9, lam0=30.0)   # hopelessly over budget
        gov.record(1.0, 0.0)                          # zero headroom forever
        coord = CascadeCoordinator(
            make_policy("R2", min_headroom=0.5),
            observed_quality=lambda r: quality_of[r.text][r.member],
            governor=gov)
        sched = make_sched(eng, coord)
        sched.queue.offer(req(text="poor", forced=0), 0.0)
        sched.queue.offer(req(text="good", forced=0), 0.0)
        while sched.queue.depth:
            sched.dispatch()
        # Both stopped at leg 1 (gate active), but only the poor answer
        # was a suppressed escalation; the good one would stop anyway.
        assert coord.stats["escalations"] == 0
        assert coord.stats["headroom_blocked"] == 1

    def test_adapter_observes_every_leg_with_unique_rids(self):
        eng = FakeCascadeEngine(lam=10.0)
        observed = []

        class SpyAdapter:
            last_explored = np.zeros(0, bool)

            def choose(self, s_hat, c_hat, lam, now):
                self.last_explored = np.zeros(len(s_hat), bool)
                return np.argmax(s_hat, axis=1)

            def observe(self, outcomes, now):
                observed.extend(outcomes)

            def tick(self, now):
                pass

        coord = CascadeCoordinator(make_policy("R2"))
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=8, max_batch=8),
            cascade=coord, adapter=SpyAdapter(),
            service_time=lambda kind, n, wall: 1e-3)
        r = req(text="a", forced=0)
        sched.queue.offer(r, 0.0)
        while sched.queue.depth:
            sched.dispatch()
        # one outcome per LEG, each with its own rid and true attribution
        assert len(observed) == coord.stats["legs"] >= 2
        rids = [o.rid for o in observed]
        assert len(set(rids)) == len(rids) and r.rid not in rids
        assert [o.member for o in observed] == r.tried
        assert [o.cost for o in observed] == pytest.approx(
            [COSTS[m] for m in r.tried])
        # snapshots are frozen at their leg: leg i saw i+1 tried members
        assert [len(o.tried) for o in observed] == list(
            range(1, len(observed) + 1))

    def test_without_cascade_behavior_unchanged(self):
        eng = FakeCascadeEngine(lam=10.0)
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=8, max_batch=8),
            service_time=lambda kind, n, wall: 1e-3)
        for i in range(3):
            sched.queue.offer(req(text=str(i)), 0.0)
        served = sched.dispatch()
        assert len(served) == 3
        summary = sched.telemetry.summary()
        assert "escalations" not in summary   # no cascade keys leak


class TestTelemetryFinalizeIdempotent:
    def test_double_finalize_counts_once(self):
        t = Telemetry(["a", "b"])
        r = req()
        r.leg = 1
        r.service_start_s, r.finish_s = 0.1, 0.2
        assert t.finalize_request(r) is True
        assert t.finalize_request(r) is False   # guarded repeat
        assert t.completed == 1
        assert t.e2e_latency.count == 1
        assert t.double_finalize_blocked == 1

    def test_merge_folds_cascade_counters(self):
        a, b = Telemetry(["m"]), Telemetry(["m"])
        for t in (a, b):
            t.record_leg(1, 0.5, 0.8, 0.01)
            t.record_escalation()
        b.record_leg(2, 1.0, 0.9, 0.02)
        a.merge(b)
        assert a.escalations == 2
        assert a.leg_served == [2, 1]
        assert a.leg_spend == pytest.approx([1.0, 1.0])


class TestEscalationRegression:
    """Deterministic escalation-rate regression pinned to a seeded trace."""

    N = 48

    def _run(self):
        rng = np.random.default_rng(42)
        texts = [f"t{i}" for i in range(self.N)]
        # Seeded per-text truth: cheap often adequate, strong nearly always.
        quality_of = {
            t: (float(rng.uniform(0.1, 0.9)),
                float(np.clip(rng.uniform(0.1, 0.9) + 0.2, 0, 1)),
                float(rng.uniform(0.85, 1.0)))
            for t in texts
        }
        eng = FakeCascadeEngine(quality_of=quality_of, lam=30.0)
        coord = CascadeCoordinator(
            make_policy("R2", max_legs=3),
            observed_quality=lambda r: quality_of[r.text][r.member])
        sched = make_sched(eng, coord)
        trace = [req(text=t, arrival=i * 1e-3, forced=0)
                 for i, t in enumerate(texts)]
        summary = sched.run_trace(trace)
        return summary, coord

    def test_pinned_escalation_counts(self):
        summary, coord = self._run()
        assert summary["completed"] == self.N
        # Pinned to seed 42: changing the policy arithmetic, the ladder,
        # or the lifecycle plumbing shifts these exact counts. (The policy
        # jumps straight to the strongest rung here — its predicted upside
        # dominates the mid rung's — so no request needs a third leg.)
        assert summary["escalations"] == 47
        assert summary["finalized_by_leg"] == [1, 47]
        assert coord.escalations_by_leg == [47]
        assert summary["escalation_rate"] == pytest.approx(47 / 48)

    def test_replays_identically(self):
        s1, c1 = self._run()
        s2, c2 = self._run()
        assert s1["escalations"] == s2["escalations"]
        assert s1["finalized_by_leg"] == s2["finalized_by_leg"]
        assert c1.stats == c2.stats


class TestCascadeRewardAccounting:
    def test_cumulative_cost_not_last_leg(self):
        q, c = cascade_outcome([0.4, 0.9], [0.1, 5.0])
        assert q == 0.9 and c == pytest.approx(5.1)

    def test_keep_best_vs_replace(self):
        q_best, _ = cascade_outcome([0.8, 0.3], [0.1, 5.0], keep_best=True)
        q_last, _ = cascade_outcome([0.8, 0.3], [0.1, 5.0], keep_best=False)
        assert q_best == 0.8 and q_last == 0.3

    def test_reward_uses_cum_cost(self):
        r_casc = cascade_reward("R1", [0.4, 0.9], [1.0, 2.0], lam=1.0)
        assert r_casc == pytest.approx(0.9 - 3.0)

    def test_empty_or_ragged_legs_rejected(self):
        with pytest.raises(ValueError):
            cascade_outcome([], [])
        with pytest.raises(ValueError):
            cascade_outcome([0.5], [0.1, 0.2])


@pytest.mark.slow
class TestCascadeSoak:
    """Full-pipeline cascade soak (real pool LMs + trained ensemble router
    + budget governor + online adapter) — nightly CI lane."""

    def test_cascade_soak_invariants(self):
        from repro.cascade import cost_ladder
        from repro.launch.serve import build_routed_engine, pool_quality_columns
        from repro.online import OnlineAdapter, OnlineUpdateConfig
        from repro.serving import (
            BudgetGovernor, TraceConfig, default_service_model, make_trace,
        )

        # lam on the pool's $/request scale (~1e-4..1e-3): leg 1 must
        # genuinely prefer the cheap member so the ladder has room to climb.
        lam = 5e-4
        eng, data, te = build_routed_engine(
            ["qwen3-0.6b", "granite-3-8b"], seed=0, epochs=60,
            n_traffic=400, quality_kind="attn-ens", lam=lam)
        quality = data.quality[:, pool_quality_columns(eng.pool, data)]
        truth = {data.texts[i]: quality[i] for i in range(len(data.texts))}
        governor = BudgetGovernor(0.05, 0.5, lam0=lam)
        coord = CascadeCoordinator(
            CascadePolicy(cost_ladder(eng.router),
                          CascadeConfig(max_legs=2)),
            observed_quality=lambda r: float(truth[r.text][r.member]),
            governor=governor)
        adapter = OnlineAdapter(
            eng, lambda r: float(truth[r.text][r.member]),
            governor=governor,
            config=OnlineUpdateConfig(update_every=48), seed=0)
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=32, max_batch=8),
            governor=governor, adapter=adapter, cascade=coord,
            service_time=default_service_model())
        n = 150
        trace = make_trace(
            TraceConfig(kind="poisson", n_requests=n, rate=400.0, seed=0,
                        max_new=2, prompt_len_max=16,
                        vocab=min(m.cfg.vocab_size for m in eng.pool)),
            texts=[data.texts[i] for i in te])
        summary = sched.run_trace(trace)

        assert summary["completed"] == n
        assert summary["double_finalize_blocked"] == 0
        assert sum(summary["finalized_by_leg"]) == n
        assert summary["escalations"] > 0
        for r in trace:
            assert r.finalized and r.status == DONE
            assert r.cum_cost == pytest.approx(sum(r.leg_costs))
            assert len(r.tried) == r.leg <= 2
        # Every leg's spend hit the shared ledger (cumulative accounting).
        assert governor.total_spend == pytest.approx(
            sum(r.cum_cost for r in trace), rel=1e-6)
        assert governor.total_spend == pytest.approx(
            sched.telemetry.total_spend, rel=1e-6)
        # The adapter saw one outcome per leg, not per request.
        assert adapter.stats["outcomes"] == sum(r.leg for r in trace)


class TestFrontierDominance:
    def test_value_at_interpolates_hull(self):
        costs = np.asarray([1.0, 2.0, 4.0])
        perfs = np.asarray([0.5, 0.7, 0.9])
        assert frontier_value_at(costs, perfs, 1.0) == pytest.approx(0.5)
        assert frontier_value_at(costs, perfs, 3.0) == pytest.approx(0.8)
        assert frontier_value_at(costs, perfs, 9.0) == pytest.approx(0.9)
        assert frontier_value_at(costs, perfs, 0.1) == float("-inf")

    def test_dominance_counts_points(self):
        ca, pa = np.asarray([1.0, 4.0]), np.asarray([0.6, 0.9])
        cb = np.asarray([1.0, 2.5, 4.0])
        pb = np.asarray([0.5, 0.9, 0.85])
        dom = frontier_dominance(ca, pa, cb, pb)
        assert dom.tolist() == [True, False, True]
