"""Cross-mixer batch-invariance harness: left-padded generate micro-batches
must produce the same output a request would get served alone.

The serving scheduler coalesces heterogeneous prompts into left-padded
micro-batches, so every mixer family in the pool has to ignore pad
positions:

  * attention — RoPE logits depend only on position differences, so masking
    pad keys (prefill) and flagging pad cache slots invalid per-row (decode)
    makes a left-padded row attend exactly as its unpadded self;
  * SSM (mamba) — pad steps are identity recurrence updates (``dt -> 0``
    drives ``dA_log -> 0``, ``dBx -> 0``) and the conv front is zeroed at
    pads, so the carried state crosses pads unchanged;
  * xLSTM — mLSTM pads get ``log_i -> -inf`` / ``log_f -> 0`` plus a masked
    conv/value stream; the sLSTM scan passes state through pad steps
    untouched;
  * MoE — pads are excluded from capacity accounting, position assignment,
    combine weights, and the aux load-balance loss, so a real token is
    never dropped because pads consumed expert capacity.

``mixer_member`` (conftest) parametrizes the suite over one smoke config
per family: qwen3-0.6b (attention), xlstm-1.3b (sLSTM+mLSTM),
granite-moe-1b-a400m (MoE), jamba-style SSM hybrid (mamba+attn+MoE). The
non-attention members are marked ``slow``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm as lm_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.serving.engine import pad_prompts, prompt_pad_mask

VOCAB = 64
MAX_NEW = 4          # 1 prefill token + 3 decode steps after prefill


def _gen(cfg, params, prompts, max_new=MAX_NEW):
    toks = pad_prompts(prompts)
    mask = prompt_pad_mask(prompts)
    return np.asarray(lm_mod.greedy_generate(
        cfg, params, toks, max_new=max_new, attn_mask=mask))


def _prompts(seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, VOCAB, size=5).astype(np.int32),
            rng.integers(0, VOCAB, size=17).astype(np.int32),
            rng.integers(0, VOCAB, size=11).astype(np.int32))


class TestPadMask:
    def test_mask_shape_and_alignment(self):
        prompts = [np.arange(3), np.arange(5)]
        mask = np.asarray(prompt_pad_mask(prompts))
        assert mask.shape == (2, 5)
        assert mask[0].tolist() == [False, False, True, True, True]
        assert mask[1].all()


class TestCrossMixerInvariance:
    """The headline contract, per mixer family: greedy generation through
    prefill *and* decode is invariant to micro-batch composition."""

    def test_batch_composition_invariance(self, mixer_member):
        """The same request generates identical tokens regardless of which
        (and how long) neighbors share its micro-batch."""
        _, cfg, params = mixer_member
        p_short, p_long, p_other = _prompts(0)

        alone = _gen(cfg, params, [p_short])
        with_long = _gen(cfg, params, [p_short, p_long])
        with_two = _gen(cfg, params, [p_short, p_other, p_long])

        np.testing.assert_array_equal(alone[0], with_long[0])
        np.testing.assert_array_equal(alone[0], with_two[0])
        # and the long neighbor (zero padding) is stable too
        np.testing.assert_array_equal(with_long[1], with_two[2])

    def test_pad_count_invariance(self, mixer_member):
        """Same prompt, different pad amounts -> same generated tokens."""
        _, cfg, params = mixer_member
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, VOCAB, size=7).astype(np.int32)
        ref = _gen(cfg, params, [prompt])[0]
        for pad in (4, 9):
            toks = jnp.asarray(np.pad(prompt, (pad, 0))[None])
            mask = jnp.asarray((np.arange(pad + len(prompt)) >= pad)[None])
            out = np.asarray(lm_mod.greedy_generate(
                cfg, params, toks, max_new=MAX_NEW, attn_mask=mask))
            np.testing.assert_array_equal(out[0], ref)

    def test_masked_prefill_matches_unpadded_logits(self, mixer_member):
        """Left-pad + mask reproduces the unpadded prefill's last-token
        logits (up to fp re-association from the shape change)."""
        _, cfg, params = mixer_member
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, VOCAB, size=7).astype(np.int32)

        tok = jnp.asarray(prompt[None])
        caches = lm_mod.init_caches(cfg, 1, tok.shape[1] + 4)
        ref, _ = lm_mod.apply_lm_prefill(cfg, params, tok, caches)

        pad = 6
        padded = jnp.asarray(np.pad(prompt, (pad, 0))[None])
        mask = jnp.asarray((np.arange(pad + len(prompt)) >= pad)[None])
        caches_p = lm_mod.init_caches(cfg, 1, padded.shape[1] + 4)
        out, _ = lm_mod.apply_lm_prefill(cfg, params, padded, caches_p,
                                         attn_mask=mask)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)

    def test_unmasked_padded_prefill_differs(self, mixer_member):
        """Control: without the mask, pad state/attendance leaks
        neighbor-length information into the logits (the bug being
        pinned out)."""
        _, cfg, params = mixer_member
        rng = np.random.default_rng(2)
        prompt = rng.integers(1, VOCAB, size=7).astype(np.int32)
        pad = 6
        padded = jnp.asarray(np.pad(prompt, (pad, 0))[None])
        mask = jnp.asarray((np.arange(pad + len(prompt)) >= pad)[None])

        caches_a = lm_mod.init_caches(cfg, 1, padded.shape[1] + 4)
        masked, _ = lm_mod.apply_lm_prefill(cfg, params, padded, caches_a,
                                            attn_mask=mask)
        caches_b = lm_mod.init_caches(cfg, 1, padded.shape[1] + 4)
        unmasked, _ = lm_mod.apply_lm_prefill(cfg, params, padded, caches_b)
        assert not np.allclose(np.asarray(masked), np.asarray(unmasked),
                               rtol=2e-4, atol=2e-5)


class TestRecurrentHandoff:
    """Prefill->decode handoff for recurrent caches: the state a masked
    padded prefill hands to decode equals the unpadded run's state (the
    recurrent analogue of the attention path's per-row ``pad_valid``)."""

    B, REAL, PAD = 2, 7, 6

    def _padded_pair(self, d_model, seed, scale=0.4):
        ks = jax.random.split(jax.random.key(seed), 2)
        x = jax.random.normal(ks[0], (self.B, self.REAL, d_model)) * scale
        junk = jax.random.normal(ks[1], (self.B, self.PAD, d_model)) * scale
        xp = jnp.concatenate([junk, x], axis=1)
        mask = jnp.asarray(
            (np.arange(self.PAD + self.REAL) >= self.PAD)[None]
            .repeat(self.B, axis=0))
        return x, xp, mask

    def _assert_state_close(self, solo, padded):
        jax.tree.map(
            lambda a, b_: np.testing.assert_allclose(
                np.asarray(b_), np.asarray(a), rtol=2e-4, atol=2e-5),
            solo, padded)

    def test_mamba_state(self):
        cfg = get_smoke_config("jamba-1.5-large-398b")
        p = ssm_mod.init_mamba(jax.random.key(0), cfg)
        x, xp, mask = self._padded_pair(cfg.d_model, 1)
        out, solo = ssm_mod.apply_mamba_train(cfg, p, x, return_state=True)
        out_p, padded = ssm_mod.apply_mamba_train(cfg, p, xp,
                                                  return_state=True, mask=mask)
        self._assert_state_close(solo, padded)
        np.testing.assert_allclose(np.asarray(out_p[:, self.PAD:]),
                                   np.asarray(out), rtol=2e-4, atol=2e-5)
        # handoff: one decode step from either state agrees
        x1 = jax.random.normal(jax.random.key(9), (self.B, 1, cfg.d_model))
        cache = {**ssm_mod.init_mamba_cache(cfg, self.B), **solo}
        cache_p = {**ssm_mod.init_mamba_cache(cfg, self.B), **padded}
        o1, _ = ssm_mod.apply_mamba_decode(cfg, p, x1, cache)
        o2, _ = ssm_mod.apply_mamba_decode(cfg, p, x1, cache_p)
        np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                                   rtol=2e-4, atol=2e-5)

    def test_mlstm_state(self):
        cfg = get_smoke_config("xlstm-1.3b")
        p = xlstm_mod.init_mlstm(jax.random.key(2), cfg)
        x, xp, mask = self._padded_pair(cfg.d_model, 3)
        out, solo = xlstm_mod.apply_mlstm_train(cfg, p, x, return_state=True)
        out_p, padded = xlstm_mod.apply_mlstm_train(cfg, p, xp,
                                                    return_state=True,
                                                    mask=mask)
        self._assert_state_close(solo, padded)
        np.testing.assert_allclose(np.asarray(out_p[:, self.PAD:]),
                                   np.asarray(out), rtol=2e-4, atol=2e-5)

    def test_slstm_state(self):
        cfg = get_smoke_config("xlstm-1.3b")
        p = xlstm_mod.init_slstm(jax.random.key(4), cfg)
        x, xp, mask = self._padded_pair(cfg.d_model, 5)
        out, solo = xlstm_mod.apply_slstm_train(cfg, p, x, return_state=True)
        out_p, padded = xlstm_mod.apply_slstm_train(cfg, p, xp,
                                                    return_state=True,
                                                    mask=mask)
        self._assert_state_close(solo, padded)
        np.testing.assert_allclose(np.asarray(out_p[:, self.PAD:]),
                                   np.asarray(out), rtol=2e-4, atol=2e-5)


class TestMoEPadCapacity:
    """Pad tokens must not consume expert capacity, shift real tokens'
    buffer positions, or bias the aux load-balance statistics."""

    T, REAL = 16, 4

    def _setup(self, cf=1.0):
        cfg = dataclasses.replace(
            get_smoke_config("granite-moe-1b-a400m"), capacity_factor=cf)
        p = moe_mod.init_moe(jax.random.key(7), cfg)
        # Pads share one embedding (a constant pad-token row), so under the
        # old accounting they pile onto the same top-k experts and exhaust
        # their capacity before the real tokens are placed.
        xr = jax.random.normal(jax.random.key(8), (self.REAL, cfg.d_model))
        padvec = jnp.tile(
            jax.random.normal(jax.random.key(9), (1, cfg.d_model)),
            (self.T - self.REAL, 1))
        x = jnp.concatenate([padvec, xr], axis=0)
        valid = np.arange(self.T) >= self.T - self.REAL
        return cfg, p, x, xr, jnp.asarray(valid)

    @staticmethod
    def _kept(gate_idx, n_experts, cap, counted):
        """Replicate the dispatcher's flattened (token-major, slot-minor)
        cumulative position accounting in plain numpy."""
        counts = np.zeros(n_experts, np.int64)
        kept = np.zeros(gate_idx.shape, bool)
        for t in range(gate_idx.shape[0]):
            for j, ex in enumerate(gate_idx[t]):
                if not counted[t]:
                    continue
                kept[t, j] = counts[ex] < cap
                counts[ex] += 1
        return kept

    def test_old_accounting_drops_real_token_new_does_not(self):
        """The acceptance case: under the old (pad-counting) capacity
        accounting a real token loses expert slots to pads; the
        pad-excluded accounting restores exactly the solo run's placement."""
        cfg, p, x, xr, valid = self._setup()
        probs = np.asarray(moe_mod._router_probs(p, x))
        gate_idx = np.asarray(jax.lax.top_k(jnp.asarray(probs), cfg.top_k)[1])
        valid_np = np.asarray(valid)

        cap_old = moe_mod._capacity(self.T, cfg)
        cap_new = moe_mod._capacity(self.REAL, cfg)
        kept_old = self._kept(gate_idx, cfg.n_experts, cap_old,
                              np.ones(self.T, bool))
        kept_new = self._kept(gate_idx, cfg.n_experts, cap_new, valid_np)

        kept_solo = self._kept(gate_idx[self.T - self.REAL:], cfg.n_experts,
                               cap_new, np.ones(self.REAL, bool))
        real = slice(self.T - self.REAL, self.T)
        # pads exhausted capacity a real token needed...
        assert (~kept_old[real] & kept_new[real]).any()
        # ...and the pad-excluded accounting matches the solo run slot-for-slot
        np.testing.assert_array_equal(kept_new[real], kept_solo)

    def test_pad_excluded_dispatch_matches_solo_run(self):
        cfg, p, x, xr, valid = self._setup()
        out_new, aux_new = moe_mod._dispatch_combine(cfg, p, x, valid=valid)
        out_solo, aux_solo = moe_mod._dispatch_combine(cfg, p, xr)
        out_old, aux_old = moe_mod._dispatch_combine(cfg, p, x)
        real = slice(self.T - self.REAL, self.T)
        np.testing.assert_allclose(np.asarray(out_new[real]),
                                   np.asarray(out_solo), rtol=1e-6, atol=1e-6)
        # old accounting visibly corrupts a real token's output
        assert not np.allclose(np.asarray(out_old[real]),
                               np.asarray(out_solo), rtol=1e-3, atol=1e-4)
        # pads don't write anything under the mask
        np.testing.assert_array_equal(
            np.asarray(out_new[: self.T - self.REAL]), 0.0)

    def test_aux_loss_excludes_pads(self):
        cfg, p, x, xr, valid = self._setup()
        _, aux_new = moe_mod._dispatch_combine(cfg, p, x, valid=valid)
        _, aux_solo = moe_mod._dispatch_combine(cfg, p, xr)
        _, aux_old = moe_mod._dispatch_combine(cfg, p, x)
        assert np.isclose(float(aux_new), float(aux_solo), rtol=1e-6)
        assert not np.isclose(float(aux_old), float(aux_solo), rtol=1e-3)

    def test_moe_train_rows_masked_independently(self):
        """apply_moe_train threads a per-row mask: a padded row's real
        tokens match the same row served unpadded."""
        cfg, p, x, xr, valid = self._setup()
        xb = jnp.stack([x, x])                          # (2, T, D)
        mask = jnp.stack([valid, jnp.ones_like(valid)])
        out, _ = moe_mod.apply_moe_train(cfg, p, xb, mask=mask)
        out_solo, _ = moe_mod.apply_moe_train(cfg, p, xr[None])
        np.testing.assert_allclose(
            np.asarray(out[0, self.T - self.REAL:]),
            np.asarray(out_solo[0]), rtol=1e-6, atol=1e-6)


class TestMoERankChunking:
    """Capacity groups past ``seq_chunk`` are chunks of *valid-token rank*,
    not absolute position: left padding can no longer shift a real token's
    group boundary, so batch invariance extends beyond ``seq_chunk``."""

    def _setup(self, cf=1.0):
        cfg = dataclasses.replace(
            get_smoke_config("granite-moe-1b-a400m"), capacity_factor=cf)
        p = moe_mod.init_moe(jax.random.key(11), cfg)
        return cfg, p

    def _padded_pair(self, cfg, real, pad, seed=0):
        xr = jax.random.normal(jax.random.key(seed), (1, real, cfg.d_model))
        junk = jax.random.normal(jax.random.key(seed + 1),
                                 (1, pad, cfg.d_model))
        xp = jnp.concatenate([junk, xr], axis=1)
        m_solo = jnp.ones((1, real), bool)
        m_pad = jnp.asarray((np.arange(pad + real) >= pad)[None])
        return xr, m_solo, xp, m_pad

    def test_invariance_beyond_seq_chunk(self):
        """REAL > seq_chunk: the padded row's real-token outputs and aux
        loss equal the solo run's — the old absolute-position grouping
        split them at different boundaries (the gap being closed)."""
        cfg, p = self._setup()
        chunk = 8
        xr, m_solo, xp, m_pad = self._padded_pair(cfg, real=20, pad=6)
        out_solo, aux_solo = moe_mod.apply_moe_train(
            cfg, p, xr, seq_chunk=chunk, mask=m_solo)
        out_pad, aux_pad = moe_mod.apply_moe_train(
            cfg, p, xp, seq_chunk=chunk, mask=m_pad)
        np.testing.assert_allclose(np.asarray(out_pad[:, 6:]),
                                   np.asarray(out_solo),
                                   rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(float(aux_pad), float(aux_solo),
                                   rtol=1e-5)
        # pads emit exactly nothing
        np.testing.assert_array_equal(np.asarray(out_pad[:, :6]), 0.0)

    def test_pad_crossing_chunk_boundary(self):
        """The regression shape: pads push a real token across what used
        to be its position-chunk boundary; rank grouping keeps it in the
        same capacity group as its unpadded self."""
        cfg, p = self._setup()
        chunk = 8
        # real=10 (solo groups: ranks 0-7, 8-9); pad=7 shifts positions by 7
        xr, m_solo, xp, m_pad = self._padded_pair(cfg, real=10, pad=7,
                                                  seed=4)
        out_solo, _ = moe_mod.apply_moe_train(
            cfg, p, xr, seq_chunk=chunk, mask=m_solo)
        out_pad, _ = moe_mod.apply_moe_train(
            cfg, p, xp, seq_chunk=chunk, mask=m_pad)
        np.testing.assert_allclose(np.asarray(out_pad[:, 7:]),
                                   np.asarray(out_solo),
                                   rtol=2e-5, atol=1e-6)

    @pytest.mark.slow
    def test_invariance_at_default_seq_chunk(self):
        """Same property at the production seq_chunk=512 boundary."""
        cfg, p = self._setup()
        xr, m_solo, xp, m_pad = self._padded_pair(cfg, real=530, pad=30,
                                                  seed=6)
        out_solo, aux_solo = moe_mod.apply_moe_train(cfg, p, xr, mask=m_solo)
        out_pad, aux_pad = moe_mod.apply_moe_train(cfg, p, xp, mask=m_pad)
        np.testing.assert_allclose(np.asarray(out_pad[:, 30:]),
                                   np.asarray(out_solo),
                                   rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(float(aux_pad), float(aux_solo),
                                   rtol=1e-5)


class TestMoEDecodeNoDrop:
    """Decode-path capacity can no longer drop real tokens: capacity per
    chunk equals the chunk's token count, so even a batch that routes every
    token to one expert (top-k << E worst case) keeps them all."""

    def _setup(self):
        # top-1 with E=8: the llama4-maverick (128e top-1) shape, reduced.
        cfg = dataclasses.replace(
            get_smoke_config("granite-moe-1b-a400m"),
            n_experts=8, top_k=1, capacity_factor=1.0, n_shared_experts=0)
        p = moe_mod.init_moe(jax.random.key(21), cfg)
        # Identical rows: all B tokens route to the same expert.
        row = jax.random.normal(jax.random.key(22), (1, cfg.d_model))
        x = jnp.tile(row, (16, 1))[:, None, :]          # (B=16, 1, D)
        return cfg, p, x

    def test_old_accounting_drops_new_does_not(self):
        """Under the old DECODE_CAPACITY_FACTOR=4 accounting this batch
        loses real tokens (cap = ceil(4*16*1/8) = 8 < 16 same-expert
        tokens); the no-drop decode path matches the solo run for every
        token, including the ones the old policy dropped."""
        cfg, p, x = self._setup()
        probs = np.asarray(moe_mod._router_probs(p, x.reshape(-1,
                                                              cfg.d_model)))
        gate_idx = np.asarray(jax.lax.top_k(jnp.asarray(probs),
                                            cfg.top_k)[1])
        assert len(set(gate_idx[:, 0].tolist())) == 1   # one hot expert
        b = x.shape[0]
        cap_old = moe_mod._capacity(b, cfg, 4.0)        # the removed cliff
        # Old accounting: positions beyond cap_old were dropped.
        dropped_old = max(0, b - cap_old)
        assert dropped_old > 0

        moe_mod.DECODE_DROP_LOG = []
        try:
            out = moe_mod.apply_moe_decode(cfg, p, x)
            solo = moe_mod.apply_moe_decode(cfg, p, x[:1])
        finally:
            drops = sum(moe_mod.DECODE_DROP_LOG)
            moe_mod.DECODE_DROP_LOG = None
        assert drops == 0
        # every token (identical input) gets the solo run's exact output —
        # under the old policy tokens past cap_old got zero expert output
        for i in (0, cap_old, b - 1):
            np.testing.assert_allclose(np.asarray(out[i]),
                                       np.asarray(solo[0]),
                                       rtol=1e-6, atol=1e-6)
        assert float(jnp.abs(out).sum()) > 0.0

    def test_chunk_boundaries_do_not_change_results(self):
        """Chunked full-capacity dispatch is exact: capacity never binds,
        so a token's output is independent of its chunk neighbors."""
        cfg, p, _ = self._setup()
        x = jax.random.normal(jax.random.key(23), (7, 1, cfg.d_model))
        out_small = moe_mod.apply_moe_decode(cfg, p, x, chunk=2)
        out_big = moe_mod.apply_moe_decode(cfg, p, x, chunk=64)
        np.testing.assert_allclose(np.asarray(out_small),
                                   np.asarray(out_big),
                                   rtol=1e-6, atol=1e-6)

    def test_large_decode_batch_no_drops_logged(self):
        """Across a batch larger than DECODE_CHUNK, the in-dispatch drop
        counter stays zero (the runtime proof of the guarantee)."""
        cfg, p, _ = self._setup()
        x = jax.random.normal(jax.random.key(24),
                              (moe_mod.DECODE_CHUNK + 40, 1, cfg.d_model))
        moe_mod.DECODE_DROP_LOG = []
        try:
            moe_mod.apply_moe_decode(cfg, p, x)
        finally:
            drops = sum(moe_mod.DECODE_DROP_LOG)
            n_calls = len(moe_mod.DECODE_DROP_LOG)
            moe_mod.DECODE_DROP_LOG = None
        assert n_calls >= 1
        assert drops == 0
