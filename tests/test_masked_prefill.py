"""Masked prefill: left-padded generate micro-batches must not attend pads.

RoPE attention logits depend only on position differences, so a left-padded
row (positions uniformly shifted by its pad count) attends exactly as its
unpadded self once pad keys are masked in prefill and pad cache slots are
flagged invalid for decode. These tests pin the resulting property: a
request's output is invariant to its micro-batch neighbors.

Scope: attention mixers only — SSM/xLSTM masked scans and MoE capacity
dispatch under padding are ROADMAP follow-ups, so the tests use the dense
attention member (qwen3-0.6b smoke config).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm as lm_mod
from repro.serving.engine import pad_prompts, prompt_pad_mask

VOCAB = 64


@pytest.fixture(scope="module")
def member():
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm_mod.init_lm(jax.random.key(0), cfg)
    return cfg, params


def _gen(cfg, params, prompts, max_new=3):
    toks = pad_prompts(prompts)
    mask = prompt_pad_mask(prompts)
    return np.asarray(lm_mod.greedy_generate(
        cfg, params, toks, max_new=max_new, attn_mask=mask))


class TestPadMask:
    def test_mask_shape_and_alignment(self):
        prompts = [np.arange(3), np.arange(5)]
        mask = np.asarray(prompt_pad_mask(prompts))
        assert mask.shape == (2, 5)
        assert mask[0].tolist() == [False, False, True, True, True]
        assert mask[1].all()

    def test_batch_composition_invariance(self, member):
        """The same request generates identical tokens regardless of which
        (and how long) neighbors share its micro-batch."""
        cfg, params = member
        rng = np.random.default_rng(0)
        p_short = rng.integers(0, VOCAB, size=5).astype(np.int32)
        p_long = rng.integers(0, VOCAB, size=17).astype(np.int32)
        p_other = rng.integers(0, VOCAB, size=11).astype(np.int32)

        alone = _gen(cfg, params, [p_short])
        with_long = _gen(cfg, params, [p_short, p_long])
        with_two = _gen(cfg, params, [p_short, p_other, p_long])

        np.testing.assert_array_equal(alone[0], with_long[0])
        np.testing.assert_array_equal(alone[0], with_two[0])
        # and the long neighbor (zero padding) is stable too
        np.testing.assert_array_equal(with_long[1], with_two[2])

    def test_masked_prefill_matches_unpadded_logits(self, member):
        """Left-pad + mask reproduces the unpadded prefill's last-token
        logits (up to fp tolerance from shifted RoPE phases)."""
        cfg, params = member
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, VOCAB, size=7).astype(np.int32)

        tok = jnp.asarray(prompt[None])
        caches = lm_mod.init_caches(cfg, 1, tok.shape[1] + 4)
        ref, _ = lm_mod.apply_lm_prefill(cfg, params, tok, caches)

        pad = 6
        padded = jnp.asarray(np.pad(prompt, (pad, 0))[None])
        mask = jnp.asarray((np.arange(pad + len(prompt)) >= pad)[None])
        caches_p = lm_mod.init_caches(cfg, 1, padded.shape[1] + 4)
        out, _ = lm_mod.apply_lm_prefill(cfg, params, padded, caches_p,
                                         attn_mask=mask)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)

    def test_unmasked_padded_batch_differs(self, member):
        """Control: without the mask, pad attendance leaks neighbor-length
        information into the logits (this is the bug being fixed)."""
        cfg, params = member
        rng = np.random.default_rng(2)
        prompt = rng.integers(1, VOCAB, size=7).astype(np.int32)
        pad = 6
        padded = jnp.asarray(np.pad(prompt, (pad, 0))[None])
        mask = jnp.asarray((np.arange(pad + len(prompt)) >= pad)[None])

        caches_a = lm_mod.init_caches(cfg, 1, padded.shape[1] + 4)
        masked, _ = lm_mod.apply_lm_prefill(cfg, params, padded, caches_a,
                                            attn_mask=mask)
        caches_b = lm_mod.init_caches(cfg, 1, padded.shape[1] + 4)
        unmasked, _ = lm_mod.apply_lm_prefill(cfg, params, padded, caches_b)
        assert not np.allclose(np.asarray(masked), np.asarray(unmasked),
                               rtol=2e-4, atol=2e-5)
