"""Online adaptation subsystem: replay, drift, exploration, membership,
updater swap atomicity, and deterministic end-to-end replay.

Everything here runs on synthetic embeddings and stub pools — no LM
generation, no featurizer — so the whole module is CPU-fast.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.predictors import PREDICTORS
from repro.core.router import PredictiveRouter
from repro.online import (
    DriftDetector,
    ExplorationConfig,
    ExplorationPolicy,
    MembershipTracker,
    OnlineAdapter,
    OnlineUpdateConfig,
    ReplayBuffer,
)
from repro.serving import DONE, MicroBatchScheduler, Request, RoutedEngine, SchedulerConfig

DQ, K, DM = 16, 2, 4
COSTS = (0.2, 1.0)


def _emb(rng, n, sign=1.0):
    e = rng.normal(0, 0.4, size=(n, DQ)).astype(np.float32)
    e[:, : DQ // 2] += 0.8 * sign
    return e / np.linalg.norm(e, axis=1, keepdims=True)


class StubMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate


def make_engine(seed=0, centroids=True):
    rng = np.random.default_rng(seed)
    memb = rng.random((K, DM)).astype(np.float32)
    qp = PREDICTORS["attn"].init(jax.random.key(seed), DQ, K, DM)
    cp = {"w": np.zeros((DQ, K), np.float32),
          "b": np.asarray(COSTS, np.float32)}
    router = PredictiveRouter(
        "attn", "reg", qp, cp, memb, reward="R2", cost_scaler=None,
        centroids=_emb(rng, 4) if centroids else None)
    pool = [StubMember(f"m{i}", c) for i, c in enumerate(COSTS)]
    return RoutedEngine(router=router, pool=pool, lam=2.0)


def serve_round(adapter, emb, quality, now=0.0):
    """Score -> choose -> synthesize outcomes -> observe. Returns choices."""
    s_hat, c_hat = adapter.engine.score_emb(emb)
    choices = adapter.choose(s_hat, c_hat, adapter.engine.lam, now)
    reqs = []
    for e, m in zip(emb, choices):
        r = Request(text="", prompt=np.zeros(1, np.int32))
        r.q_emb, r.member, r.status = e, int(m), DONE
        r.cost = float(COSTS[int(m)] if int(m) < len(COSTS) else 0.1)
        reqs.append(r)
    quality_of = {r.rid: quality for r in reqs}
    adapter.quality_feedback = lambda req: float(
        quality_of[req.rid][req.member])
    adapter.observe(reqs, now)
    return choices


class TestReplayBuffer:
    def test_deterministic_sampling(self):
        rng = np.random.default_rng(0)
        embs = rng.random((200, DQ)).astype(np.float32)

        def build():
            buf = ReplayBuffer(capacity=64, recent_frac=0.25, seed=7)
            for i in range(200):
                buf.add(embs[i], i % K, i / 200.0, 0.1, float(i))
            return buf

        b1, b2 = build(), build()
        s1 = b1.sample(32)
        s2 = b2.sample(32)
        for key in ("q_emb", "member", "s", "c", "t"):
            np.testing.assert_array_equal(s1[key], s2[key])

    def test_capacity_and_recency(self):
        buf = ReplayBuffer(capacity=40, recent_frac=0.25, seed=0)
        for i in range(500):
            buf.add(np.zeros(DQ), 0, 0.0, 0.0, float(i))
        assert len(buf) <= 40
        # The recency ring holds exactly the newest items.
        recent_ts = [item[4] for item in buf._recent]
        assert recent_ts == list(map(float, range(490, 500)))
        # Reservoir holds a spread over the evicted past, not just the tail.
        res_ts = [item[4] for item in buf._reservoir]
        assert min(res_ts) < 250

    def test_stratified_sample_mixes_recent_and_old(self):
        buf = ReplayBuffer(capacity=100, recent_frac=0.2, seed=1)
        for i in range(400):
            buf.add(np.zeros(DQ), 0, 0.0, 0.0, float(i))
        s = buf.sample(60, recent_frac=0.5)
        n_recent = int((s["t"] >= 380).sum())
        assert 20 <= n_recent <= 40          # ~half from the ring
        assert (s["t"] < 380).any()

    def test_drop_member_remaps(self):
        buf = ReplayBuffer(capacity=32, seed=0)
        for i in range(30):
            buf.add(np.zeros(DQ), i % 3, 0.0, 0.0)
        buf.drop_member(1)
        counts = buf.member_counts(3)
        assert counts[2] == 0                # old member 2 shifted down to 1
        assert counts[0] == 10 and counts[1] == 10
        assert len(buf) == 20

    def test_sample_empty_returns_none(self):
        assert ReplayBuffer(capacity=8).sample(4) is None


class TestDriftDetector:
    def test_no_alarm_in_distribution(self):
        rng = np.random.default_rng(0)
        det = DriftDetector(window=32, threshold=3.0, seed=0)
        det.fit(_emb(rng, 300))
        assert not det.observe(_emb(rng, 200))
        assert det.alarms == 0

    def test_alarm_and_recovery_deterministic(self):
        def run():
            rng = np.random.default_rng(1)
            det = DriftDetector(window=32, threshold=3.0, patience=2, seed=0)
            det.fit(_emb(rng, 300))
            fired = []
            for _ in range(4):
                fired.append(det.observe(_emb(rng, 32)))        # in-dist
            for _ in range(6):
                fired.append(det.observe(_emb(rng, 32, -1.0)))  # shifted
            det.refit()                                          # recover
            for _ in range(4):
                fired.append(det.observe(_emb(rng, 32, -1.0)))
            return fired, det.alarms

        f1, a1 = run()
        f2, a2 = run()
        assert f1 == f2 and a1 == a2
        assert a1 >= 1
        assert not any(f1[:4])        # no false alarm pre-shift
        assert not any(f1[-4:])       # re-anchored: shifted regime is normal

    def test_observe_before_fit_raises(self):
        with pytest.raises(RuntimeError):
            DriftDetector(window=4).observe(np.zeros((4, DQ)))


class TestExplorationPolicy:
    def test_pure_exploit_is_argmax_with_bonus(self):
        pol = ExplorationPolicy(2, ExplorationConfig(epsilon=0.0, bonus=0.0))
        rewards = np.array([[0.2, 0.8], [0.9, 0.1]])
        choices, explored = pol.choose(rewards)
        assert choices.tolist() == [1, 0]
        assert not explored.any()

    def test_optimistic_bonus_prefers_unobserved(self):
        pol = ExplorationPolicy(2, ExplorationConfig(epsilon=0.0, bonus=0.5))
        pol.record(np.zeros(1000, np.int64))       # member 0 heavily observed
        rewards = np.tile([0.5, 0.2], (4, 1))      # raw argmax would say 0
        choices, _ = pol.choose(rewards)
        assert (choices == 1).all()                # bonus flips to unobserved

    def test_probation_mask_blocks_exploit(self):
        pol = ExplorationPolicy(2, ExplorationConfig(epsilon=0.0))
        rewards = np.tile([0.1, 0.9], (8, 1))
        choices, _ = pol.choose(rewards, exploit_mask=np.array([True, False]))
        assert (choices == 0).all()

    def test_zero_headroom_disables_exploration(self):
        pol = ExplorationPolicy(2, ExplorationConfig(epsilon=1.0, seed=0))
        rewards = np.tile([0.9, 0.1], (64, 1))
        _, explored = pol.choose(rewards, headroom=0.0)
        assert not explored.any()
        _, explored = pol.choose(rewards, headroom=1.0)
        assert explored.all()


class TestSwapAtomicity:
    def test_live_router_leaves_never_mutated(self):
        """Regression: an online-updated engine must never serve a
        partially-written param tree. Updates build fresh trees; the live
        router's leaves stay bit-identical until the single-reference
        swap, and the published router is a different object with every
        output-head leaf replaced."""
        eng = make_engine()
        adapter = OnlineAdapter(
            eng, lambda r: 0.5,
            config=OnlineUpdateConfig(update_every=10 ** 9, min_buffer=1,
                                      batch_size=8, steps_per_update=4),
            seed=0)
        rng = np.random.default_rng(0)
        for i in range(32):
            adapter.replay.add(_emb(rng, 1)[0], i % K, 0.5, 0.2)

        live = eng.router
        snapshot = jax.tree.map(lambda x: np.array(x, copy=True),
                                live.quality_params)
        adapter.updater.run_steps(adapter.replay, live.model_emb, 4)
        # mid-update: live router untouched
        assert eng.router is live
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), b), live.quality_params, snapshot)

        published = adapter.updater.publish(eng)
        assert eng.router is published and published is not live
        assert published.version == live.version + 1
        # old object still intact after the swap (readers holding it are safe)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), b), live.quality_params, snapshot)
        # and the update actually changed the published params
        diffs = jax.tree.map(
            lambda a, b: float(np.abs(np.asarray(a) - b).max()),
            published.quality_params, snapshot)
        assert max(jax.tree.leaves(diffs)) > 0

    def test_stale_or_same_version_publish_rejected(self):
        eng = make_engine()
        live = eng.router
        with pytest.raises(ValueError):
            eng.swap_router(live)                          # same object
        newer = live.with_updates()
        eng.swap_router(newer)
        with pytest.raises(ValueError):                    # stale version
            eng.swap_router(dataclasses.replace(live, version=live.version))
        assert eng.router is newer

    def test_swap_refreshes_pool_projections(self):
        eng = make_engine()
        eng._pool_proj = ("sentinel", "sentinel")
        eng.swap_router(eng.router.with_updates())
        assert eng._pool_proj is None

    def test_published_model_emb_not_aliased_to_membership_staging(self):
        """Regression: publish() must copy the membership tracker's staging
        model_emb — otherwise a later record_outcome for a probationary
        member mutates the LIVE router's embeddings in place (no version
        bump, stale cached pool projections)."""
        eng = make_engine()
        adapter = OnlineAdapter(
            eng, lambda r: 0.5,
            config=OnlineUpdateConfig(update_every=10 ** 9, min_buffer=1,
                                      batch_size=8, steps_per_update=2),
            seed=0)
        idx = adapter.add_member(StubMember("new", 0.05))
        rng = np.random.default_rng(0)
        for i in range(16):
            adapter.replay.add(_emb(rng, 1)[0], i % K, 0.5, 0.2)
        adapter._update(2)
        live = eng.router
        assert live.model_emb is not adapter.membership.model_emb
        frozen_row = np.array(live.model_emb[idx], copy=True)
        adapter.membership.record_outcome(idx, _emb(rng, 1)[0], 0.99)
        np.testing.assert_array_equal(np.asarray(live.model_emb[idx]),
                                      frozen_row)


class TestMembership:
    def test_add_member_probation_and_graduation(self):
        eng = make_engine()
        tracker = MembershipTracker(eng, min_outcomes=5)
        idx = tracker.add_member(StubMember("new", 0.05))
        assert idx == 2 and len(eng.pool) == 3
        assert eng.router.n_members == 3
        assert tracker.exploit_mask().tolist() == [True, True, False]

        rng = np.random.default_rng(0)
        for _ in range(5):
            tracker.record_outcome(idx, _emb(rng, 1)[0], 0.9)
        assert tracker.exploit_mask().all()
        # the cold-start row moved toward observed quality in hit clusters
        touched = tracker.model_emb[idx] != np.asarray(
            eng.router.model_emb)[:2].mean(0)
        assert touched.any()

    def test_new_member_scores_and_routes(self):
        eng = make_engine()
        adapter = OnlineAdapter(eng, lambda r: 0.5, seed=0)
        adapter.add_member(StubMember("new", 0.05))
        rng = np.random.default_rng(1)
        s_hat, c_hat = eng.score_emb(_emb(rng, 8))
        assert s_hat.shape == (8, 3) and c_hat.shape == (8, 3)
        # probation: exploitation never routes to the new member
        pol_choices = adapter.choose(s_hat, c_hat, 2.0)
        explored = adapter.last_explored
        assert ((pol_choices[~explored]) != 2).all()

    def test_established_member_ema_refresh_under_drift(self):
        """ROADMAP open item: with refresh_established, a graduated
        member's embedding row follows its drifted outcome centroid
        instead of waiting for predictor gradients."""
        eng = make_engine()
        tracker = MembershipTracker(eng, refresh_established=True,
                                    refresh_rate=0.2)
        rng = np.random.default_rng(3)
        # Member 0 is established (born graduated). Its true quality in
        # the cluster nearest these embeddings has drifted to ~0.9.
        emb = _emb(rng, 1)[0]
        centroids = np.asarray(eng.router.centroids, np.float32)
        ci = int(np.argmin(np.sum((centroids - emb) ** 2, axis=1)))
        before = float(tracker.model_emb[0, ci])
        for _ in range(40):
            tracker.record_outcome(0, emb, 0.9)
        after = float(tracker.model_emb[0, ci])
        assert abs(after - 0.9) < abs(before - 0.9)   # moved toward truth
        assert after == pytest.approx(0.9, abs=0.01)  # EMA converged
        assert tracker.emb_dirty
        # Other clusters' entries are untouched.
        untouched = [c for c in range(centroids.shape[0]) if c != ci]
        np.testing.assert_array_equal(
            tracker.model_emb[0, untouched],
            np.asarray(eng.router.model_emb)[0, untouched])

    def test_established_refresh_off_by_default(self):
        eng = make_engine()
        tracker = MembershipTracker(eng)
        rng = np.random.default_rng(4)
        row = tracker.model_emb[0].copy()
        for _ in range(10):
            tracker.record_outcome(0, _emb(rng, 1)[0], 0.9)
        np.testing.assert_array_equal(tracker.model_emb[0], row)

    def test_remove_member_remaps_everything(self):
        eng = make_engine()
        adapter = OnlineAdapter(eng, lambda r: 0.5, seed=0)
        rng = np.random.default_rng(2)
        for i in range(12):
            adapter.replay.add(_emb(rng, 1)[0], i % 2, 0.5, 0.1)
        adapter.remove_member(0)
        assert len(eng.pool) == 1 and eng.router.n_members == 1
        assert adapter.policy.n_members == 1
        assert adapter.replay.member_counts(1)[0] == 6   # old member-1 only
        s_hat, _ = eng.score_emb(_emb(rng, 4))
        assert s_hat.shape == (4, 1)


class TestEndToEndDeterminism:
    def _run(self):
        eng = make_engine(seed=3)
        adapter = OnlineAdapter(
            eng, lambda r: 0.5,
            config=OnlineUpdateConfig(update_every=16, steps_per_update=4,
                                      batch_size=16, min_buffer=8,
                                      burst_steps=8),
            exploration=ExplorationConfig(epsilon=0.2, seed=0),
            drift=DriftDetector(window=16, threshold=3.0, seed=0).fit(
                _emb(np.random.default_rng(9), 200)),
            seed=0)
        rng = np.random.default_rng(11)
        all_choices = []
        for bi in range(12):
            sign = 1.0 if bi < 6 else -1.0
            quality = np.array([0.4, 0.8]) if bi < 6 else np.array([0.8, 0.3])
            choices = serve_round(adapter, _emb(rng, 16, sign), quality,
                                  now=bi * 0.1)
            all_choices.append(choices.tolist())
        return adapter, all_choices

    def test_replay_drift_and_swaps_replay_identically(self):
        a1, c1 = self._run()
        a2, c2 = self._run()
        assert c1 == c2
        assert a1.stats == a2.stats
        assert a1.engine.router.version == a2.engine.router.version
        jax.tree.map(
            lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                       np.asarray(y)),
            a1.engine.router.quality_params,
            a2.engine.router.quality_params)
        assert a1.engine.router.version >= 2          # updates actually ran
        assert a1.stats["outcomes"] == 12 * 16


class TestSchedulerIntegration:
    class FakeOnlineEngine:
        """Minimal engine exposing the online scoring surface."""

        def __init__(self):
            self.pool = [StubMember("m0", 0.2), StubMember("m1", 1.0)]
            self.lam = 2.0

        class _Router:
            reward = "R2"

        router = _Router()

        def embed(self, texts):
            rng = np.random.default_rng(len(texts))
            return rng.random((len(texts), DQ)).astype(np.float32)

        def score_emb(self, q_emb):
            b = len(q_emb)
            return (np.tile([0.4, 0.9], (b, 1)),
                    np.tile([0.2, 1.0], (b, 1)))

        def generate_member(self, mi, prompts, max_new=8):
            outs = [np.zeros(max_new, np.int32) for _ in prompts]
            return outs, self.pool[mi].cost_rate * len(prompts)

    def test_scheduler_threads_outcomes_through_adapter(self):
        eng = self.FakeOnlineEngine()
        observed = []

        class SpyAdapter:
            last_explored = np.zeros(0, bool)

            def choose(self, s_hat, c_hat, lam, now):
                self.last_explored = np.zeros(len(s_hat), bool)
                return np.argmax(s_hat * np.exp(-c_hat / lam), axis=1)

            def observe(self, served, now):
                observed.extend(served)

        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=8, max_batch=8),
            service_time=lambda kind, n, wall: 1e-3,
            adapter=SpyAdapter())
        for i in range(6):
            sched.queue.offer(
                Request(text=str(i), prompt=np.zeros(4, np.int32),
                        max_new=2), 0.0)
        served = sched.dispatch()
        assert len(served) == 6 and len(observed) == 6
        assert all(r.q_emb is not None and r.q_emb.shape == (DQ,)
                   for r in observed)
        assert all(r.status == DONE for r in observed)


class TestStagedOutcomes:
    """Delayed quality feedback: staged outcomes, out-of-order delivery,
    tick-based flush, timeout drop — no training on placeholder scores."""

    def _adapter(self, timeout_s=None, **kw):
        from repro.online import OutcomeStage

        eng = make_engine(seed=5)
        pending = {}

        def feedback(req):
            return pending.get(req.rid)   # None until delivered

        adapter = OnlineAdapter(
            eng, feedback,
            config=OnlineUpdateConfig(update_every=10**9, min_buffer=4,
                                      batch_size=8),
            stage=OutcomeStage(timeout_s=timeout_s), seed=5, **kw)
        return adapter

    def _reqs(self, n, seed=0, member=0):
        rng = np.random.default_rng(seed)
        reqs = []
        for _ in range(n):
            r = Request(text="t", prompt=np.zeros(1, np.int32))
            r.q_emb = rng.normal(0, 1, DQ).astype(np.float32)
            r.member, r.cost, r.status = member, COSTS[member], DONE
            reqs.append(r)
        return reqs

    def test_no_placeholder_training_and_flush_in_staged_order(self):
        adapter = self._adapter()
        reqs = self._reqs(3, seed=1)
        adapter.observe(reqs, now=0.0)
        assert len(adapter.replay) == 0           # nothing committed yet
        assert adapter.stats["staged"] == 3
        # deliver OUT OF ORDER: r2, r0, r1
        adapter.deliver_feedback(reqs[2].rid, 0.9, now=0.1)
        adapter.deliver_feedback(reqs[0].rid, 0.1, now=0.2)
        adapter.deliver_feedback(reqs[1].rid, 0.5, now=0.3)
        adapter.tick(now=0.4)
        assert adapter.stats["outcomes"] == 3
        assert adapter.stats["delayed_resolved"] == 3
        # committed in STAGED order (r0, r1, r2), not delivery order
        scores = [s for (_, _, s, _, _) in adapter.replay._recent]
        assert scores == [0.1, 0.5, 0.9]

    def test_partial_delivery_flushes_only_resolved(self):
        adapter = self._adapter()
        reqs = self._reqs(3, seed=2)
        adapter.observe(reqs, now=0.0)
        adapter.deliver_feedback(reqs[1].rid, 0.7, now=0.1)
        adapter.tick(now=0.2)
        assert adapter.stats["outcomes"] == 1
        assert len(adapter.stage) == 2            # two still pending

    def test_feedback_before_staging_is_held(self):
        """The feedback channel can race completion: an early delivery
        resolves the outcome the moment it is staged."""
        adapter = self._adapter()
        reqs = self._reqs(1, seed=3)
        adapter.deliver_feedback(reqs[0].rid, 0.8, now=0.0)   # early
        assert adapter.stage.early_deliveries == 1
        adapter.observe(reqs, now=0.1)
        # observe() ticks: the already-resolved outcome commits immediately
        assert adapter.stats["outcomes"] == 1
        assert [s for (_, _, s, _, _) in adapter.replay._recent] == [0.8]

    def test_timeout_drops_never_trains_on_guess(self):
        adapter = self._adapter(timeout_s=1.0)
        reqs = self._reqs(2, seed=4)
        adapter.observe(reqs, now=0.0)
        adapter.deliver_feedback(reqs[0].rid, 0.6, now=0.5)
        adapter.tick(now=0.5)
        adapter.tick(now=5.0)                     # r1's feedback never came
        assert adapter.stats["outcomes"] == 1
        assert adapter.stats["feedback_expired"] == 1
        assert len(adapter.stage) == 0
        # late delivery for the expired outcome is held, never committed
        adapter.deliver_feedback(reqs[1].rid, 0.2, now=6.0)
        adapter.tick(now=6.0)
        assert adapter.stats["outcomes"] == 1

    def test_delayed_feedback_simulator_end_to_end(self):
        from repro.online import DelayedFeedback

        eng = make_engine(seed=6)
        fb = DelayedFeedback(lambda req: 0.25 + 0.5 * req.member,
                             delay_s=0.1, jitter_s=0.05, seed=6)
        adapter = OnlineAdapter(
            eng, fb, feedback_source=fb,
            config=OnlineUpdateConfig(update_every=10**9, min_buffer=4),
            seed=6)
        reqs = self._reqs(4, seed=6)
        for i, r in enumerate(reqs):
            r.finish_s = 0.01 * i
        adapter.observe(reqs, now=0.05)
        assert adapter.stats["staged"] == 4 and len(adapter.replay) == 0
        adapter.tick(now=0.08)                    # before any delay elapsed
        assert adapter.stats["outcomes"] == 0
        adapter.tick(now=1.0)                     # all feedback due
        assert adapter.stats["outcomes"] == 4
        assert fb.in_flight == 0

    def test_mixed_immediate_and_staged(self):
        """quality_feedback may resolve some requests immediately and
        stage the rest; both streams commit exactly once."""
        adapter = self._adapter()
        reqs = self._reqs(4, seed=7)
        immediate = {reqs[0].rid: 0.3, reqs[2].rid: 0.9}
        adapter.quality_feedback = lambda r: immediate.get(r.rid)
        adapter.observe(reqs, now=0.0)
        assert adapter.stats["outcomes"] == 2
        assert adapter.stats["staged"] == 2
        adapter.deliver_feedback(reqs[1].rid, 0.5, now=0.1)
        adapter.deliver_feedback(reqs[3].rid, 0.6, now=0.1)
        adapter.tick(now=0.2)
        assert adapter.stats["outcomes"] == 4
