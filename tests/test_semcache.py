"""Semantic cache (cascade rung 0): admission/eviction/radius properties,
drift invalidation semantics, the per-request cost and queue-wait
accounting pins the cache rung depends on, and byte-identical obs replay
of a cached cascade run.

Property tests run through the ``_hypothesis_compat`` shim: real
hypothesis when installed, a bounded deterministic example grid otherwise.
"""
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.cascade import CascadeConfig, CascadeCoordinator, CascadePolicy
from repro.obs import ObsFlusher, TraceRecorder
from repro.online import DriftDetector
from repro.serving import (
    DONE,
    MicroBatchScheduler,
    PoolMember,
    REF_TOKENS_OUT,
    Request,
    RoutedEngine,
    SchedulerConfig,
    SemanticCache,
    calibrate_radius,
)

COSTS = (0.1, 1.0, 5.0)
QUAL = (0.4, 0.7, 0.95)
STD = (0.05, 0.05, 0.05)
D = 8


def emb_at(x: float, d: int = D) -> np.ndarray:
    e = np.zeros(d, np.float32)
    e[0] = x
    return e


def admit(cache, x, quality=1.0, cost=1.0, **kw):
    return cache.admit(emb_at(x), output=np.arange(4, dtype=np.int32),
                       member_name="m0", quality=quality, cost=cost, **kw)


def make_policy(reward="R2", **cfg):
    return CascadePolicy([0, 1, 2], CascadeConfig(**cfg), reward=reward)


# ---------------------------------------------------------------------------
# Admission / eviction / radius properties
# ---------------------------------------------------------------------------


class TestAdmissionEviction:
    def test_quality_floor_rejects(self):
        c = SemanticCache(0.5, cap=4, quality_floor=0.25)
        assert not admit(c, 0.0, quality=0.1)
        assert not admit(c, 0.0, quality=float("nan"))
        assert len(c) == 0
        assert admit(c, 0.0, quality=0.3)
        assert len(c) == 1

    def test_within_radius_refreshes_not_appends(self):
        c = SemanticCache(0.5, cap=4)
        admit(c, 0.0, quality=0.5)
        admit(c, 0.1, quality=0.9)           # within radius: refresh in place
        assert len(c) == 1
        assert c.stats["refreshed"] == 1
        hit = c.match(emb_at(0.05))[0]
        assert hit is not None
        assert c._entries[hit[0]].quality == 0.9

    def test_lru_evicts_least_recently_used(self):
        c = SemanticCache(0.4, cap=2)
        admit(c, 0.0)
        admit(c, 10.0)
        # Touch entry 0 (a served hit bumps its LRU tick)...
        v = c.decide(c.match(emb_at(0.0))[0], lam=10.0)
        assert v.serve
        # ...so a third admission evicts the *untouched* entry at 10.0.
        admit(c, 20.0)
        assert len(c) == 2 and c.stats["evicted"] == 1
        assert c.match(emb_at(0.0))[0] is not None
        assert c.match(emb_at(10.0))[0] is None
        assert c.match(emb_at(20.0))[0] is not None

    @settings(max_examples=32, deadline=None)
    @given(st.integers(1, 8), st.integers(1, 40))
    def test_cap_never_exceeded(self, cap, n):
        c = SemanticCache(1e-3, cap=cap)   # tiny radius: no refreshes
        for i in range(n):
            admit(c, float(i))
            assert len(c) <= cap
        assert c.stats["admitted"] == n
        assert c.stats["evicted"] == max(0, n - cap)

    @settings(max_examples=32, deadline=None)
    @given(st.floats(0.05, 1.0), st.floats(1.05, 3.0), st.floats(0.0, 3.0))
    def test_radius_serve_monotone(self, r1, scale, x):
        """A query served at radius r is served at any radius r' > r
        (no policy installed: the rung degrades to the radius threshold)."""
        small = SemanticCache(r1, cap=4)
        big = SemanticCache(r1 * scale, cap=4)
        admit(small, 0.0)
        admit(big, 0.0)
        v_small = small.decide(small.match(emb_at(x))[0], lam=10.0)
        v_big = big.decide(big.match(emb_at(x))[0], lam=10.0)
        if v_small.serve:
            assert v_big.serve
        assert v_small.serve == (x <= r1 + 1e-6)

    @settings(max_examples=32, deadline=None)
    @given(st.floats(0.0, 0.5), st.floats(0.0, 1.5), st.floats(2.0, 50.0))
    def test_rung0_escalation_monotone_in_sigma(self, s1, ds, lam):
        """decide_rung0 never flips escalate -> stop as the cache
        confidence spread widens: the stop value only degrades with
        sigma while escalation candidates are untouched."""
        p = make_policy("R2")
        kw = dict(q_cache=0.8, s_hat=np.asarray(QUAL),
                  s_std=np.asarray(STD), c_hat=np.asarray(COSTS), lam=lam)
        d1 = p.decide_rung0(sigma_cache=s1, **kw)
        d2 = p.decide_rung0(sigma_cache=s1 + ds, **kw)
        if d1.escalate:
            assert d2.escalate

    def test_calibrate_radius_on_clustered_corpus(self):
        rng = np.random.default_rng(0)
        emb = rng.standard_normal((200, D)).astype(np.float32)
        r = calibrate_radius(emb)
        assert r > 0
        # The radius is a low quantile of NN distances: most points'
        # nearest neighbors sit at or beyond it.
        d2 = ((emb[None] - emb[:, None]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        nn = np.sqrt(d2.min(axis=1))
        assert np.mean(nn >= r) > 0.8


class TestDriftInvalidation:
    def test_probe_marks_stale_and_fresh_admission_rearms(self):
        c = SemanticCache(0.5, cap=4, invalidate="probe")
        admit(c, 0.0, quality=0.9)
        c.on_drift_alarm()
        v = c.decide(c.match(emb_at(0.0))[0], lam=10.0)
        assert not v.serve and v.reason == "stale"
        assert c.stats["stale_hits"] == 1
        # A fresh outcome inside the region refreshes the entry in place
        # and clears the stale mark.
        admit(c, 0.1, quality=0.8)
        v2 = c.decide(c.match(emb_at(0.0))[0], lam=10.0)
        assert v2.serve and v2.entry.quality == 0.8

    def test_flush_drops_everything(self):
        c = SemanticCache(0.5, cap=4, invalidate="flush")
        admit(c, 0.0)
        admit(c, 10.0)
        c.on_drift_alarm()
        assert len(c) == 0 and c.stats["flushes"] == 1
        assert c.match(emb_at(0.0))[0] is None

    def test_cache_owned_detector_fires_hook(self):
        rng = np.random.default_rng(0)
        ref = rng.standard_normal((128, D)).astype(np.float32)
        det = DriftDetector(window=16, patience=1).fit(ref)
        c = SemanticCache(0.5, cap=4, drift=det)
        admit(c, 0.0)
        shifted = ref[:32] + 25.0
        c.observe_queries(shifted, now=1.0)
        assert c.stats["invalidations"] >= 1


# ---------------------------------------------------------------------------
# Satellite pins: delivered-work pricing and true queue-wait accounting
# ---------------------------------------------------------------------------


class FakeMember:
    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate

    def generate(self, prompts, max_new=8, attn_mask=None):
        return np.zeros((len(prompts), max_new), np.int32)


class TestDeliveredWorkPricing:
    def test_chunk_mates_with_different_caps_pay_different_dollars(self):
        """Pinned (token-blind cost bug): two requests in one micro-batch
        with different ``max_new`` caps must be charged different $ —
        prefill plus each request's OWN delivered tokens, never an even
        split of a flat per-request price."""
        eng = RoutedEngine(router=None, pool=[FakeMember("m0", 2.0)])
        prompts = [np.zeros(3, np.int32), np.zeros(5, np.int32)]
        outs, costs = eng.generate_member(0, prompts, max_new=8,
                                          max_new_per_req=[2, 8])
        per_tok = 2.0 / REF_TOKENS_OUT
        assert costs.shape == (2,)
        assert costs[0] == pytest.approx(per_tok * (3 + 2))
        assert costs[1] == pytest.approx(per_tok * (5 + 8))
        assert costs[0] != costs[1]

    def test_scheduler_threads_per_request_costs(self):
        eng = RoutedEngine(router=None, pool=[FakeMember("m0", 2.0)])
        eng.lam = 10.0
        eng.score_texts = lambda texts: (
            np.ones((len(texts), 1)), np.ones((len(texts), 1)))
        eng.choose = lambda s, c, lam=None: np.zeros(len(s), np.int64)
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=4, max_batch=4),
            service_time=lambda kind, n, wall: 1e-3)
        short = Request(text="a", prompt=np.zeros(3, np.int32), max_new=2,
                        arrival_s=0.0)
        long = Request(text="b", prompt=np.zeros(3, np.int32), max_new=8,
                       arrival_s=0.0)
        sched.queue.offer(short, 0.0)
        sched.queue.offer(long, 0.0)
        served = sched.dispatch()
        assert {r.status for r in served} == {DONE}
        per_tok = 2.0 / REF_TOKENS_OUT
        assert short.cost == pytest.approx(per_tok * (3 + 2))
        assert long.cost == pytest.approx(per_tok * (3 + 8))
        assert short.cost < long.cost
        # Telemetry sums the real per-request charges, not n * flat.
        assert float(np.sum(sched.telemetry.member_spend)) == pytest.approx(
            short.cost + long.cost)


class FakeCascadeEngine:
    """Cascade scoring surface with per-text belief tables (test stub)."""

    def __init__(self, quality_of=None, lam=10.0):
        self.pool = [FakeMember(f"m{i}", c) for i, c in enumerate(COSTS)]
        self.lam = lam
        self.quality_of = quality_of or {}

    def embed(self, texts):
        self._last_texts = list(texts)
        return np.zeros((len(texts), 4), np.float32)

    def score_emb_uncertainty(self, q_emb):
        b = len(q_emb)
        s = np.stack([
            np.asarray(self.quality_of.get(t, QUAL), np.float64)
            for t in self._last_texts[:b]])
        return s, np.tile(STD, (b, 1)), np.tile(COSTS, (b, 1))

    def score_emb(self, q_emb):
        s, _, c = self.score_emb_uncertainty(q_emb)
        return s, c

    def score_texts(self, texts):
        self.embed(texts)
        return self.score_emb(np.zeros((len(texts), 4), np.float32))

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8):
        outs = [np.full(max_new, mi, np.int32) for _ in prompts]
        return outs, self.pool[mi].cost_rate * len(prompts)


class TestQueueWaitAccounting:
    def test_cascade_wait_excludes_earlier_legs_service(self):
        """Pinned (queue-wait pollution bug): an escalated request's
        queued_s is the SUM of its per-leg waits — earlier legs'
        generation time must never be booked as queueing."""
        eng = FakeCascadeEngine(lam=10.0)
        coord = CascadeCoordinator(make_policy("R2"))
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=16, max_batch=16),
            cascade=coord, service_time=lambda kind, n, wall: 1e-3)
        r = Request(text="q", prompt=np.zeros(4, np.int32), max_new=2,
                    arrival_s=0.0)
        r.forced_member = 0
        r.forced_member_name = "m0"
        sched.queue.offer(r, 0.0)
        while not r.finalized:
            sched.dispatch()
        assert r.leg >= 2                      # it escalated
        e2e = r.finish_s - r.arrival_s
        gen_time = r.leg * 1e-3                # one generate advance per leg
        # True wait: arrival->service for leg 1, readmit->service after.
        # Each leg adds exactly the 1e-3 scoring advance of its dispatch.
        assert r.queued_s == pytest.approx(r.leg * 1e-3)
        # The old bug booked leg-1 generation into the last leg's wait:
        # queued_s would be finish-side, violating wait + service <= e2e.
        assert r.queued_s <= e2e - gen_time + 1e-9
        assert r.queued_s < e2e


# ---------------------------------------------------------------------------
# Byte-identical obs replay of a cached cascade run
# ---------------------------------------------------------------------------


class SemCacheReplayEngine(FakeCascadeEngine):
    """Deterministic embeddings per text; recovers texts from q_emb rows so
    scoring stays correct for the post-cache-rung SUBSET of a batch."""

    def __init__(self, emb_of, **kw):
        super().__init__(**kw)
        self.emb_of = {t: np.asarray(e, np.float32)
                       for t, e in emb_of.items()}
        self._text_of = {e.tobytes(): t for t, e in self.emb_of.items()}

    def embed(self, texts):
        return np.stack([self.emb_of[t] for t in texts])

    def score_emb_uncertainty(self, q_emb):
        texts = [self._text_of[np.asarray(r, np.float32).tobytes()]
                 for r in q_emb]
        s = np.stack([
            np.asarray(self.quality_of.get(t, QUAL), np.float64)
            for t in texts])
        b = len(texts)
        return s, np.tile(STD, (b, 1)), np.tile(COSTS, (b, 1))


def _cached_cascade_run(out_dir: str) -> str:
    rng = np.random.default_rng(7)
    centers = rng.standard_normal((4, D)).astype(np.float32)
    texts, emb_of = [], {}
    for j in range(4):
        for k in range(6):
            t = f"c{j}.v{k}"
            texts.append(t)
            emb_of[t] = (centers[j]
                         + 0.03 * rng.standard_normal(D).astype(np.float32))
    eng = SemCacheReplayEngine(emb_of, lam=25.0)
    policy = make_policy("R2", max_legs=3)
    coord = CascadeCoordinator(policy)
    det = DriftDetector(window=16, patience=1).fit(
        np.stack([emb_of[t] for t in texts]), centers)
    cache = SemanticCache(1.0, cap=16, policy=policy, drift=det)
    recorder = TraceRecorder(label="semcache-replay")
    flusher = ObsFlusher(out_dir, recorder=recorder, scrape_every_s=5e-3,
                         label="semcache-replay")
    sched = MicroBatchScheduler(
        eng, SchedulerConfig(score_batch=8, max_batch=8),
        cascade=coord, semcache=cache, tracer=recorder.scoped(0),
        flusher=flusher, service_time=lambda kind, n, wall: 1e-3)
    reqs = [Request(text=texts[i % len(texts)],
                    prompt=np.zeros(4, np.int32), max_new=2,
                    arrival_s=i * 1e-3)
            for i in range(48)]
    summary = sched.run_trace(reqs)
    flusher.finalize(sched.clock.now)
    assert summary["completed"] == 48
    assert cache.stats["served"] > 0           # the rung actually fired
    return recorder.to_json()


class TestCachedRunReplay:
    def test_obs_dir_byte_identical_across_replays(self, tmp_path):
        d1, d2 = str(tmp_path / "run1"), str(tmp_path / "run2")
        t1 = _cached_cascade_run(d1)
        t2 = _cached_cascade_run(d2)
        assert t1 == t2
        names1, names2 = sorted(os.listdir(d1)), sorted(os.listdir(d2))
        assert names1 == names2 and names1
        for n in names1:
            with open(os.path.join(d1, n), "rb") as f1, \
                    open(os.path.join(d2, n), "rb") as f2:
                assert f1.read() == f2.read(), n
