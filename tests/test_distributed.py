"""Multi-worker serving plane: replay-merge determinism, stale-version
swap rejection across workers, crash/rejoin, shared budget ledger.

Workers here use stub pool members (no LM generation) and a hash-based
text embedder, so the whole module is CPU-fast; the real-engine path is
covered by benchmarks/distributed_bench.py and the serve driver.
"""
import dataclasses
import hashlib

import jax
import numpy as np
import pytest

from repro.core.predictors import PREDICTORS
from repro.core.router import PredictiveRouter
from repro.distributed import (
    Coordinator,
    PlaneEvent,
    ServingPlane,
    SharedBudgetLedger,
    SyncConfig,
    WorkerNode,
)
from repro.online import OnlineAdapter, OnlineUpdateConfig
from repro.serving import (
    MicroBatchScheduler,
    Request,
    RoutedEngine,
    SchedulerConfig,
    TraceConfig,
    default_service_model,
    make_trace,
)
from repro.serving.scheduler import SimClock

DQ, K, DM = 16, 2, 4
COSTS = (0.2, 1.0)
VOCAB = 32


def _text_emb(text: str) -> np.ndarray:
    h = int.from_bytes(hashlib.blake2s(text.encode(), digest_size=4).digest(),
                       "little")
    e = np.random.default_rng(h).normal(0, 1, DQ).astype(np.float32)
    return e / np.linalg.norm(e)


@dataclasses.dataclass
class StubEngine(RoutedEngine):
    """RoutedEngine with a cheap deterministic embedder (no featurizer)."""

    def embed(self, texts):
        return np.stack([_text_emb(t) for t in texts])


class StubGenMember:
    """Pool member whose generate is a constant-token stub."""

    def __init__(self, name, cost_rate):
        self.name, self.cost_rate = name, cost_rate

    def generate(self, prompts, max_new=8, attn_mask=None):
        return np.zeros((int(np.asarray(prompts).shape[0]), max_new),
                        np.int32)


def _truth(text: str, member: int) -> float:
    h = int.from_bytes(
        hashlib.blake2s(f"{text}|{member}".encode(),
                        digest_size=4).digest(), "little")
    return (h % 1000) / 999.0


def make_router(seed=0):
    rng = np.random.default_rng(seed)
    memb = rng.random((K, DM)).astype(np.float32)
    qp = PREDICTORS["attn"].init(jax.random.key(seed), DQ, K, DM)
    cp = {"w": np.zeros((DQ, K), np.float32),
          "b": np.asarray(COSTS, np.float32)}
    return PredictiveRouter("attn", "reg", qp, cp, memb, reward="R2")


def make_workers(n_workers=3, seed=0, update=None):
    """N workers sharing one router lineage + stub pool."""
    router = make_router(seed)
    pool = [StubGenMember(f"m{i}", c) for i, c in enumerate(COSTS)]
    workers = []
    for wid in range(n_workers):
        engine = StubEngine(router=router, pool=pool, lam=2.0)
        adapter = OnlineAdapter(
            engine, lambda req: _truth(req.text, req.member),
            config=update or OnlineUpdateConfig(min_buffer=8, batch_size=16),
            defer_updates=True, seed=seed + 7 * wid + 1)
        sched = MicroBatchScheduler(
            engine,
            SchedulerConfig(score_batch=8, max_batch=4, max_wait_s=0.005,
                            queue_capacity=64),
            clock=SimClock(), service_time=default_service_model(),
            adapter=adapter)
        workers.append(WorkerNode(wid, engine, sched, adapter))
    return workers


def make_trace_for(workers, n=48, seed=0, rate=2000.0):
    return make_trace(
        TraceConfig(kind="poisson", n_requests=n, rate=rate, seed=seed,
                    max_new=2, prompt_len_min=4, prompt_len_max=12,
                    vocab=VOCAB),
        texts=[f"query number {i} about topic {i % 7}" for i in range(40)],
    )


def feed_outcomes(worker, n=40, seed=0, now=0.0):
    """Directly observe synthetic outcomes (bypasses the scheduler)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        r = Request(text=f"direct {i}", prompt=np.zeros(1, np.int32))
        r.q_emb = rng.normal(0, 1, DQ).astype(np.float32)
        r.member = int(rng.integers(K))
        r.cost = COSTS[r.member]
        r.status = "done"
        reqs.append(r)
    worker.adapter.observe(reqs, now)


def _replay_tuples(buf):
    return [(q.tobytes(), m, s, c, t)
            for (q, m, s, c, t) in list(buf._recent) + buf._reservoir]


class TestReplayMerge:
    def test_merge_deterministic(self):
        """Two identically-fed planes produce bit-identical merged buffers
        and identical leader router parameters."""
        results = []
        for _ in range(2):
            workers = make_workers(3, seed=5)
            for w in workers:
                feed_outcomes(w, n=30, seed=50 + w.wid)
            coord = Coordinator(workers, SyncConfig(
                merge_per_worker=16, steps_per_sync=4, min_buffer=8, seed=5))
            router = coord.sync_round(1.0)
            assert router is not None
            results.append((
                _replay_tuples(coord.merge_replay),
                jax.tree.map(np.asarray, router.quality_params),
            ))
        assert results[0][0] == results[1][0]
        jax.tree.map(np.testing.assert_array_equal,
                     results[0][1], results[1][1])

    def test_merge_order_is_by_worker_id(self):
        """Gathered samples land in ascending-wid order regardless of the
        worker list's order."""
        workers = make_workers(3, seed=2)
        for w in workers:
            feed_outcomes(w, n=20, seed=20 + w.wid)
        coord_fwd = Coordinator(workers, SyncConfig(
            merge_per_worker=8, seed=2))
        coord_rev = Coordinator(list(reversed(make_workers(3, seed=2))),
                                SyncConfig(merge_per_worker=8, seed=2))
        for w in coord_rev.workers:
            feed_outcomes(w, n=20, seed=20 + w.wid)
        coord_fwd.merge_round(0.0)
        coord_rev.merge_round(0.0)
        assert (_replay_tuples(coord_fwd.merge_replay)
                == _replay_tuples(coord_rev.merge_replay))

    def test_broadcast_converges_all_workers(self):
        workers = make_workers(3, seed=0)
        for w in workers:
            feed_outcomes(w, n=30, seed=w.wid)
        coord = Coordinator(workers, SyncConfig(min_buffer=8))
        router = coord.sync_round(0.5)
        assert router is not None
        versions = {w.router_version for w in workers}
        assert versions == {router.version}


class TestStaleSwapRejection:
    def test_missed_version_cannot_roll_back(self):
        """A worker that already holds v2 rejects a delayed v1 broadcast
        (and the original v0) — publishing backwards is impossible."""
        workers = make_workers(2, seed=1)
        v0_router = workers[1].engine.router
        for w in workers:
            feed_outcomes(w, n=30, seed=w.wid + 3)
        coord = Coordinator(workers, SyncConfig(min_buffer=8))
        r1 = coord.sync_round(0.1)
        for w in workers:
            feed_outcomes(w, n=10, seed=w.wid + 9, now=0.2)
        r2 = coord.sync_round(0.2)
        assert r2.version > r1.version
        w = workers[1]
        assert w.router_version == r2.version
        rejected_before = w.swaps_rejected
        assert not w.publish(r1)           # delayed older broadcast
        assert not w.publish(v0_router)    # ancient version
        assert w.swaps_rejected == rejected_before + 2
        assert w.router_version == r2.version

    def test_rejection_counted_by_coordinator(self):
        workers = make_workers(2, seed=3)
        for w in workers:
            feed_outcomes(w, n=30, seed=w.wid)
        coord = Coordinator(workers, SyncConfig(min_buffer=8))
        r1 = coord.sync_round(0.1)
        coord.broadcast(r1)                # re-broadcast: stale everywhere
        assert coord.stats["stale_rejected"] == len(workers)


class TestPlaneCrashRejoin:
    def _run(self, events, n_workers=3, n=60):
        workers = make_workers(n_workers, seed=0)
        coord = Coordinator(workers, SyncConfig(
            sync_every_s=0.004, merge_per_worker=16, steps_per_sync=2,
            min_buffer=8, seed=0))
        plane = ServingPlane(workers, coord, events=events)
        trace = make_trace_for(workers, n=n)
        summary = plane.run_trace(trace)
        return workers, coord, plane, summary

    def test_all_requests_survive_a_crash(self):
        workers, coord, plane, summary = self._run(
            [PlaneEvent(0.008, "crash", 1)])
        assert summary["completed"] == 60
        assert plane.reassigned > 0
        alive = [w for w in workers if w.alive]
        assert {w.wid for w in alive} == {0, 2}
        assert len({w.router_version for w in alive}) == 1

    def test_rejoin_catches_up_to_current_version(self):
        workers, coord, plane, summary = self._run(
            [PlaneEvent(0.006, "crash", 1),
             PlaneEvent(0.02, "rejoin", 1)])
        assert summary["completed"] == 60
        assert all(w.alive for w in workers)
        versions = {w.router_version for w in workers}
        assert len(versions) == 1
        assert versions == {workers[0].router_version}
        assert workers[1].crashes == 1
        # the rejoined worker's replay was rebuilt empty at rejoin time
        # (whatever it holds accumulated after the rejoin)
        assert coord.stats["updates"] > 0

    def test_leader_crash_elects_next_and_recovers(self):
        """Crash the leader: the next-lowest wid takes over (fresh updater
        anchored on its broadcast-current router), updates keep flowing,
        and the old leader re-anchors on rejoin."""
        workers, coord, plane, summary = self._run(
            [PlaneEvent(0.006, "crash", 0),
             PlaneEvent(0.025, "rejoin", 0)])
        assert summary["completed"] == 60
        assert coord.stats["leader_changes"] >= 1
        assert coord.stats["updates"] > 0
        assert len({w.router_version for w in workers if w.alive}) == 1

    @pytest.mark.slow
    def test_four_worker_soak(self):
        """Nightly soak: 4 workers, a bigger trace, a mid-run crash and
        rejoin — versions converge, nothing is lost, updates keep flowing."""
        workers, coord, plane, summary = self._run(
            [PlaneEvent(0.01, "crash", 2),
             PlaneEvent(0.04, "rejoin", 2)],
            n_workers=4, n=400)
        assert summary["completed"] == 400
        assert len({w.router_version for w in workers}) == 1
        assert coord.stats["updates"] > 2
        assert coord.stats["stale_rejected"] == 0
        # every worker served a nontrivial share (round-robin + reassignment)
        for w in workers:
            assert w.telemetry.completed > 0


class TestSharedBudgetLedger:
    def test_spend_is_global(self):
        ledger = SharedBudgetLedger(budget=1.0, window_s=10.0, lam0=1.0)
        ledger.record(0.4, now=1.0)      # worker A's clock
        ledger.record(0.5, now=0.8)      # worker B lags slightly
        assert ledger.utilization(1.0) == pytest.approx(0.9)

    def test_controller_throttled_across_workers(self):
        ledger = SharedBudgetLedger(budget=0.1, window_s=10.0, lam0=1.0,
                                    update_min_interval_s=1.0)
        ledger.record(1.0, now=0.5)      # 10x over budget
        lam1 = ledger.update(0.6)        # controller steps
        lam2 = ledger.update(0.7)        # throttled: no second tightening
        lam3 = ledger.update(0.9)        # still inside min interval
        assert lam1 < 1.0
        assert lam2 == lam1 and lam3 == lam1
        assert ledger.throttled == 2
        lam4 = ledger.update(2.0)        # past the interval: steps again
        assert lam4 < lam1

    def test_monotone_time_keeps_window_sorted(self):
        ledger = SharedBudgetLedger(budget=1.0, window_s=1.0, lam0=1.0)
        ledger.record(0.3, now=5.0)
        ledger.record(0.3, now=4.0)      # out-of-order worker clock
        ts = [t for t, _ in ledger._events]
        assert ts == sorted(ts)
        # both events are inside the [hwm - window, hwm] window
        assert ledger.window_spend(5.0) == pytest.approx(0.6)
