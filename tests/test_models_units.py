"""Model-substrate unit + property tests: attention equivalences, cache
semantics, SSM/xLSTM chunked-vs-recurrent equality, MoE, losses, optimizer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.configs.base import ATTN, MLP, LayerSpec
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import apply_rmsnorm, apply_rope, init_rmsnorm, softmax_cross_entropy
from repro.training.optim import AdamConfig, adam_init, adam_update, cosine_lr


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

class TestAttention:
    def _qkv(self, b=2, s=256, hq=4, hkv=2, hd=32, seed=0):
        ks = jax.random.split(jax.random.key(seed), 3)
        q = jax.random.normal(ks[0], (b, s, hq, hd))
        k = jax.random.normal(ks[1], (b, s, hkv, hd))
        v = jax.random.normal(ks[2], (b, s, hkv, hd))
        return q, k, v

    def test_flash_matches_dense_causal(self):
        q, k, v = self._qkv()
        dense = attn_mod.dense_attention(
            q, k, v, attn_mod.causal_mask(256, 256))
        flash = attn_mod.flash_attention(q, k, v, q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("window", [16, 64, 100])
    def test_flash_matches_dense_sliding_window(self, window):
        q, k, v = self._qkv(s=256)
        dense = attn_mod.dense_attention(
            q, k, v, attn_mod.causal_mask(256, 256, window=window))
        flash = attn_mod.flash_attention(q, k, v, window=window,
                                         q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                                   rtol=2e-4, atol=2e-5)

    def test_flash_softcap(self):
        q, k, v = self._qkv(s=128)
        dense = attn_mod.dense_attention(
            q, k, v, attn_mod.causal_mask(128, 128), softcap=30.0)
        flash = attn_mod.flash_attention(q, k, v, q_chunk=64, k_chunk=64,
                                         softcap=30.0)
        np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                                   rtol=2e-4, atol=2e-5)

    def test_causality(self):
        """Perturbing future tokens must not change past outputs."""
        q, k, v = self._qkv(s=64)
        out1 = attn_mod.flash_attention(q, k, v, q_chunk=32, k_chunk=32)
        k2 = k.at[:, 40:].set(jax.random.normal(jax.random.key(9), k[:, 40:].shape))
        v2 = v.at[:, 40:].set(jax.random.normal(jax.random.key(10), v[:, 40:].shape))
        out2 = attn_mod.flash_attention(q, k2, v2, q_chunk=32, k_chunk=32)
        np.testing.assert_allclose(np.asarray(out1[:, :40]),
                                   np.asarray(out2[:, :40]), rtol=1e-5, atol=1e-6)

    def test_ring_cache_equals_full_cache_within_window(self):
        """Sliding-window decode via ring buffer == full cache + window mask."""
        cfg = dataclasses.replace(
            get_smoke_config("gemma3-27b"), qk_norm=False)
        spec_ring = LayerSpec(mixer=ATTN, ffn=MLP, window=8)
        spec_full = LayerSpec(mixer=ATTN, ffn=MLP, window=8)
        p = attn_mod.init_attention(jax.random.key(0), cfg, spec_ring)
        b, steps = 2, 24
        xs = jax.random.normal(jax.random.key(1), (b, steps, cfg.d_model)) * 0.3

        ring = attn_mod.init_kv_cache(cfg, spec_ring, b, max_len=8)  # ring W=8
        full = attn_mod.init_kv_cache(
            cfg, dataclasses.replace(spec_full, window=0), b, max_len=steps)
        # Manually apply the window mask on the full-cache path.
        for t in range(steps):
            x_t = xs[:, t : t + 1]
            o_ring, ring = attn_mod.self_attention_decode(
                cfg, spec_ring, p, x_t, ring, jnp.int32(t))
            o_full, full = attn_mod.self_attention_decode(
                cfg, spec_ring_full_mask(spec_full), p, x_t, full, jnp.int32(t))
            np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                                       rtol=1e-4, atol=1e-5)


def spec_ring_full_mask(spec):
    # full-length cache but same window masking: window stays 8, cache is long
    return spec


# ---------------------------------------------------------------------------
# RMSNorm / RoPE
# ---------------------------------------------------------------------------

class TestLayers:
    def test_rmsnorm_unit_scale(self):
        p = init_rmsnorm(16)
        x = jax.random.normal(jax.random.key(0), (4, 16)) * 7.0
        y = apply_rmsnorm(p, x)
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)

    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.key(1), (2, 8, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        y = apply_rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(y, axis=-1)),
            np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)

    def test_rope_relative_shift_invariance(self):
        """<q_i, k_j> after rope depends only on i - j."""
        hd = 32
        q = jax.random.normal(jax.random.key(2), (1, 1, 1, hd))
        k = jax.random.normal(jax.random.key(3), (1, 1, 1, hd))
        def dot_at(pi, pj):
            qi = apply_rope(q, jnp.array([[pi]]), 10000.0)
            kj = apply_rope(k, jnp.array([[pj]]), 10000.0)
            return float(jnp.sum(qi * kj))
        assert np.isclose(dot_at(5, 3), dot_at(105, 103), atol=1e-4)

    def test_cross_entropy_uniform(self):
        v = 16
        logits = jnp.zeros((2, 4, v))
        labels = jnp.zeros((2, 4), jnp.int32)
        loss = softmax_cross_entropy(logits, labels, v)
        assert np.isclose(float(loss), np.log(v), atol=1e-5)

    def test_cross_entropy_ignores_padded_vocab(self):
        v, pad = 16, 8
        logits = jnp.concatenate(
            [jnp.zeros((2, 4, v)), jnp.full((2, 4, pad), 100.0)], axis=-1)
        labels = jnp.zeros((2, 4), jnp.int32)
        loss = softmax_cross_entropy(logits, labels, v)
        assert np.isclose(float(loss), np.log(v), atol=1e-4)

    def test_cross_entropy_chunked_matches(self):
        v = 32
        logits = jax.random.normal(jax.random.key(4), (2, 64, v))
        labels = jax.random.randint(jax.random.key(5), (2, 64), 0, v)
        full = softmax_cross_entropy(logits, labels, v)
        chunked = softmax_cross_entropy(logits, labels, v, seq_chunk=16)
        assert np.isclose(float(full), float(chunked), rtol=1e-6)


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------

class TestMamba:
    def test_chunked_scan_equals_stepwise_decode(self):
        cfg = get_smoke_config("jamba-1.5-large-398b")
        p = ssm_mod.init_mamba(jax.random.key(0), cfg)
        b, t = 2, 256  # exercises multiple chunks (chunk=128)
        x = jax.random.normal(jax.random.key(1), (b, t, cfg.d_model)) * 0.5
        full = ssm_mod.apply_mamba_train(cfg, p, x)
        cache = ssm_mod.init_mamba_cache(cfg, b)
        outs = []
        for i in range(t):
            o, cache = ssm_mod.apply_mamba_decode(cfg, p, x[:, i : i + 1], cache)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                                   rtol=1e-3, atol=1e-4)

    def test_prefill_state_continues_decode(self):
        cfg = get_smoke_config("jamba-1.5-large-398b")
        p = ssm_mod.init_mamba(jax.random.key(2), cfg)
        b, t = 2, 128
        x = jax.random.normal(jax.random.key(3), (b, t + 1, cfg.d_model)) * 0.5
        _, state = ssm_mod.apply_mamba_train(cfg, p, x[:, :t], return_state=True)
        cache = {**ssm_mod.init_mamba_cache(cfg, b), **{
            "h": state["h"], "conv": state["conv"]}}
        o_dec, _ = ssm_mod.apply_mamba_decode(cfg, p, x[:, t : t + 1], cache)
        full = ssm_mod.apply_mamba_train(cfg, p, x)
        np.testing.assert_allclose(np.asarray(o_dec[:, 0]),
                                   np.asarray(full[:, t]), rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------

class TestXLSTM:
    def test_mlstm_chunkwise_equals_recurrence(self):
        b, t, h, dh = 2, 512, 2, 16
        ks = jax.random.split(jax.random.key(0), 5)
        q = jax.random.normal(ks[0], (b, t, h, dh))
        k = jax.random.normal(ks[1], (b, t, h, dh)) * (dh ** -0.5)
        v = jax.random.normal(ks[2], (b, t, h, dh))
        log_i = jax.random.normal(ks[3], (b, t, h)) - 2.0
        log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, h)) + 2.0)

        h_chunk, final = xlstm_mod.mlstm_chunkwise(q, k, v, log_i, log_f,
                                                   chunk=128)
        state = {
            "C": jnp.zeros((b, h, dh, dh)),
            "n": jnp.zeros((b, h, dh)),
            "m": jnp.full((b, h), xlstm_mod.NEG_INF),
        }
        outs = []
        for i in range(t):
            o, state = xlstm_mod.mlstm_step(
                q[:, i], k[:, i], v[:, i], log_i[:, i], log_f[:, i], state)
            outs.append(o)
        h_step = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_step),
                                   rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final["C"]), np.asarray(state["C"]),
                                   rtol=2e-3, atol=2e-4)

    def test_mlstm_block_decode_matches_train(self):
        cfg = get_smoke_config("xlstm-1.3b")
        p = xlstm_mod.init_mlstm(jax.random.key(1), cfg)
        b, t = 2, 64
        x = jax.random.normal(jax.random.key(2), (b, t, cfg.d_model)) * 0.3
        full = xlstm_mod.apply_mlstm_train(cfg, p, x)
        cache = xlstm_mod.init_mlstm_cache(cfg, b)
        outs = []
        for i in range(t):
            o, cache = xlstm_mod.apply_mlstm_decode(cfg, p, x[:, i : i + 1], cache)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   rtol=2e-3, atol=2e-4)

    def test_slstm_normalizer_keeps_state_bounded(self):
        cfg = get_smoke_config("xlstm-1.3b")
        p = xlstm_mod.init_slstm(jax.random.key(3), cfg)
        x = jax.random.normal(jax.random.key(4), (2, 200, cfg.d_model)) * 2.0
        out = xlstm_mod.apply_slstm_train(cfg, p, x)
        assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

class TestMoE:
    def _cfg(self, cf=8.0):
        return dataclasses.replace(
            get_smoke_config("granite-moe-1b-a400m"), capacity_factor=cf)

    def test_dispatch_matches_dense_when_capacity_ample(self):
        """Capacity dispatch == explicit per-token expert mix (no drops)."""
        cfg = self._cfg(cf=32.0)
        p = moe_mod.init_moe(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (16, cfg.d_model)) * 0.5
        out, aux = moe_mod._dispatch_combine(cfg, p, x, capacity_factor=32.0)

        # Dense reference: run every expert, mix with top-k gates.
        probs = np.asarray(moe_mod._router_probs(p, x))
        gate_idx = np.argsort(-probs, axis=1)[:, : cfg.top_k]
        expect = np.zeros_like(np.asarray(x))
        for t in range(x.shape[0]):
            gv = probs[t, gate_idx[t]]
            gv = gv / gv.sum()
            for g, e in zip(gv, gate_idx[t]):
                xe = np.asarray(x[t])
                h = (jax.nn.silu(xe @ p["w_gate"][e]) * (xe @ p["w_up"][e]))
                expect[t] += g * np.asarray(h @ p["w_down"][e])
        np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-3, atol=2e-4)

    def test_capacity_drops_tokens(self):
        cfg = self._cfg(cf=0.1)
        p = moe_mod.init_moe(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
        out_small, _ = moe_mod._dispatch_combine(cfg, p, x, capacity_factor=0.1)
        out_big, _ = moe_mod._dispatch_combine(cfg, p, x, capacity_factor=32.0)
        assert not np.allclose(np.asarray(out_small), np.asarray(out_big))

    def test_aux_loss_uniform_router_is_one(self):
        """Perfectly balanced routing yields load-balance loss ~= 1."""
        e = 8
        probs = jnp.full((128, e), 1.0 / e)
        mask = jax.nn.one_hot(jnp.arange(128) % e, e)
        aux = moe_mod.aux_load_balance_loss(probs, mask)
        assert np.isclose(float(aux), 1.0, atol=1e-5)

    def test_train_decode_consistency(self):
        cfg = self._cfg(cf=16.0)
        p = moe_mod.init_moe(jax.random.key(2), cfg)
        x = jax.random.normal(jax.random.key(3), (2, 4, cfg.d_model)) * 0.5
        out_train, _ = moe_mod.apply_moe_train(cfg, p, x)
        out_dec = moe_mod.apply_moe_decode(cfg, p, x)
        np.testing.assert_allclose(np.asarray(out_train), np.asarray(out_dec),
                                   rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

class TestAdam:
    def test_first_step_matches_analytic(self):
        cfg = AdamConfig(lr=0.1)
        params = {"w": jnp.array([1.0, 2.0])}
        grads = {"w": jnp.array([0.5, -0.5])}
        state = adam_init(cfg, params)
        new_p, _ = adam_update(cfg, grads, state, params)
        # After bias correction the first Adam step is -lr * sign(g).
        np.testing.assert_allclose(
            np.asarray(new_p["w"]), np.array([0.9, 2.1]), rtol=1e-4)

    def test_cosine_schedule_endpoints(self):
        cfg = AdamConfig(lr=1.0, t_max=100, eta_min=0.1)
        assert np.isclose(float(cosine_lr(cfg, jnp.int32(0))), 1.0)
        assert np.isclose(float(cosine_lr(cfg, jnp.int32(100))), 0.1)

    @given(st.floats(1e-5, 1e-1), st.integers(1, 30))
    @settings(max_examples=20, deadline=None)
    def test_quadratic_convergence(self, lr, steps):
        """Adam on f(w)=||w||^2 never increases the loss from far away."""
        cfg = AdamConfig(lr=lr)
        params = {"w": jnp.array([3.0, -2.0])}
        state = adam_init(cfg, params)
        loss = lambda p: float(jnp.sum(p["w"] ** 2))
        l0 = loss(params)
        for _ in range(steps):
            grads = {"w": 2 * params["w"]}
            params, state = adam_update(cfg, grads, state, params)
        assert loss(params) <= l0 + 1e-6

    def test_weight_decay_shrinks_weights(self):
        cfg = AdamConfig(lr=0.01, weight_decay=1.0)
        params = {"w": jnp.array([5.0])}
        state = adam_init(cfg, params)
        new_p, _ = adam_update(cfg, {"w": jnp.array([0.0])}, state, params)
        assert float(new_p["w"][0]) < 5.0
