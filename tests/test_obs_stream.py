"""Streaming observability tests: deterministic head+tail sampling, the
bounded recorder (cap shedding + drop accounting), rotating segment
flushes on the virtual clock, segment concatenation, SLO burn-rate
monitors, counter tracks, gen span links, and the bounded telemetry
series.

The contract under test throughout: every streaming decision — keep/drop,
segment boundary, alert transition — is a pure function of the seeded
virtual-clock run, so a replay reproduces identical segment *bytes*; and
anomalous request trees (expired / rescued / escalated) survive any
sample rate, with everything dropped showing up in the drop accounting
rather than vanishing.
"""
import json
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.obs import (
    ObsFlusher,
    TraceRecorder,
    TraceSampler,
    concat_dir,
    is_anomaly_event,
    request_trees,
    validate_chrome_trace,
    validate_span_tree,
)
from repro.obs.slo import (
    BurnRateSLO,
    RollingWindow,
    SLOTracker,
    SpendBurnSLO,
    build_slo_tracker,
)
from repro.obs.stream import segment_paths
from repro.serving import MicroBatchScheduler, Request, SchedulerConfig
from repro.serving.telemetry import BoundedSeries


def req(text="q", arrival=0.0, deadline=None, n_prompt=4, max_new=2):
    return Request(text=text, prompt=np.zeros(n_prompt, np.int32),
                   max_new=max_new, arrival_s=arrival, deadline_s=deadline)


class FakeMember:
    def __init__(self, name, cost_rate):
        self.name = name
        self.cost_rate = cost_rate


class FakeEngine:
    def __init__(self, cost_rates=(1.0, 10.0), quality=(0.5, 1.0)):
        self.pool = [FakeMember(f"m{i}", c) for i, c in enumerate(cost_rates)]
        self.quality = np.asarray(quality, np.float64)
        self.lam = 100.0

    def score_texts(self, texts):
        b = len(texts)
        s = np.tile(self.quality, (b, 1))
        c = np.tile([m.cost_rate for m in self.pool], (b, 1))
        return s, c

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8):
        outs = [np.zeros(max_new, np.int32) for _ in prompts]
        return outs, self.pool[mi].cost_rate * len(prompts)


def run_streaming(out_dir, *, n=24, rate=0.25, head=4, cap=None,
                  scrape_every=0.002, tight_deadlines=(), slo=None):
    """One seeded streaming run: recorder + sampler (+cap) + flusher."""
    rec = TraceRecorder(label="stream-test",
                        sampler=TraceSampler(rate, seed=0, head=head),
                        max_buffered_per_worker=cap)
    flusher = ObsFlusher(out_dir, recorder=rec, scrape_every_s=scrape_every,
                         label="stream-test")
    sched = MicroBatchScheduler(
        FakeEngine(), SchedulerConfig(score_batch=8, max_batch=4),
        service_time=lambda kind, n_, wall: 1e-3,
        tracer=rec.scoped(0), slo=slo, flusher=flusher)
    reqs = []
    for i in range(n):
        deadline = 0.002 if i in tight_deadlines else None
        reqs.append(req(text=str(i), arrival=i * 1e-4, deadline=deadline))
    summary = sched.run_trace(reqs)
    flusher.finalize(sched.clock.now)
    return rec, flusher, sched, summary


# ---------------------------------------------------------------------------
# TraceSampler properties
# ---------------------------------------------------------------------------

class TestTraceSampler:
    @given(st.integers(0, 5000), st.floats(0.0, 1.0))
    @settings(max_examples=60, deadline=None)
    def test_keep_is_pure_function_of_seed_key_rate(self, key, rate):
        a = TraceSampler(rate, seed=7, head=0)
        b = TraceSampler(rate, seed=7, head=0)
        assert a.keep(key) == b.keep(key)

    @given(st.integers(0, 5000))
    @settings(max_examples=60, deadline=None)
    def test_keep_monotone_in_rate(self, key):
        """A request kept at a lower rate is kept at every higher rate —
        raising --trace-sample only ever adds trees, never swaps them."""
        rates = [0.0, 0.1, 0.25, 0.5, 0.9, 1.0]
        kept = [TraceSampler(r, seed=3, head=0).keep(key) for r in rates]
        assert kept == sorted(kept)   # False* then True*

    @given(st.integers(0, 64))
    @settings(max_examples=40, deadline=None)
    def test_head_always_kept(self, head):
        s = TraceSampler(0.0, seed=0, head=head)
        assert all(s.keep(k) for k in range(head))
        assert not any(s.keep(k) for k in range(head, head + 50))

    def test_rate_extremes(self):
        assert TraceSampler(1.0, head=0).keep_set(range(100)) == set(
            range(100))
        assert TraceSampler(0.0, head=0).keep_set(range(100)) == set()

    def test_keep_fraction_tracks_rate(self):
        for rate in (0.1, 0.25, 0.5, 0.75):
            frac = len(TraceSampler(rate, seed=0, head=0).keep_set(
                range(4000))) / 4000
            assert abs(frac - rate) < 0.03

    def test_seed_changes_keep_set(self):
        keys = range(200)
        a = TraceSampler(0.5, seed=0, head=0).keep_set(keys)
        b = TraceSampler(0.5, seed=1, head=0).keep_set(keys)
        assert a != b

    def test_invalid_rate_rejected(self):
        with pytest.raises(ValueError):
            TraceSampler(1.5)

    def test_anomaly_event_detection(self):
        assert is_anomaly_event("readmit", None)
        assert is_anomaly_event("expire", None)
        assert is_anomaly_event("request", {"status": "expired"})
        assert is_anomaly_event("request", {"status": "done",
                                            "rescued": True})
        assert not is_anomaly_event("request", {"status": "done"})
        assert not is_anomaly_event("admit", None)


# ---------------------------------------------------------------------------
# Recorder streaming semantics (drain / cap / accounting)
# ---------------------------------------------------------------------------

class TestRecorderStreaming:
    def close_tree(self, rec, key, t0):
        rec.instant("admit", "queue", t0, key=key)
        rec.span("leg", "request", t0, t0 + 0.01, key=key,
                 args={"leg": 1, "member": "m0"})
        rec.span("queue_wait", "queue", t0, t0 + 0.001, key=key,
                 args={"leg": 1})
        rec.span("request", "request", t0, t0 + 0.01, key=key,
                 args={"status": "done", "legs": 1})

    def test_drain_moves_closed_trees_only(self):
        rec = TraceRecorder()
        k0, k1 = rec.next_key(), rec.next_key()
        self.close_tree(rec, k0, 0.0)
        rec.instant("admit", "queue", 0.5, key=k1)   # open tree
        rec.span("score_batch", "sched", 0.0, 0.01)  # runtime scope
        out = rec.drain()
        names = [e[0] for e in out]
        assert names.count("request") == 1 and "score_batch" in names
        assert rec.n_events == 1        # k1's admit still buffered
        # Second drain with force flushes the open tree too.
        out2 = rec.drain(force=True)
        assert [e[0] for e in out2] == ["admit"] and rec.n_events == 0

    def test_sampling_drops_with_accounting_anomaly_kept(self):
        rec = TraceRecorder(sampler=TraceSampler(0.0, head=0))
        k_plain, k_anom = rec.next_key(), rec.next_key()
        self.close_tree(rec, k_plain, 0.0)
        # Anomalous tree: expired root.
        rec.instant("admit", "queue", 1.0, key=k_anom)
        rec.span("request", "request", 1.0, 1.5, key=k_anom,
                 args={"status": "expired", "legs": 0})
        out = rec.drain()
        keys = {e[6] for e in out}
        assert keys == {k_anom}
        assert rec.stats["requests_sampled_out"] == 1
        assert rec.stats["dropped_sampled"] == 4
        assert rec.n_events == 0

    def test_cap_sheds_whole_trees_and_late_events(self):
        rec = TraceRecorder(max_buffered_per_worker=6)
        keys = [rec.next_key() for _ in range(4)]
        for i, k in enumerate(keys):
            rec.instant("admit", "queue", i * 0.1, key=k)
            rec.span("queue_wait", "queue", i * 0.1, i * 0.1 + 0.01, key=k,
                     args={"leg": 1})
        # 8 events recorded against cap 6: trees opened after the cap was
        # hit are shed whole.
        assert rec.stats["requests_shed"] >= 1
        shed = set(rec._shed)
        assert shed
        # Late events of a shed tree keep dropping.
        before = rec.n_events
        rec.span("request", "request", 0.0, 1.0, key=next(iter(shed)),
                 args={"status": "done", "legs": 1})
        assert rec.n_events == before
        assert rec.stats["dropped_cap"] >= 2

    def test_event_conservation_law(self):
        """recorded == drained + still-buffered + dropped (cap + sampled)."""
        rec = TraceRecorder(sampler=TraceSampler(0.3, seed=1, head=2),
                            max_buffered_per_worker=16)
        drained = 0
        for i in range(40):
            k = rec.next_key()
            self.close_tree(rec, k, i * 0.1)
            if i % 7 == 0:
                drained += len(rec.drain())
        drained += len(rec.drain(force=True))
        s = rec.stats
        assert s["events"] == (drained + rec.n_events + s["dropped_cap"]
                               + s["dropped_sampled"])
        assert s["requests_sampled_out"] > 0

    def test_bare_recorder_unchanged(self):
        rec = TraceRecorder()
        self.close_tree(rec, rec.next_key(), 0.0)
        assert rec.stats["dropped_cap"] == 0
        assert rec.stats["dropped_sampled"] == 0
        assert validate_span_tree(rec.chrome_trace()) == []

    def test_counter_events_export_as_counter_tracks(self):
        rec = TraceRecorder()
        rec.counter("queue_depth", 0.0, 3)
        rec.counter("queue_depth", 0.1, 5)
        doc = rec.chrome_trace()
        assert validate_chrome_trace(doc) == []
        ctrs = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
        assert len(ctrs) == 2
        assert ctrs[0]["args"] == {"value": 3.0}
        assert ctrs[0]["tid"] == 0


# ---------------------------------------------------------------------------
# Flusher: rotating segments, manifest, concat, replay byte-identity
# ---------------------------------------------------------------------------

class TestObsFlusher:
    def test_segment_boundaries_pure_function_of_virtual_time(self, tmp_path):
        rec = TraceRecorder()
        fl = ObsFlusher(str(tmp_path), recorder=rec, scrape_every_s=1.0)
        assert fl.maybe_flush(0.0) == 0      # first call arms
        assert fl.maybe_flush(0.5) == 0
        assert fl.maybe_flush(3.7) == 3      # catch-up: 1.0, 2.0, 3.0
        assert fl.maybe_flush(3.8) == 0
        assert fl.seq == 3

    def test_requires_recorder_or_registry(self, tmp_path):
        with pytest.raises(ValueError):
            ObsFlusher(str(tmp_path))
        with pytest.raises(ValueError):
            ObsFlusher(str(tmp_path), recorder=TraceRecorder(),
                       scrape_every_s=0.0)

    def test_streaming_run_segments_concat_to_valid_trace(self, tmp_path):
        out = str(tmp_path / "obs")
        rec, fl, sched, summary = run_streaming(out, rate=1.0)
        paths = segment_paths(out)
        assert len(paths) >= 2               # actually rotated mid-run
        for p in paths:                      # each segment valid standalone
            with open(p) as f:
                assert validate_chrome_trace(json.load(f)) == []
        doc = concat_dir(out)
        assert validate_chrome_trace(doc) == []
        assert validate_span_tree(doc) == []
        trees = request_trees(doc)
        assert sum(t["root"] is not None for t in trees.values()) \
            == summary["completed"] == 24
        assert doc["otherData"]["segments"] == len(paths)
        # Manifest bookkeeping matches the directory.
        with open(os.path.join(out, "manifest.json")) as f:
            man = json.load(f)
        assert man["trace_segments"] == [os.path.basename(p) for p in paths]
        assert man["sampler"] == {"rate": 1.0, "seed": 0, "head": 4}

    def test_replay_segments_byte_identical(self, tmp_path):
        a, b = str(tmp_path / "a"), str(tmp_path / "b")
        run_streaming(a, rate=0.25, tight_deadlines=range(16, 24))
        run_streaming(b, rate=0.25, tight_deadlines=range(16, 24))
        names = sorted(os.listdir(a))
        assert names == sorted(os.listdir(b))
        for n in names:
            with open(os.path.join(a, n), "rb") as f:
                blob_a = f.read()
            with open(os.path.join(b, n), "rb") as f:
                blob_b = f.read()
            assert blob_a == blob_b, f"segment {n} differs across replays"

    def test_anomalous_trees_survive_zero_sample_rate(self, tmp_path):
        out = str(tmp_path / "obs")
        rec, fl, sched, summary = run_streaming(
            out, rate=0.0, head=0, tight_deadlines=range(12, 24))
        assert summary["expired"] > 0
        doc = concat_dir(out)
        trees = request_trees(doc)
        statuses = [t["root"]["args"]["status"] for t in trees.values()
                    if t["root"] is not None]
        # Every expired request retained; every plain "done" sampled out.
        assert statuses.count("expired") == summary["expired"]
        assert "done" not in statuses
        assert rec.stats["requests_sampled_out"] > 0
        assert doc["otherData"]["drops"]["requests_sampled_out"] \
            == rec.stats["requests_sampled_out"]

    def test_cap_bounds_recorder_memory(self, tmp_path):
        out = str(tmp_path / "obs")
        rec, fl, sched, summary = run_streaming(out, n=48, rate=1.0, cap=64)
        assert rec.peak_buffered < 64 + 48       # cap + one tree's slack
        # Unbounded replay of the same trace buffers far more.
        rec2, *_ = run_streaming(str(tmp_path / "ub"), n=48, rate=1.0,
                                 scrape_every=1e9)
        assert rec2.peak_buffered > rec.peak_buffered
        if rec.stats["requests_shed"]:
            d = concat_dir(out)["otherData"]["drops"]
            assert d["requests_shed"] == rec.stats["requests_shed"]

    def test_counter_tracks_in_streamed_trace(self, tmp_path):
        out = str(tmp_path / "obs")
        run_streaming(out, rate=1.0)
        doc = concat_dir(out)
        ctr_names = {e["name"] for e in doc["traceEvents"]
                     if e.get("ph") == "C"}
        assert "queue_depth" in ctr_names
        assert "budget_lam" in ctr_names

    def test_gen_span_links_validate_and_catch_mismatch(self, tmp_path):
        out = str(tmp_path / "obs")
        run_streaming(out, rate=1.0)
        doc = concat_dir(out)
        legs = [e for e in doc["traceEvents"]
                if e.get("name") == "leg" and e.get("ph") == "X"]
        assert legs and all("gen" in e["args"] for e in legs)
        assert validate_span_tree(doc) == []
        # Tampered link: point one leg at a generate batch that is not its
        # own — the validator must notice.
        legs[0]["args"]["gen"] = 10 ** 9
        assert any("gen" in p for p in validate_span_tree(doc))


# ---------------------------------------------------------------------------
# SLO window math + burn-rate alerting
# ---------------------------------------------------------------------------

class TestRollingWindow:
    @given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=40))
    @settings(max_examples=60, deadline=None)
    def test_totals_match_naive_reference(self, times):
        """Bucketed totals agree with a brute-force scan up to bucket-edge
        granularity: every event inside (now - W, now] shifted by one
        bucket width is counted, nothing older than W + width survives."""
        w = RollingWindow(2.0, n_buckets=20)
        for i, t in enumerate(times):
            w.add(t, bad=i % 2, value=1.0)
        now = max(times)
        n, bad, val = w.totals(now)
        width = w.width
        lo_n = sum(1 for t in times if now - 2.0 + width < t <= now)
        hi_n = sum(1 for t in times if now - 2.0 - width < t <= now + width)
        assert lo_n <= n <= hi_n
        assert val == float(n)

    def test_out_of_order_adds_land_in_window(self):
        w = RollingWindow(10.0)
        w.add(9.0)
        w.add(3.0)     # late arrival from a lagging worker
        w.add(9.5)
        assert w.totals(10.0)[0] == 3
        assert w.totals(25.0)[0] == 0

    def test_pruning_keeps_memory_bounded(self):
        w = RollingWindow(1.0, n_buckets=10)
        for i in range(10000):
            w.add(i * 0.01)
        assert len(w._buckets) < 30

    def test_invalid_window_rejected(self):
        with pytest.raises(ValueError):
            RollingWindow(0.0)


class TestBurnRateSLO:
    def test_burn_is_bad_fraction_over_budget(self):
        s = BurnRateSLO("deadline_miss", error_budget=0.1, short_s=1.0,
                        long_s=12.0)
        for i in range(10):
            s.observe(float(i), bad=(i < 2))   # 20% bad overall
        b = s.burns(10.0)
        assert b["long"] == pytest.approx((2 / 10) / 0.1)

    def test_multi_window_gating_resists_blips(self):
        """A short bad blip after a long good stretch must not fire; a
        sustained burn must."""
        s = BurnRateSLO("deadline_miss", error_budget=0.05, short_s=1.0,
                        long_s=12.0, threshold=1.0)
        for i in range(110):
            s.observe(i * 0.1, bad=False)      # 11s of clean traffic
        for i in range(3):
            s.observe(11.0 + i * 0.1, bad=True)
        assert s.burns(11.3)["short"] >= 1.0   # blip spikes the short win
        assert not s.evaluate(11.3)            # ...but long window holds
        for i in range(60):
            s.observe(11.3 + i * 0.1, bad=True)
        assert s.evaluate(17.3)                # sustained: both windows over

    def test_min_events_guard(self):
        s = BurnRateSLO("x", error_budget=0.01, short_s=1.0, long_s=2.0,
                        min_events=5)
        s.observe(0.0, bad=True)
        assert s.burns(0.5) == {"short": 0.0, "long": 0.0}

    @given(st.floats(0.05, 1.0), st.integers(1, 30))
    @settings(max_examples=40, deadline=None)
    def test_all_bad_burn_is_budget_inverse(self, budget, n):
        s = BurnRateSLO("x", error_budget=budget, short_s=1.0, long_s=4.0)
        for i in range(n):
            s.observe(i * 4.0 / max(n, 1) * 0.9, bad=True)
        assert s.burns(3.6)["long"] == pytest.approx(1.0 / budget)

    def test_spend_burn_tracks_rate_vs_budget(self):
        s = SpendBurnSLO("spend", budget=100.0, window_s=10.0, short_s=1.0)
        # Spend 200 over the 10s window: 2x the budgeted rate.
        for i in range(10):
            s.observe(i * 1.0 + 0.5, cost=20.0)
        assert s.burns(10.0)["long"] == pytest.approx(2.0)
        assert s.evaluate(10.0)


class TestSLOTracker:
    def test_alert_transitions_and_trace_instants(self):
        rec = TraceRecorder()
        s = BurnRateSLO("deadline_miss", error_budget=0.05, short_s=0.5,
                        long_s=6.0)
        tr = SLOTracker([s], tracer=rec, check_every_s=0.25)
        for i in range(40):
            tr.observe_request(i * 0.1, e2e_s=0.01, missed=True,
                               quality=1.0, cost=0.0)
        assert tr.check(4.0, force=True)
        assert tr.firing() == ["deadline_miss"]
        # Recovery: a long clean stretch clears both windows.
        for i in range(400):
            tr.observe_request(4.0 + i * 0.05, e2e_s=0.01, missed=False,
                               quality=1.0, cost=0.0)
        assert tr.check(24.0, force=True)
        assert tr.firing() == []
        states = [a["state"] for a in tr.alerts]
        assert states == ["firing", "resolved"]
        names = [e[0] for e in rec.events]
        assert names.count("slo_alert") == 2

    def test_check_is_throttled(self):
        s = BurnRateSLO("x", error_budget=0.5, short_s=1.0, long_s=2.0)
        tr = SLOTracker([s], check_every_s=1.0)
        tr.check(0.0)
        nxt = tr._next_check
        tr.check(0.5)
        assert tr._next_check == nxt

    def test_build_slo_tracker(self):
        assert build_slo_tracker() is None
        tr = build_slo_tracker(p95_target_s=0.01, miss_rate_budget=0.02,
                               quality_floor=0.5, spend_per_window=10.0,
                               window_s=0.24)
        assert [s.name for s in tr.slos] == [
            "latency_p95", "deadline_miss", "quality_floor", "spend"]
        assert tr.slos[0].short.window_s == pytest.approx(0.02)
        assert tr.check_every_s == pytest.approx(0.01)

    def test_scheduler_integration_fires_deadline_slo(self, tmp_path):
        slo = build_slo_tracker(miss_rate_budget=0.01, window_s=0.12,
                                threshold=1.0)
        rec, fl, sched, summary = run_streaming(
            str(tmp_path / "obs"), rate=1.0,
            tight_deadlines=range(8, 24), slo=slo)
        assert summary["expired"] > 0
        assert any(a["slo"] == "deadline_miss" and a["state"] == "firing"
                   for a in slo.alerts)
        doc = concat_dir(str(tmp_path / "obs"))
        assert any(e["name"] == "slo_alert" for e in doc["traceEvents"]
                   if e.get("ph") == "i")

    def test_slo_replay_determinism(self, tmp_path):
        def run(sub):
            slo = build_slo_tracker(miss_rate_budget=0.01, window_s=0.12)
            run_streaming(str(tmp_path / sub), rate=1.0,
                          tight_deadlines=range(8, 24), slo=slo)
            return slo.alerts
        assert run("a") == run("b")


# ---------------------------------------------------------------------------
# BoundedSeries (deterministically downsampled telemetry series)
# ---------------------------------------------------------------------------

class TestBoundedSeries:
    def test_memory_bounded_coverage_whole_run(self):
        s = BoundedSeries(cap=64)
        for i in range(10000):
            s.append(i * 0.001, float(i))
        assert len(s) < 64
        assert s.n_seen == 10000
        # Whole-run coverage: the head survives decimation (a ring buffer
        # would have discarded it) and the kept tail is recent.
        assert s[0][0] == 0.0
        assert s[-1][0] > 9.0
        # Uniform resolution: consecutive kept points are one stride apart.
        ts = [t for t, _ in s]
        gaps = {round(b - a, 9) for a, b in zip(ts, ts[1:])}
        assert len(gaps) == 1

    def test_deterministic_replay(self):
        def build():
            s = BoundedSeries(cap=32)
            for i in range(777):
                s.append(i * 0.01, i % 17)
            return list(s)
        assert build() == build()

    @given(st.integers(1, 2000))
    @settings(max_examples=40, deadline=None)
    def test_len_never_exceeds_cap(self, n):
        s = BoundedSeries(cap=16)
        for i in range(n):
            s.append(float(i), 0.0)
        assert len(s) <= 16
        assert bool(s) is (n > 0)

    def test_merge_spans_both_runs_and_stays_bounded(self):
        a, b = BoundedSeries(cap=32), BoundedSeries(cap=32)
        for i in range(500):
            a.append(i * 0.01, 1.0)           # t in [0, 5)
            b.append(5.0 + i * 0.01, 2.0)     # t in [5, 10)
        a.merge(b)
        assert len(a) < 32
        assert a.n_seen == 1000
        ts = [t for t, _ in a]
        assert ts == sorted(ts)
        assert ts[0] < 1.0 and ts[-1] > 9.0   # coverage spans both workers

    def test_small_series_kept_exactly(self):
        s = BoundedSeries(cap=4096)
        for i in range(10):
            s.append(float(i), float(-i))
        assert list(s) == [(float(i), float(-i)) for i in range(10)]
        assert s.stride == 1

    def test_telemetry_uses_bounded_series(self):
        from repro.serving.telemetry import Telemetry
        te = Telemetry(["m0"])
        for i in range(10000):
            te.record_lambda(i * 1e-3, 50.0)
            te.record_queue_depth(i * 1e-3, i % 7)
        assert isinstance(te.lam_trace, BoundedSeries)
        assert len(te.lam_trace) <= 4096
        assert len(te.depth_trace) <= 4096
        other = Telemetry(["m0"])
        other.record_lambda(99.0, 10.0)
        te.merge(other)
        assert te.lam_trace.n_seen == 10001
