"""k-means, model embeddings, and the featurizer."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.clustering import assign_clusters, kmeans, pairwise_sq_dists
from repro.core.model_repr import build_model_embeddings, embed_new_model
from repro.data.featurizer import EMB_DIM, embed_text, embed_texts


class TestKMeans:
    def test_separated_clusters_recovered(self):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((50, 4)) * 0.1 + 5.0
        b = rng.standard_normal((50, 4)) * 0.1 - 5.0
        x = np.concatenate([a, b])
        centers, assign = kmeans(x, 2, seed=0)
        assert len(set(assign[:50])) == 1
        assert len(set(assign[50:])) == 1
        assert assign[0] != assign[-1]

    def test_assignment_is_nearest_centroid(self):
        rng = np.random.default_rng(1)
        x = rng.standard_normal((80, 6)).astype(np.float32)
        centers, assign = kmeans(x, 5, seed=1)
        d = np.asarray(pairwise_sq_dists(x, centers))
        assert np.array_equal(assign, d.argmin(axis=1))

    @given(st.integers(2, 6), st.integers(20, 60))
    @settings(max_examples=10, deadline=None)
    def test_kmeans_deterministic(self, k, n):
        rng = np.random.default_rng(42)
        x = rng.standard_normal((n, 5)).astype(np.float32)
        c1, a1 = kmeans(x, k, seed=3)
        c2, a2 = kmeans(x, k, seed=3)
        assert np.allclose(c1, c2)
        assert np.array_equal(a1, a2)


class TestModelRepr:
    def test_embedding_shape_and_range(self):
        rng = np.random.default_rng(0)
        emb = rng.standard_normal((200, 16)).astype(np.float32)
        quality = rng.random((200, 4)).astype(np.float32)
        memb, centers = build_model_embeddings(emb, quality, n_clusters=8, seed=0)
        assert memb.shape == (4, 8)
        assert centers.shape == (8, 16)
        assert memb.min() >= 0.0 and memb.max() <= 1.0

    def test_perfect_model_embeds_to_ones(self):
        rng = np.random.default_rng(1)
        emb = rng.standard_normal((100, 8)).astype(np.float32)
        quality = np.ones((100, 2), np.float32)
        memb, _ = build_model_embeddings(emb, quality, n_clusters=4, seed=0)
        assert np.allclose(memb, 1.0)

    def test_dynamic_model_addition(self):
        rng = np.random.default_rng(2)
        emb = rng.standard_normal((150, 8)).astype(np.float32)
        quality = rng.random((150, 3)).astype(np.float32)
        memb, centers = build_model_embeddings(emb, quality, n_clusters=5, seed=0)
        new = embed_new_model(centers, emb, quality[:, 0])
        assert new.shape == (5,)
        assert 0.0 <= new.min() and new.max() <= 1.0


class TestFeaturizer:
    def test_deterministic(self):
        assert np.allclose(embed_text("what is 2+2?"), embed_text("what is 2+2?"))

    def test_unit_norm(self):
        v = embed_text("solve this equation for x")
        assert np.isclose(np.linalg.norm(v), 1.0, atol=1e-5)

    def test_dim(self):
        assert embed_text("hello").shape == (EMB_DIM,)

    def test_similar_texts_closer_than_different(self):
        a = embed_text("integral derivative equation algebra")
        b = embed_text("integral derivative equation arithmetic")
        c = embed_text("kitchen umbrella breakfast weekend")
        assert a @ b > a @ c

    @given(st.text(min_size=0, max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_never_nan(self, text):
        v = embed_text(text)
        assert np.all(np.isfinite(v))

    def test_batch_matches_single(self):
        texts = ["alpha beta", "gamma delta"]
        batch = embed_texts(texts)
        assert np.allclose(batch[0], embed_text(texts[0]))
        assert np.allclose(batch[1], embed_text(texts[1]))
