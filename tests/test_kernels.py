"""Pallas kernels: shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("b", [1, 8, 100, 256, 300])
@pytest.mark.parametrize("k", [2, 5, 11])
def test_router_xattn_shape_sweep(b, k):
    keys = jax.random.split(jax.random.key(b * 31 + k), 7)
    dq, dm, d = 768, 20, 20
    q = _mk(keys[0], (b, dq), jnp.float32)
    m_emb = _mk(keys[1], (k, dm), jnp.float32)
    wq = _mk(keys[2], (dq, d), jnp.float32) * 0.05
    wk = _mk(keys[3], (dm, d), jnp.float32) * 0.3
    wv = _mk(keys[4], (dm, d), jnp.float32) * 0.3
    wo = _mk(keys[5], (d, k), jnp.float32) * 0.3
    bo = _mk(keys[6], (k,), jnp.float32) * 0.1
    out = ops.router_xattn(q, wq, wk, wv, wo, bo, m_emb, interpret=True)
    expect = ref.router_xattn_ref(q, wq, wk, wv, wo, bo, m_emb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d_latent", [4, 20, 64, 128])
def test_router_xattn_dtype_latent_sweep(dtype, d_latent):
    keys = jax.random.split(jax.random.key(d_latent), 7)
    b, k, dq, dm = 64, 5, 256, 20
    q = _mk(keys[0], (b, dq), dtype)
    m_emb = _mk(keys[1], (k, dm), jnp.float32)
    wq = _mk(keys[2], (dq, d_latent), jnp.float32) * 0.05
    wk = _mk(keys[3], (dm, d_latent), jnp.float32) * 0.3
    wv = _mk(keys[4], (dm, d_latent), jnp.float32) * 0.3
    wo = _mk(keys[5], (d_latent, k), jnp.float32) * 0.3
    bo = jnp.zeros((k,))
    out = ops.router_xattn(q, wq, wk, wv, wo, bo, m_emb, interpret=True)
    expect = ref.router_xattn_ref(q, wq, wk, wv, wo, bo, m_emb)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_router_xattn_matches_predictor_module():
    """Kernel semantics == the core library's attention predictor."""
    from repro.core.predictors import PREDICTORS

    pred = PREDICTORS["attn"]
    params = pred.init(jax.random.key(0), 768, 5, 20)
    q = jax.random.normal(jax.random.key(1), (40, 768))
    m = jax.random.normal(jax.random.key(2), (5, 20))
    core = pred.apply(params, q, m)
    kern = ops.router_xattn(
        q, params["wq"], params["wk"], params["wv"], params["wo"],
        params["bo"], m, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(kern), np.asarray(core),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,k,d", [(8, 3, 16), (100, 20, 768), (256, 256, 64),
                                   (300, 37, 128), (1, 1, 8)])
def test_pairwise_l2_shape_sweep(n, k, d):
    keys = jax.random.split(jax.random.key(n * 7 + k), 2)
    x = _mk(keys[0], (n, d), jnp.float32)
    c = _mk(keys[1], (k, d), jnp.float32)
    out = ops.pairwise_l2(x, c, interpret=True)
    expect = ref.pairwise_l2_ref(x, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_dtypes(dtype):
    keys = jax.random.split(jax.random.key(0), 2)
    x = _mk(keys[0], (64, 256), dtype)
    c = _mk(keys[1], (16, 256), dtype)
    out = ops.pairwise_l2(x, c, interpret=True)
    expect = ref.pairwise_l2_ref(x, c)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_pairwise_l2_zero_distance_on_identical_rows():
    x = jnp.ones((8, 32))
    out = ops.pairwise_l2(x, x, interpret=True)
    assert float(jnp.abs(out).max()) < 1e-5


def test_pairwise_l2_matches_clustering_module():
    from repro.core.clustering import pairwise_sq_dists

    x = jax.random.normal(jax.random.key(5), (50, 96))
    c = jax.random.normal(jax.random.key(6), (7, 96))
    np.testing.assert_allclose(
        np.asarray(ops.pairwise_l2(x, c, interpret=True)),
        np.asarray(pairwise_sq_dists(x, c)),
        rtol=1e-4, atol=1e-4,
    )
