"""Deterministic CPU tests for the streaming serving runtime:
queue admission/backpressure, scheduler coalescing, budget governor,
traffic scenarios, and a small end-to-end simulated-traffic run.
"""
import numpy as np
import pytest

from repro.serving import (
    DONE,
    EXPIRED,
    REJECTED,
    SHED,
    AdmissionQueue,
    BudgetGovernor,
    Histogram,
    MicroBatchScheduler,
    Request,
    SchedulerConfig,
    TraceConfig,
    make_trace,
)


def req(text="q", arrival=0.0, deadline=None, n_prompt=4, max_new=2):
    return Request(text=text, prompt=np.zeros(n_prompt, np.int32),
                   max_new=max_new, arrival_s=arrival, deadline_s=deadline)


class FakeMember:
    def __init__(self, name, cost_rate):
        self.name = name
        self.cost_rate = cost_rate


class FakeEngine:
    """Quality/cost tables keyed by the first prompt char; counts generate
    calls so coalescing is observable. Reward semantics match the real
    engine (R2 argmax)."""

    def __init__(self, cost_rates=(1.0, 10.0), quality=(0.5, 1.0)):
        self.pool = [FakeMember(f"m{i}", c) for i, c in enumerate(cost_rates)]
        self.quality = np.asarray(quality, np.float64)
        self.lam = 100.0
        self.generate_log = []          # (member, batch_size)

    def score_texts(self, texts):
        b = len(texts)
        s = np.tile(self.quality, (b, 1))
        c = np.tile([m.cost_rate for m in self.pool], (b, 1))
        return s, c

    def choose(self, s_hat, c_hat, lam=None):
        lam = self.lam if lam is None else lam
        return np.argmax(s_hat * np.exp(-c_hat / lam), axis=-1)

    def generate_member(self, mi, prompts, max_new=8):
        self.generate_log.append((mi, len(prompts)))
        outs = [np.zeros(max_new, np.int32) for _ in prompts]
        return outs, self.pool[mi].cost_rate * len(prompts)


class TestAdmissionQueue:
    def test_fifo_admission_and_pop(self):
        q = AdmissionQueue(capacity=8)
        reqs = [req(text=str(i), arrival=float(i)) for i in range(5)]
        for i, r in enumerate(reqs):
            assert q.offer(r, now=float(i))
        assert q.depth == 5
        out = q.pop(3)
        assert [r.text for r in out] == ["0", "1", "2"]
        assert q.depth == 2

    def test_backpressure_rejects_when_full(self):
        q = AdmissionQueue(capacity=2)
        assert q.offer(req(), 0.0)
        assert q.offer(req(), 0.0)
        r3 = req()
        assert not q.offer(r3, 0.0)
        assert r3.status == REJECTED
        assert q.rejected == 1
        assert q.depth == 2

    def test_deadline_expiry(self):
        q = AdmissionQueue()
        r_live = req(deadline=10.0)
        r_dead = req(deadline=0.5)
        q.offer(r_live, 0.0)
        q.offer(r_dead, 0.0)
        dropped = q.expire(now=1.0)
        assert dropped == [r_dead]
        assert r_dead.status == EXPIRED
        assert q.depth == 1 and q.expired == 1

    def test_oldest_wait_tracks_head(self):
        q = AdmissionQueue()
        q.offer(req(), now=1.0)
        q.offer(req(), now=3.0)
        assert q.oldest_wait(5.0) == pytest.approx(4.0)


class TestSloClassShedding:
    def _mixed_queue(self, classes):
        q = AdmissionQueue()
        reqs = []
        for i, cls in enumerate(classes):
            r = req(text=str(i))
            r.slo_class = cls
            q.offer(r, 0.0)
            reqs.append(r)
        return q, reqs

    def test_sheds_only_the_lowest_class_present(self):
        q, reqs = self._mixed_queue([0, 1, 0, 2, 1])
        dropped = q.shed_lowest(1.0, alerts=("latency_p95",))
        assert [r.text for r in dropped] == ["0", "2"]
        assert all(r.status == SHED and r.finish_s == 1.0 for r in dropped)
        assert q.shed == 2 and q.depth == 3
        assert sorted(r.slo_class for r in q.peek_all()) == [1, 1, 2]
        # a second alert round now sheds class 1 — classes fall in order
        assert [r.slo_class for r in q.shed_lowest(2.0)] == [1, 1]

    def test_rescue_carrying_requests_never_shed(self):
        q, (r0, r1) = self._mixed_queue([0, 0])
        r1.best_output = np.zeros(2, np.int32)     # mid-cascade answer
        dropped = q.shed_lowest(1.0)
        assert dropped == [r0] and q.depth == 1
        assert r1.status != SHED

    def test_noop_on_empty_or_unsheddable_queue(self):
        assert AdmissionQueue().shed_lowest(0.0) == []
        q, (r0,) = self._mixed_queue([0])
        r0.best_output = np.zeros(1, np.int32)
        assert q.shed_lowest(0.0) == [] and q.shed == 0

    class _FiringSLO:
        """Stub tracker whose burn-rate alert is permanently firing."""

        tracer = None

        def __init__(self):
            self.observed = 0

        def firing(self):
            return ["latency_p95_burn"]

        def check(self, now, force=False):
            pass

        def observe_request(self, *a, **kw):
            self.observed += 1

    def test_scheduler_sheds_lowest_class_when_enforcing(self):
        eng = FakeEngine()
        slo = self._FiringSLO()
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=16, max_batch=16),
            service_time=lambda kind, n, wall: 1e-3, slo=slo)
        sched.slo_enforce = True
        for i, cls in enumerate([0, 1, 0, 1]):
            r = req(text=str(i))
            r.slo_class = cls
            sched.queue.offer(r, 0.0)
        served = sched.dispatch()
        # class-0 load shed before spending capacity on it; class 1 served
        assert sched.queue.shed == 2
        assert [r.slo_class for r in served] == [1, 1]
        assert all(r.status == DONE for r in served)
        # shed requests never feed the tracker (no self-amplified burn)
        assert slo.observed == 2

    def test_enforcement_defaults_off(self):
        eng = FakeEngine()
        slo = self._FiringSLO()
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=16, max_batch=16),
            service_time=lambda kind, n, wall: 1e-3, slo=slo)
        for i in range(3):
            sched.queue.offer(req(text=str(i)), 0.0)
        served = sched.dispatch()
        assert len(served) == 3 and sched.queue.shed == 0


class TestBudgetGovernor:
    def test_over_budget_tightens_lambda_proportionally(self):
        g = BudgetGovernor(budget=1.0, window_s=10.0, lam0=1.0, gain=1.0)
        g.record(5.0, now=0.0)
        lam1 = g.update(now=0.0)   # 5x over -> lambda shrinks 5x
        lam2 = g.update(now=0.1)
        assert lam1 == pytest.approx(0.2)
        assert lam2 == pytest.approx(0.04)
        assert g.tightened == 2

    def test_under_budget_relaxes_back_to_nominal_cap(self):
        g = BudgetGovernor(budget=1.0, window_s=1.0, lam0=2.0, decay=0.5)
        g.record(5.0, now=0.0)
        g.update(now=0.0)                 # tighten
        assert g.lam < 2.0
        # spend falls out of the window -> relax, but never above lam0
        for t in (5.0, 6.0, 7.0):
            g.update(now=t)
        assert g.lam == pytest.approx(2.0)
        assert g.relaxed >= 1

    def test_lambda_floor(self):
        g = BudgetGovernor(budget=1e-9, window_s=100.0, lam0=1.0,
                           lam_min=1e-3)
        g.record(1.0, now=0.0)
        for t in range(10):
            g.update(now=float(t) * 1e-3)
        assert g.lam == pytest.approx(1e-3)


class TestSchedulerCoalescing:
    def test_same_member_requests_land_in_one_generate_call(self):
        eng = FakeEngine()           # lam=100 -> everyone routes to m1
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=16, max_batch=16),
            service_time=lambda kind, n, wall: 1e-3)
        for i in range(6):
            sched.queue.offer(req(text=str(i)), 0.0)
        served = sched.dispatch()
        assert len(served) == 6
        assert eng.generate_log == [(1, 6)]
        assert all(r.status == DONE and r.member == 1 for r in served)

    def test_micro_batch_cap_splits_generate_calls(self):
        eng = FakeEngine()
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=16, max_batch=2),
            service_time=lambda kind, n, wall: 1e-3)
        for i in range(5):
            sched.queue.offer(req(text=str(i)), 0.0)
        sched.dispatch()
        assert eng.generate_log == [(1, 2), (1, 2), (1, 1)]

    def test_split_across_members(self):
        eng = FakeEngine()
        eng.lam = 3.0   # R2: m0 = .5*exp(-1/3) = .358 > m1 = 1*exp(-10/3) = .036
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=16, max_batch=16),
            service_time=lambda kind, n, wall: 1e-3)
        for i in range(4):
            sched.queue.offer(req(text=str(i)), 0.0)
        served = sched.dispatch()
        assert eng.generate_log == [(0, 4)]
        assert all(r.member == 0 for r in served)

    def test_wait_bound_float_rounding_still_dispatches(self):
        """Regression: admitted + max_wait can round to exactly `now`, making
        oldest_wait one ulp short of max_wait — must still dispatch (was a
        livelock in run_trace)."""
        eng = FakeEngine()
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=64, max_wait_s=0.05),
            service_time=lambda kind, n, wall: 1e-3)
        admitted = 0.16409982975992232      # from the original repro
        r = req()
        sched.clock.advance_to(admitted)
        sched.queue.offer(r, admitted)
        sched.clock.advance_to(admitted + 0.05)
        assert sched.queue.oldest_wait(sched.clock.now) <= 0.05
        assert sched.should_dispatch()

    def test_large_open_loop_trace_terminates(self):
        eng = FakeEngine()
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=64, max_batch=8,
                                 max_wait_s=0.05, queue_capacity=10_000),
            service_time=lambda kind, n, wall: 1e-3 * n)
        trace = make_trace(
            TraceConfig(kind="poisson", n_requests=2000, rate=400.0, seed=0),
            texts=["x"])
        summary = sched.run_trace(trace)
        assert summary["completed"] == 2000

    def test_scoring_is_one_batch(self):
        eng = FakeEngine()
        calls = []
        orig = eng.score_texts
        eng.score_texts = lambda texts: (calls.append(len(texts)),
                                         orig(texts))[1]
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=32, max_batch=4),
            service_time=lambda kind, n, wall: 1e-3)
        for i in range(12):
            sched.queue.offer(req(text=str(i)), 0.0)
        sched.dispatch()
        assert calls == [12]


class TestSchedulerGovernor:
    def test_tight_budget_shifts_traffic_to_cheap_member(self):
        """Quality favors the expensive member; a tight rolling budget must
        force the governor to reroute sustained traffic to the cheap one."""
        eng = FakeEngine(cost_rates=(1.0, 10.0), quality=(0.5, 1.0))
        gov = BudgetGovernor(budget=40.0, window_s=1e9, lam0=100.0,
                             decay=0.5)
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=4, max_batch=8, max_wait_s=0.01),
            governor=gov, service_time=lambda kind, n, wall: 1e-3)
        trace = [req(text=str(i), arrival=i * 0.001) for i in range(64)]
        sched.run_trace(trace)
        counts = sched.telemetry.member_counts
        assert counts[1] > 0           # started on the expensive member
        assert counts[0] > counts[1]   # governor shifted the bulk to cheap
        assert gov.lam < gov.lam0
        # lambda trace is monotone non-increasing until the shift happens
        lams = [l for _, l in sched.telemetry.lam_trace]
        assert lams[0] == gov.lam0 and min(lams) < gov.lam0

    def test_no_governor_keeps_engine_lambda(self):
        eng = FakeEngine()
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=4),
            service_time=lambda kind, n, wall: 1e-3)
        sched.queue.offer(req(), 0.0)
        sched.dispatch()
        assert sched.telemetry.lam_trace[0][1] == eng.lam


class TestTraffic:
    def test_trace_is_deterministic(self):
        cfg = TraceConfig(kind="poisson", n_requests=32, rate=100.0, seed=3)
        t1 = make_trace(cfg, texts=["a", "b", "c"])
        t2 = make_trace(cfg, texts=["a", "b", "c"])
        assert [r.arrival_s for r in t1] == [r.arrival_s for r in t2]
        assert [r.text for r in t1] == [r.text for r in t2]
        assert all(np.array_equal(a.prompt, b.prompt)
                   for a, b in zip(t1, t2))

    def test_arrivals_sorted_and_lengths_bounded(self):
        cfg = TraceConfig(kind="bursty", n_requests=64, rate=50.0, seed=0,
                          prompt_len_min=4, prompt_len_max=32)
        tr = make_trace(cfg, texts=["x"])
        arr = [r.arrival_s for r in tr]
        assert arr == sorted(arr)
        assert all(4 <= len(r.prompt) <= 32 for r in tr)

    def test_bursty_has_on_off_structure(self):
        cfg = TraceConfig(kind="bursty", n_requests=200, rate=50.0, seed=1,
                          burst_factor=20.0, on_mean_s=0.1, off_mean_s=1.0)
        gaps = np.diff([r.arrival_s for r in make_trace(cfg, texts=["x"])])
        # ON-phase gaps are tiny, OFF gaps huge: spread far beyond Poisson.
        assert gaps.max() > 20 * np.median(gaps)

    def test_drift_shifts_benchmark_mixture(self):
        texts = [f"t{i}" for i in range(400)]
        benchmarks = ["mmlu"] * 200 + ["mbpp"] * 200
        cfg = TraceConfig(kind="drift", n_requests=300, rate=100.0, seed=0)
        tr = make_trace(cfg, texts=texts, benchmarks=benchmarks)
        bench_of = dict(zip(texts, benchmarks))
        half = len(tr) // 2
        # group B = second half of the sorted benchmark names ("mmlu" here)
        late_b = np.mean([bench_of[t.text] == "mmlu" for t in tr[half:]])
        early_b = np.mean([bench_of[t.text] == "mmlu" for t in tr[:half]])
        assert late_b > early_b + 0.3

    def test_deadline_threads_through(self):
        cfg = TraceConfig(n_requests=8, rate=100.0, seed=0, deadline_s=0.5)
        tr = make_trace(cfg, texts=["x"])
        assert all(r.deadline_s == pytest.approx(r.arrival_s + 0.5)
                   for r in tr)


class TestTelemetry:
    def test_histogram_percentiles(self):
        h = Histogram()
        for v in np.linspace(0.001, 0.1, 1000):
            h.record(float(v))
        assert h.percentile(50) == pytest.approx(0.05, rel=0.15)
        assert h.percentile(99) == pytest.approx(0.1, rel=0.15)
        assert h.min == pytest.approx(0.001)
        assert h.count == 1000

    def test_run_trace_summary_accounts_everything(self):
        eng = FakeEngine()
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=8, max_batch=4, max_wait_s=0.005,
                                 queue_capacity=4),
            service_time=lambda kind, n, wall: 0.01)
        # Arrivals far faster than service -> some must be rejected.
        trace = [req(text=str(i), arrival=i * 1e-4) for i in range(40)]
        summary = sched.run_trace(trace)
        assert summary["completed"] + summary["rejected"] == 40
        assert summary["rejected"] > 0
        assert summary["total_spend"] > 0
        assert summary["max_queue_depth"] <= 4


class TestEndToEndSimulatedTraffic:
    @pytest.fixture(scope="class")
    def engine(self):
        from repro.launch.serve import build_routed_engine

        eng, data, te = build_routed_engine(
            ["qwen3-0.6b", "granite-3-8b"], seed=0, epochs=20,
            n_traffic=400)
        return eng, data, te

    def test_all_requests_complete(self, engine):
        eng, data, te = engine
        trace = make_trace(
            TraceConfig(kind="poisson", n_requests=12, rate=500.0, seed=0,
                        max_new=2, prompt_len_max=16, vocab=64),
            texts=[data.texts[i] for i in te])
        sched = MicroBatchScheduler(
            eng, SchedulerConfig(score_batch=16, max_batch=8))
        summary = sched.run_trace(trace)
        assert summary["completed"] == 12
        assert summary["rejected"] == 0 and summary["expired"] == 0
        assert all(r.status == DONE and r.output is not None
                   and len(r.output) == 2 for r in trace)
        assert summary["total_spend"] > 0
        counts = summary["per_member_counts"]
        assert sum(counts.values()) == 12

    def test_serve_entrypoint_backcompat(self, engine):
        """The one-shot RoutedEngine.serve path still works on the
        refactored stateless core (variable-length prompts included)."""
        import jax.numpy as jnp

        eng, data, te = engine
        texts = [data.texts[i] for i in te[:5]]
        prompts = jnp.zeros((5, 8), jnp.int32)
        res = eng.serve(texts, prompts, max_new=2)
        assert len(res["outputs"]) == 5
        assert all(o is not None and o.shape == (2,) for o in res["outputs"])
        assert res["per_member_counts"].sum() == 5
