"""End-to-end behaviour tests for the routing system (the paper's claims,
executed small): train the dual predictors on synthetic RouterBench, route,
and verify the framework-level properties the paper reports.
"""
import numpy as np
import pytest

from repro.core import (
    DEFAULT_LAMBDA_GRID, build_model_embeddings, evaluate_sweep, oracle_sweep,
)
from repro.core.router import PredictiveRouter
from repro.training import train_dual_predictors

EPOCHS = 80  # enough for the small fixture; benchmarks use the paper's 1000


@pytest.fixture(scope="module")
def trained(pool1):
    tr, va, te = pool1.split()
    memb, cents = build_model_embeddings(pool1.emb[tr], pool1.quality[tr], seed=0)
    qp, cp, scaler, hist = train_dual_predictors(
        "attn", "attn", pool1.emb[tr], pool1.quality[tr], pool1.cost[tr], memb,
        q_emb_val=pool1.emb[va], quality_val=pool1.quality[va],
        cost_val=pool1.cost[va], epochs=EPOCHS, seed=0,
    )
    router = PredictiveRouter("attn", "attn", qp, cp, memb, reward="R2",
                              cost_scaler=scaler)
    return router, (tr, va, te), hist


class TestEndToEnd:
    def test_training_converges(self, trained):
        _, _, hist = trained
        assert hist["quality"]["train_loss"][-1] < hist["quality"]["train_loss"][0]
        assert hist["cost"]["train_loss"][-1] < hist["cost"]["train_loss"][0]

    def test_router_beats_cheapest_single_model(self, pool1, trained):
        router, (tr, va, te), _ = trained
        ch = router.sweep(pool1.emb[te], DEFAULT_LAMBDA_GRID)
        m = evaluate_sweep(ch, pool1.quality[te], pool1.cost[te])
        cheapest = int(np.argmin(pool1.cost[te].mean(0)))
        cheapest_perf = float(pool1.quality[te][:, cheapest].mean())
        assert m["perf_max"] > cheapest_perf

    def test_lambda_monotone_cost(self, pool1, trained):
        """Higher willingness to pay must not lower average routed cost
        (up to small prediction noise)."""
        router, (_, _, te), _ = trained
        lams = np.array([1e-4, 1e-2, 1.0, 100.0])
        ch = router.sweep(pool1.emb[te], lams)
        b = np.arange(len(te))
        costs = [float(pool1.cost[te][b, c].mean()) for c in ch]
        assert costs[-1] >= costs[0] * 0.99

    def test_oracle_dominates_predictive_router(self, pool1, trained):
        router, (_, _, te), _ = trained
        ch_r = router.sweep(pool1.emb[te], DEFAULT_LAMBDA_GRID)
        m_r = evaluate_sweep(ch_r, pool1.quality[te], pool1.cost[te])
        ch_o = oracle_sweep(pool1.quality[te], pool1.cost[te],
                            DEFAULT_LAMBDA_GRID, "R2")
        m_o = evaluate_sweep(ch_o, pool1.quality[te], pool1.cost[te])
        assert m_o["aiq"] >= m_r["aiq"]
        assert m_o["perf_max"] >= m_r["perf_max"] - 1e-9

    def test_r2_oracle_less_sensitive_than_r1(self, pool1):
        """Paper Table 1's headline: R2's lambda-sensitivity << R1's."""
        _, _, te = pool1.split()
        q, c = pool1.quality[te], pool1.cost[te]
        m1 = evaluate_sweep(oracle_sweep(q, c, DEFAULT_LAMBDA_GRID, "R1"), q, c)
        m2 = evaluate_sweep(oracle_sweep(q, c, DEFAULT_LAMBDA_GRID, "R2"), q, c)
        assert m2["lam_sens_perf"] < m1["lam_sens_perf"]

    def test_router_beats_random_routing(self, pool1, trained):
        router, (_, _, te), _ = trained
        ch = router.sweep(pool1.emb[te], DEFAULT_LAMBDA_GRID)
        m = evaluate_sweep(ch, pool1.quality[te], pool1.cost[te])
        rng = np.random.default_rng(0)
        ch_rand = rng.integers(0, pool1.quality.shape[1], size=ch.shape)
        m_rand = evaluate_sweep(ch_rand, pool1.quality[te], pool1.cost[te])
        assert m["aiq"] > m_rand["aiq"]

    def test_dynamic_pool_growth_with_dot_head(self, pool1):
        """attn-dot router scores a pool member added after training."""
        from repro.core.predictors import PREDICTORS
        from repro.core.model_repr import embed_new_model

        tr, va, te = pool1.split()
        memb4, cents = build_model_embeddings(
            pool1.emb[tr], pool1.quality[tr][:, :4], seed=0)
        qp, cp, scaler, _ = train_dual_predictors(
            "attn-dot", "attn-dot", pool1.emb[tr], pool1.quality[tr][:, :4],
            pool1.cost[tr][:, :4], memb4, epochs=30, seed=0)
        new_emb = embed_new_model(cents, pool1.emb[tr], pool1.quality[tr][:, 4])
        memb5 = np.concatenate([memb4, new_emb[None]], axis=0)
        out = PREDICTORS["attn-dot"].apply(qp, pool1.emb[te][:16], memb5)
        assert out.shape == (16, 5)
        assert np.isfinite(np.asarray(out)).all()


class TestServingEngine:
    @pytest.fixture(scope="class")
    def engine_parts(self):
        from repro.launch.serve import build_pool, synthetic_pool_traffic

        pool = build_pool(["qwen3-0.6b", "granite-3-8b"])
        data, quality, cost = synthetic_pool_traffic(pool, n=400)
        tr, va, te = data.split()
        memb, _ = build_model_embeddings(data.emb[tr], quality[tr], seed=0)
        qp, cp, scaler, _ = train_dual_predictors(
            "attn", "attn", data.emb[tr], quality[tr], cost[tr], memb,
            epochs=30)
        router = PredictiveRouter("attn", "attn", qp, cp, memb,
                                  reward="R2", cost_scaler=scaler)
        return router, pool, data, te

    def test_routed_serving_end_to_end(self, engine_parts):
        import jax.numpy as jnp
        from repro.serving import RoutedEngine

        router, pool, data, te = engine_parts
        engine = RoutedEngine(router=router, pool=pool, lam=1.0)
        texts = [data.texts[i] for i in te[:6]]
        prompts = jnp.zeros((6, 8), jnp.int32)
        res = engine.serve(texts, prompts, max_new=2)
        assert len(res["outputs"]) == 6
        assert all(o is not None and o.shape == (2,) for o in res["outputs"])
        assert res["total_cost"] > 0
        assert res["per_member_counts"].sum() == 6

    def test_lambda_zero_routes_cheap(self, engine_parts):
        from repro.serving import RoutedEngine

        router, pool, data, te = engine_parts
        engine = RoutedEngine(router=router, pool=pool, lam=1e-9)
        texts = [data.texts[i] for i in te[:24]]
        choices = engine.route_texts(texts)
        cheap = int(np.argmin([m.cost_rate for m in pool]))
        assert (choices == cheap).mean() > 0.9

    def test_pallas_scoring_path_matches_reference(self, engine_parts):
        from repro.serving import RoutedEngine

        router, pool, data, te = engine_parts
        texts = [data.texts[i] for i in te[:16]]
        eng_ref = RoutedEngine(router=router, pool=pool, lam=1.0,
                               use_pallas=False)
        eng_pal = RoutedEngine(router=router, pool=pool, lam=1.0,
                               use_pallas=True)
        np.testing.assert_array_equal(
            eng_ref.route_texts(texts), eng_pal.route_texts(texts))
