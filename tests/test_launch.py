"""Launch layer: sharding rules, input specs, HLO collective parsing.

These tests run on 1 CPU device: sharding *rules* are exercised against an
AbstractMesh with the production 16x16 shape (no real devices needed), and a
real (1,1) mesh covers the end-to-end jit path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, input_specs, shape_applicable
from repro.launch.hlo_analysis import (
    collective_bytes_per_device, parse_collectives, _shape_bytes,
)
from repro.launch.sharding import (
    batch_axes, cache_shardings, param_spec, param_shardings, train_rules,
    decode_rules,
)
from repro.models import lm as lm_mod


def _make_abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        # jax<=0.4.x: AbstractMesh(shape_tuple) of (name, size) pairs.
        return AbstractMesh(tuple(zip(names, sizes)))


def abstract_mesh(multi_pod=False):
    if multi_pod:
        return _make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return _make_abstract_mesh((16, 16), ("data", "model"))


class TestParamSpecs:
    def test_embedding_sharded_on_vocab(self):
        mesh = abstract_mesh()
        cfg = get_config("gemma3-27b")
        assert param_spec(cfg, mesh, "embedding/table", 2) == P("model", "data")
        assert param_spec(cfg, mesh, "embedding/head", 2) == P("data", "model")

    def test_attention_tp(self):
        mesh = abstract_mesh()
        cfg = get_config("granite-3-8b")
        # stacked pattern params have a leading repeat axis
        assert param_spec(cfg, mesh, "pattern/0/mixer/wq", 3) == P(None, "data", "model")
        assert param_spec(cfg, mesh, "pattern/0/mixer/wo", 3) == P(None, "model", "data")
        assert param_spec(cfg, mesh, "pattern/0/norm1/scale", 2) == P(None, None)

    def test_moe_expert_parallel(self):
        mesh = abstract_mesh()
        cfg = get_config("llama4-maverick-400b-a17b")
        # pattern position 1 is the MoE layer
        assert param_spec(cfg, mesh, "pattern/1/ffn/w_gate", 4) == P(
            None, "model", "data", None)
        assert param_spec(cfg, mesh, "pattern/1/ffn/w_down", 4) == P(
            None, "model", None, "data")
        # shared expert = plain MLP sharding
        assert param_spec(cfg, mesh, "pattern/1/ffn/shared/w_gate", 3) == P(
            None, "data", "model")

    def test_multipod_folds_pod_into_fsdp(self):
        mesh = abstract_mesh(multi_pod=True)
        cfg = get_config("granite-3-8b")
        spec = param_spec(cfg, mesh, "pattern/0/mixer/wq", 3)
        assert spec == P(None, ("pod", "data"), "model")

    def test_every_param_of_every_arch_divides(self):
        """All param shardings must divide their dims on the 16x16 mesh
        (jit argument shardings require exact divisibility)."""
        mesh = abstract_mesh()
        from repro.common.tree import flatten_with_paths
        for name in ARCH_IDS:
            cfg = get_config(name)
            abstract = lm_mod.abstract_params(cfg, dtype=jnp.bfloat16)
            for path, leaf in flatten_with_paths(abstract).items():
                spec = param_spec(cfg, mesh, path, len(leaf.shape))
                for dim, ax in zip(leaf.shape, spec):
                    if ax is None:
                        continue
                    size = np.prod([mesh.shape[a] for a in
                                    (ax if isinstance(ax, tuple) else (ax,))])
                    assert dim % size == 0, (name, path, leaf.shape, spec)


class TestCacheSpecs:
    def test_all_arch_decode_caches_divide(self):
        mesh = abstract_mesh()
        for name in ARCH_IDS:
            cfg = get_config(name)
            for shape_name in ("decode_32k", "long_500k"):
                shape = SHAPES[shape_name]
                if not shape_applicable(cfg, shape):
                    continue
                caches = lm_mod.abstract_caches(cfg, shape.global_batch,
                                                shape.seq_len)
                shardings = cache_shardings(cfg, mesh, caches)
                for leaf, sh in zip(jax.tree.leaves(caches),
                                    jax.tree.leaves(shardings)):
                    for dim, ax in zip(leaf.shape, sh.spec):
                        if ax is None:
                            continue
                        size = np.prod([mesh.shape[a] for a in
                                        (ax if isinstance(ax, tuple) else (ax,))])
                        assert dim % size == 0, (name, shape_name, leaf.shape,
                                                 sh.spec)

    def test_long_context_shards_seq_over_all_axes(self):
        mesh = abstract_mesh()
        cfg = get_config("gemma3-27b")
        shape = SHAPES["long_500k"]
        caches = lm_mod.abstract_caches(cfg, 1, shape.seq_len)
        shardings = cache_shardings(cfg, mesh, caches)
        # global layers (pattern pos 5) hold the full 500k cache
        k_spec = jax.tree.leaves(
            shardings["pattern"][5], is_leaf=lambda x: hasattr(x, "spec")
        )
        specs = [s.spec for s in jax.tree.leaves(shardings["pattern"][5])]
        assert any(("data", "model") in (ax if isinstance(ax, tuple) else (ax,))
                   or ax == ("data", "model")
                   for sp in specs for ax in sp if ax is not None)


class TestRules:
    def test_train_vs_decode_cache_axis(self):
        mesh = abstract_mesh()
        assert train_rules(mesh)["cache_seq"] is None
        assert decode_rules(mesh)["cache_seq"] == "model"

    def test_batch_axes_multipod(self):
        assert batch_axes(abstract_mesh(True)) == ("pod", "data")
        assert batch_axes(abstract_mesh(False)) == "data"


class TestInputSpecs:
    def test_train_specs(self):
        cfg = get_config("qwen3-0.6b")
        specs = input_specs(cfg, SHAPES["train_4k"])
        assert specs["tokens"].shape == (256, 4096)
        assert specs["labels"].dtype == jnp.int32

    def test_decode_specs_have_one_token(self):
        cfg = get_config("qwen3-0.6b")
        specs = input_specs(cfg, SHAPES["decode_32k"])
        assert specs["token"].shape == (128, 1)
        assert specs["pos"].shape == ()
        assert "caches" in specs

    def test_vlm_specs_include_media(self):
        cfg = get_config("llama-3.2-vision-90b")
        specs = input_specs(cfg, SHAPES["train_4k"])
        assert specs["media"].shape == (256, 1601, 1280)

    def test_long500k_gate(self):
        assert not shape_applicable(get_config("qwen3-0.6b"), SHAPES["long_500k"])
        assert shape_applicable(get_config("xlstm-1.3b"), SHAPES["long_500k"])
        assert shape_applicable(get_config("gemma3-27b"), SHAPES["long_500k"])
        assert shape_applicable(get_config("jamba-1.5-large-398b"),
                                SHAPES["long_500k"])


class TestHLOParsing:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
        assert _shape_bytes("bf16[16]") == 32
        assert _shape_bytes("(f32[8], s32[4])") == 8 * 4 + 4 * 4

    def test_parse_collectives(self):
        hlo = """
  %ag = f32[32,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[64]{0} all-reduce(%y), replica_groups=[4,8]<=[32], to_apply=%sum
  %cp = f32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
        colls = parse_collectives(hlo)
        kinds = [c["kind"] for c in colls]
        assert kinds == ["all-gather", "all-reduce", "collective-permute"]
        assert colls[0]["group"] == 4
        assert colls[1]["group"] == 8
        total, by_kind = collective_bytes_per_device(colls)
        expect_ag = 32 * 128 * 4 * 3 / 4
        expect_ar = 2 * 64 * 2 * 7 / 8
        expect_cp = 16 * 4
        assert np.isclose(total, expect_ag + expect_ar + expect_cp)

    def test_no_collectives_on_single_device(self):
        f = jax.jit(lambda x: x @ x)
        compiled = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        colls = parse_collectives(compiled.as_text())
        total, _ = collective_bytes_per_device(colls)
        assert total == 0.0


class TestSmallMeshEndToEnd:
    def test_train_step_jits_on_1x1_mesh(self):
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import make_train_step, abstract_opt_state
        from repro.training.optim import adam_init

        mesh = make_debug_mesh(1, 1)
        cfg = get_smoke_config("qwen3-0.6b")
        params = lm_mod.init_lm(jax.random.key(0), cfg)
        from repro.launch.steps import TRAIN_ADAM
        opt = adam_init(TRAIN_ADAM, params)
        step = jax.jit(make_train_step(cfg, mesh))
        batch = {
            "tokens": jnp.zeros((4, 32), jnp.int32),
            "labels": jnp.zeros((4, 32), jnp.int32),
        }
        with mesh:
            loss, params, opt = step(params, opt, batch)
        assert np.isfinite(float(loss))
