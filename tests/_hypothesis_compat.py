"""``hypothesis`` shim: real library when installed, tiny fallback otherwise.

``hypothesis`` is an optional dev dependency (see README "Development").
Without it, property tests degrade to a bounded deterministic example grid —
far weaker than real property testing, but the suite still collects and the
invariants are exercised on representative values.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import itertools

    _MAX_EXAMPLES = 64  # bound on the fallback grid per test

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(sorted({lo, (lo + hi) // 2, hi}))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(sorted({lo, lo + (hi - lo) * 0.37, hi}))

        @staticmethod
        def text(min_size=0, max_size=40):
            pool = ["", "a", "hello world", "x" * max_size,
                    "ünïcode ✓\t\n", " leading and trailing "]
            return _Strategy([t for t in pool
                              if min_size <= len(t) <= max_size])

        @staticmethod
        def tuples(*strats):
            return _Strategy(itertools.product(
                *(s.examples for s in strats)))

        @staticmethod
        def lists(strat, min_size=0, max_size=10):
            exs = strat.examples
            sizes = sorted({min_size, min(max_size, min_size + 3), max_size})
            return _Strategy(
                [[exs[i % len(exs)] for i in range(n)] for n in sizes])

    st = _Strategies()

    def given(*strats, **kw_strats):
        names = list(kw_strats)

        def deco(fn):
            # No functools.wraps: copying __wrapped__ would make pytest
            # introspect the original signature and treat the example
            # parameters as fixtures.
            def run(self):
                combos = itertools.product(
                    *(s.examples for s in strats),
                    *(kw_strats[n].examples for n in names))
                for combo in itertools.islice(combos, _MAX_EXAMPLES):
                    args = combo[: len(strats)]
                    kwargs = dict(zip(names, combo[len(strats):]))
                    fn(self, *args, **kwargs)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

    def settings(**_kw):
        return lambda fn: fn
