"""Characterization: chunked-scan decompositions are chunk-size sensitive
at the float32 ULP level, and the gap is tightly bounded.

Both time-chunked scans — the Mamba selective scan (``SSM_CHUNK``) and the
chunkwise-stabilized mLSTM (``MLSTM_CHUNK``) — re-associate the same
mathematical recurrence differently per chunk size, so their outputs are
NOT bitwise identical across chunk settings. That gap is expected; what
must never change silently is its *scale*. This file pins both facts:

  * the decomposition really is non-bitwise (a future change that makes
    chunk size bit-invisible almost certainly changed the algorithm, e.g.
    fell back to a sequential scan — worth noticing);
  * the fp re-association delta stays below a tight bound calibrated at
    ~10-25x the observed gap (SSM ~4e-9, mLSTM ~3e-6 on these shapes), so
    a numerically unstable rewrite of the chunk boundary handoff fails
    loudly instead of drifting.

The batch-invariance suite covers masked/padded compute; this one covers
the orthogonal axis of how time is carved into chunks.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod

SSM_BOUND = 1e-7      # observed ~4e-9 (out scale ~0.07)
MLSTM_H_BOUND = 3e-5  # observed ~2.6e-6 (out scale ~5)
MLSTM_C_BOUND = 1e-5  # observed ~6.6e-7


class TestSSMChunkDecomposition:
    def _run(self, chunk, monkeypatch):
        monkeypatch.setattr(ssm_mod, "SSM_CHUNK", chunk)
        cfg = get_smoke_config("jamba-1.5-large-398b")
        p = ssm_mod.init_mamba(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model)) * 0.5
        return np.asarray(ssm_mod.apply_mamba_train(cfg, p, x))

    def test_chunk_boundary_gap_pinned(self, monkeypatch):
        outs = {c: self._run(c, monkeypatch) for c in (16, 32, 64)}
        gaps = [np.abs(outs[a] - outs[b]).max()
                for a, b in ((16, 64), (32, 64), (16, 32))]
        # Non-bitwise: at least one chunk pairing re-associates the scan.
        assert max(gaps) > 0.0
        assert max(gaps) < SSM_BOUND, gaps

    def test_same_chunk_is_bitwise_stable(self, monkeypatch):
        a = self._run(16, monkeypatch)
        b = self._run(16, monkeypatch)
        np.testing.assert_array_equal(a, b)


class TestMLSTMChunkDecomposition:
    def _inputs(self, b=2, t=64, h=2, dh=16, seed=5):
        ks = jax.random.split(jax.random.key(seed), 5)
        q = jax.random.normal(ks[0], (b, t, h, dh))
        k = jax.random.normal(ks[1], (b, t, h, dh)) * (dh ** -0.5)
        v = jax.random.normal(ks[2], (b, t, h, dh))
        log_i = jax.random.normal(ks[3], (b, t, h)) - 2.0
        log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, h)) + 2.0)
        return q, k, v, log_i, log_f

    def test_chunk_boundary_gap_pinned(self):
        args = self._inputs()
        res = {}
        for chunk in (8, 16, 64):   # 64 == t: single-chunk evaluation
            h_out, final = xlstm_mod.mlstm_chunkwise(*args, chunk=chunk)
            res[chunk] = (np.asarray(h_out), np.asarray(final["C"]))
        h_gaps = [np.abs(res[a][0] - res[b][0]).max()
                  for a, b in ((8, 64), (16, 64), (8, 16))]
        c_gaps = [np.abs(res[a][1] - res[b][1]).max()
                  for a, b in ((8, 64), (16, 64), (8, 16))]
        assert max(h_gaps) > 0.0
        assert max(h_gaps) < MLSTM_H_BOUND, h_gaps
        assert max(c_gaps) < MLSTM_C_BOUND, c_gaps

    def test_same_chunk_is_bitwise_stable(self):
        args = self._inputs()
        h1, f1 = xlstm_mod.mlstm_chunkwise(*args, chunk=16)
        h2, f2 = xlstm_mod.mlstm_chunkwise(*args, chunk=16)
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
        np.testing.assert_array_equal(np.asarray(f1["C"]), np.asarray(f2["C"]))
