"""Property-based tests for the online replay buffer (via the
``_hypothesis_compat`` shim: real hypothesis when installed, bounded
deterministic grid otherwise).

Three property families:
  * structural invariants of the ring/reservoir split for arbitrary
    (capacity, stream length) — sizes, ordering, and the eviction
    boundary (every reservoir item predates every ring item);
  * reservoir inclusion statistics — Algorithm R keeps a *uniform* sample
    of the evicted stream, so early and late evictions must be included
    at the same rate across seeds;
  * stratified-sample determinism — identical build + sample sequences
    under a fixed seed replay bit-identically.
"""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.online.replay import ReplayBuffer

DQ = 8


def _fill(buf, n, dq=DQ):
    for i in range(n):
        buf.add(np.full(dq, i % 17, np.float32), i % 3, i / max(n, 1), 0.1,
                float(i))
    return buf


class TestStructuralInvariants:
    @given(st.integers(2, 128), st.integers(0, 400))
    @settings(max_examples=40, deadline=None)
    def test_sizes_and_boundary(self, capacity, n_items):
        buf = _fill(ReplayBuffer(capacity=capacity, recent_frac=0.25, seed=3),
                    n_items)
        assert len(buf) <= capacity
        assert buf.added == n_items

        # Ring: exactly the newest min(n, cap_recent) items, in arrival order.
        ring_ts = [item[4] for item in buf._recent]
        n_ring = min(n_items, buf.cap_recent)
        assert ring_ts == [float(t) for t in
                           range(n_items - n_ring, n_items)]

        # Reservoir: capped uniform sample over everything evicted from
        # the ring.
        n_evicted = max(0, n_items - buf.cap_recent)
        assert buf._evicted == n_evicted
        assert len(buf._reservoir) == min(n_evicted, buf.cap_reservoir)

        # Boundary: eviction order means every reservoir item is strictly
        # older than every ring item.
        res_ts = [item[4] for item in buf._reservoir]
        if res_ts and ring_ts:
            assert max(res_ts) < min(ring_ts)
        # Reservoir members are genuinely from the evicted stream.
        assert all(t < n_evicted for t in res_ts)

    @given(st.integers(2, 64), st.floats(0.1, 0.9))
    @settings(max_examples=30, deadline=None)
    def test_region_caps_partition_capacity(self, capacity, recent_frac):
        buf = ReplayBuffer(capacity=capacity, recent_frac=recent_frac, seed=0)
        assert buf.cap_recent >= 1
        assert buf.cap_recent + buf.cap_reservoir == capacity
        _fill(buf, 3 * capacity)
        assert len(buf._recent) == buf.cap_recent
        assert len(buf._reservoir) <= buf.cap_reservoir


class TestReservoirUniformity:
    def test_inclusion_rate_uniform_over_evicted_stream(self):
        """Across seeds, every evicted item is retained with probability
        ~ cap_reservoir / n_evicted — in particular the oldest and newest
        halves of the evicted stream at the *same* rate (no recency bias
        inside the reservoir; the ring owns recency)."""
        n, capacity = 200, 40
        trials = 400
        counts = np.zeros(n)
        cap_res = None
        for seed in range(trials):
            buf = _fill(ReplayBuffer(capacity=capacity, recent_frac=0.25,
                                     seed=seed), n)
            cap_res = buf.cap_reservoir
            for item in buf._reservoir:
                counts[int(item[4])] += 1
        n_evicted = n - buf.cap_recent
        expect = cap_res / n_evicted
        inc = counts[:n_evicted] / trials
        early = inc[: n_evicted // 2].mean()
        late = inc[n_evicted // 2:].mean()
        assert np.isclose(early, expect, rtol=0.1)
        assert np.isclose(late, expect, rtol=0.1)
        # items still in the ring are never in the reservoir
        assert (counts[n_evicted:] == 0).all()

    def test_reservoir_holds_spread_not_tail(self):
        buf = _fill(ReplayBuffer(capacity=40, recent_frac=0.25, seed=0), 500)
        res_ts = [item[4] for item in buf._reservoir]
        assert min(res_ts) < 150 and max(res_ts) > 300


class TestStratifiedSampleDeterminism:
    @given(st.integers(4, 96), st.floats(0.1, 0.8))
    @settings(max_examples=20, deadline=None)
    def test_fixed_seed_replays_identically(self, capacity, recent_frac):
        def build():
            return _fill(ReplayBuffer(capacity=capacity,
                                      recent_frac=recent_frac, seed=11), 150)

        b1, b2 = build(), build()
        for draw in range(3):                  # rng state advances in lockstep
            s1 = b1.sample(24, recent_frac=0.5)
            s2 = b2.sample(24, recent_frac=0.5)
            for key in ("q_emb", "member", "s", "c", "t"):
                np.testing.assert_array_equal(s1[key], s2[key])

    @given(st.integers(8, 64))
    @settings(max_examples=20, deadline=None)
    def test_sample_strata_come_from_their_regions(self, n):
        buf = _fill(ReplayBuffer(capacity=64, recent_frac=0.25, seed=2), 256)
        ring_lo = min(item[4] for item in buf._recent)
        s = buf.sample(n, recent_frac=0.5)
        n_rec = int((s["t"] >= ring_lo).sum())
        # requested split is honored up to rounding
        assert abs(n_rec - round(n * 0.5)) <= 1
