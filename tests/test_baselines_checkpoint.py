"""Baseline routers, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.baselines import (
    KNNRouter, SVMRouter, llm_blender_choices, llm_blender_eval,
)
from repro.data import POOLS, PRICES, generate
from repro.data.lm_data import MarkovCorpus


class TestKNN:
    def test_neighbors_average(self):
        # Two well-separated clusters with distinct quality profiles.
        rng = np.random.default_rng(0)
        emb = np.concatenate([
            rng.standard_normal((30, 8)) * 0.05 + 3.0,
            rng.standard_normal((30, 8)) * 0.05 - 3.0,
        ]).astype(np.float32)
        quality = np.concatenate([
            np.tile([1.0, 0.0], (30, 1)), np.tile([0.0, 1.0], (30, 1))
        ]).astype(np.float32)
        cost = np.ones_like(quality)
        knn = KNNRouter(emb, quality, cost, k=5)
        s, c = knn.predict(np.array([[3.0] * 8, [-3.0] * 8], np.float32))
        assert s[0, 0] > 0.9 and s[0, 1] < 0.1
        assert s[1, 1] > 0.9 and s[1, 0] < 0.1


class TestSVM:
    def test_learns_linear_separation(self):
        rng = np.random.default_rng(1)
        emb = rng.standard_normal((200, 6)).astype(np.float32)
        w = rng.standard_normal(6)
        quality = np.stack([
            (emb @ w > 0).astype(np.float32),
            (emb @ w < 0).astype(np.float32),
        ], axis=1)
        cost = np.ones_like(quality)
        svm = SVMRouter.fit(emb, quality, cost)
        s, _ = svm.predict(emb)
        acc = ((s[:, 0] > 0.5) == (quality[:, 0] > 0.5)).mean()
        assert acc > 0.9


class TestBlender:
    def test_noiseless_judge_picks_best(self):
        quality = np.array([[0.1, 0.9, 0.5], [0.8, 0.2, 0.3]])
        ch = llm_blender_choices(quality, judge_noise=0.0)
        assert list(ch) == [1, 0]

    def test_cost_is_sum_of_all(self):
        quality = np.array([[0.1, 0.9]])
        cost = np.array([[1.0, 2.0]])
        perf, total = llm_blender_eval(quality, cost, judge_noise=0.0)
        assert np.isclose(total, 3.0)
        assert np.isclose(perf, 0.9)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "b": (jnp.ones((4,), jnp.bfloat16), jnp.int32(7)),
        }
        path = os.path.join(tmp_path, "ckpt.npz")
        save_checkpoint(path, tree, {"step": 42})
        restored, meta = load_checkpoint(path, tree)
        assert meta["step"] == 42
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert x.dtype == y.dtype
            np.testing.assert_allclose(
                np.asarray(x, np.float32), np.asarray(y, np.float32))

    def test_shape_mismatch_fails_loudly(self, tmp_path):
        path = os.path.join(tmp_path, "ckpt.npz")
        save_checkpoint(path, {"w": jnp.ones((2, 2))})
        with pytest.raises(ValueError, match="shape mismatch"):
            load_checkpoint(path, {"w": jnp.ones((3, 3))})

    def test_missing_key_fails_loudly(self, tmp_path):
        path = os.path.join(tmp_path, "ckpt.npz")
        save_checkpoint(path, {"w": jnp.ones((2,))})
        with pytest.raises(KeyError):
            load_checkpoint(path, {"w": jnp.ones((2,)), "extra": jnp.ones((1,))})


class TestRouterCheckpoint:
    """save_router / load_router: the launch/serve.py --save-router /
    --restore-router persistence path (params + version + scaler meta)."""

    def _router(self, quality_kind="attn-ens"):
        from repro.core.predictors import PREDICTORS
        from repro.core.router import PredictiveRouter

        rng = np.random.default_rng(0)
        dq, k, dm = 12, 3, 4
        qp = PREDICTORS[quality_kind].init(jax.random.key(0), dq, k, dm)
        cp = PREDICTORS["attn"].init(jax.random.key(1), dq, k, dm)
        # float64 scaler on purpose: restores must preserve dtype exactly
        # for denormalize_cost to reproduce the original arithmetic.
        scaler = {"mu": rng.random(k), "sd": rng.random(k) + 0.5}
        return PredictiveRouter(
            quality_kind, "attn", qp, cp,
            rng.random((k, dm)).astype(np.float32), reward="R2",
            cost_scaler=scaler, version=7,
            centroids=rng.random((dm, dq)).astype(np.float32))

    def test_roundtrip_scores_bitwise_equal(self, tmp_path):
        from repro.checkpoint import load_router, save_router

        router = self._router()
        path = os.path.join(tmp_path, "router.npz")
        save_router(path, router)
        restored = load_router(path)
        q = np.random.default_rng(1).normal(size=(9, 12)).astype(np.float32)
        s1, sd1, c1 = router.predict_with_uncertainty(q)
        s2, sd2, c2 = restored.predict_with_uncertainty(q)
        assert np.array_equal(s1, s2)
        assert np.array_equal(sd1, sd2)
        assert np.array_equal(c1, c2)
        assert restored.version == 7
        assert restored.quality_kind == "attn-ens"
        assert restored.cost_scaler["mu"].dtype == router.cost_scaler["mu"].dtype
        np.testing.assert_array_equal(restored.cost_scaler["mu"],
                                      router.cost_scaler["mu"])
        np.testing.assert_array_equal(restored.centroids, router.centroids)

    def test_non_router_checkpoint_rejected(self, tmp_path):
        from repro.checkpoint import load_router

        path = os.path.join(tmp_path, "other.npz")
        save_checkpoint(path, {"w": jnp.ones((2,))}, {"kind": "lm"})
        with pytest.raises(ValueError, match="router checkpoint"):
            load_router(path)

    def test_pool_identity_mismatch_rejected(self, tmp_path):
        """Member columns are positional: restoring against a different
        pool of the SAME size must fail loudly, not misroute silently."""
        from repro.checkpoint import load_router, save_router

        path = os.path.join(tmp_path, "router.npz")
        save_router(path, self._router(), pool_names=["a", "b", "c"])
        restored = load_router(path, expect_pool_names=["a", "b", "c"])
        assert restored.n_members == 3
        with pytest.raises(ValueError, match="pool"):
            load_router(path, expect_pool_names=["c", "d", "e"])
        # order matters too
        with pytest.raises(ValueError, match="pool"):
            load_router(path, expect_pool_names=["c", "b", "a"])


class TestRouterBenchData:
    def test_deterministic(self):
        d1 = generate(50, seed=3, embed=False)
        d2 = generate(50, seed=3, embed=False)
        np.testing.assert_allclose(d1.quality, d2.quality)
        np.testing.assert_allclose(d1.cost, d2.cost)
        assert d1.texts == d2.texts

    def test_eleven_models_eight_benchmarks(self, small_routerbench):
        d = small_routerbench
        assert d.quality.shape[1] == 11
        assert set(d.benchmark) <= {
            "mmlu", "gsm8k", "hellaswag", "arc-challenge", "winogrande",
            "mbpp", "mt-bench", "rag"}

    def test_binary_benchmarks_are_binary(self, small_routerbench):
        d = small_routerbench
        mask = np.isin(d.benchmark, ["mmlu", "gsm8k", "hellaswag",
                                     "arc-challenge", "winogrande"])
        vals = d.quality[mask]
        assert np.all((vals == 0.0) | (vals == 1.0))

    def test_gpt4_strongest_and_priciest(self, small_routerbench):
        d = small_routerbench
        gi = d.model_names.index("gpt-4")
        assert d.quality.mean(0).argmax() == gi
        assert d.cost.mean(0).argmax() == gi

    def test_pools_match_appendix_b(self):
        assert POOLS["pool4"] == ["llama-2-70b-chat", "claude-v1", "claude-v2",
                                  "gpt-4"]
        for pool in POOLS.values():
            for m in pool:
                assert m in PRICES

    def test_split_fractions(self, small_routerbench):
        tr, va, te = small_routerbench.split()
        n = len(small_routerbench.texts)
        assert len(tr) + len(va) + len(te) == n
        assert abs(len(tr) / n - 0.75) < 0.02
        # disjoint
        assert not (set(tr) & set(te)) and not (set(tr) & set(va))

    def test_paper_property_cheap_models_cover_most_of_gpt4(self, small_routerbench):
        """RouterBench's key observation: most GPT-4-answerable queries are
        answerable by at least one cheaper model."""
        d = small_routerbench
        gi = d.model_names.index("gpt-4")
        others = [i for i in range(11) if i != gi]
        gpt4_right = d.quality[:, gi] > 0.5
        any_cheap = (d.quality[:, others] > 0.5).any(axis=1)
        coverage = (gpt4_right & any_cheap).sum() / max(gpt4_right.sum(), 1)
        assert coverage > 0.8

    def test_csv_roundtrip(self, tmp_path, small_routerbench):
        import csv
        d = small_routerbench.select(np.arange(len(small_routerbench.texts)) < 20)
        path = os.path.join(tmp_path, "rb.csv")
        with open(path, "w", newline="") as f:
            wr = csv.writer(f)
            wr.writerow(["prompt", "benchmark", "domain", "model", "quality", "cost"])
            for i, text in enumerate(d.texts):
                for j, m in enumerate(d.model_names):
                    wr.writerow([text, d.benchmark[i], d.domain[i], m,
                                 d.quality[i, j], d.cost[i, j]])
        from repro.data import load_csv
        loaded = load_csv(path, model_names=d.model_names)
        assert len(loaded.texts) == 20
        np.testing.assert_allclose(
            np.sort(loaded.quality.sum(1)), np.sort(d.quality.sum(1)), rtol=1e-5)


class TestMarkovCorpus:
    def test_learnable_structure(self):
        c = MarkovCorpus(64, seed=0)
        toks, labels = next(c.batches(4, 128, seed=1))
        assert toks.shape == (4, 128) and labels.shape == (4, 128)
        assert toks.min() >= 0 and toks.max() < 64

    def test_deterministic(self):
        c1 = MarkovCorpus(64, seed=0)
        c2 = MarkovCorpus(64, seed=0)
        t1, _ = next(c1.batches(2, 32, seed=5))
        t2, _ = next(c2.batches(2, 32, seed=5))
        np.testing.assert_array_equal(t1, t2)
