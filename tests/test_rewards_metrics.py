"""Unit + property tests for rewards (paper Eq. 3) and metrics (Eqs. 1-2)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    DEFAULT_LAMBDA_GRID, aiq, lam_sensitivity, max_calls_fraction,
    pareto_frontier, reward_exponential, reward_linear, route, routed_points,
)


class TestRewards:
    def test_linear_matches_formula(self):
        s, c, lam = 0.8, 0.002, 0.1
        assert np.isclose(float(reward_linear(s, c, lam)), 0.8 - 0.02)

    def test_exponential_matches_formula(self):
        s, c, lam = 0.8, 0.002, 0.1
        assert np.isclose(float(reward_exponential(s, c, lam)), 0.8 * np.exp(-0.02))

    def test_route_prefers_quality_at_high_lambda(self):
        s = np.array([[0.5, 0.9]])
        c = np.array([[0.001, 1.0]])
        assert int(route("R2", s, c, 1e6)[0]) == 1
        assert int(route("R1", s, c, 1e6)[0]) == 1

    def test_route_prefers_cheap_at_low_lambda(self):
        s = np.array([[0.5, 0.9]])
        c = np.array([[0.001, 1.0]])
        assert int(route("R2", s, c, 1e-4)[0]) == 0
        assert int(route("R1", s, c, 1e-4)[0]) == 0

    @given(
        s=st.floats(0.0, 1.0),
        c=st.floats(0.0, 100.0),
        lam=st.floats(1e-4, 1e4),
    )
    def test_r2_bounded(self, s, c, lam):
        """The paper attributes R2's stability to boundedness: 0<=R2<=s."""
        r = float(reward_exponential(s, c, lam))
        assert 0.0 <= r <= s * (1 + 1e-6) + 1e-7   # fp32 slack

    @given(
        s=st.floats(0.01, 1.0),
        c=st.floats(0.001, 100.0),
        lam1=st.floats(1e-4, 1e3),
        factor=st.floats(1.01, 100.0),
    )
    def test_rewards_monotone_in_lambda(self, s, c, lam1, factor):
        """Higher willingness to pay never lowers either reward."""
        lam2 = lam1 * factor
        assert float(reward_linear(s, c, lam2)) >= float(reward_linear(s, c, lam1))
        assert float(reward_exponential(s, c, lam2)) >= float(
            reward_exponential(s, c, lam1)
        )


class TestPareto:
    def test_hull_of_two_points(self):
        costs = np.array([1.0, 2.0])
        perfs = np.array([0.5, 1.0])
        hx, hy = pareto_frontier(costs, perfs)
        assert np.allclose(hx, [1.0, 2.0]) and np.allclose(hy, [0.5, 1.0])

    def test_dominated_point_removed(self):
        costs = np.array([1.0, 1.5, 2.0])
        perfs = np.array([0.5, 0.4, 1.0])  # middle point dominated
        hx, hy = pareto_frontier(costs, perfs)
        assert 1.5 not in hx

    def test_aiq_constant_router(self):
        """All lambdas identical -> AIQ = the single perf value."""
        assert np.isclose(aiq(np.full(5, 2.0), np.full(5, 0.7)), 0.7)

    def test_aiq_analytic_triangle(self):
        # frontier: (0, 0) -> (1, 1): area 0.5 over range 1.
        costs = np.array([0.0, 1.0])
        perfs = np.array([0.0, 1.0])
        assert np.isclose(aiq(costs, perfs), 0.5)

    @given(
        st.lists(
            st.tuples(st.floats(0.0, 10.0), st.floats(0.0, 1.0)),
            min_size=2, max_size=30,
        )
    )
    @settings(max_examples=200)
    def test_aiq_permutation_invariant_and_bounded(self, pts):
        costs = np.array([p[0] for p in pts])
        perfs = np.array([p[1] for p in pts])
        a1 = aiq(costs, perfs)
        rng = np.random.default_rng(0)
        perm = rng.permutation(len(pts))
        a2 = aiq(costs[perm], perfs[perm])
        assert np.isclose(a1, a2)
        assert -1e-9 <= a1 <= 1.0 + 1e-9

    @given(
        st.lists(
            st.tuples(st.floats(0.0, 10.0), st.floats(0.0, 1.0)),
            min_size=2, max_size=30,
        )
    )
    @settings(max_examples=100)
    def test_hull_dominates_all_points(self, pts):
        costs = np.array([p[0] for p in pts])
        perfs = np.array([p[1] for p in pts])
        hx, hy = pareto_frontier(costs, perfs)
        # Hull is non-decreasing and >= every point at same-or-lower cost.
        assert np.all(np.diff(hy) >= -1e-9)
        for c, p in zip(costs, perfs):
            j = np.searchsorted(hx, c, side="right") - 1
            if j >= 0:
                interp = np.interp(c, hx, hy)
                assert interp >= p - 1e-6


class TestSensitivity:
    def test_constant_series_zero(self):
        lams = [0.01, 0.1, 1.0]
        assert lam_sensitivity(lams, [0.5, 0.5, 0.5]) == 0.0

    def test_paper_equation_two_points(self):
        # Eq 2 with 3 lambdas reduces to weighted average of deltas.
        lams = [0.1, 1.0, 10.0]
        vals = [0.2, 0.5, 0.6]
        expect = (np.log(10) * 0.3 + np.log(10) * 0.1) / np.log(100)
        assert np.isclose(lam_sensitivity(lams, vals), expect)

    def test_max_calls(self):
        choices = np.array([[0, 1, 1], [1, 1, 1]])
        assert max_calls_fraction(choices, 1) == 1.0
        assert max_calls_fraction(choices, 0) == pytest.approx(1 / 3)


class TestRoutedPoints:
    def test_averaging(self):
        quality = np.array([[0.0, 1.0], [1.0, 0.0]])
        cost = np.array([[1.0, 2.0], [3.0, 4.0]])
        choices = np.array([[0, 1]])     # one lambda: q0->m0, q1->m1
        costs, perfs = routed_points(choices, quality, cost)
        assert np.isclose(costs[0], (1.0 + 4.0) / 2)
        assert np.isclose(perfs[0], 0.0)
