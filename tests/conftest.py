"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override is exclusive to launch/dryrun.py)."""
import numpy as np
import pytest

# One smoke config per mixer family in the pool: dense attention, xLSTM
# (sLSTM + mLSTM), MoE (attention + capacity dispatch), and the jamba-style
# SSM hybrid (mamba + attention + MoE). The cross-mixer invariance harness
# (tests/test_masked_prefill.py) parametrizes over all of them; the
# non-attention members are marked ``slow`` (greedy generation on CPU) and
# run in the scheduled full-suite CI lane, while the attention member pins
# the property in the fast lane.
MIXER_SMOKE_CONFIGS = (
    "qwen3-0.6b",
    pytest.param("xlstm-1.3b", marks=pytest.mark.slow),
    pytest.param("granite-moe-1b-a400m", marks=pytest.mark.slow),
    pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: expensive cross-mixer invariance runs; deselect with "
        "-m 'not slow' (fast CI lane), full suite runs on a schedule")


@pytest.fixture(scope="session")
def small_routerbench():
    from repro.data import generate

    return generate(600, seed=7)


@pytest.fixture(scope="session")
def pool1(small_routerbench):
    return small_routerbench.pool("pool1")


@pytest.fixture(scope="session", params=MIXER_SMOKE_CONFIGS)
def mixer_member(request):
    """(name, smoke config, params) for one pool-member mixer family."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import lm as lm_mod

    cfg = get_smoke_config(request.param)
    params = lm_mod.init_lm(jax.random.key(0), cfg)
    return request.param, cfg, params
