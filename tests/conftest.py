"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override is exclusive to launch/dryrun.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_routerbench():
    from repro.data import generate

    return generate(600, seed=7)


@pytest.fixture(scope="session")
def pool1(small_routerbench):
    return small_routerbench.pool("pool1")
