"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

xlstm-1.3b [arXiv:2405.04517] interleaves sLSTM and mLSTM blocks 1:7. The
spec's d_ff=0 means blocks own their projections (mLSTM: pre-up-projection
x2; sLSTM: post gated FFN).

TPU adaptation (the reference implementation is a fused CUDA kernel):
  * mLSTM trains with the *chunkwise-stabilized* parallel form — a scan over
    time chunks carrying (C, n, m); within a chunk, a (Q x Q) decay-masked
    quadratic term (linear-attention style) plus an inter-chunk term against
    the carried state. Exactly equivalent to the recurrence (unit-tested
    against the step-by-step reference), O(T*Q) not O(T^2), and MXU-friendly.
  * sLSTM is strictly sequential (recurrent weights R * h_{t-1}); it runs as
    a ``lax.scan`` over time with all input projections hoisted out of the
    scan body.
  * Decode carries (C, n, m) / (c, n, m, h) states — O(1) per token, which is
    what makes xlstm-1.3b eligible for long_500k.

Forget gates use log-sigmoid (one of the two variants in the paper), input
gates are exponential with max-stabilizers, matching the official stabilized
formulation.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init
from repro.models.runtime_flags import inner_scan
from repro.models.sharding_ctx import gather_tree, get_rule, shard

MLSTM_CHUNK = 256
SLSTM_SEG = 64
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _maybe_gather(p: Dict) -> Dict:
    """ZeRO-3 gathered-weights mode (rule "xlstm_gather_params"): keep
    *storage* sharded but compute with replicated weights and fully local
    activations. Every consumer of the di-sharded stream otherwise pays an
    activation-sized all-reduce (~1 GB fp32 at train_4k) while the weights
    it would gather instead are ~10 MB — see EXPERIMENTS.md §Perf
    "xlstm-gathered-weights"."""
    if get_rule("xlstm_gather_params"):
        return gather_tree(p)
    return p

def init_mlstm(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, di = cfg.d_model, cfg.xlstm_d_inner
    h = cfg.xlstm_n_heads
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (4, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        # Block-diagonal per-head projections (official xLSTM structure;
        # dense (di, di) would double the model's parameter count).
        "wq": _blockdiag_init(ks[2], h, di // h, dtype),
        "wk": _blockdiag_init(ks[3], h, di // h, dtype),
        "wv": _blockdiag_init(ks[4], h, di // h, dtype),
        "w_igate": dense_init(ks[5], di, h, dtype),
        "b_igate": jnp.full((h,), -10.0, jnp.float32),  # official init
        "w_fgate": dense_init(ks[6], di, h, dtype),
        "b_fgate": jnp.full((h,), 3.0, jnp.float32),
        "skip": jnp.ones((di,), dtype),
        "down_proj": dense_init(ks[7], di, d, dtype),
    }


def _mlstm_gates(p: Dict, xc: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (log_i_pre, log_f) — (B,T,H) fp32; log_f = logsigmoid(f~)."""
    i_pre = (xc @ p["w_igate"]).astype(jnp.float32) + p["b_igate"]
    f_pre = (xc @ p["w_fgate"]).astype(jnp.float32) + p["b_fgate"]
    return i_pre, jax.nn.log_sigmoid(f_pre)


def _blockdiag_init(key, h, dh, dtype):
    ks = jax.random.split(key, h)
    return jnp.stack([dense_init(k_, dh, dh, dtype) for k_ in ks])


def _mlstm_qkv(cfg: ArchConfig, p: Dict, xc, xv):
    b, t, di = xc.shape
    h = cfg.xlstm_n_heads
    dh = di // h
    xh = xc.reshape(b, t, h, dh)
    q = jnp.einsum("bthd,hde->bthe", xh, p["wq"])
    k = jnp.einsum("bthd,hde->bthe", xh, p["wk"]) * (dh ** -0.5)
    v = jnp.einsum("bthd,hde->bthe", xv.reshape(b, t, h, dh), p["wv"])
    return q, k, v


def mlstm_chunkwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    log_i: jax.Array,
    log_f: jax.Array,
    state: Dict = None,
    chunk: int = MLSTM_CHUNK,
):
    """Chunkwise-stabilized mLSTM sequence evaluation.

    q,k,v (B,T,H,dh); log_i/log_f (B,T,H).
    Returns (h_out (B,T,H,dh), final_state {C (B,H,dh,dh), n (B,H,dh), m (B,H)}).
    """
    b, t, h, dh = q.shape
    qc = min(chunk, t)
    assert t % qc == 0, (t, qc)
    n_chunks = t // qc

    def to_chunks(x):
        return x.reshape(b, n_chunks, qc, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = map(to_chunks, (q.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32)))
    lis, lfs = map(to_chunks, (log_i, log_f))

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    else:
        c0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, inputs):
        c_in, n_in, m_in = carry
        qq, kk, vv, li, lf = inputs               # (B,qc,H,·)
        # Cumulative log decay within chunk: F[t] = sum_{s<=t} lf[s]
        fcum = jnp.cumsum(lf, axis=1)             # (B,qc,H)
        # log weight of in-chunk source s at target t (s<=t):
        #   li[s] + F[t] - F[s]
        log_w = (li - fcum)[:, None, :, :] + fcum[:, :, None, :]  # (B,t,s,H)
        tidx = jnp.arange(qc)
        causal = tidx[:, None] >= tidx[None, :]
        log_w = jnp.where(causal[None, :, :, None], log_w, NEG_INF)
        # log weight of the carried state at target t: m_in + F[t]
        log_carry = m_in[:, None, :] + fcum                        # (B,t,H)
        # Every target t has itself as an in-chunk source, so m_t is finite.
        m_t = jnp.maximum(log_w.max(axis=2), log_carry)            # (B,t,H)
        w = jnp.exp(log_w - m_t[:, :, None, :])                    # (B,t,s,H)
        carry_scale = jnp.exp(log_carry - m_t)                     # (B,t,H)

        scores = jnp.einsum("bthd,bshd->btsh", qq, kk)             # (B,t,s,H)
        num_intra = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, vv)
        den_intra = jnp.einsum("btsh,btsh->bth", scores, w)
        # C[d,e] = k[d] v[e]: contract q with the KEY index d.
        num_inter = jnp.einsum("bhde,bthd->bthe", c_in, qq) * carry_scale[..., None]
        den_inter = jnp.einsum("bhd,bthd->bth", n_in, qq) * carry_scale
        num = num_intra + num_inter
        den = den_intra + den_inter
        h_t = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # Carry update to the end of the chunk.
        f_total = fcum[:, -1]                                       # (B,H)
        log_src = li + (f_total[:, None, :] - fcum)                 # (B,s,H)
        m_out = jnp.maximum(m_in + f_total, log_src.max(axis=1))
        w_src = jnp.exp(log_src - m_out[:, None, :])                # (B,s,H)
        scale_old = jnp.exp(m_in + f_total - m_out)                 # (B,H)
        c_out = c_in * scale_old[..., None, None] + jnp.einsum(
            "bshd,bsh,bshe->bhde", kk, w_src, vv
        )
        n_out = n_in * scale_old[..., None] + jnp.einsum(
            "bshd,bsh->bhd", kk, w_src
        )
        return (c_out, n_out, m_out), h_t

    # Remat per chunk (bounds AD residuals to one chunk's quadratic term).
    (c_f, n_f, m_f), hs = inner_scan(jax.checkpoint(step), (c0, n0, m0),
                                     (qs, ks, vs, lis, lfs), n_chunks)
    h_out = hs.swapaxes(0, 1).reshape(b, t, h, dh)
    return h_out, {"C": c_f, "n": n_f, "m": m_f}


def mlstm_step(q, k, v, log_i, log_f, state):
    """Single-token recurrence (decode + reference oracle for the chunked form).

    q,k,v (B,H,dh); log_i/log_f (B,H).
    """
    c_in, n_in, m_in = state["C"], state["n"], state["m"]
    m_t = jnp.maximum(log_f + m_in, log_i)
    f_s = jnp.exp(log_f + m_in - m_t)
    i_s = jnp.exp(log_i - m_t)
    c_t = c_in * f_s[..., None, None] + i_s[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_t = n_in * f_s[..., None] + i_s[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", c_t, q)
    den = jnp.einsum("bhd,bhd->bh", n_t, q)
    h_t = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    return h_t, {"C": c_t, "n": n_t, "m": m_t}


def _mlstm_front(cfg, p, x, conv_state=None, mask=None):
    """Up-projection + causal conv; returns (xc, xv, z, new_conv_state).

    ``mask`` (B, S) bool zeroes pad inputs of a left-padded batch before
    the conv, so the window over leading pads matches the zero front
    padding an unpadded run sees (and the value stream ``xv`` is exactly
    zero at pads).
    """
    xz = x @ p["up_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    if mask is not None:
        xi = jnp.where(mask[..., None], xi, 0)
    if not get_rule("xlstm_gather_params"):
        xi = shard(xi, "batch", "seq", "ssm_inner")
    dc = p["conv_w"].shape[0]
    if conv_state is not None:
        window = jnp.concatenate([conv_state, xi], axis=1)
        out = jnp.einsum("bti,ti->bi", window.astype(jnp.float32),
                         p["conv_w"].astype(jnp.float32))[:, None]
        xc = jax.nn.silu(out + p["conv_b"].astype(jnp.float32)).astype(xi.dtype)
        return xc, xi, z, window[:, 1:]
    pad = jnp.zeros(xi.shape[:1] + (dc - 1,) + xi.shape[2:], xi.dtype)
    xp = jnp.concatenate([pad, xi], axis=1)
    xc = sum(xp[:, i : i + xi.shape[1]] * p["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_b"])
    return xc, xi, z, None


def apply_mlstm_train(
    cfg: ArchConfig, p: Dict, x: jax.Array, return_state: bool = False,
    mask=None,
):
    """``mask`` (B, S) bool marks real tokens of a left-padded batch.

    Pad steps contribute nothing to the chunkwise recurrence: the masked
    conv front makes the value stream exactly zero at pads, and the gates
    are overridden to ``log_i -> -inf`` (pad sources get weight
    exp(-inf) = 0) and ``log_f -> 0`` (identity decay — the carried state
    crosses pads unchanged). Real positions and the final (C, n, m) state
    then match the row's unpadded run.
    """
    p = _maybe_gather(p)
    b, t, _ = x.shape
    di = cfg.xlstm_d_inner
    xc, xv, z, _ = _mlstm_front(cfg, p, x, mask=mask)
    q, k, v = _mlstm_qkv(cfg, p, xc, xv)
    log_i, log_f = _mlstm_gates(p, xc)
    if mask is not None:
        log_i = jnp.where(mask[..., None], log_i, NEG_INF)
        log_f = jnp.where(mask[..., None], log_f, 0.0)
    h, state = mlstm_chunkwise(q, k, v, log_i, log_f)
    h = h.reshape(b, t, di).astype(x.dtype) + p["skip"] * xc
    out = (h * jax.nn.silu(z)) @ p["down_proj"]
    if return_state:
        conv_tail = xv[:, -3:, :] if t >= 3 else jnp.pad(xv, ((0, 0), (3 - t, 0), (0, 0)))
        return out, {**state, "conv": conv_tail}
    return out


def init_mlstm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    di = cfg.xlstm_d_inner
    h = cfg.xlstm_n_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), NEG_INF, jnp.float32),
        "conv": jnp.zeros((batch, 3, di), dtype),
    }


def apply_mlstm_decode(
    cfg: ArchConfig, p: Dict, x: jax.Array, cache: Dict
) -> Tuple[jax.Array, Dict]:
    p = _maybe_gather(p)
    b = x.shape[0]
    di = cfg.xlstm_d_inner
    xc, xv, z, conv_state = _mlstm_front(cfg, p, x, cache["conv"])
    q, k, v = _mlstm_qkv(cfg, p, xc, xv)
    log_i, log_f = _mlstm_gates(p, xc)
    h, new_state = mlstm_step(
        q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), log_i[:, 0], log_f[:, 0],
        cache,
    )
    h = h.reshape(b, 1, di).astype(x.dtype) + p["skip"] * xc
    out = (h * jax.nn.silu(z)) @ p["down_proj"]
    return out, {**cache, **new_state, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    h = cfg.xlstm_n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    def rinit(k_):
        return (jax.random.normal(k_, (h, dh, dh), jnp.float32) / dh**0.5).astype(dtype)
    # Round the FFN width up to 256 so it shards cleanly on the 16-way axis.
    f_ff = -(-int(cfg.xlstm_ff_factor * d) // 256) * 256
    return {
        "w": dense_init(ks[0], d, 4 * d, dtype),        # z,i,f,o input projections
        "r_z": rinit(ks[1]),
        "r_i": rinit(ks[2]),
        "r_f": rinit(ks[3]),
        "r_o": rinit(ks[4]),
        "b": jnp.concatenate([
            jnp.zeros((d,)), jnp.full((d,), -5.0), jnp.full((d,), 3.0),
            jnp.zeros((d,)),
        ]).astype(jnp.float32),
        "ff_up": dense_init(ks[5], d, 2 * f_ff, dtype),
        "ff_down": dense_init(ks[6], f_ff, d, dtype),
    }


def _slstm_cell(p: Dict, wx_t: jax.Array, state: Dict, nheads: int):
    """One sLSTM step. wx_t (B,4d) precomputed W@x_t + b; state holds
    c,n,m,h each (B,d) (h additionally feeds the recurrent matrices)."""
    b_, four_d = wx_t.shape
    d = four_d // 4
    dh = d // nheads
    h_prev = state["h"].reshape(b_, nheads, dh)

    def rec(r):
        return jnp.einsum("bhd,hde->bhe", h_prev.astype(jnp.float32),
                          r.astype(jnp.float32)).reshape(b_, d)

    z_pre, i_pre, f_pre, o_pre = jnp.split(wx_t.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z_pre + rec(p["r_z"]))
    i_log = i_pre + rec(p["r_i"])
    f_log = jax.nn.log_sigmoid(f_pre + rec(p["r_f"]))
    o = jax.nn.sigmoid(o_pre + rec(p["r_o"]))

    m_t = jnp.maximum(f_log + state["m"], i_log)
    i_s = jnp.exp(i_log - m_t)
    f_s = jnp.exp(f_log + state["m"] - m_t)
    c_t = f_s * state["c"] + i_s * z
    n_t = f_s * state["n"] + i_s
    h_t = o * c_t / jnp.maximum(n_t, 1e-6)
    return {"c": c_t, "n": n_t, "m": m_t, "h": h_t}


def init_slstm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    zeros = jnp.zeros((batch, d), jnp.float32)
    return {"c": zeros, "n": zeros, "m": jnp.full((batch, d), NEG_INF, jnp.float32),
            "h": zeros}


def _slstm_ffn(p: Dict, x: jax.Array) -> jax.Array:
    up = x @ p["ff_up"]
    a, g = jnp.split(up, 2, axis=-1)
    return (jax.nn.gelu(a) * g) @ p["ff_down"]


def apply_slstm_train(
    cfg: ArchConfig, p: Dict, x: jax.Array, return_state: bool = False,
    mask=None,
):
    """``mask`` (B, S) bool marks real tokens of a left-padded batch.

    The sLSTM scan is strictly sequential, so masking is exact state
    passthrough: at a pad step the cell state (c, n, m, h) is carried
    through unchanged, and the real-token trajectory is bitwise the same
    as the row's unpadded run.
    """
    p = _maybe_gather(p)
    b, t, d = x.shape
    nh = cfg.xlstm_n_heads
    wx = x @ p["w"] + p["b"].astype(x.dtype)          # hoisted out of the scan

    if mask is None:
        # Unmasked fast path: no per-step select over the cell state.
        def step(state, wx_t):
            new = _slstm_cell(p, wx_t, state, nh)
            return new, new["h"]

        xs = wx.swapaxes(0, 1)                        # (T,B,4d)

        def reshape_seg(seg):
            return xs.reshape(t // seg, seg, b, -1)
    else:
        def step(state, inputs):
            wx_t, m_t = inputs
            new = _slstm_cell(p, wx_t, state, nh)
            new = jax.tree.map(
                lambda a, prev: jnp.where(m_t[:, None], a, prev), new, state)
            return new, new["h"]

        xs = (wx.swapaxes(0, 1), mask.swapaxes(0, 1))  # (T,B,4d), (T,B)

        def reshape_seg(seg):
            return (xs[0].reshape(t // seg, seg, b, -1),
                    xs[1].reshape(t // seg, seg, b))

    state0 = init_slstm_cache(cfg, b)
    seg = SLSTM_SEG
    if t % seg == 0 and t > seg:
        # Two-level scan: AD saves carries only at segment boundaries and
        # recomputes within a segment (T x per-step states would otherwise
        # dominate training memory at 4k seq).
        @jax.checkpoint
        def seg_fn(state, seg_inputs):
            return jax.lax.scan(step, state, seg_inputs)

        final, hs = jax.lax.scan(seg_fn, state0, reshape_seg(seg))
        h = hs.reshape(t, b, -1).swapaxes(0, 1).astype(x.dtype)
    else:
        final, hs = jax.lax.scan(step, state0, xs)
        h = hs.swapaxes(0, 1).astype(x.dtype)         # (B,T,d)
    out = _slstm_ffn(p, h)
    if return_state:
        return out, final
    return out


def apply_slstm_decode(
    cfg: ArchConfig, p: Dict, x: jax.Array, cache: Dict
) -> Tuple[jax.Array, Dict]:
    p = _maybe_gather(p)
    b = x.shape[0]
    nh = cfg.xlstm_n_heads
    wx = (x @ p["w"] + p["b"].astype(x.dtype))[:, 0]
    new = _slstm_cell(p, wx, cache, nh)
    out = _slstm_ffn(p, new["h"][:, None].astype(x.dtype))
    return out, {**cache, **new}
