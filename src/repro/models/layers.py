"""Core layers: initializers, RMSNorm, RoPE, SwiGLU MLP, embeddings.

Plain functional style: ``init_*`` returns a nested-dict param tree,
``apply_*`` consumes it. No module framework (flax is not available offline).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.sharding_ctx import shard


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (what most LLM codebases use)."""
    std = scale / math.sqrt(d_in)
    return (
        jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out), jnp.float32)
        * std
    ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def apply_rmsnorm(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(orig_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` (..., seq, heads, head_dim) by ``positions`` (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                      # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(params: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard(h, "batch", "seq", "ff")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Token embedding + LM head (vocab padded for clean 16-way TP sharding)
# ---------------------------------------------------------------------------

def init_embedding(key, padded_vocab: int, d_model: int, dtype=jnp.float32) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "table": embed_init(k1, padded_vocab, d_model, dtype),
        "head": dense_init(k2, d_model, padded_vocab, dtype),
    }


def embed_tokens(params: Dict, token_ids: jax.Array) -> jax.Array:
    return jnp.take(params["table"], token_ids, axis=0)


def lm_logits(params: Dict, x: jax.Array) -> jax.Array:
    return x @ params["head"]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(
    logits: jax.Array,
    labels: jax.Array,
    vocab_size: int,
    mask: Optional[jax.Array] = None,
    seq_chunk: int = 0,
) -> jax.Array:
    """Mean next-token cross entropy, ignoring padded vocab entries.

    ``seq_chunk`` > 0 computes the loss in sequence chunks under ``lax.map``
    so the (batch, seq, padded_vocab) fp32 logsumexp intermediate never
    materializes at once — this matters for gemma3's 262k vocab.
    """

    def _ce(lg, lb):
        lg = lg.astype(jnp.float32)
        pad = lg.shape[-1] - vocab_size
        if pad > 0:
            neg = jnp.full((pad,), -1e30, dtype=jnp.float32)
            lg = lg + jnp.concatenate([jnp.zeros((vocab_size,)), neg])
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
        return lse - gold

    if seq_chunk and logits.shape[1] > seq_chunk:
        b, s = labels.shape
        n = s // seq_chunk
        lg = logits[:, : n * seq_chunk].reshape(b, n, seq_chunk, -1)
        lb = labels[:, : n * seq_chunk].reshape(b, n, seq_chunk)
        losses = jax.lax.map(lambda args: _ce(*args), (lg.swapaxes(0, 1), lb.swapaxes(0, 1)))
        losses = losses.swapaxes(0, 1).reshape(b, n * seq_chunk)
        if n * seq_chunk < s:
            tail = _ce(logits[:, n * seq_chunk :], labels[:, n * seq_chunk :])
            losses = jnp.concatenate([losses, tail], axis=1)
    else:
        losses = _ce(logits, labels)

    if mask is not None:
        return jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(losses)
