"""Composable decoder LM over periodic layer plans.

The stack executes an :class:`ArchConfig`'s layer plan:

    [pattern block_0 ... block_{P-1}] x n_repeats  +  remainder blocks

The repeated pattern runs under ``jax.lax.scan`` with parameters stacked on a
leading (n_repeats) axis — one HLO body per *pattern*, not per layer, which
keeps compile time bounded for the 100-layer pool members. Heterogeneous
blocks inside a pattern (jamba's mamba/attn/moe interleave, gemma3's
local:global, llama-vision's self:cross) are unrolled *within* the scan body.

Three entry points:
  * train:   full causal sequence -> token loss (+ MoE aux)
  * prefill: full sequence -> last-token logits + decode caches
  * decode:  one token + caches -> logits + updated caches
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN, MAMBA, MLP, MLSTM, MOE, NONE, SLSTM, XATTN, ArchConfig, LayerSpec,
)
from repro.models import attention as attn_mod
from repro.models import runtime_flags
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    apply_mlp, apply_rmsnorm, embed_tokens, init_embedding, init_mlp,
    init_rmsnorm, lm_logits,
)
from repro.models.sharding_ctx import shard

LOSS_SEQ_CHUNK = 512


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, spec: LayerSpec, dtype=jnp.float32) -> Dict:
    k_mix, k_ffn = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if spec.mixer in (ATTN, XATTN):
        p["mixer"] = attn_mod.init_attention(k_mix, cfg, spec, dtype)
    elif spec.mixer == MAMBA:
        p["mixer"] = ssm_mod.init_mamba(k_mix, cfg, dtype)
    elif spec.mixer == MLSTM:
        p["mixer"] = xlstm_mod.init_mlstm(k_mix, cfg, dtype)
    elif spec.mixer == SLSTM:
        p["mixer"] = xlstm_mod.init_slstm(k_mix, cfg, dtype)
    if spec.ffn != NONE:
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if spec.ffn == MLP:
            p["ffn"] = init_mlp(k_ffn, cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = moe_mod.init_moe(k_ffn, cfg, dtype)
    return p


def _apply_ffn_train(cfg, spec, p, x, mask=None):
    """``mask`` (B, S) bool marks real tokens of a left-padded batch; MoE
    excludes pads from capacity accounting and the aux loss."""
    if spec.ffn == NONE:
        return x, jnp.float32(0.0)
    h = apply_rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.ffn == MLP:
        return x + apply_mlp(p["ffn"], h), jnp.float32(0.0)
    y, aux = moe_mod.apply_moe_train(cfg, p["ffn"], h, mask=mask)
    return x + y, aux


def _apply_ffn_decode(cfg, spec, p, x):
    if spec.ffn == NONE:
        return x
    h = apply_rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.ffn == MLP:
        return x + apply_mlp(p["ffn"], h)
    return x + moe_mod.apply_moe_decode(cfg, p["ffn"], h)


def apply_block_train(cfg, spec, p, x, positions, media, mask=None):
    """``mask`` (B, S) bool marks real tokens of a left-padded batch; every
    mixer family applies its masked-compute variant (pad keys masked /
    identity recurrence updates / pad-excluded MoE capacity)."""
    h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.mixer == ATTN:
        y = attn_mod.self_attention_full_seq(cfg, spec, p["mixer"], h, positions,
                                             kv_valid=mask)
    elif spec.mixer == XATTN:
        y = attn_mod.cross_attention_full_seq(cfg, p["mixer"], h, media)
    elif spec.mixer == MAMBA:
        y = ssm_mod.apply_mamba_train(cfg, p["mixer"], h, mask=mask)
    elif spec.mixer == MLSTM:
        y = xlstm_mod.apply_mlstm_train(cfg, p["mixer"], h, mask=mask)
    elif spec.mixer == SLSTM:
        y = xlstm_mod.apply_slstm_train(cfg, p["mixer"], h, mask=mask)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    x = x + y
    return _apply_ffn_train(cfg, spec, p, x, mask=mask)


def init_block_cache(cfg, spec, batch: int, max_len: int, dtype=jnp.float32):
    if spec.mixer in (ATTN, XATTN):
        return attn_mod.init_kv_cache(cfg, spec, batch, max_len, dtype)
    if spec.mixer == MAMBA:
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if spec.mixer == MLSTM:
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if spec.mixer == SLSTM:
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(spec.mixer)  # pragma: no cover


def apply_block_prefill(cfg, spec, p, x, positions, media, cache,
                        attn_mask=None):
    """Full-sequence pass that also fills this block's decode cache.

    ``attn_mask`` (B, S) bool marks real tokens of a left-padded batch.
    Every mixer family is batch-composition invariant under it: attention
    masks pad keys (and records per-row validity in the decode cache);
    SSM/xLSTM recurrences treat pad steps as identity updates so the
    carried state — which *is* the decode cache — crosses pads unchanged;
    MoE excludes pads from capacity accounting. Pinned by the cross-mixer
    harness in tests/test_masked_prefill.py.
    """
    h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.mixer == ATTN:
        y = attn_mod.self_attention_full_seq(cfg, spec, p["mixer"], h, positions,
                                             kv_valid=attn_mask)
        cache = attn_mod.prefill_self_cache(cfg, spec, p["mixer"], h, positions,
                                            cache, kv_valid=attn_mask)
    elif spec.mixer == XATTN:
        y = attn_mod.cross_attention_full_seq(cfg, p["mixer"], h, media)
        cache = attn_mod.prefill_cross_cache(cfg, p["mixer"], media, cache)
    elif spec.mixer == MAMBA:
        y, state = ssm_mod.apply_mamba_train(cfg, p["mixer"], h,
                                             return_state=True, mask=attn_mask)
        cache = {**cache, "h": state["h"],
                 "conv": state["conv"].astype(cache["conv"].dtype)}
    elif spec.mixer == MLSTM:
        y, state = xlstm_mod.apply_mlstm_train(cfg, p["mixer"], h,
                                               return_state=True, mask=attn_mask)
        cache = {**cache, "C": state["C"], "n": state["n"], "m": state["m"],
                 "conv": state["conv"].astype(cache["conv"].dtype)}
    elif spec.mixer == SLSTM:
        y, state = xlstm_mod.apply_slstm_train(cfg, p["mixer"], h,
                                               return_state=True, mask=attn_mask)
        cache = {**cache, **state}
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    x = x + y
    # Prefill uses the train-path FFN: chunked capacity dispatch for MoE
    # (decode-path dispatch over B*S tokens at once would blow up memory).
    x, _ = _apply_ffn_train(cfg, spec, p, x, mask=attn_mask)
    return x, cache


def apply_block_decode(cfg, spec, p, x, pos, cache):
    h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.mixer == ATTN:
        y, cache = attn_mod.self_attention_decode(cfg, spec, p["mixer"], h, cache, pos)
    elif spec.mixer == XATTN:
        y, cache = attn_mod.cross_attention_decode(cfg, p["mixer"], h, cache)
    elif spec.mixer == MAMBA:
        y, cache = ssm_mod.apply_mamba_decode(cfg, p["mixer"], h, cache)
    elif spec.mixer == MLSTM:
        y, cache = xlstm_mod.apply_mlstm_decode(cfg, p["mixer"], h, cache)
    elif spec.mixer == SLSTM:
        y, cache = xlstm_mod.apply_slstm_decode(cfg, p["mixer"], h, cache)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    x = x + y
    x = _apply_ffn_decode(cfg, spec, p, x)
    return x, cache


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    k_emb, k_pat, k_rem = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embedding": init_embedding(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    pat = tuple(cfg.pattern)

    def init_repeat(k):
        ks = jax.random.split(k, len(pat))
        return tuple(init_block(ks[i], cfg, pat[i], dtype) for i in range(len(pat)))

    if cfg.n_repeats > 0:
        params["pattern"] = jax.vmap(init_repeat)(
            jax.random.split(k_pat, cfg.n_repeats)
        )
    if cfg.remainder:
        ks = jax.random.split(k_rem, len(cfg.remainder))
        params["remainder"] = tuple(
            init_block(ks[i], cfg, spec, dtype)
            for i, spec in enumerate(cfg.remainder)
        )
    return params


def abstract_params(cfg: ArchConfig, dtype=jnp.float32):
    """ShapeDtypeStruct tree of the full-size parameters (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_lm, cfg=cfg, dtype=dtype), jax.random.key(0)
    )


def _positions(tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))



def _outer_scan(body, x, xs, n: int):
    """lax.scan over stacked layer-pattern params/caches, or a Python loop
    under the roofline probe flag (see runtime_flags)."""
    if not runtime_flags.UNROLL_INNER:
        return jax.lax.scan(body, x, xs)
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda a, i=i: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs, 0), *ys)
    else:
        ys = None
    return x, ys


def _backbone_train(cfg, params, x, positions, media, remat: bool = True,
                    mask=None):
    """Run the layer plan over (B,S,D) activations. Returns (x, moe aux)."""
    aux_total = jnp.float32(0.0)
    pat = tuple(cfg.pattern)
    if cfg.n_repeats > 0:
        def body(x, pslice):
            aux = jnp.float32(0.0)
            for i, spec in enumerate(pat):
                x, a = apply_block_train(cfg, spec, pslice[i], x, positions,
                                         media, mask=mask)
                aux = aux + a
            x = shard(x, "batch", "seq", "embed")
            return x, aux

        if remat:
            body = jax.checkpoint(body)
        x, auxes = _outer_scan(body, x, params["pattern"], cfg.n_repeats)
        aux_total = aux_total + auxes.sum()
    for i, spec in enumerate(cfg.remainder):
        x, a = apply_block_train(cfg, spec, params["remainder"][i], x, positions,
                                 media, mask=mask)
        aux_total = aux_total + a
    return apply_rmsnorm(params["final_norm"], x, cfg.norm_eps), aux_total


def apply_lm_train(cfg, params, tokens, media=None, remat=True, attn_mask=None):
    """Full logits (small-vocab / test path). Returns (logits, aux).

    ``attn_mask`` (B, S) bool marks real tokens of a left-padded batch
    (None = all real); masked compute applies in every mixer family.
    """
    x = embed_tokens(params["embedding"], tokens)
    x = shard(x, "batch", "seq", "embed")
    x, aux = _backbone_train(cfg, params, x, _positions(tokens), media, remat,
                             mask=attn_mask)
    return lm_logits(params["embedding"], x), aux


def lm_loss(cfg, params, tokens, labels, media=None, remat=True,
            attn_mask=None):
    """Next-token CE + MoE aux, computed in sequence chunks so the
    (B, S, padded_vocab) logits tensor never fully materializes.

    ``attn_mask`` (B, S) bool marks real tokens of a left-padded batch:
    pad positions are excluded from the CE (numerator *and* denominator)
    and, through the backbone, from MoE capacity/aux accounting.
    """
    x = embed_tokens(params["embedding"], tokens)
    x = shard(x, "batch", "seq", "embed")
    x, aux = _backbone_train(cfg, params, x, _positions(tokens), media, remat,
                             mask=attn_mask)

    b, s, d = x.shape
    head = params["embedding"]["head"]

    @jax.checkpoint
    def chunk_loss(xc, lc, mc=None):
        logits = (xc @ head).astype(jnp.float32)
        pad = logits.shape[-1] - cfg.vocab_size
        if pad > 0:
            logits = logits - jnp.concatenate(
                [jnp.zeros((cfg.vocab_size,)), jnp.full((pad,), 1e30)]
            )
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tok_loss = lse - gold
        if mc is not None:
            tok_loss = tok_loss * mc
        return jnp.sum(tok_loss)

    chunk = min(LOSS_SEQ_CHUNK, s)
    if s % chunk == 0 and s > chunk:
        n = s // chunk
        args = (x.reshape(b, n, chunk, d).swapaxes(0, 1),
                labels.reshape(b, n, chunk).swapaxes(0, 1))
        if attn_mask is not None:
            args += (attn_mask.reshape(b, n, chunk).swapaxes(0, 1),)
        if runtime_flags.UNROLL_INNER:
            total = sum(chunk_loss(*(a[i] for a in args)) for i in range(n))
        else:
            totals = jax.lax.map(lambda aa: chunk_loss(*aa), args)
            total = totals.sum()
    else:
        total = chunk_loss(x, labels, attn_mask)
    denom = (b * s) if attn_mask is None else jnp.maximum(attn_mask.sum(), 1)
    loss = total / denom
    return loss + cfg.router_aux_coef * aux


def init_caches(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32):
    """Decode caches matching the params tree layout (pattern stacked)."""
    pat = tuple(cfg.pattern)
    caches: Dict[str, Any] = {}

    def one_repeat(_):
        return tuple(
            init_block_cache(cfg, spec, batch, max_len, dtype) for spec in pat
        )

    if cfg.n_repeats > 0:
        caches["pattern"] = jax.vmap(one_repeat)(jnp.arange(cfg.n_repeats))
    if cfg.remainder:
        caches["remainder"] = tuple(
            init_block_cache(cfg, spec, batch, max_len, dtype)
            for spec in cfg.remainder
        )
    return caches


def abstract_caches(cfg, batch, max_len, dtype=jnp.float32):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, max_len, dtype)
    )


def apply_lm_prefill(cfg, params, tokens, caches, media=None, attn_mask=None):
    """Prefill: full forward + cache build. Returns (last_logits, caches).

    ``attn_mask`` (B, S) bool marks real tokens of a left-padded batch
    (None = all real); see :func:`apply_block_prefill`.
    """
    x = embed_tokens(params["embedding"], tokens)
    x = shard(x, "batch", "seq", "embed")
    positions = _positions(tokens)
    pat = tuple(cfg.pattern)
    new_caches: Dict[str, Any] = {}
    if cfg.n_repeats > 0:
        def apply_repeat(x, pslice, cslice):
            new = []
            for j, spec in enumerate(pat):
                x, c = apply_block_prefill(
                    cfg, spec, pslice[j], x, positions, media, cslice[j],
                    attn_mask=attn_mask,
                )
                new.append(c)
            x = shard(x, "batch", "seq", "embed")
            return x, tuple(new)

        if runtime_flags.UNROLL_INNER:
            def body(x, inputs):
                pslice, cslice = inputs
                return apply_repeat(x, pslice, cslice)

            x, new_caches["pattern"] = _outer_scan(
                body, x, (params["pattern"], caches["pattern"]), cfg.n_repeats
            )
        else:
            # Carry-threaded caches: in-place update, no xs/ys double buffer
            # (same rationale as apply_lm_decode).
            def body_carry(carry, inputs):
                x, cache_stack = carry
                i, pslice = inputs
                cslice = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                           keepdims=False),
                    cache_stack,
                )
                x, new = apply_repeat(x, pslice, cslice)
                cache_stack = jax.tree.map(
                    lambda st, nc: jax.lax.dynamic_update_index_in_dim(
                        st, nc.astype(st.dtype), i, 0),
                    cache_stack, new,
                )
                return (x, cache_stack), None

            (x, new_caches["pattern"]), _ = jax.lax.scan(
                body_carry, (x, caches["pattern"]),
                (jnp.arange(cfg.n_repeats), params["pattern"]),
            )
    if cfg.remainder:
        new_rem = []
        for i, spec in enumerate(cfg.remainder):
            x, c = apply_block_prefill(
                cfg, spec, params["remainder"][i], x, positions, media,
                caches["remainder"][i], attn_mask=attn_mask,
            )
            new_rem.append(c)
        new_caches["remainder"] = tuple(new_rem)
    x_last = apply_rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return lm_logits(params["embedding"], x_last), new_caches


def apply_lm_decode(cfg, params, token, caches, pos):
    """One decode step. token (B,1) int32; pos scalar int32 (next position).

    The stacked caches thread through the scan CARRY and are updated in
    place with ``dynamic_update_index_in_dim``. The earlier xs/ys form kept
    TWO copies of the full KV cache live (scan xs and ys cannot alias):
    decode temps were ~2.6x the cache size (EXPERIMENTS.md §Perf iteration
    "decode-carry-cache").
    """
    x = embed_tokens(params["embedding"], token)
    pat = tuple(cfg.pattern)
    new_caches: Dict[str, Any] = {}
    if cfg.n_repeats > 0:
        def apply_repeat(x, pslice, cslice):
            new = []
            for j, spec in enumerate(pat):
                x, c = apply_block_decode(cfg, spec, pslice[j], x, pos, cslice[j])
                new.append(c)
            return x, tuple(new)

        if runtime_flags.UNROLL_INNER:
            def body(x, inputs):
                pslice, cslice = inputs
                return apply_repeat(x, pslice, cslice)

            x, new_caches["pattern"] = _outer_scan(
                body, x, (params["pattern"], caches["pattern"]), cfg.n_repeats
            )
        else:
            def body_carry(carry, inputs):
                x, cache_stack = carry
                i, pslice = inputs
                cslice = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                           keepdims=False),
                    cache_stack,
                )
                x, new = apply_repeat(x, pslice, cslice)
                cache_stack = jax.tree.map(
                    lambda st, nc: jax.lax.dynamic_update_index_in_dim(
                        st, nc.astype(st.dtype), i, 0),
                    cache_stack, new,
                )
                return (x, cache_stack), None

            (x, new_caches["pattern"]), _ = jax.lax.scan(
                body_carry, (x, caches["pattern"]),
                (jnp.arange(cfg.n_repeats), params["pattern"]),
            )
    if cfg.remainder:
        new_rem = []
        for i, spec in enumerate(cfg.remainder):
            x, c = apply_block_decode(
                cfg, spec, params["remainder"][i], x, pos, caches["remainder"][i]
            )
            new_rem.append(c)
        new_caches["remainder"] = tuple(new_rem)
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params["embedding"], x), new_caches


def greedy_generate(cfg, params, prompt, max_new: int, media=None,
                    dtype=jnp.float32, attn_mask=None):
    """Simple greedy decoding loop for the examples (not perf-critical).

    ``attn_mask`` (B, S) bool marks real prompt tokens of a left-padded
    batch so every pool member's output — attention, SSM, xLSTM, and MoE
    alike — is invariant to micro-batch composition (see serving engine
    ``pad_prompts`` and tests/test_masked_prefill.py).
    """
    b, s = prompt.shape
    caches = init_caches(cfg, b, s + max_new, dtype)
    logits, caches = apply_lm_prefill(cfg, params, prompt, caches, media,
                                      attn_mask=attn_mask)
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
    out = [tok]
    for i in range(max_new - 1):
        logits, caches = apply_lm_decode(cfg, params, tok, caches, jnp.int32(s + i))
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
