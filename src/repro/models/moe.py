"""Mixture-of-Experts FFN (GShard/MaxText-style capacity dispatch).

Covers granite-moe (32e top-8), jamba (16e top-2), llama4-maverick
(128e top-1 + shared expert).

TPU adaptation: instead of CUDA grouped-GEMM / Megablocks sorting, tokens are
dispatched with one-hot capacity einsums — the canonical XLA/TPU formulation,
which shards cleanly with experts on the "model"/"expert" mesh axis and turns
into an all-to-all under expert parallelism. Compiled FLOPs scale with
top-k · capacity_factor (active experts), not with E, so the roofline stays
honest for the 128-expert pool member.

Dispatch tensors are (tokens, E, C); the sequence is processed in chunks
under ``lax.map`` to bound the live footprint (granite-moe's top-8 would
otherwise materialize multi-GB one-hots at 4k seq).

Decode uses the same dispatch einsums with worst-case (no-drop) capacity in
bounded chunks — see :func:`apply_moe_decode`.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import runtime_flags
from repro.models.layers import dense_init


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    fe = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    init_e = jax.vmap(lambda k_, din, dout: dense_init(k_, din, dout, dtype),
                      in_axes=(0, None, None))
    p = {
        "router": dense_init(ks[0], d, e, dtype),
        "w_gate": init_e(jax.random.split(ks[1], e), d, fe),
        "w_up": init_e(jax.random.split(ks[2], e), d, fe),
        "w_down": init_e(jax.random.split(ks[3], e), fe, d),
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], d, fs, dtype),
            "w_up": dense_init(kk[1], d, fs, dtype),
            "w_down": dense_init(kk[2], fs, d, dtype),
        }
    return p


def _router_probs(p: Dict, x: jax.Array) -> jax.Array:
    """(..., D) -> (..., E) softmax router probabilities in fp32."""
    logits = (x @ p["router"]).astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def aux_load_balance_loss(probs: jax.Array, expert_mask: jax.Array,
                          valid: jax.Array = None) -> jax.Array:
    """Switch-style load-balance loss: E * sum_e f_e * p_e.

    ``valid`` (T,) bool excludes pad tokens of a left-padded batch from
    both the routed-fraction and mean-probability statistics, so pads
    don't bias the expert-balance gradient.
    """
    e = probs.shape[-1]
    mask2 = expert_mask.reshape(-1, e)
    probs2 = probs.reshape(-1, e)
    if valid is None:
        f = mask2.mean(axis=0)                           # fraction routed
        pbar = probs2.mean(axis=0)                       # mean router prob
    else:
        v = valid.reshape(-1, 1).astype(jnp.float32)
        n = jnp.maximum(v.sum(), 1.0)
        f = (mask2 * v).sum(axis=0) / n
        pbar = (probs2 * v).sum(axis=0) / n
    return e * jnp.sum(f * pbar)


def _capacity(n_tokens: int, cfg: ArchConfig, factor: float = 0.0) -> int:
    f = factor or cfg.capacity_factor
    c = int(math.ceil(f * n_tokens * cfg.top_k / cfg.n_experts))
    return max(cfg.top_k, min(c, n_tokens))


def _dispatch_combine(
    cfg: ArchConfig, p: Dict, x2d: jax.Array, capacity_factor: float = 0.0,
    valid: jax.Array = None, return_drops: bool = False,
) -> Tuple[jax.Array, ...]:
    """Capacity-based MoE over (T, D) tokens. Returns (out (T,D), aux loss).

    ``valid`` (T,) bool marks real tokens of a left-padded batch. Pads are
    excluded from *everything* that could perturb a real token: their
    expert assignments are struck from the capacity position count (a real
    token's buffer slot depends only on the real tokens before it), the
    effective capacity shrinks to what the valid-token count alone would
    earn (so a padded row can't keep tokens its unpadded self would drop),
    their combine weights are zeroed, and they're excluded from the aux
    loss statistics. The capacity *buffer* stays statically sized from T;
    only the keep threshold is dynamic.

    ``return_drops`` appends the number of *real-token* (token, slot)
    assignments struck by the capacity threshold — the decode path logs it
    to prove its no-drop guarantee at runtime.
    """
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg, capacity_factor)

    probs = _router_probs(p, x2d)                         # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert's capacity buffer.
    slot_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # (T,k,E)
    eff_cap = cap
    if valid is not None:
        slot_onehot = slot_onehot * valid.astype(jnp.float32)[:, None, None]
        gate_vals = gate_vals * valid[:, None]
        # Same formula as _capacity, evaluated at the dynamic valid count:
        # max(top_k, min(ceil(f * n_valid * k / E), n_valid)), <= cap.
        f = capacity_factor or cfg.capacity_factor
        n_valid = valid.sum().astype(jnp.float32)
        eff_cap = jnp.clip(
            jnp.minimum(jnp.ceil(f * n_valid * k / e), n_valid), k, cap
        ).astype(jnp.int32)
    flat = slot_onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = jnp.einsum("tke,tke->tk", pos_in_expert, slot_onehot)    # (T,k)
    keep = pos < eff_cap
    gate_vals = gate_vals * keep

    # combine[t, e, c]: weight with which token t writes expert e's slot c.
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)           # (T,k,C)
    combine = jnp.einsum("tk,tke,tkc->tec", gate_vals, slot_onehot, pos_oh)
    dispatch = (combine > 0).astype(x2d.dtype)                     # (T,E,C)

    xe = jnp.einsum("tec,td->ecd", dispatch, x2d)                  # (E,C,D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])                # (E,C,D)
    out = jnp.einsum("tec,ecd->td", combine.astype(ye.dtype), ye)  # (T,D)

    aux = aux_load_balance_loss(probs, slot_onehot.sum(axis=1), valid)
    if return_drops:
        slot_real = slot_onehot.sum(axis=-1) > 0          # (T,k); pads struck
        dropped = jnp.sum(jnp.logical_and(~keep, slot_real))
        return out, aux, dropped
    return out, aux


def apply_moe_train(
    cfg: ArchConfig, p: Dict, x: jax.Array, seq_chunk: int = 512,
    mask: jax.Array = None,
) -> Tuple[jax.Array, jax.Array]:
    """MoE over (B, S, D), capacity-grouped per (batch row x seq chunk).

    Grouping matters: dense dispatch costs 2*T*(E*C)*D FLOPs with
    C ~ cf*T*k/E, i.e. *quadratic* in group size T. At T=512 the dispatch
    einsums stay below the expert GEMMs for every assigned MoE config
    (granite-moe worst case: ratio ~0.4). Chunks run under ``lax.map`` to
    bound live memory; batch rows are vmapped inside each chunk.

    ``mask`` (B, S) bool marks real tokens of a left-padded batch: pads
    are excluded from capacity accounting, dispatch, and the aux loss (see
    :func:`_dispatch_combine`), and capacity groups are chunks of
    *valid-token rank* rather than absolute position (see
    :func:`_moe_train_masked`), so a row's group boundaries do not shift
    with its pad count — batch-composition invariance holds at any prompt
    length, not just up to ``seq_chunk``.
    """
    b, s, d = x.shape
    if mask is not None:
        out, aux = _moe_train_masked(cfg, p, x, seq_chunk, mask)
    else:
        # Remat per chunk: dispatch/combine one-hots are cheap to recompute
        # and expensive to keep (E*C per token).
        per_row = jax.checkpoint(
            jax.vmap(lambda row: _dispatch_combine(cfg, p, row)))
        if s > seq_chunk and s % seq_chunk == 0:
            n = s // seq_chunk
            chunked = x.reshape(b, n, seq_chunk, d).swapaxes(0, 1)
            if runtime_flags.UNROLL_INNER:
                res = [per_row(chunked[i]) for i in range(n)]
                outs = jnp.stack([r[0] for r in res], 0)
                auxes = jnp.stack([r[1] for r in res], 0)
            else:
                outs, auxes = jax.lax.map(per_row, chunked)
            out = outs.swapaxes(0, 1).reshape(b, s, d)
            aux = _aux_mean(auxes)
        else:
            out, aux = per_row(x)
            aux = _aux_mean(aux)
    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out, aux


def _moe_train_masked(
    cfg: ArchConfig, p: Dict, x: jax.Array, seq_chunk: int, mask: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Masked MoE with *pad-aware* capacity grouping.

    Position chunks break batch invariance past ``seq_chunk``: left padding
    shifts where a real token's chunk boundary falls, so a padded row's
    capacity groups differ from its unpadded self's. Instead each row's
    tokens are regrouped by **valid-token rank** — a stable compaction
    moves real tokens to the front in order (pads to the back), the
    compacted sequence is padded up to a ``seq_chunk`` multiple and chunked
    there, and outputs are scattered back afterwards. Chunk membership then
    depends only on how many real tokens precede a token, which is exactly
    the quantity padding preserves. Pads carry zero dispatch/combine weight
    throughout, so the compaction changes no sums (adding zeros is exact in
    fp) — only the grouping.
    """
    b, s, d = x.shape
    per_row = jax.checkpoint(jax.vmap(
        lambda row, vrow: _dispatch_combine(cfg, p, row, valid=vrow)))
    if s <= seq_chunk:
        # One capacity group: dispatch is permutation-invariant (pads carry
        # zero slot/combine weight), so the compaction would change nothing
        # — skip it on the serving hot path (micro-batch prompts land here).
        out, aux = per_row(x, mask)
        return out, _aux_mean(aux, mask)
    # Unique integer sort keys (pad?, position): valid-first, order-stable
    # without relying on the backend sort's stability.
    pos = jnp.arange(s)[None, :]
    order = jnp.argsort(jnp.where(mask, 0, 1) * s + pos, axis=1)   # (B, S)
    inv = jnp.argsort(order, axis=1)
    xs = jnp.take_along_axis(x, order[..., None], axis=1)
    ms = jnp.take_along_axis(mask, order, axis=1)
    s_pad = -(-s // seq_chunk) * seq_chunk
    if s_pad != s:
        xs = jnp.pad(xs, ((0, 0), (0, s_pad - s), (0, 0)))
        ms = jnp.pad(ms, ((0, 0), (0, s_pad - s)))
    n = s_pad // seq_chunk
    xc = xs.reshape(b, n, seq_chunk, d).swapaxes(0, 1)
    mc = ms.reshape(b, n, seq_chunk).swapaxes(0, 1)
    if runtime_flags.UNROLL_INNER:
        res = [per_row(xc[i], mc[i]) for i in range(n)]
        outs = jnp.stack([r[0] for r in res], 0)
        auxes = jnp.stack([r[1] for r in res], 0)
    else:
        outs, auxes = jax.lax.map(lambda aa: per_row(*aa), (xc, mc))
    out_s = outs.swapaxes(0, 1).reshape(b, s_pad, d)[:, :s]
    aux = _aux_mean(auxes, mc)
    return jnp.take_along_axis(out_s, inv[..., None], axis=1), aux


def _aux_mean(auxes: jax.Array, masks: jax.Array = None) -> jax.Array:
    """Mean of per-(row x chunk) aux losses; with a pad mask the mean is
    weighted by each group's valid-token count — an all-pad group reports
    aux = 0 and an unweighted mean would dilute the balance gradient in
    proportion to the batch's pad fraction."""
    if masks is None:
        return auxes.mean()
    w = masks.sum(axis=-1).astype(jnp.float32)
    return (auxes * w).sum() / jnp.maximum(w.sum(), 1.0)


# Chunk size bounding the decode dispatch one-hot footprint (chunk^2 * E).
DECODE_CHUNK = 128

# Set to a list to record per-call dropped-real-token counts (host callback;
# asserted all-zero by benchmarks/distributed_bench.py and the decode
# regression in tests/test_masked_prefill.py). None = zero overhead.
# NOTE: the gate is evaluated at TRACE time — set the list before the decode
# path is first traced/jitted in the process, or cached compilations will
# log nothing (auditors should assert the call count is nonzero too).
DECODE_DROP_LOG = None


def _log_decode_drops(n) -> None:
    if DECODE_DROP_LOG is not None:
        DECODE_DROP_LOG.append(int(n))


def apply_moe_decode(cfg: ArchConfig, p: Dict, x: jax.Array,
                     chunk: int = DECODE_CHUNK) -> jax.Array:
    """Decode-path MoE for (B, 1, D) with a per-step **no-drop guarantee**.

    Uses the same capacity-dispatch einsums as training (SPMD-friendly under
    expert parallelism — per-token weight *gathers* would force cross-device
    expert-weight collectives), but the capacity buffer is sized to the
    worst case: tokens are processed in chunks of ``chunk`` and each chunk's
    capacity equals its token count, so even if every token in the chunk
    routes to the same expert, nothing is dropped. The old fixed
    ``DECODE_CAPACITY_FACTOR = 4`` silently dropped real tokens for
    top-k << E pools (llama4-maverick 128e top-1) once a decode batch put
    more than ``ceil(4*B*k/E)`` tokens on one expert — a served-quality
    cliff, not graceful degradation.

    Cost: dispatch-einsum FLOPs scale with E*C per chunk instead of
    ``cf*B*k``, but at decode the expert GEMMs are *weight-bandwidth* bound
    (all E expert matrices are read regardless of C), so wall time is
    insensitive to C at these sizes; chunking bounds the (T, E, C) one-hot
    footprint to ``chunk^2 * E``. Chunk boundaries cannot change results —
    capacity never binds, so every token's output is its exact gate-weighted
    expert mixture regardless of neighbors.
    """
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    # capacity_factor = E/k makes _capacity() return exactly n_tokens.
    cf_full = cfg.n_experts / cfg.top_k
    outs, drops = [], []
    for lo in range(0, x2d.shape[0], chunk):
        o, _, dr = _dispatch_combine(cfg, p, x2d[lo:lo + chunk],
                                     capacity_factor=cf_full,
                                     return_drops=True)
        outs.append(o)
        drops.append(dr)
    out = jnp.concatenate(outs, axis=0).reshape(b, s, d)
    if DECODE_DROP_LOG is not None:
        jax.debug.callback(_log_decode_drops, sum(drops))
    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out
