"""Mixture-of-Experts FFN (GShard/MaxText-style capacity dispatch).

Covers granite-moe (32e top-8), jamba (16e top-2), llama4-maverick
(128e top-1 + shared expert).

TPU adaptation: instead of CUDA grouped-GEMM / Megablocks sorting, tokens are
dispatched with one-hot capacity einsums — the canonical XLA/TPU formulation,
which shards cleanly with experts on the "model"/"expert" mesh axis and turns
into an all-to-all under expert parallelism. Compiled FLOPs scale with
top-k · capacity_factor (active experts), not with E, so the roofline stays
honest for the 128-expert pool member.

Dispatch tensors are (tokens, E, C); the sequence is processed in chunks
under ``lax.map`` to bound the live footprint (granite-moe's top-8 would
otherwise materialize multi-GB one-hots at 4k seq).

Decode (a handful of tokens) uses weight-gather instead: FLOPs = k·D·F per
token with no capacity slack.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import runtime_flags
from repro.models.layers import dense_init


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    fe = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    init_e = jax.vmap(lambda k_, din, dout: dense_init(k_, din, dout, dtype),
                      in_axes=(0, None, None))
    p = {
        "router": dense_init(ks[0], d, e, dtype),
        "w_gate": init_e(jax.random.split(ks[1], e), d, fe),
        "w_up": init_e(jax.random.split(ks[2], e), d, fe),
        "w_down": init_e(jax.random.split(ks[3], e), fe, d),
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], d, fs, dtype),
            "w_up": dense_init(kk[1], d, fs, dtype),
            "w_down": dense_init(kk[2], fs, d, dtype),
        }
    return p


def _router_probs(p: Dict, x: jax.Array) -> jax.Array:
    """(..., D) -> (..., E) softmax router probabilities in fp32."""
    logits = (x @ p["router"]).astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def aux_load_balance_loss(probs: jax.Array, expert_mask: jax.Array,
                          valid: jax.Array = None) -> jax.Array:
    """Switch-style load-balance loss: E * sum_e f_e * p_e.

    ``valid`` (T,) bool excludes pad tokens of a left-padded batch from
    both the routed-fraction and mean-probability statistics, so pads
    don't bias the expert-balance gradient.
    """
    e = probs.shape[-1]
    mask2 = expert_mask.reshape(-1, e)
    probs2 = probs.reshape(-1, e)
    if valid is None:
        f = mask2.mean(axis=0)                           # fraction routed
        pbar = probs2.mean(axis=0)                       # mean router prob
    else:
        v = valid.reshape(-1, 1).astype(jnp.float32)
        n = jnp.maximum(v.sum(), 1.0)
        f = (mask2 * v).sum(axis=0) / n
        pbar = (probs2 * v).sum(axis=0) / n
    return e * jnp.sum(f * pbar)


def _capacity(n_tokens: int, cfg: ArchConfig, factor: float = 0.0) -> int:
    f = factor or cfg.capacity_factor
    c = int(math.ceil(f * n_tokens * cfg.top_k / cfg.n_experts))
    return max(cfg.top_k, min(c, n_tokens))


def _dispatch_combine(
    cfg: ArchConfig, p: Dict, x2d: jax.Array, capacity_factor: float = 0.0,
    valid: jax.Array = None,
) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based MoE over (T, D) tokens. Returns (out (T,D), aux loss).

    ``valid`` (T,) bool marks real tokens of a left-padded batch. Pads are
    excluded from *everything* that could perturb a real token: their
    expert assignments are struck from the capacity position count (a real
    token's buffer slot depends only on the real tokens before it), the
    effective capacity shrinks to what the valid-token count alone would
    earn (so a padded row can't keep tokens its unpadded self would drop),
    their combine weights are zeroed, and they're excluded from the aux
    loss statistics. The capacity *buffer* stays statically sized from T;
    only the keep threshold is dynamic.
    """
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg, capacity_factor)

    probs = _router_probs(p, x2d)                         # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert's capacity buffer.
    slot_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # (T,k,E)
    eff_cap = cap
    if valid is not None:
        slot_onehot = slot_onehot * valid.astype(jnp.float32)[:, None, None]
        gate_vals = gate_vals * valid[:, None]
        # Same formula as _capacity, evaluated at the dynamic valid count:
        # max(top_k, min(ceil(f * n_valid * k / E), n_valid)), <= cap.
        f = capacity_factor or cfg.capacity_factor
        n_valid = valid.sum().astype(jnp.float32)
        eff_cap = jnp.clip(
            jnp.minimum(jnp.ceil(f * n_valid * k / e), n_valid), k, cap
        ).astype(jnp.int32)
    flat = slot_onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = jnp.einsum("tke,tke->tk", pos_in_expert, slot_onehot)    # (T,k)
    keep = pos < eff_cap
    gate_vals = gate_vals * keep

    # combine[t, e, c]: weight with which token t writes expert e's slot c.
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)           # (T,k,C)
    combine = jnp.einsum("tk,tke,tkc->tec", gate_vals, slot_onehot, pos_oh)
    dispatch = (combine > 0).astype(x2d.dtype)                     # (T,E,C)

    xe = jnp.einsum("tec,td->ecd", dispatch, x2d)                  # (E,C,D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])                # (E,C,D)
    out = jnp.einsum("tec,ecd->td", combine.astype(ye.dtype), ye)  # (T,D)

    aux = aux_load_balance_loss(probs, slot_onehot.sum(axis=1), valid)
    return out, aux


def apply_moe_train(
    cfg: ArchConfig, p: Dict, x: jax.Array, seq_chunk: int = 512,
    mask: jax.Array = None,
) -> Tuple[jax.Array, jax.Array]:
    """MoE over (B, S, D), capacity-grouped per (batch row x seq chunk).

    Grouping matters: dense dispatch costs 2*T*(E*C)*D FLOPs with
    C ~ cf*T*k/E, i.e. *quadratic* in group size T. At T=512 the dispatch
    einsums stay below the expert GEMMs for every assigned MoE config
    (granite-moe worst case: ratio ~0.4). Chunks run under ``lax.map`` to
    bound live memory; batch rows are vmapped inside each chunk.

    ``mask`` (B, S) bool marks real tokens of a left-padded batch: pads
    are excluded from capacity accounting, dispatch, and the aux loss (see
    :func:`_dispatch_combine`). Caveat: capacity groups are *position*
    chunks, so for sequences longer than ``seq_chunk`` a row's group
    boundaries shift with its pad count — padded prefill batches are
    invariant only up to ``seq_chunk`` tokens (serving micro-batches are
    well under it; documented in the README support matrix).
    """
    b, s, d = x.shape
    # Remat per chunk: dispatch/combine one-hots are cheap to recompute and
    # expensive to keep (E*C per token).
    if mask is None:
        per_row = jax.checkpoint(
            jax.vmap(lambda row: _dispatch_combine(cfg, p, row)))
        args = (x,)
    else:
        per_row = jax.checkpoint(jax.vmap(
            lambda row, vrow: _dispatch_combine(cfg, p, row, valid=vrow)))
        args = (x, mask)
    if s > seq_chunk and s % seq_chunk == 0:
        n = s // seq_chunk

        def to_chunks(a):
            return a.reshape(b, n, seq_chunk, *a.shape[2:]).swapaxes(0, 1)

        chunked = tuple(map(to_chunks, args))              # each (n,B,c,...)
        if runtime_flags.UNROLL_INNER:
            res = [per_row(*(a[i] for a in chunked)) for i in range(n)]
            outs = jnp.stack([r[0] for r in res], 0)
            auxes = jnp.stack([r[1] for r in res], 0)
        else:
            outs, auxes = jax.lax.map(lambda aa: per_row(*aa), chunked)
        out = outs.swapaxes(0, 1).reshape(b, s, d)
        aux = _aux_mean(auxes, None if mask is None else chunked[1])
    else:
        out, aux = per_row(*args)
        aux = _aux_mean(aux, mask)
    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out, aux


def _aux_mean(auxes: jax.Array, masks: jax.Array = None) -> jax.Array:
    """Mean of per-(row x chunk) aux losses; with a pad mask the mean is
    weighted by each group's valid-token count — an all-pad group reports
    aux = 0 and an unweighted mean would dilute the balance gradient in
    proportion to the batch's pad fraction."""
    if masks is None:
        return auxes.mean()
    w = masks.sum(axis=-1).astype(jnp.float32)
    return (auxes * w).sum() / jnp.maximum(w.sum(), 1.0)


DECODE_CAPACITY_FACTOR = 4.0


def apply_moe_decode(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    """Decode-path MoE for (B, 1, D).

    Uses the same capacity-dispatch einsums as training (SPMD-friendly under
    expert parallelism — per-token weight *gathers* would force cross-device
    expert-weight collectives) but with a generous capacity factor: at decode
    T = B tokens, so the dispatch tensors are tiny and drops would directly
    hurt served quality.
    """
    b, s, d = x.shape
    cf = max(DECODE_CAPACITY_FACTOR, cfg.capacity_factor)
    out, _ = _dispatch_combine(cfg, p, x.reshape(-1, d), capacity_factor=cf)
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out
