"""Runtime flags for roofline probing.

``xla.cost_analysis()`` counts a ``while`` body ONCE, not trip_count times
(verified empirically). All inner loops in this codebase have *static* trip
counts, so the roofline tool lowers a "probe" variant with inner loops
Python-unrolled (exact HLO cost) at n_repeats in {1, 2} and extrapolates
per-repeat costs; the production lowering keeps ``lax.scan`` for bounded
compile time. The sLSTM time scan (4096 steps) is the one loop never
unrolled — its cost is corrected analytically (see benchmarks/roofline.py).
"""
import contextlib

UNROLL_INNER = False


@contextlib.contextmanager
def unroll_inner():
    global UNROLL_INNER
    prev = UNROLL_INNER
    UNROLL_INNER = True
    try:
        yield
    finally:
        UNROLL_INNER = prev


def inner_scan(step, carry, xs_list, length: int):
    """lax.scan over a list-like of per-step inputs, or a Python loop when
    probing. ``xs_list`` is a tuple of arrays with leading dim ``length``.

    Returns (final_carry, stacked_ys) like ``lax.scan``; ys may be None.
    """
    import jax
    import jax.numpy as jnp

    if not UNROLL_INNER:
        return jax.lax.scan(step, carry, xs_list, length=length)
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a, i=i: a[i], xs_list) if xs_list is not None else None
        carry, y = step(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs, 0), *ys)
    else:
        ys = None
    return carry, ys
