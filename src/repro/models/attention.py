"""Grouped-query attention: full / sliding-window / cross variants.

Covers the attention needs of the assigned pool:
  * GQA with arbitrary (n_heads, n_kv_heads)      [all dense archs]
  * QKV bias                                      [qwen1.5-4b]
  * qk RMSNorm                                    [qwen3-0.6b]
  * sliding-window + ring-buffer KV cache         [gemma3-27b locals]
  * cross attention to stubbed modality tokens    [llama-3.2-vision]

Long sequences (prefill_32k) use a chunked online-softmax ("flash") path in
pure JAX: the q-chunk loop is unrolled at trace time so the causal band is
*statically* skipped — compiled FLOPs match the true banded cost instead of
the full S^2 rectangle. Decode reads a preallocated cache (full or ring).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec, XATTN
from repro.models.layers import apply_rmsnorm, apply_rope, dense_init, init_rmsnorm
from repro.models import runtime_flags
from repro.models.runtime_flags import inner_scan
from repro.models.sharding_ctx import shard

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, spec: LayerSpec, dtype=jnp.float32) -> Dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    if spec.mixer == XATTN and cfg.frontend_dim:
        # Learned projector from the (stubbed) modality encoder space.
        p["w_proj"] = dense_init(ks[4], cfg.frontend_dim, d, dtype)
        p["proj_norm"] = init_rmsnorm(d, dtype)
    return p


def _project_q(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    hq, hd = cfg.n_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, hq, hd)
    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
    return q


def _project_kv(cfg: ArchConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    b, s, _ = x.shape
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        k = apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# Dense (small-S) attention
# ---------------------------------------------------------------------------

def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,Hkv,G,D), k (B,Sk,Hkv,D) -> (B,Hkv,G,Sq,Sk) fp32."""
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    )


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs (B,Hkv,G,Sq,Sk), v (B,Sk,Hkv,D) -> (B,Sq,Hkv,G,D)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(probs.dtype))


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: Optional[jax.Array],
    softcap: float = 0.0,
) -> jax.Array:
    """Unchunked GQA attention. q (B,Sq,Hq,D); k,v (B,Sk,Hkv,D)."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd) * (1.0 / math.sqrt(hd))
    scores = _gqa_scores(qg, k)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, hd)


def causal_mask(sq: int, sk: int, q_offset: int = 0, window: int = 0) -> jax.Array:
    """(1, Sq, Sk) bool mask: key j visible to query i iff j<=i (& in window)."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    m = kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > qpos[:, None] - window
    return m[None]


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (statically banded)
# ---------------------------------------------------------------------------

def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    softcap: float = 0.0,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Causal (optionally sliding-window) attention, O(band) compute.

    The q-chunk loop is a Python loop (static), so each q chunk's k-range
    [lo, hi] is known at trace time and out-of-band chunks are never emitted
    into the HLO. The inner k loop is a ``lax.scan`` over the band with an
    online-softmax carry — peak memory is one (B,Hkv,G,qc,kc) tile.
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    if runtime_flags.UNROLL_INNER:
        # Roofline probe: coarser tiles bound the unrolled HLO size. The
        # masked diagonal-tile waste grows from ~qc/2S to ~4096/2S of the
        # causal band (<5% deviation), documented in benchmarks/roofline.py.
        q_chunk = max(q_chunk, 4096)
        k_chunk = max(k_chunk, 4096)
    q_chunk = min(q_chunk, s)
    k_chunk = min(k_chunk, s)
    assert s % q_chunk == 0 and s % k_chunk == 0, (s, q_chunk, k_chunk)

    qg = (q * scale).reshape(b, s, hkv, g, hd)
    outs = []
    for qi in range(s // q_chunk):
        q_lo, q_hi = qi * q_chunk, (qi + 1) * q_chunk
        qc = qg[:, q_lo:q_hi]
        # Band of k-chunks this q chunk can see.
        k_lo_chunk = 0 if window <= 0 else max(0, (q_lo - window) // k_chunk)
        k_hi_chunk = (q_hi + k_chunk - 1) // k_chunk  # causal bound
        n_band = k_hi_chunk - k_lo_chunk

        def kv_at(ci):
            start = (k_lo_chunk + ci) * k_chunk
            kc = jax.lax.dynamic_slice_in_dim(k, start, k_chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, k_chunk, axis=1)
            return kc, vc, start

        def step(carry, ci):
            m_prev, l_prev, acc = carry
            kc, vc, start = kv_at(ci)
            scores = _gqa_scores(qc, kc)  # (B,Hkv,G,qc,kc) fp32
            if softcap > 0.0:
                scores = jnp.tanh(scores / softcap) * softcap
            qpos = q_lo + jnp.arange(q_chunk)
            kpos = start + jnp.arange(k_chunk)
            msk = kpos[None, :] <= qpos[:, None]
            if window > 0:
                msk &= kpos[None, :] > qpos[:, None] - window
            scores = jnp.where(msk[None, None, None], scores, NEG_INF)
            if kv_valid is not None:
                vc_valid = jax.lax.dynamic_slice_in_dim(
                    kv_valid, start, k_chunk, axis=1)
                scores = jnp.where(
                    vc_valid[:, None, None, None, :], scores, NEG_INF)
            m_new = jnp.maximum(m_prev, scores.max(axis=-1))
            alpha = jnp.exp(m_prev - m_new)
            probs = jnp.exp(scores - m_new[..., None])
            l_new = l_prev * alpha + probs.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", probs, vc.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, hd), jnp.float32)
        # Remat per kv chunk: AD must not save the (.., qc, kc) probs tile
        # for every band step.
        (m_f, l_f, acc), _ = inner_scan(jax.checkpoint(step), (m0, l0, a0),
                                        jnp.arange(n_band), n_band)
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


# Threshold above which the chunked path is used (keeps smoke tests simple).
FLASH_MIN_SEQ = 2048


def self_attention_full_seq(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Causal self attention over a full sequence (train / prefill).

    ``kv_valid`` (B, S) bool marks real (non-pad) key positions for
    left-padded prefill micro-batches; None means all keys are real. RoPE
    logits depend only on position *differences*, so masking pad keys is
    sufficient for a left-padded row to attend exactly as its unpadded
    self (positions are uniformly shifted by the pad count). This is the
    attention member of the cross-mixer masked-compute contract pinned by
    tests/test_masked_prefill.py (SSM/xLSTM use identity pad updates, MoE
    pad-excluded capacity).
    """
    b, s, _ = x.shape
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if s >= FLASH_MIN_SEQ:
        out = flash_attention(
            q, k, v, window=spec.window, softcap=cfg.attn_logit_softcap,
            kv_valid=kv_valid,
        )
    else:
        mask = causal_mask(s, s, window=spec.window)
        if kv_valid is not None:
            mask = mask & kv_valid[:, None, :]
        out = dense_attention(q, k, v, mask, cfg.attn_logit_softcap)
    out = out.reshape(b, s, -1)
    return out @ p["wo"]


def cross_attention_full_seq(
    cfg: ArchConfig,
    p: Dict,
    x: jax.Array,
    media: jax.Array,
) -> jax.Array:
    """Cross attention: text queries attend to projected modality tokens.

    ``media`` is (B, n_frontend_tokens, frontend_dim) from the stub encoder.
    """
    b, s, _ = x.shape
    mtok = apply_rmsnorm(p["proj_norm"], media @ p["w_proj"], cfg.norm_eps)
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, mtok)
    # No RoPE across modalities (media tokens carry their own ordering).
    out = dense_attention(q, k, v, mask=None, softcap=cfg.attn_logit_softcap)
    out = out.reshape(b, s, -1)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_kv_cache(
    cfg: ArchConfig,
    spec: LayerSpec,
    batch: int,
    max_len: int,
    dtype=jnp.float32,
) -> Dict:
    """Preallocated cache. Sliding-window layers use a ring buffer of size W."""
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if spec.mixer == XATTN:
        n = cfg.n_frontend_tokens
        return {
            "k": jnp.zeros((batch, n, hkv, hd), dtype),
            "v": jnp.zeros((batch, n, hkv, hd), dtype),
        }
    length = min(spec.window, max_len) if spec.window > 0 else max_len
    return {
        "k": jnp.zeros((batch, length, hkv, hd), dtype),
        "v": jnp.zeros((batch, length, hkv, hd), dtype),
        # Absolute position stored in each slot (-1 = empty).
        "slot_pos": jnp.full((length,), -1, jnp.int32),
        # Per-row slot validity: False where a left-padded prefill wrote a
        # pad token (rows in a micro-batch have different pad counts, so
        # this cannot live in the shared slot_pos).
        "pad_valid": jnp.ones((batch, length), jnp.bool_),
    }


def _write_slot(cache: Dict, k_new, v_new, pos: jax.Array, ring: bool) -> Dict:
    """Write one token's k,v at ring/linear slot for position ``pos``."""
    length = cache["k"].shape[1]
    slot = pos % length if ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], pos[None].astype(jnp.int32), slot, axis=0
    )
    # Decode tokens are always real: the slot becomes valid for every row.
    pad_valid = jax.lax.dynamic_update_slice_in_dim(
        cache["pad_valid"],
        jnp.ones((cache["pad_valid"].shape[0], 1), jnp.bool_), slot, axis=1,
    )
    return {**cache, "k": k, "v": v, "slot_pos": slot_pos,
            "pad_valid": pad_valid}


def self_attention_decode(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,
    cache: Dict,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict]:
    """One-token decode. x (B,1,D); pos scalar int32 (same for whole batch)."""
    b = x.shape[0]
    q = _project_q(cfg, p, x)
    k_new, v_new = _project_kv(cfg, p, x)
    pos_b = jnp.broadcast_to(pos, (b, 1))
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k_new = apply_rope(k_new, pos_b, cfg.rope_theta)
    cache = _write_slot(cache, k_new.astype(cache["k"].dtype),
                        v_new.astype(cache["v"].dtype), pos, spec.window > 0)

    k, v = cache["k"], cache["v"]
    k = shard(k, "batch", "cache_seq", "kv_heads", None)
    v = shard(v, "batch", "cache_seq", "kv_heads", None)
    # Valid = slot holds a position in (pos - W, pos] AND is not a pad
    # written by a left-padded prefill (per-row).
    sp = cache["slot_pos"]
    valid = (sp >= 0) & (sp <= pos)
    if spec.window > 0:
        valid &= sp > pos - spec.window
    mask = valid[None, None, :] & cache["pad_valid"][:, None, :]
    out = dense_attention(q, k, v, mask, cfg.attn_logit_softcap)
    out = out.reshape(b, 1, -1)
    return out @ p["wo"], cache


def cross_attention_decode(
    cfg: ArchConfig,
    p: Dict,
    x: jax.Array,
    cache: Dict,
) -> Tuple[jax.Array, Dict]:
    """Decode-time cross attention reads the prefilled media cache."""
    b = x.shape[0]
    q = _project_q(cfg, p, x)
    out = dense_attention(q, cache["k"], cache["v"], mask=None,
                          softcap=cfg.attn_logit_softcap)
    out = out.reshape(b, 1, -1)
    return out @ p["wo"], cache


def prefill_cross_cache(
    cfg: ArchConfig, p: Dict, media: jax.Array, cache: Dict
) -> Dict:
    mtok = apply_rmsnorm(p["proj_norm"], media @ p["w_proj"], cfg.norm_eps)
    k, v = _project_kv(cfg, p, mtok)
    return {**cache, "k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}


def prefill_self_cache(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    cache: Dict,
    kv_valid: Optional[jax.Array] = None,
) -> Dict:
    """Fill a decode cache from a full prefill sequence.

    Ring caches keep only the trailing ``window`` tokens (the only ones a
    future decode step may attend to). ``kv_valid`` (B, S) bool marks real
    tokens of a left-padded batch; pad slots are written but flagged
    invalid per-row so decode never attends them.
    """
    s = x.shape[1]
    k, v = _project_kv(cfg, p, x)
    k = apply_rope(k, positions, cfg.rope_theta)
    length = cache["k"].shape[1]
    valid = (jnp.ones(x.shape[:2], jnp.bool_) if kv_valid is None
             else kv_valid.astype(jnp.bool_))
    if spec.window > 0 and s >= length:
        # Trailing `length` positions land at slots pos % length.
        tail_pos = positions[0, s - length:]
        order = jnp.argsort(tail_pos % length)
        k_tail = k[:, s - length:][:, order]
        v_tail = v[:, s - length:][:, order]
        slot_pos = tail_pos[order].astype(jnp.int32)
        return {**cache, "k": k_tail.astype(cache["k"].dtype),
                "v": v_tail.astype(cache["v"].dtype), "slot_pos": slot_pos,
                "pad_valid": valid[:, s - length:][:, order]}
    n = min(s, length)
    k_c = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k[:, :n].astype(cache["k"].dtype), 0, axis=1)
    v_c = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v[:, :n].astype(cache["v"].dtype), 0, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], positions[0, :n].astype(jnp.int32), 0, axis=0)
    pad_valid = jax.lax.dynamic_update_slice_in_dim(
        cache["pad_valid"], valid[:, :n], 0, axis=1)
    return {**cache, "k": k_c, "v": v_c, "slot_pos": slot_pos,
            "pad_valid": pad_valid}
