"""Mamba-1 selective SSM block (jamba's recurrent mixer).

TPU adaptation of the CUDA selective-scan kernel: the recurrence
``h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t`` is evaluated as a ``lax.scan``
over fixed-size time *chunks*, with a log-space ``associative_scan`` inside
each chunk. This keeps the materialized state tensor at
(B, chunk, d_inner, d_state) — the full (B, T, d_inner, d_state) tensor that
a naive associative scan would allocate is ~TBs at jamba's train shape.

Decode carries the (B, d_inner, d_state) state explicitly: O(1) per token,
which is what makes jamba eligible for the 500k-context shape.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init
from repro.models.runtime_flags import inner_scan
from repro.models.sharding_ctx import shard

SSM_CHUNK = 128


def init_mamba(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, di = cfg.d_model, cfg.ssm_d_inner
    ds, dc, dtr = cfg.ssm_d_state, cfg.ssm_d_conv, cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias initialized so softplus(dt) spans
    # (1e-3, 1e-1) as in the reference implementation.
    a_init = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[0], (di,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    inv_softplus = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": dense_init(ks[1], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[2], (dc, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[3], di, dtr + 2 * ds, dtype),
        "dt_proj": dense_init(ks[4], dtr, di, dtype, scale=dtr**0.5),
        "dt_bias": inv_softplus.astype(jnp.float32),
        "A_log": jnp.log(a_init),                     # fp32: recurrence basis
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _ssm_inputs(cfg: ArchConfig, p: Dict, x: jax.Array):
    """Shared front section: projections, causal conv, dt/B/C computation."""
    di, ds, dtr = cfg.ssm_d_inner, cfg.ssm_d_state, cfg.resolved_dt_rank
    xz = x @ p["in_proj"]                              # (B,S,2*di)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shard(xi, "batch", "seq", "ssm_inner")
    return xi, z, di, ds, dtr


def _causal_conv(p: Dict, xi: jax.Array, conv_state=None):
    """Depthwise causal conv along time. conv_state (B, dc-1, di) for decode."""
    dc = p["conv_w"].shape[0]
    if conv_state is not None:
        window = jnp.concatenate([conv_state, xi], axis=1)   # (B,dc,di)
        out = jnp.einsum("bti,ti->bi", window.astype(jnp.float32),
                         p["conv_w"].astype(jnp.float32))[:, None]
        new_state = window[:, 1:]
        return (jax.nn.silu(out + p["conv_b"].astype(jnp.float32))
                .astype(xi.dtype), new_state)
    pad = jnp.zeros(xi.shape[:1] + (dc - 1,) + xi.shape[2:], xi.dtype)
    xp = jnp.concatenate([pad, xi], axis=1)                  # (B,S+dc-1,di)
    out = sum(
        xp[:, i : i + xi.shape[1]] * p["conv_w"][i] for i in range(dc)
    )
    return jax.nn.silu(out + p["conv_b"]), None


def _dt_b_c(cfg, p, xc):
    ds, dtr = cfg.ssm_d_state, cfg.resolved_dt_rank
    dbc = xc @ p["x_proj"]                                   # (B,S,dtr+2ds)
    dt_r, b_mat, c_mat = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )                                                        # (B,S,di) fp32
    return dt, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32)


def _chunk_recurrence(dA_log, dBx, h0):
    """Within-chunk linear recurrence via associative scan.

    dA_log (B,Q,di,ds) = dt*(-A)  (log of decay, <= 0);
    dBx    (B,Q,di,ds) = dt*B*x.
    Returns h for every step (B,Q,di,ds) given carry h0 (B,di,ds).
    """
    def combine(a, b):
        (la, xa), (lb, xb) = a, b
        return la + lb, xb + jnp.exp(lb) * xa

    _, h_inner = jax.lax.associative_scan(combine, (dA_log, dBx), axis=1)
    # Fold the incoming state: h_t += exp(cumsum dA_log) * h0
    p_t = jnp.exp(jnp.cumsum(dA_log, axis=1))
    return h_inner + p_t * h0[:, None]


def apply_mamba_train(
    cfg: ArchConfig, p: Dict, x: jax.Array, return_state: bool = False,
    mask=None,
):
    """Full-sequence selective scan, chunked along time.

    ``return_state=True`` additionally returns the decode cache captured at
    the end of the sequence (used by the prefill step).

    ``mask`` (B, S) bool marks real tokens of a left-padded batch (None =
    all real). Pad steps become *identity* recurrence updates: the conv
    input is zeroed at pads (so the conv window over leading pads matches
    the zero front-padding an unpadded run sees) and ``dt`` is zeroed at
    pads, which drives ``dA_log -> 0`` (decay exp(0) = 1) and ``dBx -> 0``
    — the hidden state crosses pad positions unchanged. A left-padded
    row's real positions and final state therefore match its unpadded run,
    making outputs invariant to micro-batch composition.
    """
    b, s, _ = x.shape
    xi, z, di, ds, _ = _ssm_inputs(cfg, p, x)
    if mask is not None:
        xi = jnp.where(mask[..., None], xi, 0)
    xc, _ = _causal_conv(p, xi)
    dt, b_mat, c_mat = _dt_b_c(cfg, p, xc)
    if mask is not None:
        dt = jnp.where(mask[..., None], dt, 0.0)

    neg_a = -jnp.exp(p["A_log"])                             # (di,ds)
    q = min(SSM_CHUNK, s)
    if s % q:
        q = s                        # odd lengths: single chunk (tests only)
    n_chunks = s // q

    def to_chunks(t):  # (B,S,...) -> (n,B,q,...)
        return t.reshape(b, n_chunks, q, *t.shape[2:]).swapaxes(0, 1)

    xcs, dts, bs, cs = map(to_chunks, (xc.astype(jnp.float32), dt, b_mat, c_mat))

    def step(h, inputs):
        xq, dtq, bq, cq = inputs
        dA_log = dtq[..., None] * neg_a                      # (B,q,di,ds)
        dBx = (dtq * xq)[..., None] * bq[:, :, None, :]      # (B,q,di,ds)
        hs = _chunk_recurrence(dA_log, dBx, h)
        y = jnp.einsum("bqis,bqs->bqi", hs, cq)              # (B,q,di)
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, ds), jnp.float32)
    # Remat each chunk: AD otherwise saves the (B,q,di,ds) recurrence
    # tensors for EVERY chunk (hundreds of GB at jamba's train shape).
    h_final, ys = inner_scan(jax.checkpoint(step), h0, (xcs, dts, bs, cs), n_chunks)
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard(y, "batch", "seq", "ssm_inner")
    out = y @ p["out_proj"]
    if return_state:
        dc1 = cfg.ssm_d_conv - 1
        # Short prompts: pad the window front with zeros — exactly what the
        # causal conv's implicit front padding supplies. ``xi`` is already
        # zeroed at pad positions, so a left-padded row's window matches
        # its unpadded run.
        conv_tail = (xi[:, -dc1:, :] if s >= dc1
                     else jnp.pad(xi, ((0, 0), (dc1 - s, 0), (0, 0))))
        state = {"h": h_final, "conv": conv_tail}
        return out, state
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    di, ds, dc = cfg.ssm_d_inner, cfg.ssm_d_state, cfg.ssm_d_conv
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
    }


def apply_mamba_decode(
    cfg: ArchConfig, p: Dict, x: jax.Array, cache: Dict
) -> Tuple[jax.Array, Dict]:
    """One-token step: x (B,1,D)."""
    b = x.shape[0]
    xi, z, di, ds, _ = _ssm_inputs(cfg, p, x)
    xc, conv_state = _causal_conv(p, xi, cache["conv"])
    dt, b_mat, c_mat = _dt_b_c(cfg, p, xc)                   # (B,1,·)

    neg_a = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * neg_a)                  # (B,di,ds)
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b_mat[:, 0, None, :]
    h = dA * cache["h"] + dBx
    y = jnp.einsum("bis,bs->bi", h, c_mat[:, 0])[:, None]    # (B,1,di)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    return out, {**cache, "h": h, "conv": conv_state}
