"""Logical-axis activation sharding (MaxText-style, minimal).

Model code annotates activations with *logical* axis names
(``shard(x, "batch", "seq", "embed")``). The launcher installs a mesh and a
logical->mesh-axis rule table; outside a context (unit tests, examples on one
CPU device) the annotation is a no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

MeshAxes = Union[None, str, Tuple[str, ...]]


def _current() -> Tuple[Optional[Mesh], Dict[str, MeshAxes]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", {})


@contextlib.contextmanager
def logical_sharding(mesh: Mesh, rules: Dict[str, MeshAxes]):
    """Install ``mesh`` + logical-axis ``rules`` for the enclosed trace."""
    prev = _current()
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def logical_to_spec(axes: Sequence[Optional[str]], rules=None) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    if rules is None:
        rules = _current()[1]
    return P(*[rules.get(a) if a is not None else None for a in axes])


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o context)."""
    mesh, rules = _current()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} array")
    spec = logical_to_spec(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def get_rule(name: str, default=None):
    """Read a boolean/strategy entry from the active rule table."""
    return _current()[1].get(name, default)


def replicate(x: jax.Array) -> jax.Array:
    """Constrain to fully replicated (forces a weight all-gather when the
    stored array is sharded — the ZeRO-3 gathered-weights pattern)."""
    mesh, _ = _current()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim)))
    )


def gather_tree(tree):
    """Replicate every leaf of a param subtree at compute time."""
    if _current()[0] is None:
        return tree
    return jax.tree.map(replicate, tree)
