"""Fixed-capacity outcome replay buffer for online router adaptation.

Stores ``(q_emb, member, s_obs, c_obs, t)`` tuples emitted by the serving
scheduler. Two regions under one capacity:

  * a **recency ring** holding the newest outcomes verbatim — the signal
    that matters most under drift;
  * a **reservoir** fed by items aging out of the ring, maintaining a
    uniform sample over the whole evicted stream (Vitter's Algorithm R) —
    the anchor that stops the updater from catastrophically forgetting the
    stationary part of the distribution.

Sampling is recency-stratified: a configurable fraction of each batch comes
from the ring, the rest from the reservoir. All randomness flows from one
seeded ``numpy`` Generator, so buffer contents and samples replay
identically under a fixed seed and add/sample order.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

# One stored outcome: (q_emb, member, s_obs, c_obs, t).
_Item = Tuple[np.ndarray, int, float, float, float]


class ReplayBuffer:
    def __init__(self, capacity: int = 4096, *, recent_frac: float = 0.25,
                 seed: int = 0):
        if capacity < 2:
            raise ValueError("capacity must be >= 2")
        if not 0.0 < recent_frac < 1.0:
            raise ValueError("recent_frac must be in (0, 1)")
        self.capacity = capacity
        self.cap_recent = max(1, int(round(capacity * recent_frac)))
        self.cap_reservoir = capacity - self.cap_recent
        self.rng = np.random.default_rng(seed)
        self._recent: Deque[_Item] = deque()
        self._reservoir: List[_Item] = []
        self._evicted = 0      # length of the stream feeding the reservoir
        self.added = 0

    def __len__(self) -> int:
        return len(self._recent) + len(self._reservoir)

    # -- writes --------------------------------------------------------------

    def add(self, q_emb: np.ndarray, member: int, s_obs: float,
            c_obs: float, t: float = 0.0) -> None:
        item = (np.asarray(q_emb, np.float32), int(member), float(s_obs),
                float(c_obs), float(t))
        self.added += 1
        self._recent.append(item)
        if len(self._recent) > self.cap_recent:
            self._reservoir_add(self._recent.popleft())

    def _reservoir_add(self, item: _Item) -> None:
        self._evicted += 1
        if len(self._reservoir) < self.cap_reservoir:
            self._reservoir.append(item)
            return
        j = int(self.rng.integers(self._evicted))
        if j < self.cap_reservoir:
            self._reservoir[j] = item

    def drop_member(self, idx: int) -> None:
        """Hot pool removal: discard the member's outcomes, shift indices
        of members above it down by one (matching the mutated pool)."""
        def remap(items):
            return [(q, m - (m > idx), s, c, t) for (q, m, s, c, t) in items
                    if m != idx]
        self._recent = deque(remap(self._recent))
        self._reservoir = remap(self._reservoir)

    # -- reads ---------------------------------------------------------------

    def member_counts(self, n_members: int) -> np.ndarray:
        counts = np.zeros(n_members, np.int64)
        for _, m, _, _, _ in list(self._recent) + self._reservoir:
            if m < n_members:
                counts[m] += 1
        return counts

    def sample(self, n: int, *, recent_frac: float = 0.5) -> Optional[Dict]:
        """Recency-stratified batch of ``n`` outcomes (with replacement).

        Returns ``{"q_emb" (n,dq), "member" (n,), "s" (n,), "c" (n,),
        "t" (n,)}`` or None when the buffer is empty. Strata fall back on
        each other while one side is still sparse.
        """
        if len(self) == 0:
            return None
        recent = list(self._recent)
        n_rec = int(round(n * recent_frac))
        if not self._reservoir:
            n_rec = n
        elif not recent:
            n_rec = 0
        picks: List[_Item] = []
        if n_rec:
            idx = self.rng.integers(len(recent), size=n_rec)
            picks.extend(recent[i] for i in idx)
        if n - n_rec:
            idx = self.rng.integers(len(self._reservoir), size=n - n_rec)
            picks.extend(self._reservoir[i] for i in idx)
        return {
            "q_emb": np.stack([p[0] for p in picks]),
            "member": np.asarray([p[1] for p in picks], np.int32),
            "s": np.asarray([p[2] for p in picks], np.float32),
            "c": np.asarray([p[3] for p in picks], np.float32),
            "t": np.asarray([p[4] for p in picks], np.float64),
        }
