"""Staged/delayed quality-feedback outcomes for the online loop.

The original ``OnlineAdapter`` assumed quality feedback is available the
moment a request completes (``quality_feedback(request) -> float``). Real
feedback signals — user ratings, auto-eval verdicts, downstream task
success — lag completion by seconds to hours and arrive out of order. This
module is the staging layer between completion and training:

  * ``quality_feedback`` may now return **None**, parking the outcome in an
    :class:`OutcomeStage` instead of training on a placeholder score;
  * the real score arrives later via
    ``OnlineAdapter.deliver_feedback(rid, s_obs)`` — in any order, even
    *before* the outcome was staged (the feedback channel can race the
    serving thread);
  * every scheduler dispatch round calls ``OnlineAdapter.tick(now)``, which
    flushes resolved outcomes in their original staged order (deterministic
    replay under a fixed seed) and expires outcomes whose feedback never
    arrived within ``timeout_s`` — they are *dropped*, never trained on a
    guessed score.

The cross-worker replay merge (``repro.distributed``) consumes exactly what
this layer commits: a worker's replay buffer only ever holds real observed
scores, so the leader's merged updates are placeholder-free by construction.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class _Staged:
    req: object
    staged_t: float
    seq: int
    s_obs: Optional[float] = None


class OutcomeStage:
    """Pending-outcome staging area with out-of-order tolerant delivery.

    ``timeout_s`` bounds how long an unresolved outcome is held (expired
    outcomes are dropped, never trained on a guess); None holds pending
    outcomes indefinitely — only safe when the feedback channel is
    guaranteed to deliver (e.g. the synchronous simulators). Early
    deliveries for never-staged rids are additionally FIFO-capped at
    ``early_capacity`` so a crashed-and-rejoined worker's orphaned
    feedback can't grow without bound.
    """

    def __init__(self, timeout_s: Optional[float] = None,
                 early_capacity: int = 4096):
        self.timeout_s = timeout_s
        self.early_capacity = early_capacity
        self._pending: Dict[int, _Staged] = {}
        # Feedback that arrived before its outcome was staged: rid -> (s, t).
        self._early: Dict[int, Tuple[float, float]] = {}
        self._seq = 0
        self.staged = 0
        self.resolved = 0
        self.expired = 0
        self.early_deliveries = 0

    def __len__(self) -> int:
        return len(self._pending)

    def stage(self, req, now: float) -> None:
        """Park a completed request until its quality feedback arrives."""
        entry = _Staged(req, float(now), self._seq)
        self._seq += 1
        self.staged += 1
        rid = int(req.rid)
        if rid in self._early:                 # feedback raced completion
            entry.s_obs = self._early.pop(rid)[0]
            self.resolved += 1
        self._pending[rid] = entry

    def deliver(self, rid: int, s_obs: float, now: float = 0.0) -> None:
        """Attach a score to a staged outcome; unknown rids are held as
        early deliveries (out-of-order tolerance), never an error."""
        entry = self._pending.get(int(rid))
        if entry is None:
            self._early[int(rid)] = (float(s_obs), float(now))
            self.early_deliveries += 1
            while len(self._early) > self.early_capacity:   # FIFO bound
                del self._early[next(iter(self._early))]
            return
        if entry.s_obs is None:
            self.resolved += 1
        entry.s_obs = float(s_obs)

    def flush(self, now: float) -> List[Tuple[object, float]]:
        """Resolved outcomes in staged order; expires timed-out entries.

        Staged order (not delivery order) keeps the committed stream
        deterministic regardless of how the feedback channel interleaved.
        """
        ready, dead = [], []
        for rid, e in self._pending.items():
            if e.s_obs is not None:
                ready.append((e.seq, rid, e))
            elif (self.timeout_s is not None
                  and now - e.staged_t > self.timeout_s):
                dead.append(rid)
        for rid in dead:
            del self._pending[rid]
            self.expired += 1
        if self.timeout_s is not None:
            self._early = {r: (s, t) for r, (s, t) in self._early.items()
                           if now - t <= self.timeout_s}
        out = []
        for _, rid, e in sorted(ready):
            del self._pending[rid]
            out.append((e.req, e.s_obs))
        return out


class DelayedFeedback:
    """Simulator: ground-truth scores that arrive ``delay_s`` after a
    request finishes (plus optional jitter, which reorders deliveries).

    Install as both the adapter's ``quality_feedback`` and its
    ``feedback_source``: calls return None (staging the outcome) while the
    true score is queued for delivery at ``finish_s + delay``; the
    adapter's ``tick()`` drains :meth:`due` each dispatch round.
    """

    def __init__(self, truth_fn: Callable[[object], float], delay_s: float,
                 *, jitter_s: float = 0.0, seed: int = 0):
        self.truth_fn = truth_fn
        self.delay_s = float(delay_s)
        self.jitter_s = float(jitter_s)
        self.rng = np.random.default_rng(seed)
        self._heap: List[Tuple[float, int, int, float]] = []
        self._n = 0

    def __call__(self, req) -> None:
        t = float(req.finish_s) + self.delay_s
        if self.jitter_s:
            t += float(self.rng.uniform(0.0, self.jitter_s))
        heapq.heappush(self._heap,
                       (t, self._n, int(req.rid), float(self.truth_fn(req))))
        self._n += 1
        return None

    def due(self, now: float) -> List[Tuple[int, float]]:
        out = []
        while self._heap and self._heap[0][0] <= now:
            _, _, rid, s = heapq.heappop(self._heap)
            out.append((rid, s))
        return out

    @property
    def in_flight(self) -> int:
        return len(self._heap)
