"""Hot pool membership: add/remove serving members at runtime.

Adding a member mid-stream poses two problems the offline pipeline never
sees: the router has no model embedding for it (those come from observed
quality over training clusters, paper §5), and its predictions are
untrained. The tracker solves both:

  * the new member's embedding row cold-starts at the pool mean and is
    then replaced cluster-by-cluster with *observed* mean quality — each
    outcome is assigned to its nearest k-means centroid (the same
    centroids, carried on the router, that built the offline embeddings —
    exactly :func:`repro.core.model_repr.embed_new_model`, incrementalized);
  * until the member has ``min_outcomes`` observed outcomes it is
    **probationary**: masked out of the exploitation argmax and reachable
    only through the exploration policy, so cold predictions never steer
    real traffic.

Removal shifts member indices down; the tracker propagates the remap to
the replay buffer and exploration counts so stale indices can't dangle.

**Established-member refresh** (``refresh_established=True``): graduated
members' embedding rows normally adapt only through predictor gradients —
under drift the *embedding* itself (per-cluster observed mean quality,
paper §5) goes stale even while the predictor compensates. The flagged
path applies an EMA of observed outcomes to the graduated member's row in
the outcome's cluster, so the row tracks the member's live per-cluster
quality. Off by default: it changes long-standing rows, so the operator
opts in (``serve.py --refresh-established``).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class MembershipTracker:
    def __init__(self, engine, *, min_outcomes: int = 25,
                 prior_weight: float = 1.0,
                 refresh_established: bool = False,
                 refresh_rate: float = 0.05):
        self.engine = engine
        self.min_outcomes = min_outcomes
        self.prior_weight = prior_weight
        self.refresh_established = refresh_established
        self.refresh_rate = refresh_rate
        k = len(engine.pool)
        # Offline-trained members are born graduated.
        self.counts = np.full(k, min_outcomes, np.int64)
        self.model_emb = np.array(engine.router.model_emb, np.float32,
                                  copy=True)
        # Per-probationary-member per-cluster (sum, n) accumulators.
        self._cluster_stats: Dict[int, Dict[str, np.ndarray]] = {}
        self._prior_rows: Dict[int, np.ndarray] = {}
        self.emb_dirty = False

    @property
    def n_members(self) -> int:
        return len(self.counts)

    def exploit_mask(self) -> np.ndarray:
        """(K,) bool — False while a member is probationary."""
        return self.counts >= self.min_outcomes

    def in_probation(self, idx: int) -> bool:
        return bool(self.counts[idx] < self.min_outcomes)

    # -- pool mutation -------------------------------------------------------

    def add_member(self, pool_member,
                   emb_row: Optional[np.ndarray] = None) -> int:
        """Append a member to the live pool; returns its index.

        Publishes a grown router (cold-started embedding row + expanded
        predictor heads) via the engine's atomic swap, then registers the
        member as probationary.
        """
        router = self.engine.router.add_member(emb_row)
        self.engine.pool.append(pool_member)
        self.engine.swap_router(router)
        idx = router.n_members - 1
        self.counts = np.append(self.counts, 0)
        self.model_emb = np.array(router.model_emb, np.float32, copy=True)
        c = self.model_emb.shape[1]
        self._cluster_stats[idx] = {"sum": np.zeros(c, np.float64),
                                    "n": np.zeros(c, np.int64)}
        self._prior_rows[idx] = self.model_emb[idx].copy()
        return idx

    def remove_member(self, idx: int, *, replay=None, policy=None) -> None:
        """Drop a member from the live pool and remap dependent state.

        Pool-list surgery and the router swap are two steps, so membership
        mutations must run between dispatch rounds (the adapter API is
        driven from the scheduler's thread). The router swaps first: in
        the transient window choices are bounded by the shrunk router, so
        a straggling scorer can never index past the end of the pool.
        """
        router = self.engine.router.remove_member(idx)
        self.engine.swap_router(router)
        del self.engine.pool[idx]
        self.counts = np.delete(self.counts, idx)
        self.model_emb = np.array(router.model_emb, np.float32, copy=True)
        self._cluster_stats = {
            m - (m > idx): st for m, st in self._cluster_stats.items()
            if m != idx}
        self._prior_rows = {
            m - (m > idx): row for m, row in self._prior_rows.items()
            if m != idx}
        if replay is not None:
            replay.drop_member(idx)
        if policy is not None:
            policy.remove_member(idx)

    # -- outcome accounting --------------------------------------------------

    def record_outcome(self, member: int, q_emb: np.ndarray,
                       s_obs: float) -> None:
        member = int(member)
        self.counts[member] += 1
        stats = self._cluster_stats.get(member)
        if stats is None and not self.refresh_established:
            return
        centroids = self.engine.router.centroids
        if centroids is None:
            return
        # Same nearest-centroid rule as core.clustering.assign_clusters,
        # inlined in numpy: this runs once per served outcome, where a
        # single-row eager jnp dispatch would dominate the cost.
        d2 = np.sum((np.asarray(centroids, np.float32)
                     - np.asarray(q_emb, np.float32)[None, :]) ** 2, axis=1)
        ci = int(np.argmin(d2))
        if stats is None:
            # Established member under the flagged refresh: EMA the row's
            # cluster entry toward the observed outcome, so drift in the
            # member's real per-cluster quality reaches the embedding
            # without waiting for predictor gradients to route around it.
            rho = self.refresh_rate
            self.model_emb[member, ci] = (
                (1.0 - rho) * self.model_emb[member, ci] + rho * float(s_obs))
            self.emb_dirty = True
            return
        stats["sum"][ci] += float(s_obs)
        stats["n"][ci] += 1
        prior = self._prior_rows[member][ci]
        w0 = self.prior_weight
        self.model_emb[member, ci] = (
            (w0 * prior + stats["sum"][ci]) / (w0 + stats["n"][ci]))
        self.emb_dirty = True
        if self.counts[member] >= self.min_outcomes:
            # Graduated: keep the observed row, stop accumulating (the
            # updater's gradient steps take over from here).
            del self._cluster_stats[member]
            del self._prior_rows[member]
