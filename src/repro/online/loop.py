"""OnlineAdapter: the feedback loop closing serve -> observe -> learn.

Sits between the micro-batching scheduler and the online machinery:

  * at the **scoring step** it replaces the plain reward argmax with the
    exploration policy (epsilon annealed by budget-governor headroom,
    optimistic bonus, probation masking);
  * on **served outcomes** it fills the replay buffer, advances hot-member
    probation, feeds the drift detector, and schedules bounded incremental
    updates — a drift alarm triggers a concentrated burst plus a detector
    re-anchor (recovery), steady state updates every ``update_every``
    outcomes;
  * every update **publishes** a new router version through the engine's
    atomic swap.

The quality feedback signal is a caller-supplied
``quality_feedback(request) -> float in [0, 1]`` — a user rating, an
auto-eval, or (in the simulator) the synthetic RouterBench truth. It may
return **None** for feedback that has not arrived yet: the outcome is then
*staged* (``repro.online.staging``) instead of trained on a placeholder,
and committed when the real score lands via :meth:`deliver_feedback` and
the next :meth:`tick` — out-of-order tolerant, timeout-dropped.

Two roles: a **solo** adapter runs its own ``IncrementalUpdater`` (the
default); a **follower** (``defer_updates=True``, used by the multi-worker
plane in ``repro.distributed``) only collects outcomes into its local
replay — the leader's coordinator merges replays, runs the bounded update
steps, and broadcasts versioned routers back. A follower's drift alarm
raises ``pending_burst`` for the coordinator instead of bursting locally.

Determinism: policy and replay own seeded generators, staged outcomes flush
in staged order, and the scheduler drives everything from the virtual
clock, so a fixed seed replays the whole adapt cycle identically (tested in
tests/test_online.py).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.rewards import REWARDS
from repro.online.drift import DriftDetector
from repro.online.exploration import ExplorationConfig, ExplorationPolicy
from repro.online.membership import MembershipTracker
from repro.online.replay import ReplayBuffer
from repro.online.staging import OutcomeStage
from repro.online.updater import IncrementalUpdater, OnlineUpdateConfig


class OnlineAdapter:
    def __init__(self, engine, quality_feedback: Callable[[object], object],
                 *, governor=None,
                 config: Optional[OnlineUpdateConfig] = None,
                 exploration: Optional[ExplorationConfig] = None,
                 replay: Optional[ReplayBuffer] = None,
                 drift: Optional[DriftDetector] = None,
                 membership: Optional[MembershipTracker] = None,
                 updater: Optional[IncrementalUpdater] = None,
                 stage: Optional[OutcomeStage] = None,
                 feedback_source=None,
                 defer_updates: bool = False,
                 seed: int = 0):
        self.engine = engine
        self.quality_feedback = quality_feedback
        self.governor = governor
        self.config = config or OnlineUpdateConfig()
        # `is None` checks: ReplayBuffer/OutcomeStage define __len__, so a
        # freshly-constructed (empty) instance is falsy under `or`.
        self.replay = ReplayBuffer(seed=seed) if replay is None else replay
        self.drift = drift   # None disables drift detection
        self.membership = membership or MembershipTracker(engine)
        self.policy = ExplorationPolicy(
            len(engine.pool), exploration or ExplorationConfig(seed=seed))
        self.updater = updater or IncrementalUpdater(engine.router,
                                                     self.config)
        self.stage = OutcomeStage() if stage is None else stage
        # Optional pull-based feedback channel: ``due(now) -> [(rid, s)]``
        # drained on every tick (see repro.online.staging.DelayedFeedback).
        self.feedback_source = feedback_source
        # Follower mode (multi-worker plane): never run local update steps;
        # drift alarms raise ``pending_burst`` for the coordinator instead.
        self.defer_updates = defer_updates
        self.pending_burst = False
        self._since_update = 0
        # Observability hook (repro.obs): observe/drift/update events.
        # Installed by the scheduler; None = no tracing overhead.
        self.tracer = None
        self.last_explored = np.zeros(0, bool)   # per-request, last batch
        self.stats: Dict[str, float] = {
            "outcomes": 0, "explored": 0, "updates": 0, "update_steps": 0,
            "bursts": 0, "drift_alarms": 0, "router_swaps": 0,
            "members_added": 0, "members_removed": 0,
            "staged": 0, "delayed_resolved": 0, "feedback_expired": 0,
            "last_quality_loss": float("nan"),
            "last_cost_loss": float("nan"),
        }

    # -- scoring-step hook ---------------------------------------------------

    def headroom(self, now: float) -> float:
        """Budget slack in [0, 1] annealing exploration (1 = no governor)."""
        if self.governor is None:
            return 1.0
        return self.governor.headroom(now)

    def choose(self, s_hat: np.ndarray, c_hat: np.ndarray, lam: float,
               now: float = 0.0) -> np.ndarray:
        """Exploration-aware routing for one score batch (scheduler hook)."""
        rewards = np.asarray(
            REWARDS[self.engine.router.reward](s_hat, c_hat, lam))
        choices, explored = self.policy.choose(
            rewards, self.membership.exploit_mask(), self.headroom(now))
        self.last_explored = explored
        self.stats["explored"] += int(explored.sum())
        return choices

    # -- outcome hooks -------------------------------------------------------

    def observe(self, served: List, now: float = 0.0) -> None:
        """Fold one dispatch round's served requests into the loop.

        Requests whose feedback is immediate commit right away; the rest
        are staged until :meth:`deliver_feedback` resolves them.
        """
        ready: List[Tuple[object, float]] = []
        n_staged = 0
        for r in served:
            if getattr(r, "q_emb", None) is None or r.member < 0:
                continue
            s_obs = self.quality_feedback(r)
            if s_obs is None:
                self.stage.stage(r, now)
                self.stats["staged"] += 1
                n_staged += 1
            else:
                ready.append((r, float(s_obs)))
        if self.tracer is not None and served:
            self.tracer.instant(
                "adapter_observe", "online", now,
                args={"served": len(served), "immediate": len(ready),
                      "staged": n_staged})
        self._commit(ready, now)
        self.tick(now)

    def deliver_feedback(self, rid: int, s_obs: float,
                         now: float = 0.0) -> None:
        """Late quality feedback for a served request (any order)."""
        self.stage.deliver(rid, s_obs, now)

    def tick(self, now: float = 0.0) -> None:
        """Flush resolved staged outcomes (called every dispatch round)."""
        if self.feedback_source is not None:
            for rid, s in self.feedback_source.due(now):
                self.stage.deliver(rid, s, now)
        ready = self.stage.flush(now)
        if ready:
            self.stats["delayed_resolved"] += len(ready)
            self._commit(ready, now)
        self.stats["feedback_expired"] = self.stage.expired

    def _commit(self, outcomes: List[Tuple[object, float]],
                now: float) -> None:
        """Train-ready outcomes -> replay / membership / drift / updates."""
        embs, members = [], []
        for r, s_obs in outcomes:
            self.replay.add(r.q_emb, r.member, s_obs, r.cost, now)
            self.membership.record_outcome(r.member, r.q_emb, s_obs)
            members.append(r.member)
            embs.append(np.asarray(r.q_emb, np.float32))
            self.stats["outcomes"] += 1
            self._since_update += 1
        if members:
            self.policy.record(np.asarray(members))

        if self.drift is not None and embs:
            if self.drift.observe(np.stack(embs), now):
                self.stats["drift_alarms"] += 1
                if self.tracer is not None:
                    stats = self.drift.last_stats
                    self.tracer.instant(
                        "drift_alarm", "online", now,
                        args={"shift_z": stats.get("shift_z"),
                              "dispersion_z": stats.get("dispersion_z"),
                              "deferred": self.defer_updates})
                if self.defer_updates:
                    self.pending_burst = True
                else:
                    self.stats["bursts"] += 1
                    self._update(self.config.burst_steps, now)
                # Recovery: re-anchor the detector on the post-shift regime
                # so it arms for the *next* excursion instead of alarming
                # on every subsequent window.
                self.drift.refit()
        if (self._since_update >= self.config.update_every
                and not self.defer_updates):
            self._update(self.config.steps_per_update, now)

    # -- incremental updates -------------------------------------------------

    def _update(self, n_steps: int, now: float = 0.0) -> None:
        self._since_update = 0
        if len(self.replay) < self.config.min_buffer:
            return
        res = self.updater.run_steps(self.replay, self.membership.model_emb,
                                     n_steps)
        if res["steps"] == 0:
            return
        self.updater.publish(self.engine, self.membership.model_emb)
        self.membership.emb_dirty = False
        self.stats["updates"] += 1
        self.stats["update_steps"] += res["steps"]
        self.stats["router_swaps"] += 1
        self.stats["last_quality_loss"] = res["quality_loss"]
        self.stats["last_cost_loss"] = res["cost_loss"]
        if self.tracer is not None:
            # The engine's on_swap hook already emitted "router_swap"; this
            # carries the update's provenance alongside it.
            self.tracer.instant(
                "router_update", "online", now,
                args={"steps": int(res["steps"]),
                      "version": self.engine.router.version})

    # -- crash recovery (multi-worker plane) ---------------------------------

    def reset_outcome_state(self, seed: int) -> None:
        """Rejoin-after-crash support: in-memory outcome state (replay,
        staged feedback) did not survive the process; rebuild it empty."""
        frac = self.replay.cap_recent / self.replay.capacity
        self.replay = ReplayBuffer(self.replay.capacity,
                                   recent_frac=frac, seed=seed)
        self.stage = OutcomeStage(timeout_s=self.stage.timeout_s)
        self.pending_burst = False
        self._since_update = 0

    # -- hot pool membership -------------------------------------------------

    def add_member(self, pool_member,
                   emb_row: Optional[np.ndarray] = None) -> int:
        """Hot-add a pool member (probationary until min outcome count)."""
        idx = self.membership.add_member(pool_member, emb_row)
        self.policy.add_member()
        self.updater.warm_start(self.engine.router)
        self.stats["members_added"] += 1
        self.stats["router_swaps"] += 1
        return idx

    def remove_member(self, idx: int) -> None:
        """Hot-remove a pool member; dependent state is remapped."""
        self.membership.remove_member(idx, replay=self.replay,
                                      policy=self.policy)
        self.updater.warm_start(self.engine.router)
        self.stats["members_removed"] += 1
        self.stats["router_swaps"] += 1

    # -- reporting -----------------------------------------------------------

    def report(self) -> str:
        s = self.stats
        staged = ""
        if s["staged"]:
            staged = (f"  staged {int(s['staged'])} "
                      f"(resolved {int(s['delayed_resolved'])}, "
                      f"expired {int(s['feedback_expired'])})")
        return (
            f"online: outcomes {int(s['outcomes'])}  "
            f"explored {int(s['explored'])}  "
            f"updates {int(s['updates'])} ({int(s['update_steps'])} steps, "
            f"{int(s['bursts'])} bursts)  "
            f"drift alarms {int(s['drift_alarms'])}  "
            f"router v{self.engine.router.version} "
            f"({int(s['router_swaps'])} swaps)  "
            f"pool {len(self.engine.pool)} members "
            f"(+{int(s['members_added'])}/-{int(s['members_removed'])})"
            f"{staged}"
        )
