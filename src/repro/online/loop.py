"""OnlineAdapter: the feedback loop closing serve -> observe -> learn.

Sits between the micro-batching scheduler and the online machinery:

  * at the **scoring step** it replaces the plain reward argmax with the
    exploration policy (epsilon annealed by budget-governor headroom,
    optimistic bonus, probation masking);
  * on **served outcomes** it fills the replay buffer, advances hot-member
    probation, feeds the drift detector, and schedules bounded incremental
    updates — a drift alarm triggers a concentrated burst plus a detector
    re-anchor (recovery), steady state updates every ``update_every``
    outcomes;
  * every update **publishes** a new router version through the engine's
    atomic swap.

The quality feedback signal is a caller-supplied
``quality_feedback(request) -> float in [0, 1]`` — a user rating, an
auto-eval, or (in the simulator) the synthetic RouterBench truth.

Determinism: policy and replay own seeded generators and the scheduler
drives everything from the virtual clock, so a fixed seed replays the
whole adapt cycle identically (tested in tests/test_online.py).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.rewards import REWARDS
from repro.online.drift import DriftDetector
from repro.online.exploration import ExplorationConfig, ExplorationPolicy
from repro.online.membership import MembershipTracker
from repro.online.replay import ReplayBuffer
from repro.online.updater import IncrementalUpdater, OnlineUpdateConfig


class OnlineAdapter:
    def __init__(self, engine, quality_feedback: Callable[[object], float],
                 *, governor=None,
                 config: Optional[OnlineUpdateConfig] = None,
                 exploration: Optional[ExplorationConfig] = None,
                 replay: Optional[ReplayBuffer] = None,
                 drift: Optional[DriftDetector] = None,
                 membership: Optional[MembershipTracker] = None,
                 updater: Optional[IncrementalUpdater] = None,
                 seed: int = 0):
        self.engine = engine
        self.quality_feedback = quality_feedback
        self.governor = governor
        self.config = config or OnlineUpdateConfig()
        self.replay = replay or ReplayBuffer(seed=seed)
        self.drift = drift   # None disables drift detection
        self.membership = membership or MembershipTracker(engine)
        self.policy = ExplorationPolicy(
            len(engine.pool), exploration or ExplorationConfig(seed=seed))
        self.updater = updater or IncrementalUpdater(engine.router,
                                                     self.config)
        self._since_update = 0
        self.last_explored = np.zeros(0, bool)   # per-request, last batch
        self.stats: Dict[str, float] = {
            "outcomes": 0, "explored": 0, "updates": 0, "update_steps": 0,
            "bursts": 0, "drift_alarms": 0, "router_swaps": 0,
            "members_added": 0, "members_removed": 0,
            "last_quality_loss": float("nan"),
            "last_cost_loss": float("nan"),
        }

    # -- scoring-step hook ---------------------------------------------------

    def headroom(self, now: float) -> float:
        """Budget slack in [0, 1] annealing exploration (1 = no governor)."""
        if self.governor is None:
            return 1.0
        return float(np.clip(1.0 - self.governor.utilization(now), 0.0, 1.0))

    def choose(self, s_hat: np.ndarray, c_hat: np.ndarray, lam: float,
               now: float = 0.0) -> np.ndarray:
        """Exploration-aware routing for one score batch (scheduler hook)."""
        rewards = np.asarray(
            REWARDS[self.engine.router.reward](s_hat, c_hat, lam))
        choices, explored = self.policy.choose(
            rewards, self.membership.exploit_mask(), self.headroom(now))
        self.last_explored = explored
        self.stats["explored"] += int(explored.sum())
        return choices

    # -- outcome hook --------------------------------------------------------

    def observe(self, served: List, now: float = 0.0) -> None:
        """Fold one dispatch round's served requests into the loop."""
        embs, members = [], []
        for r in served:
            if getattr(r, "q_emb", None) is None or r.member < 0:
                continue
            s_obs = float(self.quality_feedback(r))
            self.replay.add(r.q_emb, r.member, s_obs, r.cost, now)
            self.membership.record_outcome(r.member, r.q_emb, s_obs)
            members.append(r.member)
            embs.append(np.asarray(r.q_emb, np.float32))
            self.stats["outcomes"] += 1
            self._since_update += 1
        if members:
            self.policy.record(np.asarray(members))

        if self.drift is not None and embs:
            if self.drift.observe(np.stack(embs), now):
                self.stats["drift_alarms"] += 1
                self.stats["bursts"] += 1
                self._update(self.config.burst_steps)
                # Recovery: re-anchor the detector on the post-shift regime
                # so it arms for the *next* excursion instead of alarming
                # on every subsequent window.
                self.drift.refit()
        if self._since_update >= self.config.update_every:
            self._update(self.config.steps_per_update)

    # -- incremental updates -------------------------------------------------

    def _update(self, n_steps: int) -> None:
        self._since_update = 0
        if len(self.replay) < self.config.min_buffer:
            return
        res = self.updater.run_steps(self.replay, self.membership.model_emb,
                                     n_steps)
        if res["steps"] == 0:
            return
        self.updater.publish(self.engine, self.membership.model_emb)
        self.membership.emb_dirty = False
        self.stats["updates"] += 1
        self.stats["update_steps"] += res["steps"]
        self.stats["router_swaps"] += 1
        self.stats["last_quality_loss"] = res["quality_loss"]
        self.stats["last_cost_loss"] = res["cost_loss"]

    # -- hot pool membership -------------------------------------------------

    def add_member(self, pool_member,
                   emb_row: Optional[np.ndarray] = None) -> int:
        """Hot-add a pool member (probationary until min outcome count)."""
        idx = self.membership.add_member(pool_member, emb_row)
        self.policy.add_member()
        self.updater.warm_start(self.engine.router)
        self.stats["members_added"] += 1
        self.stats["router_swaps"] += 1
        return idx

    def remove_member(self, idx: int) -> None:
        """Hot-remove a pool member; dependent state is remapped."""
        self.membership.remove_member(idx, replay=self.replay,
                                      policy=self.policy)
        self.updater.warm_start(self.engine.router)
        self.stats["members_removed"] += 1
        self.stats["router_swaps"] += 1

    # -- reporting -----------------------------------------------------------

    def report(self) -> str:
        s = self.stats
        return (
            f"online: outcomes {int(s['outcomes'])}  "
            f"explored {int(s['explored'])}  "
            f"updates {int(s['updates'])} ({int(s['update_steps'])} steps, "
            f"{int(s['bursts'])} bursts)  "
            f"drift alarms {int(s['drift_alarms'])}  "
            f"router v{self.engine.router.version} "
            f"({int(s['router_swaps'])} swaps)  "
            f"pool {len(self.engine.pool)} members "
            f"(+{int(s['members_added'])}/-{int(s['members_removed'])})"
        )
