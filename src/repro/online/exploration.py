"""Exploration over the pool at the scheduler's scoring step.

Pure exploitation starves the replay buffer of counterfactuals: once the
router believes member ``m`` is best for a region, only ``m``'s outcomes
are ever observed there and the other members' predictions can never be
corrected. Two mechanisms, composable:

  * **optimistic per-member bonus** ``bonus / sqrt(n_m + 1)`` added to the
    predicted reward — under-observed members (freshly added ones above
    all) win ties and decay back to honest scores as outcomes accumulate;
  * **epsilon-greedy** — a per-request coin flip routes uniformly over the
    explorable members.

Epsilon is annealed by the budget governor's *headroom*: exploration costs
money (it sometimes picks expensive members the reward argmax would not),
so a window running hot on budget explores less and a window with slack
explores at the configured rate.

The exploit argmax additionally honors a membership mask: probationary
members (below their minimum outcome count) are only reachable via the
exploration paths, never via exploitation — cold predictions should not
steer real traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ExplorationConfig:
    epsilon: float = 0.05       # exploration rate at full budget headroom
    bonus: float = 0.05         # optimistic bonus scale (reward units)
    seed: int = 0


class ExplorationPolicy:
    def __init__(self, n_members: int,
                 config: Optional[ExplorationConfig] = None):
        self.config = config or ExplorationConfig()
        self.counts = np.zeros(n_members, np.int64)
        self.rng = np.random.default_rng(self.config.seed)

    @property
    def n_members(self) -> int:
        return len(self.counts)

    def choose(self, rewards: np.ndarray,
               exploit_mask: Optional[np.ndarray] = None,
               headroom: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
        """(choices (B,), explored (B,) bool) for one score batch.

        ``rewards`` (B, K) are the predicted rewards at the effective
        lambda; ``exploit_mask`` (K,) False for probationary members;
        ``headroom`` in [0, 1] scales epsilon (1 = full exploration).
        """
        rewards = np.asarray(rewards, np.float64)
        b, k = rewards.shape
        if k != self.n_members:
            raise ValueError(f"rewards K={k} != tracked members "
                             f"{self.n_members}")
        biased = rewards + (self.config.bonus
                            / np.sqrt(self.counts + 1.0))[None, :]
        if exploit_mask is not None:
            biased = np.where(np.asarray(exploit_mask, bool)[None, :],
                              biased, -np.inf)
        choices = np.argmax(biased, axis=1)

        eps = self.config.epsilon * float(np.clip(headroom, 0.0, 1.0))
        explored = self.rng.random(b) < eps
        n_exp = int(explored.sum())
        if n_exp:
            choices = choices.copy()
            choices[explored] = self.rng.integers(k, size=n_exp)
        return choices.astype(np.int64), explored

    def record(self, members: np.ndarray) -> None:
        """Fold served members back into the observation counts."""
        np.add.at(self.counts, np.asarray(members, np.int64), 1)

    # -- hot pool membership -------------------------------------------------

    def add_member(self) -> None:
        self.counts = np.append(self.counts, 0)

    def remove_member(self, idx: int) -> None:
        self.counts = np.delete(self.counts, idx)
