"""Query-distribution drift detection over embedding population statistics.

The detector is fit on a reference sample of query embeddings (typically
the router's offline training split) and then watches the live stream in
fixed-size windows. Two statistics per window:

  * **mean shift** — L2 distance between the window mean embedding and the
    reference mean;
  * **dispersion** — mean distance-to-nearest-centroid, computed with the
    Pallas :func:`repro.kernels.ops.pairwise_l2` kernel against the
    k-means centroids that also back the model embeddings (batched
    distance-to-centroid is exactly that kernel's shape).

Both statistics are calibrated against a **bootstrap null**: ``fit``
resamples same-sized windows from the reference and records the null mean
and spread of each statistic. This matters in high dimension — the
expected shift of an in-distribution window is ``~sigma/sqrt(n)`` but its
*spread* around that expectation is far tighter, so an analytic
``sigma/sqrt(n)`` threshold would need the drifted mean to move further
than real embedding drift ever does. Alarms compare z-scores under the
empirical null instead.

``patience`` consecutive abnormal windows raise one alarm (then the
counter re-arms), so a single weird batch doesn't trigger an update burst
but a sustained excursion does. :meth:`refit` re-anchors the reference —
the adapter calls it after an adaptation burst so the detector "recovers"
and watches for the *next* shift instead of alarming forever.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.kernels import ops as kops


class DriftDetector:
    def __init__(self, *, window: int = 64, threshold: float = 4.0,
                 patience: int = 2, n_bootstrap: int = 64, seed: int = 0):
        if window < 2:
            raise ValueError("window must be >= 2")
        self.window = window
        self.threshold = threshold          # z-score under the bootstrap null
        self.patience = patience
        self.n_bootstrap = n_bootstrap
        self._boot_rng = np.random.default_rng(seed)

        self.ref_mean: Optional[np.ndarray] = None
        # Bootstrap null (mean, std) of each window statistic.
        self.null_shift = (0.0, 1.0)
        self.null_dispersion = (0.0, 1.0)
        self.centroids: Optional[np.ndarray] = None

        self._buf: List[np.ndarray] = []
        self._last_window: Optional[np.ndarray] = None
        self._abnormal_streak = 0
        self.alarms = 0
        self.windows_seen = 0
        self.last_stats: Dict[str, float] = {}
        # Alarm fan-out: callables invoked with the alarm time whenever an
        # alarm fires, regardless of which caller fed observe(). The
        # semantic cache registers its invalidation here so one detector
        # (the adapter's or its own) drives both adaptation and cache
        # invalidation without the callers coordinating.
        self.alarm_hooks: List = []

    @property
    def abnormal_streak(self) -> int:
        """Consecutive abnormal windows so far (alarm fires at patience).

        Public alarm-state readout for the metrics registry: 0 = nominal,
        >= 1 = an excursion is building toward an alarm.
        """
        return self._abnormal_streak

    # -- reference -----------------------------------------------------------

    def _dispersion(self, emb: np.ndarray) -> float:
        """Mean distance to the nearest centroid (Pallas pairwise-L2)."""
        d2 = np.asarray(kops.pairwise_l2(
            np.asarray(emb, np.float32), self.centroids))
        return float(np.sqrt(np.maximum(d2.min(axis=1), 0.0)).mean())

    def fit(self, ref_emb: np.ndarray,
            centroids: Optional[np.ndarray] = None) -> "DriftDetector":
        ref_emb = np.asarray(ref_emb, np.float32)
        self.ref_mean = ref_emb.mean(axis=0)
        self.centroids = (np.asarray(centroids, np.float32)
                          if centroids is not None else self.ref_mean[None])
        # Bootstrap null: statistics of in-distribution windows of the
        # deployed size. Window picks use a detector-owned seeded rng, so
        # fit/refit is deterministic.
        n = len(ref_emb)
        size = min(self.window, n)
        shifts, disps = [], []
        # All per-point distances once; window dispersion = mean over picks.
        d_point = np.sqrt(np.maximum(np.asarray(kops.pairwise_l2(
            ref_emb, self.centroids)).min(axis=1), 0.0))
        for _ in range(self.n_bootstrap):
            idx = self._boot_rng.integers(n, size=size)
            shifts.append(float(np.linalg.norm(
                ref_emb[idx].mean(axis=0) - self.ref_mean)))
            disps.append(float(d_point[idx].mean()))
        # Bootstrap windows measure shift against the ref mean of the SAME
        # sample, so they miss the ref mean's own error: an independent
        # window shifts by ~sigma*sqrt(1/size + 1/n), not sigma/sqrt(size).
        # Matters after refit(), when the reference is a single window.
        infl = float(np.sqrt(1.0 + size / max(n, 1)))
        self.null_shift = (float(np.mean(shifts)) * infl,
                           float(np.std(shifts)) * infl + 1e-12)
        self.null_dispersion = (float(np.mean(disps)),
                                float(np.std(disps)) * infl + 1e-12)
        return self

    def refit(self, emb: Optional[np.ndarray] = None) -> None:
        """Re-anchor the reference to the current regime (recovery).

        With no argument, uses the last completed window (the sample that
        raised the alarm — i.e. the post-shift regime) plus any buffered
        stragglers.
        """
        if emb is None:
            parts = ([self._last_window] if self._last_window is not None
                     else [])
            if self._buf:
                parts.append(np.stack(self._buf))
            if not parts:
                return
            emb = np.concatenate(parts, axis=0)
        self._buf.clear()
        self._abnormal_streak = 0
        self.fit(emb, self.centroids)

    # -- stream --------------------------------------------------------------

    def observe(self, q_emb: np.ndarray, now: float = 0.0) -> bool:
        """Feed a batch of query embeddings; True when an alarm fires."""
        if self.ref_mean is None:
            raise RuntimeError("DriftDetector.observe before fit()")
        q_emb = np.asarray(q_emb, np.float32)
        if q_emb.ndim == 1:
            q_emb = q_emb[None]
        self._buf.extend(q_emb)
        fired = False
        while len(self._buf) >= self.window:
            win = np.stack(self._buf[: self.window])
            del self._buf[: self.window]
            fired |= self._check_window(win, now)
        return fired

    def _check_window(self, win: np.ndarray, now: float) -> bool:
        self.windows_seen += 1
        self._last_window = win
        shift = float(np.linalg.norm(win.mean(axis=0) - self.ref_mean))
        dispersion = self._dispersion(win)
        shift_z = (shift - self.null_shift[0]) / self.null_shift[1]
        disp_z = ((dispersion - self.null_dispersion[0])
                  / self.null_dispersion[1])
        self.last_stats = {
            "now": now,
            "mean_shift": shift,
            "shift_z": shift_z,
            "dispersion": dispersion,
            "dispersion_z": disp_z,
        }
        abnormal = (shift_z > self.threshold
                    or abs(disp_z) > self.threshold)
        if not abnormal:
            self._abnormal_streak = 0
            return False
        self._abnormal_streak += 1
        if self._abnormal_streak >= self.patience:
            self._abnormal_streak = 0
            self.alarms += 1
            for hook in self.alarm_hooks:
                hook(now)
            return True
        return False
