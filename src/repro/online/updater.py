"""Incremental router updater: bounded Adam steps + atomic versioned swap.

Warm-starts from the live :class:`~repro.core.router.PredictiveRouter`'s
parameter trees and runs bounded masked-MSE Adam steps (the reusable
jit-compiled step from :mod:`repro.training.predictor_trainer`) on replay
batches. The live router's leaves are **never mutated** — every step
produces fresh trees, and :meth:`publish` hands the engine one fully-built
next-version router for a single-reference atomic swap. A scorer running
concurrently therefore sees either the complete old or the complete new
parameters, never a mix.

Cost targets go through the router's frozen offline scaler (the same
normalization the offline trainer used), so online and offline gradients
live on the same scale and ``denormalize_cost`` keeps working unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.training.optim import AdamConfig, adam_init
from repro.training.predictor_trainer import make_masked_predictor_step


@dataclasses.dataclass(frozen=True)
class OnlineUpdateConfig:
    batch_size: int = 64
    steps_per_update: int = 8    # bounded work per scheduled update
    burst_steps: int = 48        # drift alarm -> one concentrated burst
    update_every: int = 32       # outcomes between scheduled updates
    recent_frac: float = 0.5     # replay stratification for update batches
    lr_quality: float = 1e-3
    lr_cost: float = 1e-4
    weight_decay: float = 0.0
    min_buffer: int = 32         # don't update on near-empty replay


class IncrementalUpdater:
    def __init__(self, router, config: Optional[OnlineUpdateConfig] = None):
        self.config = config or OnlineUpdateConfig()
        self._q_opt = AdamConfig(lr=self.config.lr_quality,
                                 weight_decay=self.config.weight_decay)
        self._c_opt = AdamConfig(lr=self.config.lr_cost,
                                 weight_decay=self.config.weight_decay)
        self._q_step = make_masked_predictor_step(router.quality_kind,
                                                  self._q_opt)
        self._c_step = make_masked_predictor_step(router.cost_kind,
                                                  self._c_opt)
        self.total_steps = 0
        self.warm_start(router)

    def warm_start(self, router) -> None:
        """(Re)anchor on a router's current params; resets optimizer moments.

        Also the recovery path after hot pool mutation — param shapes
        changed, so stale Adam moments would be meaningless.
        """
        self.q_params = router.quality_params
        self.c_params = router.cost_params
        self.q_state = adam_init(self._q_opt, self.q_params)
        self.c_state = adam_init(self._c_opt, self.c_params)
        self._scaler = router.cost_scaler

    def run_steps(self, replay, model_emb: np.ndarray,
                  n_steps: int) -> Dict[str, float]:
        """Up to ``n_steps`` masked Adam steps on replay batches."""
        cfg = self.config
        losses_q, losses_c = [], []
        m = np.asarray(model_emb, np.float32)
        for _ in range(n_steps):
            batch = replay.sample(cfg.batch_size,
                                  recent_frac=cfg.recent_frac)
            if batch is None:
                break
            member = batch["member"]
            lq, self.q_params, self.q_state = self._q_step(
                self.q_params, self.q_state, batch["q_emb"], m,
                member, batch["s"])
            c_t = batch["c"]
            if self._scaler is not None:
                mu = np.asarray(self._scaler["mu"])
                sd = np.asarray(self._scaler["sd"])
                if mu.ndim == 1:
                    c_t = (c_t - mu[member]) / sd[member]
                else:
                    c_t = (c_t - mu) / sd
            lc, self.c_params, self.c_state = self._c_step(
                self.c_params, self.c_state, batch["q_emb"], m,
                member, np.asarray(c_t, np.float32))
            losses_q.append(float(lq))
            losses_c.append(float(lc))
            self.total_steps += 1
        return {
            "steps": len(losses_q),
            "quality_loss": float(np.mean(losses_q)) if losses_q else np.nan,
            "cost_loss": float(np.mean(losses_c)) if losses_c else np.nan,
        }

    def publish(self, engine,
                model_emb: Optional[np.ndarray] = None):
        """Build the next router version and atomically swap it live.

        ``model_emb`` is copied: callers (the membership tracker) keep
        mutating their staging array, and the published router must stay
        immutable — sharing the buffer would let record_outcome write into
        the live router behind the cached pool projections' back.
        """
        new_router = engine.router.with_updates(
            quality_params=self.q_params,
            cost_params=self.c_params,
            model_emb=(None if model_emb is None
                       else np.array(model_emb, copy=True)),
        )
        engine.swap_router(new_router)
        return new_router
