"""Online adaptation: the served router learns from the traffic it serves.

The offline pipeline trains the cross-attention router once on a static
RouterBench dump; this package closes the loop for the streaming runtime —
replay-buffered outcome feedback, bounded incremental updates published by
atomic versioned swap, drift detection over query-embedding statistics,
budget-aware exploration, and hot pool membership.

Layers: :mod:`replay` — reservoir + recency outcome buffer; :mod:`updater`
— warm-started masked Adam steps and router publishing; :mod:`drift` —
windowed mean-shift/dispersion alarms (Pallas pairwise-L2 distances);
:mod:`exploration` — epsilon-greedy + optimistic bonus at the scoring
step; :mod:`membership` — runtime add/remove with probation; :mod:`staging`
— delayed/out-of-order quality feedback staged until the real score lands;
:mod:`loop` — the :class:`OnlineAdapter` the scheduler drives.
"""
from repro.online.drift import DriftDetector
from repro.online.exploration import ExplorationConfig, ExplorationPolicy
from repro.online.loop import OnlineAdapter
from repro.online.membership import MembershipTracker
from repro.online.replay import ReplayBuffer
from repro.online.staging import DelayedFeedback, OutcomeStage
from repro.online.updater import IncrementalUpdater, OnlineUpdateConfig

__all__ = [
    "DelayedFeedback", "DriftDetector", "ExplorationConfig",
    "ExplorationPolicy", "IncrementalUpdater", "MembershipTracker",
    "OnlineAdapter", "OnlineUpdateConfig", "OutcomeStage", "ReplayBuffer",
]
