"""RouterBench-shaped dataset: synthetic generator + real-CSV loader.

RouterBench [arXiv:2403.12031] logs the responses of 11 LLMs on 8 benchmarks
(~1 response per model per prompt), with exact-match quality for
MMLU/GSM8K/HellaSwag/ARC-C/Winogrande and GPT-evaluated (normalized [0,1])
quality for MBPP/MT-Bench/RAG; costs follow API pricing.

The dataset itself is not redistributable/offline, so :func:`generate`
produces a deterministic synthetic benchmark with the same shape and the
properties the paper's analysis relies on:

  * most queries answerable by a cheap model, a hard tail needing GPT-4
    (the paper: "most answers an expensive model can answer, smaller models
    can too");
  * per-model skill profiles over latent domains; benchmarks are mixtures of
    domains; MMLU carries sub-domains (for the paper's domain-wise figures);
  * prompts are synthetic *text* whose wording encodes domain + difficulty,
    so the full pipeline (text -> hashed featurizer -> predictors) is
    exercised end-to-end, not short-circuited with oracle features.

``load_csv`` ingests the real RouterBench dump (long format: one row per
(prompt, model)) when available, producing the identical structure.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.featurizer import embed_texts

# ---------------------------------------------------------------------------
# Pool definitions (paper Appendix B) and API-pricing cost table ($/1M tok)
# ---------------------------------------------------------------------------

MODELS: List[str] = [
    "mistral-7b-chat",        # 0
    "mixtral-8x7b-chat",      # 1
    "wizardlm-13b",           # 2
    "codellama-34b-instruct", # 3
    "yi-34b-chat",            # 4
    "gpt-4",                  # 5
    "gpt-3.5-turbo",          # 6
    "claude-instant-v1",      # 7
    "claude-v1",              # 8
    "claude-v2",              # 9
    "llama-2-70b-chat",       # 10
]

# (input, output) $ per 1M tokens — TogetherAI for OSS, vendor API otherwise.
PRICES: Dict[str, Tuple[float, float]] = {
    "mistral-7b-chat": (0.20, 0.20),
    "mixtral-8x7b-chat": (0.60, 0.60),
    "wizardlm-13b": (0.30, 0.30),
    "codellama-34b-instruct": (0.78, 0.78),
    "yi-34b-chat": (0.80, 0.80),
    "gpt-4": (30.00, 60.00),
    "gpt-3.5-turbo": (1.00, 2.00),
    "claude-instant-v1": (0.80, 2.40),
    "claude-v1": (8.00, 24.00),
    "claude-v2": (8.00, 24.00),
    "llama-2-70b-chat": (0.90, 0.90),
}

POOLS: Dict[str, List[str]] = {
    # Paper Appendix B, name-for-name.
    "pool1": ["mistral-7b-chat", "wizardlm-13b", "mixtral-8x7b-chat",
              "codellama-34b-instruct", "gpt-4"],
    "pool2": ["wizardlm-13b", "codellama-34b-instruct", "yi-34b-chat",
              "claude-instant-v1", "claude-v2"],
    "pool3": ["mistral-7b-chat", "mixtral-8x7b-chat",
              "codellama-34b-instruct", "yi-34b-chat", "gpt-4"],
    "pool4": ["llama-2-70b-chat", "claude-v1", "claude-v2", "gpt-4"],
}

BENCHMARKS = ["mmlu", "gsm8k", "hellaswag", "arc-challenge", "winogrande",
              "mbpp", "mt-bench", "rag"]
BINARY_BENCHMARKS = {"mmlu", "gsm8k", "hellaswag", "arc-challenge", "winogrande"}

MMLU_DOMAINS = ["professional_law", "mathematics", "biology", "computer_science",
                "world_history", "philosophy"]

# Latent skill axes.
_SKILLS = ["reasoning", "math", "code", "knowledge", "commonsense",
           "reading", "instruction", "long_context"]
_NSK = len(_SKILLS)

# Benchmark -> skill mixture.
_BENCH_MIX = {
    "mmlu":          [0.3, 0.1, 0.0, 0.5, 0.0, 0.1, 0.0, 0.0],
    "gsm8k":         [0.4, 0.6, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    "hellaswag":     [0.1, 0.0, 0.0, 0.1, 0.7, 0.1, 0.0, 0.0],
    "arc-challenge": [0.4, 0.1, 0.0, 0.4, 0.1, 0.0, 0.0, 0.0],
    "winogrande":    [0.2, 0.0, 0.0, 0.0, 0.7, 0.1, 0.0, 0.0],
    "mbpp":          [0.2, 0.1, 0.7, 0.0, 0.0, 0.0, 0.0, 0.0],
    "mt-bench":      [0.2, 0.0, 0.1, 0.2, 0.1, 0.1, 0.3, 0.0],
    "rag":           [0.1, 0.0, 0.0, 0.2, 0.0, 0.4, 0.1, 0.2],
}

_MMLU_DOMAIN_MIX = {
    "professional_law":  [0.5, 0.0, 0.0, 0.3, 0.0, 0.2, 0.0, 0.0],
    "mathematics":       [0.3, 0.7, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    "biology":           [0.2, 0.0, 0.0, 0.7, 0.0, 0.1, 0.0, 0.0],
    "computer_science":  [0.2, 0.1, 0.5, 0.2, 0.0, 0.0, 0.0, 0.0],
    "world_history":     [0.1, 0.0, 0.0, 0.8, 0.0, 0.1, 0.0, 0.0],
    "philosophy":        [0.4, 0.0, 0.0, 0.3, 0.0, 0.3, 0.0, 0.0],
}

# Model -> (overall strength, per-skill profile). Strength is a logit offset;
# profiles are multiplied into the benchmark mixture. Loosely calibrated to
# RouterBench's published orderings (gpt-4 strongest, codellama strong on
# code, yi/mixtral mid-field, 7B/13B weakest).
# Calibrated so the four pools' ORACLE statistics track the paper's Table 1
# (AIQ ~0.86-0.89, max-calls-to-GPT-4 ~12-25%, GPT-4 mean ~0.85).
_MODEL_STRENGTH = {
    "mistral-7b-chat":        (-0.35, [0.6, 0.4, 0.5, 0.6, 0.8, 0.7, 0.7, 0.4]),
    "mixtral-8x7b-chat":      (0.70,  [0.8, 0.7, 0.7, 0.8, 0.9, 0.8, 0.8, 0.6]),
    "wizardlm-13b":           (-0.15, [0.7, 0.5, 0.5, 0.6, 0.8, 0.7, 0.8, 0.4]),
    "codellama-34b-instruct": (0.05,  [0.6, 0.6, 1.1, 0.5, 0.6, 0.6, 0.6, 0.5]),
    "yi-34b-chat":            (0.70,  [0.8, 0.6, 0.6, 0.9, 0.9, 0.8, 0.8, 0.6]),
    "gpt-4":                  (1.15,  [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]),
    "gpt-3.5-turbo":          (0.75,  [0.8, 0.7, 0.8, 0.8, 0.9, 0.8, 0.9, 0.6]),
    "claude-instant-v1":      (0.55,  [0.8, 0.6, 0.6, 0.8, 0.8, 0.8, 0.8, 0.7]),
    "claude-v1":              (0.85,  [0.9, 0.8, 0.7, 0.9, 0.9, 0.9, 0.9, 0.8]),
    "claude-v2":              (0.95,  [0.9, 0.8, 0.8, 0.9, 0.9, 0.9, 0.9, 0.9]),
    "llama-2-70b-chat":       (0.55,  [0.8, 0.6, 0.6, 0.8, 0.9, 0.8, 0.8, 0.6]),
}

# Vocabulary per skill axis for synthetic prompt text (the featurizer sees
# only text — this is how the latent signal reaches the embeddings).
_SKILL_WORDS = {
    "reasoning": ["deduce", "therefore", "premise", "logic", "infer", "syllogism",
                  "contradiction", "entail", "proof", "consistent"],
    "math": ["integral", "equation", "algebra", "numerator", "polynomial",
             "arithmetic", "fraction", "derivative", "modulo", "quotient"],
    "code": ["function", "compile", "python", "variable", "recursion", "loop",
             "array", "debug", "syntax", "algorithm"],
    "knowledge": ["history", "capital", "discovered", "century", "theory",
                  "empire", "element", "biology", "constitution", "treaty"],
    "commonsense": ["kitchen", "umbrella", "breakfast", "neighbor", "holiday",
                    "weather", "grocery", "garden", "traffic", "weekend"],
    "reading": ["passage", "paragraph", "author", "summarize", "context",
                "excerpt", "narrator", "tone", "quote", "article"],
    "instruction": ["please", "rewrite", "steps", "format", "bullet", "draft",
                    "polite", "email", "explain", "concise"],
    "long_context": ["document", "archive", "transcript", "chapter", "appendix",
                     "ledger", "catalogue", "minutes", "volume", "registry"],
}

_DIFFICULTY_WORDS = [
    ["simple", "basic", "easy", "quick"],
    ["standard", "typical", "common", "regular"],
    ["tricky", "subtle", "layered", "detailed"],
    ["hard", "complex", "advanced", "intricate"],
    ["expert", "formidable", "exhaustive", "labyrinthine"],
]


@dataclasses.dataclass
class RouterBenchData:
    texts: List[str]
    emb: np.ndarray               # (N, 768)
    benchmark: np.ndarray         # (N,) str
    domain: np.ndarray            # (N,) str (mmlu sub-domain or == benchmark)
    quality: np.ndarray           # (N, K) in [0, 1]
    cost: np.ndarray              # (N, K) $ per query
    model_names: List[str]

    def split(self, train=0.75, val=0.05, seed: int = 0):
        """75/5/20 split (paper §5), stratified-free random permutation."""
        n = len(self.texts)
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n)
        n_tr, n_val = int(train * n), int(val * n)
        return perm[:n_tr], perm[n_tr : n_tr + n_val], perm[n_tr + n_val :]

    def subset_models(self, names: Sequence[str]) -> "RouterBenchData":
        idx = [self.model_names.index(m) for m in names]
        return dataclasses.replace(
            self,
            quality=self.quality[:, idx],
            cost=self.cost[:, idx],
            model_names=list(names),
        )

    def pool(self, pool_name: str) -> "RouterBenchData":
        return self.subset_models(POOLS[pool_name])

    def select(self, mask: np.ndarray) -> "RouterBenchData":
        idx = np.flatnonzero(mask)
        return dataclasses.replace(
            self,
            texts=[self.texts[i] for i in idx],
            emb=self.emb[idx],
            benchmark=self.benchmark[idx],
            domain=self.domain[idx],
            quality=self.quality[idx],
            cost=self.cost[idx],
        )


def _sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def _make_prompt(rng, bench: str, mix: np.ndarray, difficulty: float) -> str:
    words = [bench.replace("-", " ")]
    n_words = 12
    for _ in range(n_words):
        skill = rng.choice(_NSK, p=mix)
        words.append(rng.choice(_SKILL_WORDS[_SKILLS[skill]]))
    tier = int(np.clip(difficulty * len(_DIFFICULTY_WORDS), 0,
                       len(_DIFFICULTY_WORDS) - 1))
    words.append(rng.choice(_DIFFICULTY_WORDS[tier]))
    words.append(rng.choice(_DIFFICULTY_WORDS[tier]))
    rng.shuffle(words)
    return " ".join(words)


def generate(
    n_queries: int = 4000, *, seed: int = 0, embed: bool = True
) -> RouterBenchData:
    """Deterministic synthetic RouterBench. ~even benchmark coverage."""
    rng = np.random.default_rng(seed)
    texts, benches, domains, mixes, diffs = [], [], [], [], []
    for _ in range(n_queries):
        bench = BENCHMARKS[rng.integers(len(BENCHMARKS))]
        if bench == "mmlu":
            dom = MMLU_DOMAINS[rng.integers(len(MMLU_DOMAINS))]
            mix = np.asarray(_MMLU_DOMAIN_MIX[dom], np.float64)
        else:
            dom = bench
            mix = np.asarray(_BENCH_MIX[bench], np.float64)
        mix = mix + 0.02
        mix = mix / mix.sum()
        difficulty = float(np.clip(rng.beta(2.0, 2.6), 0.0, 1.0))
        texts.append(_make_prompt(rng, bench, mix, difficulty))
        benches.append(bench)
        domains.append(dom)
        mixes.append(mix)
        diffs.append(difficulty)

    mixes = np.stack(mixes)                     # (N, S)
    diffs = np.asarray(diffs)                   # (N,)

    k = len(MODELS)
    quality = np.zeros((n_queries, k), np.float32)
    cost = np.zeros((n_queries, k), np.float32)
    len_in = rng.integers(120, 900, size=n_queries)          # prompt tokens

    for mi, name in enumerate(MODELS):
        strength, profile = _MODEL_STRENGTH[name]
        profile = np.asarray(profile, np.float64)
        skill_match = mixes @ profile                        # (N,)
        logit = 1.2 * strength + 2.6 * skill_match - 6.0 * diffs + 0.6
        p = _sigmoid(logit)
        for qi in range(n_queries):
            bench = benches[qi]
            if bench in BINARY_BENCHMARKS:
                quality[qi, mi] = float(rng.random() < p[qi])
            else:
                # GPT-evaluated scores are coarse (MT-Bench: 1-10 scale
                # normalized) — quantize to 0.1 so ties exist and the oracle
                # can prefer the cheaper model, as in real RouterBench.
                raw = np.clip(p[qi] + rng.normal(0, 0.20), 0.0, 1.0)
                quality[qi, mi] = float(np.round(raw * 10.0) / 10.0)
        p_in, p_out = PRICES[name]
        len_out = rng.integers(80, 600, size=n_queries)
        cost[:, mi] = (p_in * len_in + p_out * len_out) / 1e6

    emb = embed_texts(texts) if embed else np.zeros((n_queries, 768), np.float32)
    return RouterBenchData(
        texts=texts,
        emb=emb,
        benchmark=np.asarray(benches),
        domain=np.asarray(domains),
        quality=quality,
        cost=cost,
        model_names=list(MODELS),
    )


def load_csv(path: str, model_names: Optional[List[str]] = None) -> RouterBenchData:
    """Load a real RouterBench dump (long CSV:
    prompt,benchmark,domain,model,quality,cost). Rows for the same prompt are
    merged across models; prompts missing any pool member are dropped."""
    import csv
    from collections import defaultdict

    rows = defaultdict(dict)
    meta = {}
    with open(path, newline="") as f:
        for r in csv.DictReader(f):
            key = r["prompt"]
            rows[key][r["model"]] = (float(r["quality"]), float(r["cost"]))
            meta[key] = (r.get("benchmark", "unknown"), r.get("domain", "unknown"))
    names = model_names or sorted({m for d in rows.values() for m in d})
    texts, bench, dom, qual, cost = [], [], [], [], []
    for prompt, per_model in rows.items():
        if not all(m in per_model for m in names):
            continue
        texts.append(prompt)
        b, d = meta[prompt]
        bench.append(b)
        dom.append(d)
        qual.append([per_model[m][0] for m in names])
        cost.append([per_model[m][1] for m in names])
    return RouterBenchData(
        texts=texts,
        emb=embed_texts(texts),
        benchmark=np.asarray(bench),
        domain=np.asarray(dom),
        quality=np.asarray(qual, np.float32),
        cost=np.asarray(cost, np.float32),
        model_names=names,
    )
