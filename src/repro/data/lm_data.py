"""Synthetic LM token pipeline for the end-to-end training example.

A seeded order-1 Markov chain over the vocabulary with a low-entropy
transition structure: real learning signal (loss drops well below uniform)
without any external corpus. Order 1 keeps the context space (= vocab_size)
small enough that a few hundred small-batch steps see every context dozens
of times — an order-2 chain over a 4k vocab has 16.7M contexts and is
unlearnable at example scale. Batches stream deterministically.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


class MarkovCorpus:
    def __init__(self, vocab_size: int, *, seed: int = 0, branching: int = 4):
        self.vocab_size = vocab_size
        rng = np.random.default_rng(seed)
        # Each prev-token context prefers `branching` successor tokens.
        self._ctx_seed = int(rng.integers(1 << 31))
        self.branching = branching

    def _successors(self, a: int, b: int) -> np.ndarray:
        h = (b * 9176 + self._ctx_seed) % (1 << 31)
        rng = np.random.default_rng(h)
        return rng.integers(0, self.vocab_size, size=self.branching)

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty((length,), np.int32)
        a, b = rng.integers(self.vocab_size), rng.integers(self.vocab_size)
        for i in range(length):
            succ = self._successors(int(a), int(b))
            # 90% follow structure, 10% noise.
            if rng.random() < 0.9:
                nxt = succ[rng.integers(self.branching)]
            else:
                nxt = rng.integers(self.vocab_size)
            out[i] = nxt
            a, b = b, nxt
        return out

    def batches(
        self, batch: int, seq_len: int, *, seed: int = 0
    ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yields (tokens, labels) of shape (batch, seq_len) forever."""
        rng = np.random.default_rng(seed)
        while True:
            seqs = np.stack([self.sample(rng, seq_len + 1) for _ in range(batch)])
            yield seqs[:, :-1], seqs[:, 1:]

    def padded_batches(
        self, batch: int, seq_len: int, *, min_len: int = None,
        seed: int = 0, pad_id: int = 0
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Variable-length batches, left-padded: (tokens, labels, mask).

        Per-row lengths are uniform over [min_len, seq_len] (min_len
        defaults to ``seq_len // 4``, floored at 2); rows are left-padded
        to the fixed ``seq_len`` so jitted train steps see one static
        shape — the same convention the serving engine's ``pad_prompts``
        uses for generate micro-batches. ``mask`` (batch, seq_len) bool is
        True at real positions; tokens/labels under pads are ``pad_id``
        and MUST be excluded through ``lm_loss(attn_mask=mask)`` (which
        also drives the MoE pad-aware capacity accounting). Streams
        deterministically under a fixed seed.
        """
        min_len = max(2, seq_len // 4) if min_len is None else min_len
        if not 1 <= min_len <= seq_len:
            raise ValueError(f"min_len {min_len} not in [1, {seq_len}]")
        rng = np.random.default_rng(seed)
        while True:
            toks = np.full((batch, seq_len), pad_id, np.int32)
            labs = np.full((batch, seq_len), pad_id, np.int32)
            mask = np.zeros((batch, seq_len), bool)
            lens = rng.integers(min_len, seq_len + 1, size=batch)
            for i, length in enumerate(lens):
                seq = self.sample(rng, int(length) + 1)
                toks[i, seq_len - length:] = seq[:-1]
                labs[i, seq_len - length:] = seq[1:]
                mask[i, seq_len - length:] = True
            yield toks, labs, mask
