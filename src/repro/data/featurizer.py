"""Prompt embedding frontend (DistilBERT stand-in — the carve-out stub).

The paper encodes prompts with DistilBERT into 768-d, L2-normalized vectors.
DistilBERT is not available offline, so this module provides a deterministic
hashed-character-n-gram embedder:

  1. extract character 3..5-grams,
  2. hash each n-gram to one of ``n_buckets`` (blake2, stable across runs),
  3. bucket counts -> a fixed seeded Gaussian random projection to 768-d,
  4. L2 normalize (the paper normalizes too).

Semantically weaker than DistilBERT, but: deterministic, offline, and it
preserves the *structure* the routing experiments need (similar prompts map
to nearby embeddings). The synthetic RouterBench generator additionally
plants its latent domain signal in designated embedding directions so the
learnability of query->quality relations matches the benchmark's character.
"""
from __future__ import annotations

import hashlib
from typing import List, Sequence

import numpy as np

EMB_DIM = 768
N_BUCKETS = 4096
_PROJ_SEED = 1234567


def _ngrams(text: str, lo: int = 3, hi: int = 5) -> List[str]:
    t = f"^{text.lower()}$"
    out = []
    for n in range(lo, hi + 1):
        out.extend(t[i : i + n] for i in range(max(0, len(t) - n + 1)))
    return out


def _bucket(ngram: str) -> int:
    h = hashlib.blake2s(ngram.encode("utf-8"), digest_size=4).digest()
    return int.from_bytes(h, "little") % N_BUCKETS


_PROJECTION = None


def _projection() -> np.ndarray:
    global _PROJECTION
    if _PROJECTION is None:
        rng = np.random.default_rng(_PROJ_SEED)
        _PROJECTION = rng.standard_normal((N_BUCKETS, EMB_DIM)).astype(
            np.float32
        ) / np.sqrt(EMB_DIM)
    return _PROJECTION


def embed_text(text: str) -> np.ndarray:
    """One prompt -> (768,) unit-norm embedding. Deterministic."""
    counts = np.zeros((N_BUCKETS,), dtype=np.float32)
    for g in _ngrams(text):
        counts[_bucket(g)] += 1.0
    if counts.sum() > 0:
        counts = np.log1p(counts)
    v = counts @ _projection()
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def embed_texts(texts: Sequence[str]) -> np.ndarray:
    return np.stack([embed_text(t) for t in texts])
