"""Data pipeline: synthetic RouterBench, embedding frontend, LM token streams."""
from repro.data.featurizer import EMB_DIM, embed_text, embed_texts
from repro.data.routerbench import (
    BENCHMARKS,
    MODELS,
    POOLS,
    PRICES,
    RouterBenchData,
    generate,
    load_csv,
)

__all__ = [
    "EMB_DIM", "embed_text", "embed_texts", "BENCHMARKS", "MODELS", "POOLS",
    "PRICES", "RouterBenchData", "generate", "load_csv",
]
