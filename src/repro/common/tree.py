"""Pytree helpers used by the param/optimizer/checkpoint layers.

These are deliberately dependency-free (no flax/optax offline): parameter
trees throughout the framework are plain nested dicts/tuples of jax arrays.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def tree_stack(trees: Sequence[Any]) -> Any:
    """Stack a sequence of identically-structured pytrees along a new axis 0.

    Used to build the scanned parameter stacks for repeated layer patterns.
    """
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Any, n: int) -> List[Any]:
    """Inverse of :func:`tree_stack` for a known leading length ``n``."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def _path_str(path: Tuple[Any, ...]) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:  # pragma: no cover - future key types
            parts.append(str(p))
    return "/".join(parts)


def flatten_with_paths(tree: Any) -> Dict[str, Any]:
    """Flatten a pytree into ``{"a/b/0/c": leaf}`` form (checkpoint format)."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(path): leaf for path, leaf in leaves}


def unflatten_from_paths(tree_like: Any, flat: Dict[str, Any]) -> Any:
    """Rebuild a pytree with the structure of ``tree_like`` from a flat dict."""
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for path, old_leaf in leaves_with_paths:
        key = _path_str(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing parameter {key!r}")
        leaf = flat[key]
        if tuple(np.shape(leaf)) != tuple(np.shape(old_leaf)):
            raise ValueError(
                f"shape mismatch for {key!r}: checkpoint {np.shape(leaf)} vs "
                f"model {np.shape(old_leaf)}"
            )
        new_leaves.append(jnp.asarray(leaf))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def tree_count(tree: Any) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree (works on ShapeDtypeStructs too)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_paths(tree: Any) -> List[str]:
    """All leaf paths of a pytree as strings."""
    return list(flatten_with_paths(tree).keys())
