"""Mixed-precision policy.

TPU v5e peaks at 197 TFLOP/s in bf16 — the production policy keeps parameters
and activations in bf16 with fp32 softmax/normalizer accumulations and fp32
optimizer moments. The CPU test policy runs everything fp32 so pytest
tolerances stay tight.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    # Accumulations (softmax denominators, scan carries, losses) always fp32.
    accum_dtype: jnp.dtype = jnp.float32

    def cast_param(self, x):
        return x.astype(self.param_dtype)

    def cast_compute(self, x):
        return x.astype(self.compute_dtype)


# CPU-test default: full fp32.
DEFAULT_POLICY = DTypePolicy()

# Production TPU policy used by the dry-run: bf16 params + compute.
BF16_POLICY = DTypePolicy(
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16, accum_dtype=jnp.float32
)
