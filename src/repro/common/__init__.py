"""Common utilities shared across the repro framework."""
from repro.common.tree import (
    tree_stack,
    tree_unstack,
    flatten_with_paths,
    unflatten_from_paths,
    tree_bytes,
    tree_count,
)
from repro.common.dtypes import DTypePolicy, DEFAULT_POLICY

__all__ = [
    "tree_stack",
    "tree_unstack",
    "flatten_with_paths",
    "unflatten_from_paths",
    "tree_bytes",
    "tree_count",
    "DTypePolicy",
    "DEFAULT_POLICY",
]
