"""Kernel profiling hooks: wall-clock timing (and optional jax profiler
trace-context) around the Pallas kernel entry points.

:mod:`repro.kernels.ops` exposes :func:`repro.kernels.ops.set_kernel_profiler`;
installing a :class:`KernelProfiler` there makes every
``router_xattn_pool`` / ``pairwise_l2`` dispatch

  * land in a per-kernel log-bucketed latency :class:`Histogram`
    (µs per call, plus call/element counters), and
  * optionally emit a per-batch ``cat="kernel"`` span into a
    :class:`~repro.obs.trace.TraceRecorder`.

Kernel spans are the one place the trace touches the wall clock, so they
live in :data:`~repro.obs.trace.WALL_CATS` and are excluded from the
deterministic export — replay bit-identity is unaffected. Timestamps are
wall seconds relative to profiler construction (device work is *not*
synchronized here; a span measures dispatch + any blocking the caller
already does, which is exactly the cost the serving hot path sees).

When ``use_jax_profiler=True`` each dispatch also runs under
``jax.profiler.TraceAnnotation`` so the spans line up with XLA's own
timeline in a ``jax.profiler.trace`` capture; the wall-clock path is the
fallback that always works.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Optional

from repro.serving.telemetry import Histogram

try:  # pragma: no cover - availability depends on the jax build
    from jax.profiler import TraceAnnotation as _JaxAnnotation
except Exception:  # pragma: no cover
    _JaxAnnotation = None


class KernelProfiler:
    """Collects per-kernel dispatch timings; optionally feeds a tracer."""

    def __init__(self, tracer=None, use_jax_profiler: bool = False):
        self.tracer = tracer
        self.use_jax_profiler = use_jax_profiler and _JaxAnnotation is not None
        self.hists: Dict[str, Histogram] = {}
        self.calls: Dict[str, int] = {}
        self.elements: Dict[str, int] = {}
        self._t0 = time.perf_counter()

    @contextmanager
    def annotate(self, name: str, batch: Optional[int] = None):
        """Time one kernel dispatch (``with profiler.annotate("pairwise_l2",
        batch=B):``)."""
        ann = _JaxAnnotation(name) if self.use_jax_profiler else None
        if ann is not None:
            ann.__enter__()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            if ann is not None:
                ann.__exit__(None, None, None)
            self._record(name, t0, t1, batch)

    def _record(self, name, t0, t1, batch):
        us = (t1 - t0) * 1e6
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram()
            self.calls[name] = 0
            self.elements[name] = 0
        h.record(us)
        self.calls[name] += 1
        if batch is not None:
            self.elements[name] += int(batch)
        if self.tracer is not None:
            args = {"us": round(us, 3)}
            if batch is not None:
                args["batch"] = int(batch)
            self.tracer.span(f"kernel:{name}", "kernel",
                             t0 - self._t0, t1 - self._t0, args=args)

    # -- reporting -----------------------------------------------------------

    def summary(self) -> Dict[str, Dict]:
        out = {}
        for name in sorted(self.hists):
            h = self.hists[name]
            out[name] = {
                "calls": self.calls[name],
                "elements": self.elements[name],
                "p50_us": h.percentile(50),
                "p99_us": h.percentile(99),
                "total_ms": h.total / 1e3,
            }
        return out

    def register_metrics(self, registry, prefix: str = "kernel") -> None:
        """Expose per-kernel series on a MetricsRegistry (all wall-clock)."""
        for name in sorted(self.hists):
            labels = (("op", name),)
            registry.counter(f"{prefix}_calls_total", "kernel dispatches",
                             labels=labels, wall=True,
                             fn=lambda n=name: self.calls[n])
            registry.counter(f"{prefix}_elements_total",
                             "rows processed by kernel dispatches",
                             labels=labels, wall=True,
                             fn=lambda n=name: self.elements[n])
            registry.histogram(f"{prefix}_latency_us",
                               "kernel dispatch wall latency (us)",
                               labels=labels, wall=True,
                               fn=lambda n=name: self.hists[n])

    def report(self) -> str:
        lines = ["kernel profile:"]
        for name, s in self.summary().items():
            lines.append(
                f"  {name:<20s} calls {s['calls']:>6d}  rows "
                f"{s['elements']:>8d}  p50 {s['p50_us']:>9.1f}us  "
                f"p99 {s['p99_us']:>9.1f}us  total {s['total_ms']:.1f}ms")
        if len(lines) == 1:
            lines.append("  (no kernel dispatches recorded)")
        return "\n".join(lines)
