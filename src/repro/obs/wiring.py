"""Standard metric registrations for the serving subsystems.

Every function here registers *callback* metrics: the registry holds
closures over the live objects and reads them at export time, so the
serving hot path pays nothing for being observable. The series names are
the stable external contract (``launch/serve.py --metrics-out``, the CI
smoke artifacts, dashboards) — keep them append-only.

Solo runs call :func:`register_scheduler_metrics` (+
:func:`register_governor_metrics` when a governor exists); the
multi-worker plane calls :func:`register_plane_metrics`, which labels
per-worker series with ``worker=<wid>`` and registers the shared ledger
and coordinator exactly once.
"""
from __future__ import annotations

import math

from repro.obs.metrics import MetricsRegistry


def register_governor_metrics(reg: MetricsRegistry, governor, clock_fn,
                              labels=()) -> None:
    """Budget governor / shared ledger series. ``clock_fn() -> now`` supplies
    the virtual time the rolling window is evaluated at."""
    reg.gauge("budget_lam", "effective willingness-to-pay", labels=labels,
              fn=lambda: governor.lam)
    reg.gauge("budget_headroom", "budget slack in [0,1]", labels=labels,
              fn=lambda: governor.headroom(clock_fn()))
    reg.gauge("budget_utilization", "window spend / budget", labels=labels,
              fn=lambda: governor.utilization(clock_fn()))
    reg.counter("budget_total_spend", "cumulative $ spent", labels=labels,
                fn=lambda: governor.total_spend)
    reg.counter("budget_tightened_total", "lambda tighten steps",
                labels=labels, fn=lambda: governor.tightened)
    reg.counter("budget_relaxed_total", "lambda relax steps", labels=labels,
                fn=lambda: governor.relaxed)
    throttled = getattr(governor, "throttled", None)
    if throttled is not None:
        reg.counter("budget_throttled_total",
                    "ledger updates skipped by the throttle", labels=labels,
                    fn=lambda: governor.throttled)


def register_scheduler_metrics(reg: MetricsRegistry, sched,
                               labels=()) -> None:
    """Queue / telemetry / engine / adapter / cascade series of one
    scheduler (one worker). The governor is NOT registered here — it may
    be shared across workers (see :func:`register_plane_metrics`)."""
    queue, tel, engine = sched.queue, sched.telemetry, sched.engine
    clock_fn = lambda: sched.clock.now

    reg.gauge("queue_depth", "requests waiting for dispatch", labels=labels,
              fn=lambda: queue.depth)
    reg.counter("queue_admitted_total", "admissions", labels=labels,
                fn=lambda: queue.admitted)
    reg.counter("queue_rejected_total", "backpressure rejections",
                labels=labels, fn=lambda: queue.rejected)
    reg.counter("queue_expired_total", "deadline expiries", labels=labels,
                fn=lambda: queue.expired)
    reg.counter("queue_readmitted_total", "cascade re-admissions",
                labels=labels, fn=lambda: queue.readmitted)
    reg.counter("queue_shed_total", "SLO-class load-shedding drops",
                labels=labels, fn=lambda: queue.shed)

    reg.counter("requests_completed_total", "finalized requests",
                labels=labels, fn=lambda: tel.completed)
    reg.counter("score_batches_total", "router scoring rounds", labels=labels,
                fn=lambda: tel.score_batches)
    reg.counter("generate_calls_total", "generate micro-batches",
                labels=labels, fn=lambda: tel.generate_calls)
    reg.counter("spend_total", "cumulative $ across members", labels=labels,
                fn=lambda: tel.total_spend)
    reg.multi_gauge("member_served", "requests served per pool member",
                    "member", labels=labels,
                    fn=lambda: dict(zip(tel.member_names,
                                        (int(c) for c in tel.member_counts))))
    reg.histogram("queue_wait_s", "admission -> service (virtual s)",
                  labels=labels, fn=lambda: tel.queue_wait)
    reg.histogram("e2e_latency_s", "arrival -> finish (virtual s)",
                  labels=labels, fn=lambda: tel.e2e_latency)
    # Routing latency is measured wall time -> excluded from the
    # deterministic snapshot.
    reg.histogram("routing_latency_s", "score-batch wall latency",
                  labels=labels, wall=True, fn=lambda: tel.routing_latency)

    # Stub engines in tests/smokes may have no versioned router.
    if getattr(engine, "router", None) is not None:
        reg.gauge("router_version", "live router version on this engine",
                  labels=labels,
                  fn=lambda: getattr(engine.router, "version", 0))

    adapter = sched.adapter
    if adapter is not None:
        reg.counter("online_outcomes_total", "outcomes folded into replay",
                    labels=labels, fn=lambda: adapter.stats["outcomes"])
        reg.counter("online_explored_total", "exploration overrides",
                    labels=labels, fn=lambda: adapter.stats["explored"])
        reg.counter("online_router_swaps_total", "router publishes",
                    labels=labels, fn=lambda: adapter.stats["router_swaps"])
        reg.gauge("exploration_epsilon",
                  "effective epsilon (headroom-annealed)", labels=labels,
                  fn=lambda: adapter.policy.config.epsilon
                  * min(max(adapter.headroom(clock_fn()), 0.0), 1.0))
        if adapter.drift is not None:
            drift = adapter.drift
            reg.counter("drift_alarms_total", "drift alarms raised",
                        labels=labels, fn=lambda: drift.alarms)
            reg.gauge("drift_abnormal_streak",
                      "consecutive abnormal windows (alarm at patience)",
                      labels=labels, fn=lambda: drift.abnormal_streak)
            reg.gauge("drift_shift_z", "last window mean-shift z-score",
                      labels=labels,
                      fn=lambda: drift.last_stats.get("shift_z", math.nan))

    cascade = sched.cascade
    if cascade is not None:
        reg.counter("cascade_legs_total", "completed cascade legs",
                    labels=labels, fn=lambda: cascade.stats["legs"])
        reg.counter("cascade_escalations_total", "escalation decisions",
                    labels=labels, fn=lambda: cascade.stats["escalations"])
        reg.counter("cascade_headroom_blocked_total",
                    "escalations suppressed by the budget gate",
                    labels=labels,
                    fn=lambda: cascade.stats["headroom_blocked"])
        reg.gauge("cascade_escalation_rate", "escalations per finalized",
                  labels=labels, fn=lambda: cascade.escalation_rate)
        # Escalation rate by rung: escalations out of leg n / legs served
        # at leg n (the tail rung never escalates by construction).
        def _by_leg():
            esc = cascade.escalations_by_leg
            return {
                str(i + 1): ((esc[i] if i < len(esc) else 0) / served
                             if served else 0.0)
                for i, served in enumerate(sched.telemetry.leg_served)
            }
        reg.multi_gauge(
            "cascade_escalation_rate_by_leg",
            "P(escalate | completed leg n)", "leg", labels=labels,
            fn=_by_leg)

    semcache = getattr(sched, "semcache", None)
    if semcache is not None:
        reg.counter("semcache_hits_total", "cache answers served (rung 0)",
                    labels=labels, fn=lambda: semcache.stats["served"])
        reg.counter("semcache_misses_total", "lookups with no usable entry",
                    labels=labels, fn=lambda: semcache.stats["misses"])
        reg.counter("semcache_fallthroughs_total",
                    "hits the rung-0 policy escalated past",
                    labels=labels,
                    fn=lambda: semcache.stats["fallthroughs"])
        reg.counter("semcache_stale_hits_total",
                    "hits on drift-invalidated entries (never served)",
                    labels=labels,
                    fn=lambda: semcache.stats["stale_hits"])
        reg.counter("semcache_invalidations_total",
                    "entries invalidated by drift alarms", labels=labels,
                    fn=lambda: semcache.stats["invalidations"])
        reg.counter("semcache_evictions_total", "LRU evictions at capacity",
                    labels=labels, fn=lambda: semcache.stats["evicted"])
        reg.gauge("semcache_entries", "live cache entries", labels=labels,
                  fn=lambda: len(semcache))
        reg.gauge("semcache_hit_rate", "served / lookups", labels=labels,
                  fn=lambda: semcache.report()["hit_rate"])


def register_transport_metrics(reg: MetricsRegistry, transport,
                               labels=()) -> None:
    """RPC telemetry of one transport endpoint.

    Handles both stats shapes: an :class:`~repro.distributed.transport.
    RpcStats` (LocalTransport / SocketTransport) registers the ``rpc_*``
    series — per-kind/per-peer request counts, frame bytes, failure
    counters, in-flight gauge, and the wall-measured round-trip latency
    histogram; a plain fault-injection dict
    (:class:`~repro.distributed.transport.FaultyTransport`) registers
    each counter as ``transport_fault_<key>_total`` and recurses into the
    wrapped inner transport.
    """
    from repro.distributed.transport import RpcStats

    s = getattr(transport, "stats", None)
    if isinstance(s, dict):
        for k in sorted(s):
            reg.counter(f"transport_fault_{k}_total",
                        f"fault-injection events: {k}", labels=labels,
                        fn=lambda k=k: transport.stats.get(k, 0))
        inner = getattr(transport, "inner", None)
        if inner is not None:
            register_transport_metrics(reg, inner, labels=labels)
        return
    if not isinstance(s, RpcStats):
        return
    reg.multi_gauge("rpc_requests", "completed RPCs by message kind",
                    "kind", labels=labels, fn=lambda: dict(s.requests))
    reg.multi_gauge("rpc_peer_requests", "completed RPCs by peer wid",
                    "peer", labels=labels, fn=lambda: dict(s.peer_requests))
    reg.multi_gauge("rpc_bytes_out", "frame bytes sent by peer wid",
                    "peer", labels=labels, fn=lambda: dict(s.bytes_out))
    reg.multi_gauge("rpc_bytes_in", "frame bytes received by peer wid",
                    "peer", labels=labels, fn=lambda: dict(s.bytes_in))
    reg.counter("rpc_retries_total", "connect re-dials", labels=labels,
                fn=lambda: s.retries)
    reg.counter("rpc_timeouts_total", "request deadline misses",
                labels=labels, fn=lambda: s.timeouts)
    reg.counter("rpc_unreachable_total", "sends to unreachable peers",
                labels=labels, fn=lambda: s.unreachable)
    reg.counter("rpc_errors_total", "remote handler failures (ERROR replies)",
                labels=labels, fn=lambda: s.errors)
    reg.gauge("rpc_in_flight", "requests awaiting a reply", labels=labels,
              fn=lambda: s.in_flight)
    reg.histogram("rpc_latency_s", "RPC round-trip wall latency (all kinds)",
                  labels=labels, wall=True, fn=s.merged_latency)


def register_slo_metrics(reg: MetricsRegistry, tracker, clock_fn,
                         labels=()) -> None:
    """Burn-rate / firing-state series of an :class:`SLOTracker`.
    ``clock_fn() -> now`` supplies the virtual time the rolling windows
    are evaluated at."""
    reg.counter("slo_alerts_total", "SLO alert transitions", labels=labels,
                fn=lambda: tracker.alerts_total)
    reg.multi_gauge(
        "slo_burn_rate_short", "error-budget burn over the short window",
        "slo", labels=labels,
        fn=lambda: {s.name: s.burns(clock_fn())["short"]
                    for s in tracker.slos})
    reg.multi_gauge(
        "slo_burn_rate_long", "error-budget burn over the long window",
        "slo", labels=labels,
        fn=lambda: {s.name: s.burns(clock_fn())["long"]
                    for s in tracker.slos})
    reg.multi_gauge(
        "slo_firing", "1 = multi-window alert condition active", "slo",
        labels=labels,
        fn=lambda: {s.name: float(s.firing) for s in tracker.slos})


def register_stream_metrics(reg: MetricsRegistry, flusher,
                            labels=()) -> None:
    """Segment/drop accounting of an :class:`ObsFlusher` + its recorder."""
    reg.counter("obs_segments_total", "segment flushes written",
                labels=labels, fn=lambda: flusher.seq)
    rec = flusher.recorder
    if rec is not None:
        reg.gauge("obs_buffered_events", "events buffered in the recorder",
                  labels=labels, fn=lambda: rec.n_events)
        reg.gauge("obs_peak_buffered_events", "high-water buffered events",
                  labels=labels, fn=lambda: rec.peak_buffered)
        reg.counter("obs_dropped_sampled_total",
                    "events dropped by trace sampling", labels=labels,
                    fn=lambda: rec.stats["dropped_sampled"])
        reg.counter("obs_dropped_cap_total",
                    "events dropped by the per-worker cap", labels=labels,
                    fn=lambda: rec.stats["dropped_cap"])
        reg.counter("obs_requests_shed_total",
                    "request trees shed by the cap", labels=labels,
                    fn=lambda: rec.stats["requests_shed"])


def register_plane_metrics(reg: MetricsRegistry, plane) -> None:
    """Fleet-level series: per-worker scheduler metrics (labelled
    ``worker=<wid>``), worker liveness, the coordinator's sync counters,
    and the shared budget ledger (registered once)."""
    workers = sorted(plane.workers.values(), key=lambda w: w.wid)
    ledger = None
    for w in workers:
        labels = (("worker", w.wid),)
        # Remote-process workers (socket transport) are represented by
        # proxies without a local scheduler; their liveness/swap counters
        # are still mirrored and registered below.
        sched = getattr(w, "scheduler", None)
        if sched is not None:
            register_scheduler_metrics(reg, sched, labels=labels)
        reg.gauge("worker_alive", "1 = serving, 0 = crashed", labels=labels,
                  fn=lambda w=w: float(w.alive))
        reg.counter("worker_crashes_total", "crash events", labels=labels,
                    fn=lambda w=w: w.crashes)
        reg.counter("router_swaps_accepted_total", "broadcasts accepted",
                    labels=labels, fn=lambda w=w: w.swaps_accepted)
        reg.counter("router_swaps_rejected_total", "stale publishes rejected",
                    labels=labels, fn=lambda w=w: w.swaps_rejected)
        if sched is not None and sched.governor is not None:
            ledger = sched.governor

    if ledger is not None:
        # Shared ledger: evaluate the rolling window at the fleet's newest
        # virtual time (workers advance independently).
        clock_fn = lambda: max(w.clock.now for w in plane.workers.values())
        register_governor_metrics(reg, ledger, clock_fn)

    coord = plane.coordinator
    reg.counter("plane_reassigned_total", "orphaned requests reassigned",
                fn=lambda: plane.reassigned)
    reg.counter("sync_rounds_total", "coordinator sync rounds",
                fn=lambda: coord.stats["syncs"])
    reg.counter("sync_updates_total", "leader updates published",
                fn=lambda: coord.stats["updates"])
    reg.counter("sync_broadcasts_total", "router broadcasts",
                fn=lambda: coord.stats["broadcasts"])
    reg.counter("sync_bursts_total", "escalated drift bursts",
                fn=lambda: coord.stats["bursts"])
    reg.counter("sync_unreachable_total",
                "worker RPCs that found the peer unreachable",
                fn=lambda: coord.stats["unreachable"])
    reg.counter("sync_cache_invals_total",
                "semantic-cache invalidation broadcasts",
                fn=lambda: coord.stats["cache_invals"])
    reg.gauge("plane_alive_workers", "workers currently serving",
              fn=lambda: sum(w.alive for w in plane.workers.values()))
    register_transport_metrics(reg, coord.transport)
