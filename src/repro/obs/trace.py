"""Structured per-request tracing over the serving runtime's virtual clocks.

One :class:`TraceRecorder` collects *events* — instants and completed spans
— from every subsystem a request flows through: admission, queue wait,
score batch, per-member generate micro-batches, each cascade leg, the
escalation decision (with the policy's expected-marginal-reward inputs),
budget-governor verdicts, online-adapter observe/update, and finalize.

Design constraints, in order:

  * **Deterministic.** Event timestamps come from the runtime's virtual
    clocks, request identity is a recorder-assigned dense *trace key*
    (admission order, never the process-global ``rid`` counter, which
    shifts between in-process replays), and the export serializes with
    sorted keys — so a seeded run's trace is bit-identical across
    replays. The only wall-clock events are kernel-profiling spans, which
    live in the ``WALL_CATS`` categories and are excluded from the
    deterministic export.
  * **Cheap when off.** Every integration point is an ``if tracer is not
    None`` branch; with no recorder installed the runtime does zero extra
    work. When on, recording one event is a single tuple append.
  * **Fleet-aware.** Events carry a worker id; in the multi-worker plane
    all workers share one recorder through :meth:`TraceRecorder.scoped`
    views (the plane's event loop is single-process and deterministic),
    and independently-built recorders can still :meth:`merge` at rollup.

The export target is the Chrome trace-event JSON format (``ph: "X"``
complete spans + ``ph: "i"`` instants + ``ph: "C"`` counter samples, which
Perfetto renders as native counter tracks), loaded directly by Perfetto /
``chrome://tracing``: ``pid`` is the worker id, ``tid`` is the per-request
trace key (0 = scheduler/runtime scope). ``tools/trace_export.py``
filters, validates, concatenates, and summarizes saved traces.

**Streaming mode** (:mod:`repro.obs.stream`) keeps the recorder bounded
for unbounded runs: events of *closed* request trees (root span recorded)
are periodically :meth:`~TraceRecorder.drain`-ed to rotating segment
files, optionally head+tail-sampled per request
(:mod:`repro.obs.sampling`), and a hard per-worker buffered-event cap
sheds whole request trees (with drop accounting) under overload. With no
sampler/cap/drain the recorder behaves exactly as the append-only PR-6
log.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.sampling import is_anomaly_event

# Categories whose events carry wall-clock measurements; excluded from the
# deterministic export (and therefore from replay bit-identity checks).
WALL_CATS = frozenset({"kernel"})

# Event tuple layout (kept a tuple, not a dict/dataclass: recording must be
# a single append on the scheduler hot path).
#   (name, cat, ph, ts_s, dur_s, wid, key, args)
_NAME, _CAT, _PH, _TS, _DUR, _WID, _KEY, _ARGS = range(8)


class TraceRecorder:
    """Append-only event log with deterministic per-request keys.

    ``sampler`` (a :class:`repro.obs.sampling.TraceSampler`) and
    ``max_buffered_per_worker`` opt the recorder into streaming semantics:
    sampling is applied per closed request tree at :meth:`drain` time (so
    the tail-lane anomaly flag is known), and the cap sheds whole request
    trees at record time once a worker's buffered events exceed it. Both
    default off — a bare recorder keeps everything, exactly as before.
    """

    def __init__(self, label: str = "run", *, sampler=None,
                 max_buffered_per_worker: Optional[int] = None,
                 key_base: int = 0):
        self.label = label
        self.events: List[tuple] = []
        # ``key_base`` partitions the trace-key space across processes: a
        # socket-mode follower starts at ``wid * 1_000_000`` so its keys
        # never collide with the controller's when drained batches are
        # absorbed verbatim (no re-keying, links stay valid).
        self._next_key = int(key_base)
        self.sampler = sampler
        self.max_buffered_per_worker = max_buffered_per_worker
        # Streaming state: closed request trees awaiting drain, anomalous
        # keys (always-keep lane), shed keys (cap overflow), per-worker
        # buffered-event counts, and drop accounting.
        self._closed: set = set()
        self._anomaly: set = set()
        self._shed: set = set()
        self._buffered: Dict[int, int] = {}
        self.peak_buffered = 0
        self.stats = {"events": 0, "dropped_cap": 0, "dropped_sampled": 0,
                      "requests_closed": 0, "requests_sampled_out": 0,
                      "requests_shed": 0}

    # -- request identity ----------------------------------------------------

    def next_key(self) -> int:
        k = self._next_key
        self._next_key += 1
        return k

    def ensure_key(self, req) -> int:
        """Assign ``req.trace_key`` on first sight (admission order)."""
        if req.trace_key < 0:
            req.trace_key = self.next_key()
        return req.trace_key

    # -- recording -----------------------------------------------------------

    def _record(self, name: str, cat: str, ph: str, ts: float, dur: float,
                wid: int, key: Optional[int], args: Optional[dict]) -> None:
        """Single recording funnel: cap shedding, close/anomaly marking."""
        self.stats["events"] += 1
        if key is not None:
            if key in self._shed:
                self.stats["dropped_cap"] += 1
                return
            cap = self.max_buffered_per_worker
            if cap is not None and self._buffered.get(wid, 0) >= cap:
                # Hard cap: shed this request's tree (already-buffered
                # events of the key are discarded at the next drain).
                self._shed.add(key)
                self._closed.discard(key)
                self._anomaly.discard(key)
                self.stats["requests_shed"] += 1
                self.stats["dropped_cap"] += 1
                return
            if name in ("reject", "shed") or (name == "request"
                                              and ph == "X"):
                # Tree complete: a rejection/shed is a terminal instant, a
                # root span is the finalize. Flushable at the next drain.
                self._closed.add(key)
                self.stats["requests_closed"] += 1
            if is_anomaly_event(name, args):
                self._anomaly.add(key)
        self.events.append((name, cat, ph, ts, dur, wid, key, args))
        self._buffered[wid] = self._buffered.get(wid, 0) + 1
        if len(self.events) > self.peak_buffered:
            self.peak_buffered = len(self.events)

    def instant(self, name: str, cat: str, t: float, *, wid: int = 0,
                key: Optional[int] = None, args: Optional[dict] = None):
        self._record(name, cat, "i", t, 0.0, wid, key, args)

    def span(self, name: str, cat: str, t0: float, t1: float, *,
             wid: int = 0, key: Optional[int] = None,
             args: Optional[dict] = None):
        self._record(name, cat, "X", t0, max(t1 - t0, 0.0), wid, key, args)

    def counter(self, name: str, t: float, value: float, *,
                wid: int = 0) -> None:
        """One sample of a Perfetto counter track (``ph: "C"``) — e.g. the
        budget ledger's effective lambda or a worker's queue depth."""
        self._record(name, "counter", "C", t, 0.0, wid, None,
                     {"value": float(value)})

    def scoped(self, wid: int) -> "ScopedTrace":
        """A view stamping ``wid`` on every event (shared event log)."""
        return ScopedTrace(self, wid)

    # -- streaming drain ------------------------------------------------------

    def drain(self, force: bool = False) -> List[tuple]:
        """Remove and return the flushable events.

        Flushable = runtime-scope events (no request key) + events of
        *closed* request trees that survive sampling (anomalous trees are
        always kept, shed trees are always dropped). ``force=True`` also
        drains open trees (end of run) — unsampled, since an open tree
        never finished deciding its tail. Buffered memory after a drain is
        bounded by in-flight requests, not run length.
        """
        drop = set()
        if self.sampler is not None:
            drop = {k for k in self._closed
                    if k not in self._anomaly and not self.sampler.keep(k)}
            self.stats["requests_sampled_out"] += len(drop)
        out: List[tuple] = []
        kept: List[tuple] = []
        for e in self.events:
            key = e[_KEY]
            if key is None:
                out.append(e)
            elif key in self._shed:
                self.stats["dropped_cap"] += 1
            elif key in drop:
                self.stats["dropped_sampled"] += 1
            elif force or key in self._closed:
                out.append(e)
            else:
                kept.append(e)
        self.events = kept
        # Shed keys stay tracked (late events of a shed tree must keep
        # dropping); closed/anomaly bookkeeping for drained trees is done.
        self._closed.clear()
        self._anomaly = {k for k in self._anomaly if k not in drop}
        if force:
            self._anomaly.clear()
        self._buffered = {}
        for e in kept:
            self._buffered[e[_WID]] = self._buffered.get(e[_WID], 0) + 1
        return out

    @property
    def drop_stats(self) -> Dict[str, int]:
        return dict(self.stats)

    def absorb(self, events: Sequence[tuple]) -> None:
        """Fold a batch drained from a peer recorder with a *disjoint* key
        space (a follower built with ``key_base``): events are appended
        verbatim — keys, wids, and span-link args survive untouched — and
        their keys are marked closed + anomalous so this recorder's next
        drain flushes them unconditionally instead of re-sampling trees
        the peer already sampled."""
        for e in events:
            e = tuple(e)
            self.events.append(e)
            self.stats["events"] += 1
            key = e[_KEY]
            if key is not None:
                self._closed.add(key)
                self._anomaly.add(key)
            self._buffered[e[_WID]] = self._buffered.get(e[_WID], 0) + 1
        if len(self.events) > self.peak_buffered:
            self.peak_buffered = len(self.events)

    # -- rollup --------------------------------------------------------------

    def merge(self, other: "TraceRecorder") -> None:
        """Fold an independently-built recorder in (request keys re-based
        so two recorders that both started at key 0 cannot collide)."""
        base = self._next_key
        for e in other.events:
            key = e[_KEY]
            self.events.append(e if key is None else
                               e[:_KEY] + (key + base,) + e[_KEY + 1:])
        self._next_key = base + other._next_key

    # -- export --------------------------------------------------------------

    def chrome_trace(self, include_wall: bool = False) -> Dict:
        """Chrome trace-event JSON document (Perfetto-loadable).

        ``include_wall=False`` (the default) drops wall-clock categories so
        the document is a pure function of the seeded virtual-clock run.
        Timestamps are microseconds (virtual seconds * 1e6).
        """
        return build_trace_doc(self.events, label=self.label,
                               include_wall=include_wall)

    def to_json(self, include_wall: bool = False) -> str:
        """Canonical serialization — byte-comparable across replays."""
        return json.dumps(self.chrome_trace(include_wall=include_wall),
                          sort_keys=True, separators=(",", ":"))

    def save(self, path: str, include_wall: bool = False) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(include_wall=include_wall))

    @property
    def n_events(self) -> int:
        return len(self.events)


class ScopedTrace:
    """Worker-scoped view of a shared :class:`TraceRecorder`."""

    __slots__ = ("recorder", "wid")

    def __init__(self, recorder: TraceRecorder, wid: int):
        self.recorder = recorder
        self.wid = int(wid)

    def ensure_key(self, req) -> int:
        return self.recorder.ensure_key(req)

    def instant(self, name, cat, t, *, key=None, args=None):
        self.recorder._record(name, cat, "i", t, 0.0, self.wid, key, args)

    def span(self, name, cat, t0, t1, *, key=None, args=None):
        self.recorder._record(name, cat, "X", t0, max(t1 - t0, 0.0),
                              self.wid, key, args)

    def counter(self, name, t, value):
        self.recorder.counter(name, t, value, wid=self.wid)


# -- export helpers -----------------------------------------------------------


def build_trace_doc(events: Sequence[tuple], *, label: str = "run",
                    include_wall: bool = False,
                    other: Optional[dict] = None) -> Dict:
    """Build a Chrome trace-event document from raw event tuples.

    Shared by :meth:`TraceRecorder.chrome_trace` (whole buffer) and the
    streaming flusher (one drained batch per segment). Events are sorted by
    (ts, wid, arrival index) so the output is a pure function of the event
    set, and ``process_name`` metadata rows are emitted for every worker
    seen in *this* document.
    """
    out = []
    wids = set()
    order = sorted(range(len(events)),
                   key=lambda i: (events[i][_TS], events[i][_WID], i))
    for i in order:
        name, cat, ph, ts, dur, wid, key, args = events[i]
        if not include_wall and cat in WALL_CATS:
            continue
        wids.add(wid)
        ev = {
            "name": name, "cat": cat, "ph": ph,
            "ts": ts * 1e6, "pid": wid,
            "tid": 0 if key is None else key + 1,
        }
        if ph == "X":
            ev["dur"] = dur * 1e6
        if ph == "i":
            ev["s"] = "t"               # instant scope: thread
        if args:
            ev["args"] = args
        out.append(ev)
    meta = [{"name": "process_name", "ph": "M", "pid": wid, "tid": 0,
             "args": {"name": f"worker {wid}"}}
            for wid in sorted(wids)]
    other_data = {"label": label, "deterministic": not include_wall}
    if other:
        other_data.update(other)
    return {
        "traceEvents": meta + out,
        "displayTimeUnit": "ms",
        "otherData": other_data,
    }


def trace_doc_to_json(doc: Dict) -> str:
    """Canonical serialization — byte-comparable across replays."""
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


# -- validation ---------------------------------------------------------------

_REQUIRED = ("name", "cat", "ph", "ts", "pid", "tid")


def validate_chrome_trace(doc) -> List[str]:
    """Schema problems of a Chrome trace-event document ([] = valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["document must be a dict with a 'traceEvents' list"]
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        if ev.get("ph") == "M":
            continue
        for k in _REQUIRED:
            if k not in ev:
                problems.append(f"event {i} ({ev.get('name')}): missing {k!r}")
        if ev.get("ph") not in ("X", "i", "C"):
            problems.append(f"event {i}: unknown ph {ev.get('ph')!r}")
        if ev.get("ph") == "X" and not (
                isinstance(ev.get("dur"), (int, float)) and ev["dur"] >= 0):
            problems.append(f"event {i} ({ev.get('name')}): X without dur>=0")
        if ev.get("ph") == "C":
            args = ev.get("args")
            if not (isinstance(args, dict) and args and all(
                    isinstance(v, (int, float)) for v in args.values())):
                problems.append(f"event {i} ({ev.get('name')}): C counter "
                                "without numeric args")
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i}: non-numeric ts")
    return problems


def request_trees(doc) -> Dict[int, Dict]:
    """Group a trace's request-scope events into per-request trees.

    Returns ``{tid: {"root": event|None, "events": [...], "legs": [...],
    "admits": [...]}}`` over every tid > 0 (request scope), across all
    workers — a request that migrated between workers (crash reassignment,
    cascade re-admission in the plane) contributes events from several
    pids to one tree.
    """
    trees: Dict[int, Dict] = {}
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "M" or ev.get("tid", 0) == 0:
            continue
        t = trees.setdefault(ev["tid"], {"root": None, "events": [],
                                         "legs": [], "admits": []})
        t["events"].append(ev)
        if ev["name"] == "request" and ev["ph"] == "X":
            t["root"] = ev
        elif ev["name"] == "leg" and ev["ph"] == "X":
            t["legs"].append(ev)
        elif ev["name"] in ("admit", "readmit"):
            t["admits"].append(ev)
    for t in trees.values():
        t["legs"].sort(key=lambda e: e["ts"])
    return trees


def validate_span_tree(doc, eps_us: float = 0.5) -> List[str]:
    """Well-formedness of the per-request span trees ([] = well-formed).

    Every finalized request (a ``request`` root span) must cover
    admission -> legs -> finalize: at least one admit event, all events
    inside the root interval, completed roots with >= 1 leg span, legs
    time-ordered and non-overlapping, and per-leg queue_wait spans.

    Legs carrying a ``gen`` arg (span link) must resolve to a runtime-scope
    ``generate`` micro-batch span on the same worker whose interval lies
    inside the leg's. Legs without the arg are skipped — hand-built traces
    and pre-link documents stay valid.

    RPC flow links are validated fleet-wide: every client-side ``rpc``
    span must have a matching server-side span (same ``rpc`` link id) —
    a dangling client link is a validation error, since the transport
    only emits the client span after a successful reply. Unmatched
    *server* spans are fine (the reply can be lost in transit). Legs
    carrying an ``rpc`` arg (remote GENERATE dispatch) must resolve to a
    client span on the leg's own pid and a server span on the owning pid.
    """
    problems: List[str] = []
    gen_spans: Dict[Tuple[int, int], Dict] = {}
    rpc_client: Dict[int, Dict] = {}
    rpc_server: Dict[int, Dict] = {}
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") != "X" or ev.get("tid", 0) != 0:
            continue
        if ev.get("name") == "generate":
            gen = (ev.get("args") or {}).get("gen")
            if gen is not None:
                gen_spans[(ev["pid"], gen)] = ev
        elif ev.get("name") == "rpc":
            args = ev.get("args") or {}
            link = args.get("rpc")
            if link is not None:
                side = rpc_client if args.get("side") == "client" \
                    else rpc_server
                side[link] = ev
    for link, ev in sorted(rpc_client.items()):
        if link not in rpc_server:
            problems.append(
                f"rpc {link}: client span on worker {ev['pid']} "
                f"(kind={((ev.get('args') or {}).get('kind'))!r}) has no "
                "matching server span — dangling flow link")
    for tid, t in sorted(request_trees(doc).items()):
        root = t["root"]
        if root is None:
            # Un-finalized request scope: only backpressure rejections and
            # SLO-class load shedding are allowed to stay rootless (those
            # requests never reached dispatch).
            names = {e["name"] for e in t["events"]}
            if names - {"reject"} and "shed" not in names:
                problems.append(f"request {tid}: events {sorted(names)} "
                                "without a 'request' root span")
            continue
        lo, hi = root["ts"] - eps_us, root["ts"] + root["dur"] + eps_us
        if not t["admits"]:
            problems.append(f"request {tid}: no admission event")
        for ev in t["events"]:
            end = ev["ts"] + ev.get("dur", 0.0)
            if ev["ts"] < lo or end > hi:
                problems.append(
                    f"request {tid}: {ev['name']} [{ev['ts']:.1f},"
                    f"{end:.1f}]us outside root [{lo:.1f},{hi:.1f}]us")
        root_args = root.get("args") or {}
        status = root_args.get("status")
        if status == "done" and not t["legs"] and not root_args.get("cached"):
            # Cache-served requests legitimately finish with zero legs —
            # the semantic cache is rung 0, no pool member ran.
            problems.append(f"request {tid}: done without a leg span")
        # Expiry/rescue consistency: a done root must never contain an
        # `expire` instant (the queue classifies rescues up front), and a
        # `rescued` instant only appears under a rescued root.
        if status == "done" and any(
                e["name"] == "expire" for e in t["events"]):
            problems.append(
                f"request {tid}: 'expire' instant under a done root")
        if (any(e["name"] == "rescued" for e in t["events"])
                and not root_args.get("rescued")):
            problems.append(
                f"request {tid}: 'rescued' instant under an un-rescued root")
        prev_end = None
        for leg in t["legs"]:
            if prev_end is not None and leg["ts"] < prev_end - eps_us:
                problems.append(f"request {tid}: overlapping leg spans")
            prev_end = leg["ts"] + leg["dur"]
            gen = (leg.get("args") or {}).get("gen")
            if gen is None:
                continue
            src = gen_spans.get((leg["pid"], gen))
            if src is None:
                problems.append(f"request {tid}: leg links gen={gen} but no "
                                f"generate span on worker {leg['pid']}")
                continue
            if (src["ts"] < leg["ts"] - eps_us or
                    src["ts"] + src["dur"] > prev_end + eps_us):
                problems.append(
                    f"request {tid}: linked generate span gen={gen} "
                    f"[{src['ts']:.1f},{src['ts'] + src['dur']:.1f}]us "
                    f"outside leg [{leg['ts']:.1f},{prev_end:.1f}]us")
            lm = (leg.get("args") or {}).get("member")
            gm = (src.get("args") or {}).get("member")
            if lm is not None and gm is not None and lm != gm:
                problems.append(f"request {tid}: leg member {lm!r} != "
                                f"linked generate member {gm!r}")
            rlink = (leg.get("args") or {}).get("rpc")
            if rlink is None:
                continue
            cli = rpc_client.get(rlink)
            if cli is None:
                problems.append(f"request {tid}: leg links rpc={rlink} but "
                                "no client rpc span")
            elif cli["pid"] != leg["pid"]:
                problems.append(
                    f"request {tid}: rpc={rlink} client span on worker "
                    f"{cli['pid']} != leg worker {leg['pid']}")
            if rlink not in rpc_server:
                problems.append(f"request {tid}: leg links rpc={rlink} but "
                                "no server rpc span")
        n_waits = sum(e["name"] == "queue_wait" for e in t["events"])
        if t["legs"] and n_waits < len(t["legs"]):
            problems.append(f"request {tid}: {len(t['legs'])} legs but only "
                            f"{n_waits} queue_wait spans")
    return problems


def trace_summary(doc) -> Dict:
    """Aggregate counts for quick inspection / tooling."""
    by_name: Dict[str, int] = {}
    by_cat: Dict[str, int] = {}
    wids = set()
    n = 0
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "M":
            continue
        n += 1
        by_name[ev["name"]] = by_name.get(ev["name"], 0) + 1
        by_cat[ev["cat"]] = by_cat.get(ev["cat"], 0) + 1
        wids.add(ev["pid"])
    trees = request_trees(doc)
    return {
        "events": n,
        "by_name": dict(sorted(by_name.items())),
        "by_cat": dict(sorted(by_cat.items())),
        "workers": sorted(wids),
        "requests": len(trees),
        "finalized": sum(t["root"] is not None for t in trees.values()),
    }
