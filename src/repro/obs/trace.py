"""Structured per-request tracing over the serving runtime's virtual clocks.

One :class:`TraceRecorder` collects *events* — instants and completed spans
— from every subsystem a request flows through: admission, queue wait,
score batch, per-member generate micro-batches, each cascade leg, the
escalation decision (with the policy's expected-marginal-reward inputs),
budget-governor verdicts, online-adapter observe/update, and finalize.

Design constraints, in order:

  * **Deterministic.** Event timestamps come from the runtime's virtual
    clocks, request identity is a recorder-assigned dense *trace key*
    (admission order, never the process-global ``rid`` counter, which
    shifts between in-process replays), and the export serializes with
    sorted keys — so a seeded run's trace is bit-identical across
    replays. The only wall-clock events are kernel-profiling spans, which
    live in the ``WALL_CATS`` categories and are excluded from the
    deterministic export.
  * **Cheap when off.** Every integration point is an ``if tracer is not
    None`` branch; with no recorder installed the runtime does zero extra
    work. When on, recording one event is a single tuple append.
  * **Fleet-aware.** Events carry a worker id; in the multi-worker plane
    all workers share one recorder through :meth:`TraceRecorder.scoped`
    views (the plane's event loop is single-process and deterministic),
    and independently-built recorders can still :meth:`merge` at rollup.

The export target is the Chrome trace-event JSON format (``ph: "X"``
complete spans + ``ph: "i"`` instants), which Perfetto / ``chrome://tracing``
load directly: ``pid`` is the worker id, ``tid`` is the per-request trace
key (0 = scheduler/runtime scope). ``tools/trace_export.py`` filters,
validates, and summarizes saved traces.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

# Categories whose events carry wall-clock measurements; excluded from the
# deterministic export (and therefore from replay bit-identity checks).
WALL_CATS = frozenset({"kernel"})

# Event tuple layout (kept a tuple, not a dict/dataclass: recording must be
# a single append on the scheduler hot path).
#   (name, cat, ph, ts_s, dur_s, wid, key, args)
_NAME, _CAT, _PH, _TS, _DUR, _WID, _KEY, _ARGS = range(8)


class TraceRecorder:
    """Append-only event log with deterministic per-request keys."""

    def __init__(self, label: str = "run"):
        self.label = label
        self.events: List[tuple] = []
        self._next_key = 0

    # -- request identity ----------------------------------------------------

    def next_key(self) -> int:
        k = self._next_key
        self._next_key += 1
        return k

    def ensure_key(self, req) -> int:
        """Assign ``req.trace_key`` on first sight (admission order)."""
        if req.trace_key < 0:
            req.trace_key = self.next_key()
        return req.trace_key

    # -- recording -----------------------------------------------------------

    def instant(self, name: str, cat: str, t: float, *, wid: int = 0,
                key: Optional[int] = None, args: Optional[dict] = None):
        self.events.append((name, cat, "i", t, 0.0, wid, key, args))

    def span(self, name: str, cat: str, t0: float, t1: float, *,
             wid: int = 0, key: Optional[int] = None,
             args: Optional[dict] = None):
        self.events.append((name, cat, "X", t0, max(t1 - t0, 0.0), wid, key,
                            args))

    def scoped(self, wid: int) -> "ScopedTrace":
        """A view stamping ``wid`` on every event (shared event log)."""
        return ScopedTrace(self, wid)

    # -- rollup --------------------------------------------------------------

    def merge(self, other: "TraceRecorder") -> None:
        """Fold an independently-built recorder in (request keys re-based
        so two recorders that both started at key 0 cannot collide)."""
        base = self._next_key
        for e in other.events:
            key = e[_KEY]
            self.events.append(e if key is None else
                               e[:_KEY] + (key + base,) + e[_KEY + 1:])
        self._next_key = base + other._next_key

    # -- export --------------------------------------------------------------

    def chrome_trace(self, include_wall: bool = False) -> Dict:
        """Chrome trace-event JSON document (Perfetto-loadable).

        ``include_wall=False`` (the default) drops wall-clock categories so
        the document is a pure function of the seeded virtual-clock run.
        Timestamps are microseconds (virtual seconds * 1e6).
        """
        events = []
        wids = set()
        order = sorted(range(len(self.events)),
                       key=lambda i: (self.events[i][_TS],
                                      self.events[i][_WID], i))
        for i in order:
            name, cat, ph, ts, dur, wid, key, args = self.events[i]
            if not include_wall and cat in WALL_CATS:
                continue
            wids.add(wid)
            ev = {
                "name": name, "cat": cat, "ph": ph,
                "ts": ts * 1e6, "pid": wid,
                "tid": 0 if key is None else key + 1,
            }
            if ph == "X":
                ev["dur"] = dur * 1e6
            if ph == "i":
                ev["s"] = "t"           # instant scope: thread
            if args:
                ev["args"] = args
            events.append(ev)
        meta = [{"name": "process_name", "ph": "M", "pid": wid, "tid": 0,
                 "args": {"name": f"worker {wid}"}}
                for wid in sorted(wids)]
        return {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": {"label": self.label,
                          "deterministic": not include_wall},
        }

    def to_json(self, include_wall: bool = False) -> str:
        """Canonical serialization — byte-comparable across replays."""
        return json.dumps(self.chrome_trace(include_wall=include_wall),
                          sort_keys=True, separators=(",", ":"))

    def save(self, path: str, include_wall: bool = False) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(include_wall=include_wall))

    @property
    def n_events(self) -> int:
        return len(self.events)


class ScopedTrace:
    """Worker-scoped view of a shared :class:`TraceRecorder`."""

    __slots__ = ("recorder", "wid")

    def __init__(self, recorder: TraceRecorder, wid: int):
        self.recorder = recorder
        self.wid = int(wid)

    def ensure_key(self, req) -> int:
        return self.recorder.ensure_key(req)

    def instant(self, name, cat, t, *, key=None, args=None):
        self.recorder.events.append((name, cat, "i", t, 0.0, self.wid, key,
                                     args))

    def span(self, name, cat, t0, t1, *, key=None, args=None):
        self.recorder.events.append((name, cat, "X", t0,
                                     max(t1 - t0, 0.0), self.wid, key, args))


# -- validation ---------------------------------------------------------------

_REQUIRED = ("name", "cat", "ph", "ts", "pid", "tid")


def validate_chrome_trace(doc) -> List[str]:
    """Schema problems of a Chrome trace-event document ([] = valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["document must be a dict with a 'traceEvents' list"]
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        if ev.get("ph") == "M":
            continue
        for k in _REQUIRED:
            if k not in ev:
                problems.append(f"event {i} ({ev.get('name')}): missing {k!r}")
        if ev.get("ph") not in ("X", "i"):
            problems.append(f"event {i}: unknown ph {ev.get('ph')!r}")
        if ev.get("ph") == "X" and not (
                isinstance(ev.get("dur"), (int, float)) and ev["dur"] >= 0):
            problems.append(f"event {i} ({ev.get('name')}): X without dur>=0")
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i}: non-numeric ts")
    return problems


def request_trees(doc) -> Dict[int, Dict]:
    """Group a trace's request-scope events into per-request trees.

    Returns ``{tid: {"root": event|None, "events": [...], "legs": [...],
    "admits": [...]}}`` over every tid > 0 (request scope), across all
    workers — a request that migrated between workers (crash reassignment,
    cascade re-admission in the plane) contributes events from several
    pids to one tree.
    """
    trees: Dict[int, Dict] = {}
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "M" or ev.get("tid", 0) == 0:
            continue
        t = trees.setdefault(ev["tid"], {"root": None, "events": [],
                                         "legs": [], "admits": []})
        t["events"].append(ev)
        if ev["name"] == "request" and ev["ph"] == "X":
            t["root"] = ev
        elif ev["name"] == "leg" and ev["ph"] == "X":
            t["legs"].append(ev)
        elif ev["name"] in ("admit", "readmit"):
            t["admits"].append(ev)
    for t in trees.values():
        t["legs"].sort(key=lambda e: e["ts"])
    return trees


def validate_span_tree(doc, eps_us: float = 0.5) -> List[str]:
    """Well-formedness of the per-request span trees ([] = well-formed).

    Every finalized request (a ``request`` root span) must cover
    admission -> legs -> finalize: at least one admit event, all events
    inside the root interval, completed roots with >= 1 leg span, legs
    time-ordered and non-overlapping, and per-leg queue_wait spans.
    """
    problems: List[str] = []
    for tid, t in sorted(request_trees(doc).items()):
        root = t["root"]
        if root is None:
            # Un-finalized request scope: only backpressure rejections are
            # allowed to stay rootless (they never entered the runtime).
            names = {e["name"] for e in t["events"]}
            if names - {"reject"}:
                problems.append(f"request {tid}: events {sorted(names)} "
                                "without a 'request' root span")
            continue
        lo, hi = root["ts"] - eps_us, root["ts"] + root["dur"] + eps_us
        if not t["admits"]:
            problems.append(f"request {tid}: no admission event")
        for ev in t["events"]:
            end = ev["ts"] + ev.get("dur", 0.0)
            if ev["ts"] < lo or end > hi:
                problems.append(
                    f"request {tid}: {ev['name']} [{ev['ts']:.1f},"
                    f"{end:.1f}]us outside root [{lo:.1f},{hi:.1f}]us")
        status = (root.get("args") or {}).get("status")
        if status == "done" and not t["legs"]:
            problems.append(f"request {tid}: done without a leg span")
        prev_end = None
        for leg in t["legs"]:
            if prev_end is not None and leg["ts"] < prev_end - eps_us:
                problems.append(f"request {tid}: overlapping leg spans")
            prev_end = leg["ts"] + leg["dur"]
        n_waits = sum(e["name"] == "queue_wait" for e in t["events"])
        if t["legs"] and n_waits < len(t["legs"]):
            problems.append(f"request {tid}: {len(t['legs'])} legs but only "
                            f"{n_waits} queue_wait spans")
    return problems


def trace_summary(doc) -> Dict:
    """Aggregate counts for quick inspection / tooling."""
    by_name: Dict[str, int] = {}
    by_cat: Dict[str, int] = {}
    wids = set()
    n = 0
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "M":
            continue
        n += 1
        by_name[ev["name"]] = by_name.get(ev["name"], 0) + 1
        by_cat[ev["cat"]] = by_cat.get(ev["cat"], 0) + 1
        wids.add(ev["pid"])
    trees = request_trees(doc)
    return {
        "events": n,
        "by_name": dict(sorted(by_name.items())),
        "by_cat": dict(sorted(by_cat.items())),
        "workers": sorted(wids),
        "requests": len(trees),
        "finalized": sum(t["root"] is not None for t in trees.values()),
    }
