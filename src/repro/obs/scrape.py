"""HTTP scrape endpoint for the live metrics registry.

Serves a :class:`~repro.obs.metrics.MetricsRegistry` over localhost HTTP
for the duration of a run:

  * ``GET /metrics`` — Prometheus text exposition
    (:meth:`MetricsRegistry.prometheus`), the format every scraper
    understands;
  * ``GET /metrics.json`` — the registry's canonical JSON snapshot
    (:meth:`MetricsRegistry.to_json`), for ad-hoc ``curl | jq``.

The server runs on a daemon thread (one ``ThreadingHTTPServer``), so a
serving run never blocks on a slow scraper and exits without waiting for
open connections. Gauges read their callbacks at scrape time — a scrape
mid-run observes the runtime's *live* state, which is exactly the point:
the snapshot files (``--metrics-out``) are for replay-stable artifacts,
this endpoint is for watching a run happen.

Scrapes are read-only against runtime objects mutated by the main
thread; values may be mid-update-torn across series (a scrape is not a
transaction), the standard Prometheus contract.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def merge_prom_texts(texts) -> str:
    """Concatenate Prometheus text expositions into one scrape body.

    ``# HELP`` / ``# TYPE`` header lines are deduplicated by metric name
    (first exposition wins) — a federated scrape merges the controller's
    registry with follower registries that expose the same series under
    different ``worker`` labels, and repeating the headers per process
    would be invalid exposition.
    """
    lines = []
    seen = set()
    for text in texts:
        for line in text.splitlines():
            if line.startswith("# HELP ") or line.startswith("# TYPE "):
                parts = line.split(" ", 3)
                key = (parts[1], parts[2])
                if key in seen:
                    continue
                seen.add(key)
            if line:
                lines.append(line)
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Daemon-threaded HTTP server over one metrics registry.

    ``port=0`` binds an ephemeral port; :meth:`start` returns the port
    actually bound. ``deterministic=False`` by default — the endpoint
    reports live values including wall-clock-derived ones; pass True to
    serve the replay-stable view instead.
    """

    def __init__(self, registry, *, port: int = 0,
                 host: str = "127.0.0.1", deterministic: bool = False):
        if registry is None:
            raise ValueError("MetricsServer needs a MetricsRegistry")
        self.registry = registry
        self.host = host
        self.port = int(port)
        self.deterministic = deterministic
        self.scrapes = 0
        # Federated view: wid -> that follower's latest Prometheus text,
        # refreshed by the serving loop at sync boundaries (the scrape
        # thread only READS this cache — it must never issue transport
        # RPCs itself, the socket protocol is single-threaded lockstep).
        self.fleet = {}
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    def update_fleet(self, wid: int, prom_text: str) -> None:
        """Cache one follower's scraped registry for /metrics merging."""
        if prom_text:
            self.fleet[int(wid)] = prom_text

    def render(self) -> str:
        """The merged exposition /metrics serves: own registry first,
        then each cached follower exposition in ascending wid order."""
        own = self.registry.prometheus(deterministic=self.deterministic)
        if not self.fleet:
            return own
        return merge_prom_texts(
            [own] + [self.fleet[w] for w in sorted(self.fleet)])

    def _handler_class(self):
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                path = self.path.split("?", 1)[0]
                if path in ("/metrics", "/"):
                    body = server.render().encode()
                    ctype = PROM_CONTENT_TYPE
                elif path == "/metrics.json":
                    body = server.registry.to_json(
                        deterministic=server.deterministic).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                server.scrapes += 1
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt, *fmt_args):
                pass                   # scrapes are not run output

        return Handler

    def start(self) -> int:
        """Bind and serve on a daemon thread; returns the bound port."""
        if self._httpd is not None:
            return self.port
        self._httpd = ThreadingHTTPServer((self.host, self.port),
                                          self._handler_class())
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name=f"metrics-server-{self.port}", daemon=True)
        self._thread.start()
        return self.port

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._httpd = None
        self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def __enter__(self) -> "MetricsServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
