"""Deterministic head+tail trace sampling for heavy traffic.

The PR-6 recorder keeps every event of every request — correct for seeded
acceptance runs, unbounded for a real fleet. Streaming mode bounds it with
three mechanisms, applied at *request* granularity so span trees stay whole:

  * **Head sampling** (:class:`TraceSampler`): the keep/drop decision is a
    pure function of the request's admission-order trace key and a seed —
    a seeded ``blake2b`` hash mapped to [0, 1) and compared against the
    sample rate. No RNG state, no wall clock: the keep-set of a seeded run
    is bit-identical across replays, and two workers sharing a recorder
    agree for free. The first ``head`` keys are always kept (the start of
    a run is where config mistakes show up).
  * **Tail lane** (:func:`is_anomaly_event`): requests that did something
    anomalous — escalated up the cascade, expired or were deadline-rescued
    — are always kept regardless of the sample rate. The recorder flags
    the key the moment an anomaly event is recorded; the sampling decision
    is deferred to drain time, after the tail is known. Runtime-scope
    anomalies (drift alarms, budget tighten/throttle verdicts, worker
    crash/rejoin) carry no request key and are never sampled at all.
  * **Hard cap** (``TraceRecorder(max_buffered_per_worker=...)``): a
    per-worker bound on buffered events. When a worker hits it, new
    request trees are *shed* (dropped whole, with drop accounting) until a
    flush makes room. The cap wins over the always-keep lane — it is the
    memory-safety backstop, and a shed anomaly is counted, not silent.
"""
from __future__ import annotations

import hashlib
from typing import Iterable, Optional, Set

# Request-scope event names that flag the request's tree as anomalous
# (tail-sampling always-keep lane). Runtime-scope events (no request key)
# are never subject to sampling, so they need no entry here even when
# anomalous (drift_alarm, worker_crash/rejoin, governor verdicts).
ANOMALY_EVENTS = frozenset({"readmit", "expire", "rescued"})

# Root-span statuses / flags that mark the tree anomalous at finalize.
_ANOMALY_STATUS = frozenset({"expired"})


def is_anomaly_event(name: str, args: Optional[dict]) -> bool:
    """True when recording this event must pin its request in the trace."""
    if name in ANOMALY_EVENTS:
        return True
    if name == "request" and args:
        return bool(args.get("rescued")) or (
            args.get("status") in _ANOMALY_STATUS)
    return False


class TraceSampler:
    """Deterministic per-request keep/drop decision.

    ``keep(key)`` is a pure function of ``(seed, key)``: a replay with the
    same seed and the same admission order reproduces the identical
    keep-set. ``rate`` is the asymptotic fraction of request trees kept;
    the first ``head`` keys are always kept.
    """

    def __init__(self, rate: float, *, seed: int = 0, head: int = 8):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"sample rate must be in [0, 1], got {rate}")
        self.rate = float(rate)
        self.seed = int(seed)
        self.head = int(head)
        self._key = self.seed.to_bytes(8, "little", signed=True)

    def keep(self, key: int) -> bool:
        if key < self.head or self.rate >= 1.0:
            return True
        if self.rate <= 0.0:
            return False
        h = hashlib.blake2b(int(key).to_bytes(8, "little", signed=True),
                            key=self._key, digest_size=8).digest()
        return int.from_bytes(h, "little") / 2.0 ** 64 < self.rate

    def keep_set(self, keys: Iterable[int]) -> Set[int]:
        return {k for k in keys if self.keep(k)}

    def describe(self) -> dict:
        return {"rate": self.rate, "seed": self.seed, "head": self.head}
