"""SLO monitors with multi-window burn-rate alerting on the virtual clock.

Each SLO is stated as an *error budget*: the fraction of requests allowed
to be "bad" over a compliance window ("p95 e2e latency <= 2s" is "at most
5% of requests slower than 2s"; deadline-miss rate and quality floor are
direct bad-fractions; the $/window budget is a spend rate). The **burn
rate** is how fast the budget is being consumed relative to plan::

    burn = bad_fraction / error_budget        (1.0 = exactly on budget)
    burn = spend_rate   / budgeted_rate       (spend SLOs)

Following the multi-window pattern (Google SRE workbook), an alert fires
only when the burn exceeds the threshold over **both** a short window
(fast detection, catches ongoing incidents) and a long window (resists
blips: a single slow request in a quiet period spikes the short-window
fraction but not the long one). All windows run on the runtime's virtual
clock via bucketed rolling counters, so a seeded run fires the identical
alerts at identical virtual times on every replay — and the bucket map is
tolerant of the mildly out-of-order completion times a multi-worker plane
produces.

:class:`SLOTracker` bundles the standard four (latency, deadline-miss,
quality floor, spend), observes each finalized request once, emits
``slo_alert`` trace instants on firing/resolved transitions, and exposes
live burn rates for :func:`repro.obs.wiring.register_slo_metrics`.
"""
from __future__ import annotations

from typing import Dict, List, Optional


class RollingWindow:
    """Bucketed rolling (count, bad, value) totals over virtual time.

    O(n_buckets) memory; observations may arrive out of order (cross-worker
    completion skew) — anything newer than ``hi - window`` still lands in
    its correct bucket.
    """

    def __init__(self, window_s: float, n_buckets: int = 30):
        if window_s <= 0:
            raise ValueError("window_s must be > 0")
        self.window_s = float(window_s)
        self.width = self.window_s / int(n_buckets)
        self._buckets: Dict[int, List[float]] = {}
        self._hi = None  # highest bucket index seen

    def add(self, t: float, *, bad: int = 0, value: float = 0.0,
            n: int = 1) -> None:
        idx = int(t // self.width)
        b = self._buckets.get(idx)
        if b is None:
            b = self._buckets[idx] = [0, 0, 0.0]
        b[0] += n
        b[1] += bad
        b[2] += value
        if self._hi is None or idx > self._hi:
            self._hi = idx
            # Prune on high-water advance: drop buckets that can no longer
            # intersect any window ending >= hi's bucket start.
            lo = idx - int(self.window_s / self.width) - 1
            for k in [k for k in self._buckets if k < lo]:
                del self._buckets[k]

    def totals(self, now: float) -> List[float]:
        """(count, bad, value) over ``(now - window_s, now]``."""
        lo = int((now - self.window_s) // self.width)
        n = bad = 0
        val = 0.0
        for idx, b in self._buckets.items():
            if idx > lo:
                n += b[0]
                bad += b[1]
                val += b[2]
        return [n, bad, val]


class BurnRateSLO:
    """Bad-fraction SLO with short+long window burn-rate alerting."""

    kind = "ratio"

    def __init__(self, name: str, *, error_budget: float,
                 short_s: float, long_s: float, threshold: float = 1.0,
                 min_events: int = 1):
        if not 0.0 < error_budget <= 1.0:
            raise ValueError("error_budget must be in (0, 1]")
        if short_s >= long_s:
            raise ValueError("short window must be shorter than long")
        self.name = name
        self.error_budget = float(error_budget)
        self.threshold = float(threshold)
        self.min_events = int(min_events)
        self.short = RollingWindow(short_s)
        self.long = RollingWindow(long_s)
        self.firing = False

    def observe(self, t: float, bad: bool) -> None:
        self.short.add(t, bad=int(bad))
        self.long.add(t, bad=int(bad))

    def _burn(self, win: RollingWindow, now: float) -> float:
        n, bad, _ = win.totals(now)
        if n < self.min_events:
            return 0.0
        return (bad / n) / self.error_budget

    def burns(self, now: float) -> Dict[str, float]:
        return {"short": self._burn(self.short, now),
                "long": self._burn(self.long, now)}

    def evaluate(self, now: float) -> bool:
        """Current alert condition (both windows over threshold)."""
        b = self.burns(now)
        return (b["short"] >= self.threshold
                and b["long"] >= self.threshold)


class SpendBurnSLO:
    """$/window SLO: spend rate vs the budgeted rate, short+long windows."""

    kind = "spend"

    def __init__(self, name: str, *, budget: float, window_s: float,
                 short_s: Optional[float] = None, threshold: float = 1.0):
        if budget <= 0:
            raise ValueError("budget must be > 0")
        self.name = name
        self.budget = float(budget)           # allowed spend per window_s
        self.window_s = float(window_s)
        self.threshold = float(threshold)
        self.short = RollingWindow(short_s if short_s is not None
                                   else max(window_s / 12.0, 1e-9))
        self.long = RollingWindow(window_s)
        self.firing = False

    def observe(self, t: float, cost: float) -> None:
        self.short.add(t, value=float(cost))
        self.long.add(t, value=float(cost))

    def _burn(self, win: RollingWindow, now: float) -> float:
        _, _, spend = win.totals(now)
        allowed = self.budget * (win.window_s / self.window_s)
        return spend / allowed if allowed > 0 else 0.0

    def burns(self, now: float) -> Dict[str, float]:
        return {"short": self._burn(self.short, now),
                "long": self._burn(self.long, now)}

    def evaluate(self, now: float) -> bool:
        b = self.burns(now)
        return (b["short"] >= self.threshold
                and b["long"] >= self.threshold)


class SLOTracker:
    """The run's SLO set: observe finalized requests, alert on transitions.

    ``check(now)`` evaluates every SLO and records a transition event
    (``state: firing|resolved``) whenever the multi-window condition flips,
    emitting it as a runtime-scope ``slo_alert`` trace instant when a
    tracer is attached. Alert history accumulates in :attr:`alerts`.
    """

    def __init__(self, slos, *, tracer=None, check_every_s: float = 1.0):
        self.slos = list(slos)
        self.tracer = tracer
        self.check_every_s = float(check_every_s)
        self.alerts: List[Dict] = []
        self.alerts_total = 0
        self._next_check: Optional[float] = None

    def observe_request(self, t: float, *, e2e_s: float, missed: bool,
                        quality: Optional[float], cost: float,
                        quality_floor: Optional[float] = None) -> None:
        for s in self.slos:
            if s.kind == "spend":
                s.observe(t, cost)
            elif s.name == "latency_p95":
                s.observe(t, bad=e2e_s > s.target_s)
            elif s.name == "deadline_miss":
                s.observe(t, bad=missed)
            elif s.name == "quality_floor":
                if quality is not None:
                    s.observe(t, bad=quality < s.floor)
            else:
                s.observe(t, bad=missed)

    def check(self, now: float, force: bool = False) -> List[Dict]:
        """Throttled evaluation; returns this call's transition records."""
        if not force:
            if self._next_check is not None and now < self._next_check:
                return []
            self._next_check = now + self.check_every_s
        out: List[Dict] = []
        for s in self.slos:
            state = s.evaluate(now)
            if state == s.firing:
                continue
            s.firing = state
            b = s.burns(now)
            rec = {"slo": s.name, "state": "firing" if state else "resolved",
                   "t": now, "burn_short": round(b["short"], 6),
                   "burn_long": round(b["long"], 6)}
            out.append(rec)
            self.alerts.append(rec)
            self.alerts_total += 1
            if self.tracer is not None:
                self.tracer.instant(
                    "slo_alert", "slo", now,
                    args={k: v for k, v in rec.items() if k != "t"})
        return out

    def firing(self) -> List[str]:
        return [s.name for s in self.slos if s.firing]

    def burn_rates(self, now: float) -> Dict[str, Dict[str, float]]:
        return {s.name: s.burns(now) for s in self.slos}


def build_slo_tracker(*, tracer=None, p95_target_s: Optional[float] = None,
                      p95_budget: float = 0.05,
                      miss_rate_budget: Optional[float] = None,
                      quality_floor: Optional[float] = None,
                      quality_budget: float = 0.10,
                      spend_per_window: Optional[float] = None,
                      window_s: float = 600.0, threshold: float = 1.0,
                      check_every_s: Optional[float] = None
                      ) -> Optional[SLOTracker]:
    """Standard four-SLO tracker from launch flags; None if nothing set.

    The short window is long/12 (the SRE workbook's 5m:1h ratio). Windows
    are in *virtual* seconds — the simulated deployment's service model
    runs whole traces in sub-second virtual time, so pass windows on that
    scale (e.g. ``--slo-window 0.1``). ``check_every_s`` defaults to half
    the short window.
    """
    short_s = window_s / 12.0
    slos = []
    if p95_target_s is not None:
        s = BurnRateSLO("latency_p95", error_budget=p95_budget,
                        short_s=short_s, long_s=window_s,
                        threshold=threshold)
        s.target_s = float(p95_target_s)
        slos.append(s)
    if miss_rate_budget is not None:
        slos.append(BurnRateSLO("deadline_miss",
                                error_budget=miss_rate_budget,
                                short_s=short_s, long_s=window_s,
                                threshold=threshold))
    if quality_floor is not None:
        s = BurnRateSLO("quality_floor", error_budget=quality_budget,
                        short_s=short_s, long_s=window_s,
                        threshold=threshold)
        s.floor = float(quality_floor)
        slos.append(s)
    if spend_per_window is not None:
        slos.append(SpendBurnSLO("spend", budget=spend_per_window,
                                 window_s=window_s, short_s=short_s,
                                 threshold=threshold))
    if not slos:
        return None
    if check_every_s is None:
        check_every_s = short_s / 2.0
    return SLOTracker(slos, tracer=tracer, check_every_s=check_every_s)
