"""Exportable metrics registry: counters, gauges, histograms.

Every serving subsystem registers its live state here — queue depth, router
swap versions, drift alarm state, exploration epsilon, budget-ledger
headroom, escalation rate by cascade rung — and two exporters read the
registry: Prometheus text exposition and a canonical JSON snapshot.

Two registration styles:

  * **owned** metrics hold their own value (``Counter.inc`` /
    ``Gauge.set`` / ``HistogramMetric.observe``);
  * **callback** metrics wrap a ``fn`` evaluated at export time — the
    preferred style for serving wiring (see :mod:`repro.obs.wiring`),
    because it costs the hot path nothing: the scheduler keeps mutating
    its native counters and the registry reads them only when scraped.

Histograms reuse the serving runtime's log-bucketed
:class:`repro.serving.telemetry.Histogram` (O(buckets) memory at any
traffic volume); the Prometheus exporter emits its buckets as cumulative
``_bucket{le=...}`` samples.

``wall=True`` marks metrics whose values derive from wall-clock
measurement (routing latency, kernel timings). ``snapshot(deterministic=
True)`` excludes them, so a seeded run's deterministic snapshot is
bit-identical across replays — the same contract as the trace export.
"""
from __future__ import annotations

import json
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.telemetry import Histogram


def _norm_labels(labels) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    if isinstance(labels, dict):
        labels = labels.items()
    return tuple(sorted((str(k), str(v)) for k, v in labels))


def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


def _finite(x):
    """JSON-safe number (non-finite -> None)."""
    x = float(x)
    return x if math.isfinite(x) else None


class Metric:
    """Base: a named series with fixed labels."""

    mtype = "untyped"

    def __init__(self, name: str, help: str = "", labels=(),
                 wall: bool = False):
        self.name = name
        self.help = help
        self.labels = _norm_labels(labels)
        self.wall = wall

    @property
    def key(self) -> str:
        return self.name + _label_str(self.labels)


class Counter(Metric):
    mtype = "counter"

    def __init__(self, name, help="", labels=(), fn=None, wall=False):
        super().__init__(name, help, labels, wall)
        self.fn = fn
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if self.fn is not None:
            raise TypeError(f"counter {self.name} is callback-backed")
        self.value += v

    def read(self) -> float:
        return float(self.fn()) if self.fn is not None else self.value


class Gauge(Metric):
    mtype = "gauge"

    def __init__(self, name, help="", labels=(), fn=None, wall=False):
        super().__init__(name, help, labels, wall)
        self.fn = fn
        self.value = float("nan")

    def set(self, v: float) -> None:
        if self.fn is not None:
            raise TypeError(f"gauge {self.name} is callback-backed")
        self.value = float(v)

    def read(self) -> float:
        return float(self.fn()) if self.fn is not None else self.value


class MultiGauge(Metric):
    """A gauge family over one dynamic label (e.g. escalation rate by
    cascade rung, whose rung count grows during the run). ``fn()`` returns
    ``{label_value: number}`` at export time."""

    mtype = "gauge"

    def __init__(self, name, help, label_name: str,
                 fn: Callable[[], Dict], labels=(), wall=False):
        super().__init__(name, help, labels, wall)
        self.label_name = label_name
        self.fn = fn

    def read(self) -> Dict[str, float]:
        return {str(k): float(v) for k, v in self.fn().items()}


class HistogramMetric(Metric):
    """Wraps a log-bucketed :class:`Histogram` (owned or callback)."""

    mtype = "histogram"

    def __init__(self, name, help="", labels=(), hist: Optional[Histogram]
                 = None, fn=None, wall=False):
        super().__init__(name, help, labels, wall)
        if hist is not None and fn is not None:
            raise ValueError("pass hist or fn, not both")
        self.fn = fn
        self.hist = hist if hist is not None or fn is not None else Histogram()

    def observe(self, v: float) -> None:
        if self.fn is not None:
            raise TypeError(f"histogram {self.name} is callback-backed")
        self.hist.record(v)

    def resolve(self) -> Histogram:
        return self.fn() if self.fn is not None else self.hist


class MetricsRegistry:
    """All metrics of one run; exporters read it, subsystems register."""

    def __init__(self):
        self._metrics: List[Metric] = []
        self._keys = set()

    def register(self, metric: Metric) -> Metric:
        if metric.key in self._keys:
            raise ValueError(f"duplicate metric {metric.key}")
        self._keys.add(metric.key)
        self._metrics.append(metric)
        return metric

    # -- convenience constructors -------------------------------------------

    def counter(self, name, help="", labels=(), fn=None,
                wall=False) -> Counter:
        return self.register(Counter(name, help, labels, fn, wall))

    def gauge(self, name, help="", labels=(), fn=None, wall=False) -> Gauge:
        return self.register(Gauge(name, help, labels, fn, wall))

    def histogram(self, name, help="", labels=(), hist=None, fn=None,
                  wall=False) -> HistogramMetric:
        return self.register(
            HistogramMetric(name, help, labels, hist, fn, wall))

    def multi_gauge(self, name, help, label_name, fn, labels=(),
                    wall=False) -> MultiGauge:
        return self.register(
            MultiGauge(name, help, label_name, fn, labels, wall))

    def __len__(self) -> int:
        return len(self._metrics)

    # -- exporters -----------------------------------------------------------

    def snapshot(self, deterministic: bool = False) -> Dict:
        """JSON-safe snapshot: ``{series_key: {type, value|summary}}``.

        ``deterministic=True`` excludes wall-clock-backed metrics so the
        snapshot of a seeded run is replay-stable.
        """
        out: Dict[str, Dict] = {}
        for m in self._metrics:
            if deterministic and m.wall:
                continue
            if isinstance(m, MultiGauge):
                for lv, v in sorted(m.read().items()):
                    labels = m.labels + ((m.label_name, lv),)
                    out[m.name + _label_str(labels)] = {
                        "type": m.mtype, "value": _finite(v)}
            elif isinstance(m, HistogramMetric):
                h = m.resolve()
                out[m.key] = {
                    "type": m.mtype, "count": int(h.count),
                    "sum": _finite(h.total), "min": _finite(h.min),
                    "max": _finite(h.max), "mean": _finite(h.mean),
                    "p50": _finite(h.percentile(50)),
                    "p99": _finite(h.percentile(99)),
                }
            else:
                out[m.key] = {"type": m.mtype, "value": _finite(m.read())}
        return out

    def to_json(self, deterministic: bool = False) -> str:
        return json.dumps(self.snapshot(deterministic=deterministic),
                          sort_keys=True, separators=(",", ":"))

    def save(self, path: str, deterministic: bool = False) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(deterministic=deterministic))

    def prometheus(self, deterministic: bool = False) -> str:
        """Prometheus text exposition format (one scrape of the registry).

        ``deterministic=True`` skips wall-clock metrics, mirroring
        :meth:`snapshot` — streaming segment scrapes use it so the whole
        obs directory stays byte-identical across seeded replays."""
        lines: List[str] = []
        seen_names = set()
        for m in self._metrics:
            if deterministic and m.wall:
                continue
            if m.name not in seen_names:
                seen_names.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.mtype}")
            if isinstance(m, MultiGauge):
                for lv, v in sorted(m.read().items()):
                    labels = m.labels + ((m.label_name, lv),)
                    lines.append(f"{m.name}{_label_str(labels)} {v:g}")
            elif isinstance(m, HistogramMetric):
                h = m.resolve()
                # OpenMetrics-style exemplars: the histogram keeps one
                # deterministically min-hash-sampled trace key per raw
                # bucket; bucket line j carries raw bucket j's exemplar
                # and +Inf carries the overflow bucket's.
                ex = getattr(h, "exemplars", None) or {}
                cum = 0
                for j, edge in enumerate(h.edges):
                    cum = int(h.counts[: j + 1].sum())
                    labels = m.labels + (("le", f"{edge:g}"),)
                    line = f"{m.name}_bucket{_label_str(labels)} {cum}"
                    e = ex.get(j)
                    if e is not None:
                        line += f' # {{trace_key="{e[1]}"}} {e[2]:g}'
                    lines.append(line)
                labels = m.labels + (("le", "+Inf"),)
                line = (f"{m.name}_bucket{_label_str(labels)} "
                        f"{int(h.count)}")
                e = ex.get(len(h.edges))
                if e is not None:
                    line += f' # {{trace_key="{e[1]}"}} {e[2]:g}'
                lines.append(line)
                lines.append(f"{m.name}_sum{_label_str(m.labels)} "
                             f"{h.total:g}")
                lines.append(f"{m.name}_count{_label_str(m.labels)} "
                             f"{int(h.count)}")
            else:
                v = m.read()
                lines.append(f"{m.name}{_label_str(m.labels)} "
                             f"{v:g}" if math.isfinite(v)
                             else f"{m.name}{_label_str(m.labels)} NaN")
        return "\n".join(lines) + "\n"

    def save_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus())
