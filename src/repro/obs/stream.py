"""Streaming flushes: rotating trace/metrics segments on the virtual clock.

PR 6's plane is end-of-run only: the recorder buffers every event until
``save()`` and the registry is scraped once at exit. For a long-lived
service that is unbounded memory and zero mid-run visibility. The
:class:`ObsFlusher` fixes both on the runtime's *virtual* clock (so flush
points — and therefore segment contents — are deterministic for a seeded
run):

  * every ``scrape_every_s`` virtual seconds it drains the recorder's
    completed request trees (:meth:`TraceRecorder.drain` — sampling and
    cap accounting happen there) into a rotating ``trace-<seq>.json``
    segment, and snapshots the :class:`MetricsRegistry` into
    ``metrics-<seq>.json`` + ``metrics-<seq>.prom``;
  * :meth:`finalize` force-drains whatever is still open, writes the last
    segments, and drops a ``manifest.json`` describing the run (segment
    list, sampler config, drop accounting, recorder peak).

Each trace segment is itself a valid Chrome trace document (loadable in
Perfetto on its own); :func:`concat_segments` — exposed as
``tools/trace_export.py concat`` — stitches a segment directory back into
one document equivalent to what a non-streaming run would have saved,
modulo sampled-out trees.

Drive it from the host loop: the solo scheduler calls
:meth:`maybe_flush` once per dispatch step, the multi-worker plane calls
it at its deterministic event-loop points. The flusher keeps an internal
high-water mark, so calling it more often than ``scrape_every_s`` is
free, and a coarse caller just produces fewer, larger segments.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.obs.trace import (TraceRecorder, build_trace_doc,
                             trace_doc_to_json)

MANIFEST = "manifest.json"


class ObsFlusher:
    """Virtual-clock-driven segment writer for one run's recorder+registry."""

    def __init__(self, out_dir: str, *, recorder: Optional[TraceRecorder]
                 = None, registry=None, scrape_every_s: Optional[float]
                 = None, label: str = "run", include_wall: bool = False,
                 deterministic_metrics: bool = True):
        if recorder is None and registry is None:
            raise ValueError("flusher needs a recorder and/or a registry")
        if scrape_every_s is not None and scrape_every_s <= 0:
            raise ValueError("scrape_every_s must be > 0")
        self.out_dir = out_dir
        self.recorder = recorder
        self.registry = registry
        self.scrape_every_s = scrape_every_s
        self.label = label
        self.include_wall = include_wall
        self.deterministic_metrics = deterministic_metrics
        self.seq = 0
        self.trace_segments: List[str] = []
        self.metric_segments: List[str] = []
        self._next: Optional[float] = None   # next scheduled flush time
        self._finalized = False
        os.makedirs(out_dir, exist_ok=True)

    # -- driving -------------------------------------------------------------

    def maybe_flush(self, now: float) -> int:
        """Flush every ``scrape_every_s`` of virtual time; returns the number
        of flushes performed (0 almost always — cheap to call per step)."""
        if self.scrape_every_s is None or self._finalized:
            return 0
        if self._next is None:
            self._next = now + self.scrape_every_s
            return 0
        n = 0
        # Catch-up loop: a long quiet gap still yields one segment per
        # period boundary, so segment boundaries are a pure function of
        # virtual time, not of how often the host loop ticked.
        while now >= self._next:
            self.flush(self._next)
            self._next += self.scrape_every_s
            n += 1
        return n

    def flush(self, t: float, *, force: bool = False) -> None:
        """Write one segment pair stamped with virtual time ``t``."""
        seq = self.seq
        self.seq += 1
        if self.recorder is not None:
            events = self.recorder.drain(force=force)
            doc = build_trace_doc(
                events, label=self.label, include_wall=self.include_wall,
                other={"segment": seq, "t": t,
                       "drops": self.recorder.drop_stats})
            path = os.path.join(self.out_dir, f"trace-{seq:05d}.json")
            with open(path, "w") as f:
                f.write(trace_doc_to_json(doc))
            self.trace_segments.append(os.path.basename(path))
        if self.registry is not None:
            snap = {"segment": seq, "t": t,
                    "metrics": self.registry.snapshot(
                        deterministic=self.deterministic_metrics)}
            path = os.path.join(self.out_dir, f"metrics-{seq:05d}.json")
            with open(path, "w") as f:
                f.write(json.dumps(snap, sort_keys=True,
                                   separators=(",", ":")))
            self.metric_segments.append(os.path.basename(path))
            with open(os.path.join(self.out_dir,
                                   f"metrics-{seq:05d}.prom"), "w") as f:
                f.write(self.registry.prometheus(
                    deterministic=self.deterministic_metrics))

    def finalize(self, now: float) -> str:
        """Force-drain the tail, write the manifest; returns manifest path."""
        if not self._finalized:
            self.flush(now, force=True)
            self._finalized = True
        manifest = {
            "label": self.label,
            "scrape_every_s": self.scrape_every_s,
            "trace_segments": self.trace_segments,
            "metric_segments": self.metric_segments,
        }
        if self.recorder is not None:
            manifest["drops"] = self.recorder.drop_stats
            manifest["peak_buffered"] = self.recorder.peak_buffered
            if self.recorder.sampler is not None:
                manifest["sampler"] = self.recorder.sampler.describe()
        path = os.path.join(self.out_dir, MANIFEST)
        with open(path, "w") as f:
            f.write(json.dumps(manifest, sort_keys=True,
                               separators=(",", ":")))
        return path

    def describe(self) -> Dict:
        return {"out_dir": self.out_dir,
                "scrape_every_s": self.scrape_every_s,
                "segments": self.seq}


# -- segment stitching --------------------------------------------------------


def segment_paths(obs_dir: str) -> List[str]:
    """Trace segment files of an obs directory, in flush order (via the
    manifest when present, else by the zero-padded filename)."""
    mpath = os.path.join(obs_dir, MANIFEST)
    if os.path.exists(mpath):
        with open(mpath) as f:
            names = json.load(f).get("trace_segments", [])
    else:
        names = sorted(n for n in os.listdir(obs_dir)
                       if n.startswith("trace-") and n.endswith(".json"))
    return [os.path.join(obs_dir, n) for n in names]


def concat_segments(paths: List[str], label: Optional[str] = None) -> Dict:
    """Stitch trace segments into one valid Chrome trace document.

    Metadata (``ph: "M"``) rows are deduplicated per pid; event rows keep
    segment order (each segment is internally (ts, wid)-sorted, and later
    segments hold later-closing trees — Perfetto does not require global
    ts order). ``otherData`` reports the stitch and the final segment's
    drop accounting.
    """
    events: List[Dict] = []
    meta: Dict[int, Dict] = {}
    other: Dict = {}
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        for ev in doc.get("traceEvents", ()):
            if ev.get("ph") == "M":
                meta.setdefault(ev.get("pid", 0), ev)
            else:
                events.append(ev)
        other = doc.get("otherData", {}) or other
    out_other = {"label": label if label is not None
                 else other.get("label", "run"),
                 "deterministic": other.get("deterministic", True),
                 "segments": len(paths)}
    if "drops" in other:
        out_other["drops"] = other["drops"]
    return {
        "traceEvents": [meta[p] for p in sorted(meta)] + events,
        "displayTimeUnit": "ms",
        "otherData": out_other,
    }


def concat_dir(obs_dir: str, label: Optional[str] = None) -> Dict:
    return concat_segments(segment_paths(obs_dir), label=label)
