"""Unified observability plane: tracing, metrics, streaming, SLOs.

Pillars over the serving fleet:

  * :mod:`repro.obs.trace` — deterministic per-request trace spans over
    the runtime's virtual clocks, exported as Chrome-trace/Perfetto JSON
    (complete spans, instants, and native counter tracks);
  * :mod:`repro.obs.metrics` — a counter/gauge/histogram registry with
    Prometheus-text and canonical-JSON exporters;
  * :mod:`repro.obs.stream` — virtual-clock-driven segment flushes that
    bound recorder memory for long-lived runs, plus segment stitching;
  * :mod:`repro.obs.sampling` — deterministic head+tail per-request trace
    sampling with an always-keep anomaly lane and a hard buffered cap;
  * :mod:`repro.obs.slo` — SLO monitors with multi-window burn-rate
    alerting on the virtual clock;
  * :mod:`repro.obs.scrape` — a localhost HTTP endpoint serving the live
    registry (``/metrics`` Prometheus text, ``/metrics.json``);
  * :mod:`repro.obs.profiling` — wall-clock (+ optional jax profiler)
    timing hooks around the Pallas kernel entry points.

``repro.obs.wiring`` registers the standard serving metric series;
``launch/serve.py`` wires everything into the serving driver
(``--trace-out/--metrics-out/--scrape-every/--trace-sample/--slo-*``),
and ``tools/trace_export.py`` / ``tools/obs_smoke.py`` consume the
artifacts.
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    HistogramMetric,
    MetricsRegistry,
    MultiGauge,
)
from repro.obs.profiling import KernelProfiler
from repro.obs.sampling import TraceSampler, is_anomaly_event
from repro.obs.scrape import MetricsServer, merge_prom_texts
from repro.obs.slo import (
    BurnRateSLO,
    RollingWindow,
    SLOTracker,
    SpendBurnSLO,
    build_slo_tracker,
)
from repro.obs.stream import ObsFlusher, concat_dir, concat_segments
from repro.obs.trace import (
    WALL_CATS,
    ScopedTrace,
    TraceRecorder,
    build_trace_doc,
    request_trees,
    trace_summary,
    validate_chrome_trace,
    validate_span_tree,
)
from repro.obs.wiring import (
    register_governor_metrics,
    register_plane_metrics,
    register_scheduler_metrics,
    register_slo_metrics,
    register_stream_metrics,
    register_transport_metrics,
)

__all__ = [
    "BurnRateSLO",
    "Counter",
    "Gauge",
    "HistogramMetric",
    "KernelProfiler",
    "MetricsRegistry",
    "MetricsServer",
    "MultiGauge",
    "ObsFlusher",
    "RollingWindow",
    "SLOTracker",
    "ScopedTrace",
    "SpendBurnSLO",
    "TraceRecorder",
    "TraceSampler",
    "WALL_CATS",
    "build_slo_tracker",
    "build_trace_doc",
    "concat_dir",
    "concat_segments",
    "is_anomaly_event",
    "merge_prom_texts",
    "register_governor_metrics",
    "register_plane_metrics",
    "register_scheduler_metrics",
    "register_slo_metrics",
    "register_stream_metrics",
    "register_transport_metrics",
    "request_trees",
    "trace_summary",
    "validate_chrome_trace",
    "validate_span_tree",
]
