"""Unified observability plane: tracing, metrics, kernel profiling.

Three pillars over the serving fleet:

  * :mod:`repro.obs.trace` — deterministic per-request trace spans over
    the runtime's virtual clocks, exported as Chrome-trace/Perfetto JSON;
  * :mod:`repro.obs.metrics` — a counter/gauge/histogram registry with
    Prometheus-text and canonical-JSON exporters;
  * :mod:`repro.obs.profiling` — wall-clock (+ optional jax profiler)
    timing hooks around the Pallas kernel entry points.

``repro.obs.wiring`` registers the standard serving metric series;
``launch/serve.py --trace-out/--metrics-out`` wires everything into the
serving driver, and ``tools/trace_export.py`` / ``tools/obs_smoke.py``
consume the artifacts.
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    HistogramMetric,
    MetricsRegistry,
    MultiGauge,
)
from repro.obs.profiling import KernelProfiler
from repro.obs.trace import (
    WALL_CATS,
    ScopedTrace,
    TraceRecorder,
    request_trees,
    trace_summary,
    validate_chrome_trace,
    validate_span_tree,
)
from repro.obs.wiring import (
    register_governor_metrics,
    register_plane_metrics,
    register_scheduler_metrics,
)

__all__ = [
    "Counter",
    "Gauge",
    "HistogramMetric",
    "KernelProfiler",
    "MetricsRegistry",
    "MultiGauge",
    "ScopedTrace",
    "TraceRecorder",
    "WALL_CATS",
    "register_governor_metrics",
    "register_plane_metrics",
    "register_scheduler_metrics",
    "request_trees",
    "trace_summary",
    "validate_chrome_trace",
    "validate_span_tree",
]
