"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These match :mod:`repro.core.predictors.attention_scores` /
:mod:`repro.core.clustering.pairwise_sq_dists` semantics exactly; kernel
tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def router_xattn_ref(q, wq, wk, wv, wo, bo, m_emb):
    """Reference fused routing scores.

    q (B, dq); m_emb (K, dm); wq (dq, d); wk/wv (dm, d); wo (d, K); bo (K,).
    Returns (B, K) fp32 scores.
    """
    qf = q.astype(jnp.float32)
    qp = qf @ wq.astype(jnp.float32)
    kt = m_emb.astype(jnp.float32) @ wk.astype(jnp.float32)
    vt = m_emb.astype(jnp.float32) @ wv.astype(jnp.float32)
    d = qp.shape[-1]
    logits = (qp @ kt.T) / math.sqrt(d)
    alpha = jnp.exp(logits - logits.max(-1, keepdims=True))
    alpha = alpha / alpha.sum(-1, keepdims=True)
    ctx = alpha @ vt
    return ctx @ wo.astype(jnp.float32) + bo.astype(jnp.float32)


def pairwise_l2_ref(x, c):
    """(N, d), (K, d) -> (N, K) squared euclidean distances, fp32."""
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    x2 = jnp.sum(xf * xf, axis=1, keepdims=True)
    c2 = jnp.sum(cf * cf, axis=1)
    return jnp.maximum(x2 - 2.0 * (xf @ cf.T) + c2[None, :], 0.0)
