"""Fused single-head cross-attention routing-score kernel (Pallas TPU).

The paper's serving hot path: score a batch of query embeddings against the
model pool. One VMEM-resident pass per batch tile computes

    qp     = q @ Wq                       (768 -> d_latent)
    logits = qp @ K~^T / sqrt(d)          (K~ = model_emb @ Wk, precomputed)
    alpha  = softmax_K(logits)
    ctx    = alpha @ V~
    scores = ctx @ Wo + bo                ((B_tile, K) per-model scores)

TPU adaptation: the paper's latent d=20 and pool size K<=16 are far below
MXU/VPU tile granularity, so the wrapper (ops.py) zero-pads d_latent and K
to 128 lanes; padded K columns are masked to -inf before the softmax. One
batch tile (default 256 rows) keeps the whole working set
(256x768 q + 768x128 Wq + 3x128x128 pool mats ~ 1.2 MB fp32) comfortably in
the ~16 MB v5e VMEM while saturating the 128x128 MXU.

Grid: (B / block_b,). All operands are placed in VMEM via BlockSpecs; the
pool-side matrices are small and broadcast to every grid step.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _router_xattn_kernel(
    q_ref,      # (block_b, dq)
    wq_ref,     # (dq, d_pad)
    kt_ref,     # (k_pad, d_pad)   projected model keys
    vt_ref,     # (k_pad, d_pad)   projected model values
    wo_ref,     # (d_pad, k_pad)
    bo_ref,     # (1, k_pad)
    kmask_ref,  # (1, k_pad)  1.0 for real models, 0.0 for padding
    out_ref,    # (block_b, k_pad)
    *,
    d_latent: int,
):
    q = q_ref[...].astype(jnp.float32)
    wq = wq_ref[...].astype(jnp.float32)
    qp = jnp.dot(q, wq, preferred_element_type=jnp.float32)       # (b, d_pad)

    kt = kt_ref[...].astype(jnp.float32)                          # (K, d_pad)
    scale = 1.0 / math.sqrt(d_latent)
    logits = jnp.dot(qp, kt.T, preferred_element_type=jnp.float32) * scale

    kmask = kmask_ref[0, :]                                       # (k_pad,)
    logits = jnp.where(kmask[None, :] > 0, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    alpha = e / jnp.sum(e, axis=-1, keepdims=True)                # (b, K)

    vt = vt_ref[...].astype(jnp.float32)
    ctx = jnp.dot(alpha, vt, preferred_element_type=jnp.float32)  # (b, d_pad)

    wo = wo_ref[...].astype(jnp.float32)
    scores = jnp.dot(ctx, wo, preferred_element_type=jnp.float32)
    out_ref[...] = (scores + bo_ref[0, :][None, :]).astype(out_ref.dtype)


def router_xattn_pallas(
    q, wq, kt, vt, wo, bo, kmask, *, d_latent: int, block_b: int = 256,
    interpret: bool = False,
):
    """Padded-shape kernel entry. q (B, dq); B % block_b == 0."""
    b, dq = q.shape
    k_pad, d_pad = kt.shape
    assert b % block_b == 0, (b, block_b)
    kernel = functools.partial(_router_xattn_kernel, d_latent=d_latent)
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, dq), lambda i: (i, 0)),
            pl.BlockSpec((dq, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((k_pad, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((k_pad, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((d_pad, k_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k_pad), jnp.float32),
        interpret=interpret,
    )(q, wq, kt, vt, wo, bo, kmask)
