"""jit'd public wrappers around the Pallas kernels.

Handle padding to TPU tile granularity (128 lanes), interpret-mode fallback
on CPU (this container), and un-padding of results. The rest of the codebase
calls only these entry points.

Profiling: :func:`set_kernel_profiler` installs a
:class:`repro.obs.profiling.KernelProfiler` (or anything with a compatible
``annotate(name, batch=...)`` context manager) around the serving-hot
entry points — ``router_xattn_pool`` and ``pairwise_l2``. With a profiler
installed each dispatch blocks until the result is ready (so the timing
covers device work, not just dispatch) and lands in per-kernel latency
histograms / per-batch trace spans; with none installed (the default) the
call goes straight to the jit'd function.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pairwise_l2 import pairwise_l2_pallas
from repro.kernels.router_xattn import router_xattn_pallas

LANE = 128

# Installed profiler (None = zero-overhead pass-through).
_PROFILER = None


def set_kernel_profiler(profiler) -> None:
    """Install (or with ``None`` remove) the kernel dispatch profiler."""
    global _PROFILER
    _PROFILER = profiler


def get_kernel_profiler():
    return _PROFILER


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pool_projections(wk, wv, m_emb):
    """Pool-side K~ = m_emb Wk and V~ = m_emb Wv (fp32, (K, d)).

    Per-pool constants at serving time: compute once when the pool is
    (re)built and reuse across every score batch via
    :func:`router_xattn_pool`.
    """
    kt = m_emb.astype(jnp.float32) @ wk.astype(jnp.float32)
    vt = m_emb.astype(jnp.float32) @ wv.astype(jnp.float32)
    return kt, vt


def _xattn_padded(q, wq, kt, vt, wo, bo, *, block_b, interpret):
    """Pad to TPU tile granularity and invoke the Pallas kernel."""
    b, dq = q.shape
    k, d = kt.shape

    d_pad = _round_up(d, LANE)
    k_pad = _round_up(k, LANE)
    b_pad = _round_up(b, block_b)

    qp = jnp.pad(q, ((0, b_pad - b), (0, 0)))
    wq_p = jnp.pad(wq, ((0, 0), (0, d_pad - d)))
    kt_p = jnp.pad(kt, ((0, k_pad - k), (0, d_pad - d)))
    vt_p = jnp.pad(vt, ((0, k_pad - k), (0, d_pad - d)))
    wo_p = jnp.pad(wo, ((0, d_pad - d), (0, k_pad - k)))
    bo_p = jnp.pad(bo, (0, k_pad - k))[None, :]
    kmask = (jnp.arange(k_pad) < k).astype(jnp.float32)[None, :]

    out = router_xattn_pallas(
        qp, wq_p, kt_p, vt_p, wo_p, bo_p, kmask,
        d_latent=d, block_b=block_b, interpret=interpret,
    )
    return out[:b, :k]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def router_xattn(
    q, wq, wk, wv, wo, bo, m_emb, *, block_b: int = 256, interpret: bool = None
):
    """Fused routing scores: q (B, dq), m_emb (K, dm) -> (B, K) fp32.

    Pads d_latent and K to 128 lanes and B to the batch tile; the pool-side
    projections (K~ = m_emb Wk etc.) are tiny and computed outside the
    kernel (they are per-pool constants at serving time).
    """
    if interpret is None:
        interpret = not _on_tpu()
    kt, vt = pool_projections(wk, wv, m_emb)
    return _xattn_padded(q, wq, kt, vt, wo, bo,
                         block_b=block_b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _router_xattn_pool_jit(
    q, wq, kt, vt, wo, bo, *, block_b: int = 256, interpret: bool = None
):
    if interpret is None:
        interpret = not _on_tpu()
    return _xattn_padded(q, wq, kt, vt, wo, bo,
                         block_b=block_b, interpret=interpret)


def router_xattn_pool(
    q, wq, kt, vt, wo, bo, *, block_b: int = 256, interpret: bool = None
):
    """Fused routing scores against precomputed pool projections.

    The serving scheduler's hot path: K~/V~ from :func:`pool_projections`
    are computed once per pool and reused across every score micro-batch,
    so the per-batch work is only the query-side projection + attention.
    """
    if _PROFILER is None:
        return _router_xattn_pool_jit(q, wq, kt, vt, wo, bo,
                                      block_b=block_b, interpret=interpret)
    with _PROFILER.annotate("router_xattn_pool", batch=int(q.shape[0])):
        out = _router_xattn_pool_jit(q, wq, kt, vt, wo, bo,
                                     block_b=block_b, interpret=interpret)
        jax.block_until_ready(out)
    return out


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def _pairwise_l2_jit(
    x, c, *, block_n: int = 256, block_k: int = 256, interpret: bool = None
):
    if interpret is None:
        interpret = not _on_tpu()
    n, d = x.shape
    k = c.shape[0]
    block_n = min(block_n, _round_up(n, 8))
    block_k = min(block_k, _round_up(k, 8))
    n_pad = _round_up(n, block_n)
    k_pad = _round_up(k, block_k)
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    cp = jnp.pad(c, ((0, k_pad - k), (0, 0)))
    out = pairwise_l2_pallas(
        xp, cp, block_n=block_n, block_k=block_k, interpret=interpret
    )
    return out[:n, :k]


def pairwise_l2(
    x, c, *, block_n: int = 256, block_k: int = 256, interpret: bool = None
):
    """Squared L2 distances x (N,d) vs c (K,d) -> (N,K) fp32."""
    if _PROFILER is None:
        return _pairwise_l2_jit(x, c, block_n=block_n, block_k=block_k,
                                interpret=interpret)
    with _PROFILER.annotate("pairwise_l2", batch=int(x.shape[0])):
        out = _pairwise_l2_jit(x, c, block_n=block_n, block_k=block_k,
                               interpret=interpret)
        jax.block_until_ready(out)
    return out
