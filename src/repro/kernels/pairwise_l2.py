"""Tiled pairwise squared-L2 distance kernel (Pallas TPU).

Backs the KNN baseline router and k-means model-embedding construction:
dist2[n, k] = ||x_n - c_k||^2 computed as x2 + c2 - 2 x.c with the cross
term on the MXU.

Grid: (N / block_n, K / block_k); the feature dimension is kept whole in
VMEM (d <= 1024 covers the 768-d embeddings; block_n=256, block_k=256 tiles
use ~1.5 MB). Squared norms are computed in-kernel, so the only HBM traffic
is the two operand tiles and the output tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_l2_kernel(x_ref, c_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)            # (bn, d)
    c = c_ref[...].astype(jnp.float32)            # (bk, d)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)    # (bn, 1)
    c2 = jnp.sum(c * c, axis=1)                   # (bk,)
    cross = jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    d2 = x2 - 2.0 * cross + c2[None, :]
    out_ref[...] = jnp.maximum(d2, 0.0).astype(out_ref.dtype)


def pairwise_l2_pallas(
    x, c, *, block_n: int = 256, block_k: int = 256, interpret: bool = False
):
    """x (N, d), c (K, d) -> (N, K) squared distances. N, K pre-padded."""
    n, d = x.shape
    k = c.shape[0]
    assert n % block_n == 0 and k % block_k == 0, (n, k, block_n, block_k)
    return pl.pallas_call(
        _pairwise_l2_kernel,
        grid=(n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(x, c)
