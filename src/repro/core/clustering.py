"""K-means from scratch (no sklearn offline): kmeans++ init + Lloyd.

Backs the training-free model embeddings (paper §5) and the KNN baseline's
neighborhood machinery. Deterministic under a seed.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(N, d) x (K, d) -> (N, K) squared euclidean distances."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    return jnp.maximum(x2 - 2.0 * (x @ c.T) + c2[None, :], 0.0)


def _kmeanspp_init(key, x: jnp.ndarray, k: int) -> jnp.ndarray:
    n = x.shape[0]
    keys = jax.random.split(key, k)
    idx0 = jax.random.randint(keys[0], (), 0, n)
    centers = [x[idx0]]
    d2 = pairwise_sq_dists(x, jnp.stack(centers))[:, 0]
    for i in range(1, k):
        probs = d2 / jnp.maximum(d2.sum(), 1e-12)
        idx = jax.random.choice(keys[i], n, p=probs)
        centers.append(x[idx])
        d2 = jnp.minimum(d2, pairwise_sq_dists(x, x[idx][None])[:, 0])
    return jnp.stack(centers)


def kmeans(
    x: np.ndarray, k: int, *, seed: int = 0, n_iters: int = 50
) -> Tuple[np.ndarray, np.ndarray]:
    """Lloyd's algorithm. Returns (centroids (K,d), assignments (N,))."""
    xj = jnp.asarray(x, jnp.float32)
    centers = _kmeanspp_init(jax.random.key(seed), xj, k)

    @jax.jit
    def step(c):
        assign = jnp.argmin(pairwise_sq_dists(xj, c), axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)     # (N,K)
        counts = onehot.sum(axis=0)                                # (K,)
        sums = onehot.T @ xj                                       # (K,d)
        new_c = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), c
        )
        return new_c, assign

    assign = None
    for _ in range(n_iters):
        new_centers, assign = step(centers)
        if bool(jnp.allclose(new_centers, centers, atol=1e-6)):
            centers = new_centers
            break
        centers = new_centers
    return np.asarray(centers), np.asarray(assign)


def assign_clusters(x: np.ndarray, centers: np.ndarray) -> np.ndarray:
    d = pairwise_sq_dists(jnp.asarray(x, jnp.float32), jnp.asarray(centers, jnp.float32))
    return np.asarray(jnp.argmin(d, axis=1))
