"""The paper's contribution: cost-aware cross-attention LLM routing."""
from repro.core.predictors import ENSEMBLE_KINDS, PREDICTORS, attention_scores
from repro.core.rewards import (
    REWARDS,
    cascade_outcome,
    cascade_reward,
    reward_exponential,
    reward_linear,
    route,
)
from repro.core.metrics import (
    DEFAULT_LAMBDA_GRID,
    aiq,
    evaluate_router,
    frontier_dominance,
    frontier_value_at,
    lam_sensitivity,
    max_calls_fraction,
    pareto_frontier,
    routed_points,
)
from repro.core.model_repr import build_model_embeddings, embed_new_model
from repro.core.router import (
    PredictiveRouter,
    evaluate_sweep,
    oracle_sweep,
)
from repro.core.clustering import kmeans, pairwise_sq_dists

__all__ = [
    "ENSEMBLE_KINDS", "PREDICTORS", "REWARDS", "attention_scores",
    "cascade_outcome", "cascade_reward", "reward_exponential",
    "reward_linear", "route", "DEFAULT_LAMBDA_GRID", "aiq", "evaluate_router",
    "frontier_dominance", "frontier_value_at",
    "lam_sensitivity", "max_calls_fraction", "pareto_frontier",
    "routed_points", "build_model_embeddings", "embed_new_model",
    "PredictiveRouter", "evaluate_sweep", "oracle_sweep", "kmeans",
    "pairwise_sq_dists",
]
