"""The paper's contribution: cost-aware cross-attention LLM routing."""
from repro.core.predictors import PREDICTORS, attention_scores
from repro.core.rewards import REWARDS, reward_exponential, reward_linear, route
from repro.core.metrics import (
    DEFAULT_LAMBDA_GRID,
    aiq,
    evaluate_router,
    lam_sensitivity,
    max_calls_fraction,
    pareto_frontier,
    routed_points,
)
from repro.core.model_repr import build_model_embeddings, embed_new_model
from repro.core.router import (
    PredictiveRouter,
    evaluate_sweep,
    oracle_sweep,
)
from repro.core.clustering import kmeans, pairwise_sq_dists

__all__ = [
    "PREDICTORS", "REWARDS", "attention_scores", "reward_exponential",
    "reward_linear", "route", "DEFAULT_LAMBDA_GRID", "aiq", "evaluate_router",
    "lam_sensitivity", "max_calls_fraction", "pareto_frontier",
    "routed_points", "build_model_embeddings", "embed_new_model",
    "PredictiveRouter", "evaluate_sweep", "oracle_sweep", "kmeans",
    "pairwise_sq_dists",
]
