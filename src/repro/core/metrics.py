"""Routing evaluation metrics: Pareto frontier, AIQ, lambda-sensitivity.

AIQ (paper Eq. 1): sweep the user parameter lambda over a grid; each lambda
yields an (average cost, average quality) point on the test set. The
non-decreasing convex hull of those points is the router's cost-quality
Pareto frontier; AIQ is the area under that frontier divided by the cost
range [a, b].

lambda-sensitivity (paper Eq. 2): log-lambda-weighted average change of
performance (resp. cost) — lower means the router is stabler in the user
parameter.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

DEFAULT_LAMBDA_GRID = np.logspace(-4.5, 1.5, 25)


def routed_points(
    choices_per_lam: np.ndarray,      # (L, B) routed model index per lambda
    quality: np.ndarray,              # (B, K) true quality per (query, model)
    cost: np.ndarray,                 # (B, K) true cost
) -> Tuple[np.ndarray, np.ndarray]:
    """Average (cost, quality) per lambda. Returns (costs (L,), perfs (L,))."""
    b = np.arange(quality.shape[0])
    costs, perfs = [], []
    for ch in choices_per_lam:
        costs.append(float(cost[b, ch].mean()))
        perfs.append(float(quality[b, ch].mean()))
    return np.asarray(costs), np.asarray(perfs)


def pareto_frontier(costs: np.ndarray, perfs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Upper-left non-decreasing convex hull of the (cost, perf) points.

    Returns frontier (costs_sorted, hull_perfs) suitable for trapezoid
    integration. Duplicate costs keep the best perf.
    """
    order = np.argsort(costs, kind="stable")
    cs, ps = costs[order], perfs[order]
    # Dedup equal costs keeping max perf.
    uniq_c, uniq_p = [], []
    for c, p in zip(cs, ps):
        if uniq_c and np.isclose(c, uniq_c[-1]):
            uniq_p[-1] = max(uniq_p[-1], p)
        else:
            uniq_c.append(float(c))
            uniq_p.append(float(p))
    cs, ps = np.asarray(uniq_c), np.asarray(uniq_p)
    if len(cs) == 1:
        return cs, ps
    # Monotone non-decreasing envelope.
    ps = np.maximum.accumulate(ps)
    # Upper convex hull (Andrew's monotone chain, keeping concave-down turns).
    hull: list = []
    for x, y in zip(cs, ps):
        while len(hull) >= 2:
            (x1, y1), (x2, y2) = hull[-2], hull[-1]
            # Remove middle point if it lies below the chord (convexity).
            if (y2 - y1) * (x - x1) <= (y - y1) * (x2 - x1):
                hull.pop()
            else:
                break
        hull.append((float(x), float(y)))
    hx = np.asarray([h[0] for h in hull])
    hy = np.asarray([h[1] for h in hull])
    return hx, hy


def aiq(costs: np.ndarray, perfs: np.ndarray) -> float:
    """Average Improvement in Quality: hull area / cost range (Eq. 1)."""
    hx, hy = pareto_frontier(costs, perfs)
    if len(hx) < 2 or np.isclose(hx[-1], hx[0]):
        return float(hy.max())
    area = float(np.trapezoid(hy, hx))
    return area / float(hx[-1] - hx[0])


def frontier_value_at(costs: np.ndarray, perfs: np.ndarray,
                      at_cost: float) -> float:
    """Quality the frontier of (costs, perfs) delivers at budget ``at_cost``.

    Linear interpolation on the non-decreasing convex hull. Below the
    hull's cheapest point the frontier delivers nothing comparable
    (-inf — the policy cannot spend that little); above its priciest
    point the hull is flat (spending more cannot *lose* quality).
    """
    hx, hy = pareto_frontier(np.asarray(costs, np.float64),
                             np.asarray(perfs, np.float64))
    if at_cost < hx[0] and not np.isclose(at_cost, hx[0]):
        return float("-inf")
    return float(np.interp(at_cost, hx, hy))


def frontier_dominance(
    costs_a: np.ndarray, perfs_a: np.ndarray,
    costs_b: np.ndarray, perfs_b: np.ndarray,
    tol: float = 1e-9,
) -> np.ndarray:
    """Pointwise weak dominance of frontier A over B's operating points.

    For each point (c_i, p_i) traced by policy B, True when policy A's
    frontier delivers at least p_i quality at budget c_i (within ``tol``).
    The cascade acceptance gate counts these: a cascade dominates the
    single-shot router at a lambda point when, for the single-shot
    policy's realized spend there, the cascade frontier matches or beats
    its realized quality.
    """
    costs_b = np.asarray(costs_b, np.float64)
    perfs_b = np.asarray(perfs_b, np.float64)
    return np.asarray([
        frontier_value_at(costs_a, perfs_a, c) >= p - tol
        for c, p in zip(costs_b, perfs_b)
    ])


def lam_sensitivity(lams: Sequence[float], values: Sequence[float]) -> float:
    """Paper Eq. 2: sum_i log(l_{i+1}/l_i)*(v_{i+1}-v_i) / log(l_n/l_1)."""
    lams = np.asarray(lams, dtype=np.float64)
    values = np.asarray(values, dtype=np.float64)
    if len(lams) < 2:
        return 0.0
    num = float(np.sum(np.log(lams[1:] / lams[:-1]) * np.abs(np.diff(values))))
    den = float(np.log(lams[-1] / lams[0]))
    return num / den


def max_calls_fraction(
    choices_per_lam: np.ndarray, expensive_idx: int
) -> float:
    """Max over lambda of the fraction of queries routed to the priciest model."""
    fracs = (choices_per_lam == expensive_idx).mean(axis=1)
    return float(fracs.max())


def evaluate_router(
    choices_per_lam: np.ndarray,
    quality: np.ndarray,
    cost: np.ndarray,
    lams: np.ndarray,
    expensive_idx: int,
) -> Dict[str, float]:
    """All paper metrics for one router on one test set."""
    costs, perfs = routed_points(choices_per_lam, quality, cost)
    return {
        "aiq": aiq(costs, perfs),
        "perf_max": float(perfs.max()),
        "lam_sens_perf": lam_sensitivity(lams, perfs),
        "lam_sens_cost": lam_sensitivity(lams, costs),
        "max_calls_expensive": max_calls_fraction(choices_per_lam, expensive_idx),
        "avg_costs": costs,
        "avg_perfs": perfs,
    }
