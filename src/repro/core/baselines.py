"""Baseline routers from RouterBench (KNN, MLP, SVM) + LLM-Blender.

Implemented from scratch (sklearn is unavailable offline):

  * KNN (k=20): predicted quality of model m = mean observed quality of m on
    the k nearest training prompts (euclidean in embedding space).
  * SVM (margin=0): one linear SVM per model trained with hinge loss on
    binarized correctness; the (calibrated) decision value is the quality
    estimate.
  * MLP: RouterBench's MLP router — same role as the 2-FCN predictor but
    trained as a baseline quality head (cost estimated per-model mean).
  * LLM-Blender: post-generation ensembling — queries EVERY pool member,
    ranks responses pairwise, answers with the argmax-wins model. Its cost
    is the sum of all model costs per prompt (paper §5). Without PairRM
    offline, the pairwise judge is simulated: a comparison of the true
    qualities observed under judge noise (flip probability eps), which is
    exactly how a pairwise reward model behaves to first order.

All baselines route through the same reward machinery so AIQ is comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import pairwise_sq_dists


# ---------------------------------------------------------------------------
# KNN router
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KNNRouter:
    train_emb: np.ndarray        # (N, d)
    train_quality: np.ndarray    # (N, K)
    train_cost: np.ndarray       # (N, K)
    k: int = 20

    def predict(self, q_emb: np.ndarray, batch: int = 1024):
        """Mean quality/cost of the k nearest training prompts."""
        xt = jnp.asarray(self.train_emb)
        sq = jnp.asarray(self.train_quality)
        sc = jnp.asarray(self.train_cost)
        k = min(self.k, self.train_emb.shape[0])

        @jax.jit
        def chunk(q):
            d = pairwise_sq_dists(q, xt)                    # (B, N)
            _, idx = jax.lax.top_k(-d, k)                   # (B, k)
            return sq[idx].mean(axis=1), sc[idx].mean(axis=1)

        outs_s, outs_c = [], []
        for i in range(0, len(q_emb), batch):
            s, c = chunk(jnp.asarray(q_emb[i : i + batch]))
            outs_s.append(np.asarray(s))
            outs_c.append(np.asarray(c))
        return np.concatenate(outs_s), np.concatenate(outs_c)


# ---------------------------------------------------------------------------
# Linear SVM router (hinge loss, from scratch)
# ---------------------------------------------------------------------------

def _train_linear_svm(
    x: np.ndarray, y: np.ndarray, *, c_reg: float = 1.0, epochs: int = 200,
    lr: float = 0.05, seed: int = 0,
) -> Tuple[np.ndarray, float]:
    """Binary linear SVM via hinge-loss full-batch GD. y in {-1, +1}."""
    xj, yj = jnp.asarray(x), jnp.asarray(y, jnp.float32)
    d = x.shape[1]
    w = jnp.zeros((d,))
    b = jnp.float32(0.0)

    def loss(params):
        w, b = params
        margins = yj * (xj @ w + b)
        hinge = jnp.mean(jnp.maximum(0.0, 1.0 - margins))
        return 0.5 / c_reg * jnp.sum(w * w) / len(x) + hinge

    grad = jax.jit(jax.grad(loss))
    params = (w, b)
    for _ in range(epochs):
        g = grad(params)
        params = jax.tree.map(lambda p_, g_: p_ - lr * g_, params, g)
    return np.asarray(params[0]), float(params[1])


@dataclasses.dataclass
class SVMRouter:
    weights: np.ndarray          # (K, d)
    biases: np.ndarray           # (K,)
    mean_cost: np.ndarray        # (K,)
    margin: float = 0.0

    @classmethod
    def fit(cls, train_emb, train_quality, train_cost, margin: float = 0.0):
        n, k = train_quality.shape
        ws, bs = [], []
        for m in range(k):
            y = np.where(train_quality[:, m] > 0.5, 1.0, -1.0)
            w, b = _train_linear_svm(train_emb, y)
            ws.append(w)
            bs.append(b)
        return cls(
            weights=np.stack(ws),
            biases=np.asarray(bs),
            mean_cost=train_cost.mean(axis=0),
            margin=margin,
        )

    def predict(self, q_emb: np.ndarray):
        dec = q_emb @ self.weights.T + self.biases       # (B, K)
        # Squash decision values to a [0,1] quality proxy; margin shifts the
        # decision boundary (margin=0 in the paper's configuration).
        s_hat = 1.0 / (1.0 + np.exp(-(dec - self.margin)))
        c_hat = np.broadcast_to(self.mean_cost, s_hat.shape)
        return s_hat, c_hat


# ---------------------------------------------------------------------------
# LLM-Blender (post-generation, simulated PairRM)
# ---------------------------------------------------------------------------

def llm_blender_choices(
    quality: np.ndarray, *, judge_noise: float = 0.1, seed: int = 0
) -> np.ndarray:
    """Per-prompt argmax-wins over all pairwise comparisons. (B,) indices."""
    rng = np.random.default_rng(seed)
    b, k = quality.shape
    wins = np.zeros((b, k), dtype=np.int32)
    for i in range(k):
        for j in range(i + 1, k):
            better = quality[:, i] >= quality[:, j]
            flip = rng.random(b) < judge_noise
            i_wins = better ^ flip
            wins[:, i] += i_wins
            wins[:, j] += ~i_wins
    return wins.argmax(axis=1)


def llm_blender_eval(quality: np.ndarray, cost: np.ndarray, **kw):
    """(perf, total_cost): quality of the winner, cost of querying everyone."""
    ch = llm_blender_choices(quality, **kw)
    b = np.arange(len(ch))
    perf = float(quality[b, ch].mean())
    total_cost = float(cost.sum(axis=1).mean())
    return perf, total_cost
