"""Predictor-based routing framework (paper §3).

A :class:`PredictiveRouter` bundles a trained quality predictor and a cost
predictor; routing is ``argmax_m Reward(s_hat, c_hat; lambda)``. Training of
the predictors is decoupled from the user parameter lambda (the point of the
framework), so a single trained router serves the whole lambda sweep.

The oracle router applies the same reward to the *true* (s, c) — the paper's
gold standard for each reward function.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rewards as rewards_mod
from repro.core.metrics import DEFAULT_LAMBDA_GRID, evaluate_router
from repro.core.predictors import PREDICTORS


@dataclasses.dataclass
class PredictiveRouter:
    quality_kind: str
    cost_kind: str
    quality_params: Dict
    cost_params: Dict
    model_emb: np.ndarray            # (K, C)
    reward: str = "R2"
    cost_scaler: Optional[Dict] = None   # {"mu","sd"} from the cost trainer

    def denormalize_cost(self, c_hat: np.ndarray) -> np.ndarray:
        """Undo the cost trainer's target normalization and clamp at zero.

        The single place this happens — every scoring path (predict here,
        the serving engine's fused Pallas path) must route through it so the
        two cannot drift.
        """
        c_hat = np.asarray(c_hat)
        if self.cost_scaler is not None:
            c_hat = c_hat * self.cost_scaler["sd"] + self.cost_scaler["mu"]
        return np.maximum(c_hat, 0.0)

    def predict(self, q_emb: np.ndarray):
        m = jnp.asarray(self.model_emb)
        q = jnp.asarray(q_emb)
        s_hat = PREDICTORS[self.quality_kind].apply(self.quality_params, q, m)
        c_hat = PREDICTORS[self.cost_kind].apply(self.cost_params, q, m)
        return np.asarray(s_hat), self.denormalize_cost(c_hat)

    def route(self, q_emb: np.ndarray, lam: float) -> np.ndarray:
        s_hat, c_hat = self.predict(q_emb)
        return np.asarray(rewards_mod.route(self.reward, s_hat, c_hat, lam))

    def sweep(self, q_emb: np.ndarray, lams: Sequence[float]) -> np.ndarray:
        """(L, B) routed indices across the lambda grid (one predict pass)."""
        s_hat, c_hat = self.predict(q_emb)
        out = []
        for lam in lams:
            out.append(np.asarray(rewards_mod.route(self.reward, s_hat, c_hat, lam)))
        return np.stack(out)


def oracle_sweep(
    quality: np.ndarray, cost: np.ndarray, lams: Sequence[float], reward: str
) -> np.ndarray:
    """Oracle router choices (true s, c) across the lambda grid: (L, B)."""
    out = []
    for lam in lams:
        out.append(np.asarray(rewards_mod.route(reward, quality, cost, lam)))
    return np.stack(out)


def evaluate_sweep(
    choices: np.ndarray,
    quality: np.ndarray,
    cost: np.ndarray,
    lams: Optional[np.ndarray] = None,
    expensive_idx: Optional[int] = None,
) -> Dict[str, float]:
    lams = DEFAULT_LAMBDA_GRID if lams is None else lams
    if expensive_idx is None:
        expensive_idx = int(np.argmax(cost.mean(axis=0)))
    return evaluate_router(choices, quality, cost, lams, expensive_idx)
