"""Predictor-based routing framework (paper §3).

A :class:`PredictiveRouter` bundles a trained quality predictor and a cost
predictor; routing is ``argmax_m Reward(s_hat, c_hat; lambda)``. Training of
the predictors is decoupled from the user parameter lambda (the point of the
framework), so a single trained router serves the whole lambda sweep.

The oracle router applies the same reward to the *true* (s, c) — the paper's
gold standard for each reward function.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rewards as rewards_mod
from repro.core.metrics import DEFAULT_LAMBDA_GRID, evaluate_router
from repro.core.predictors import ENSEMBLE_KINDS, PREDICTORS


def _expand_pool_axis(kind: str, params: Dict) -> Dict:
    """Grow a non-pool-free predictor's output head by one member column.

    The new column is cold-started at the mean of the existing columns, so
    the added member initially predicts like "an average pool member" and
    online outcome gradients (which for these heads flow only into the
    routed member's column) specialize it from there.
    """
    if PREDICTORS[kind].pool_free:
        return params
    p = dict(params)
    if kind == "attn":
        w_key, b_key = "wo", "bo"
    elif kind == "reg":
        w_key, b_key = "w", "b"
    elif kind == "attn-ens":
        # Per-head output maps carry a leading head axis: grow every head's
        # member column at its own mean, so head disagreement on the new
        # member starts at the heads' existing spread (nonzero epistemic
        # std — the cascade policy treats the newcomer as uncertain).
        p["wo"] = jnp.concatenate(
            [params["wo"], params["wo"].mean(axis=2, keepdims=True)], axis=2)
        p["bo"] = jnp.concatenate(
            [params["bo"], params["bo"].mean(axis=1, keepdims=True)], axis=1)
        return p
    elif kind in ("2fcn", "3fcn"):
        last = f"layer{len(params) - 1}"
        inner = dict(params[last])
        inner["w"] = jnp.concatenate(
            [inner["w"], inner["w"].mean(axis=1, keepdims=True)], axis=1)
        inner["b"] = jnp.concatenate(
            [inner["b"], inner["b"].mean(keepdims=True)])
        p[last] = inner
        return p
    else:  # pragma: no cover - new predictor kinds must declare a policy
        raise ValueError(f"no pool-expansion rule for predictor {kind!r}")
    p[w_key] = jnp.concatenate(
        [params[w_key], params[w_key].mean(axis=1, keepdims=True)], axis=1)
    p[b_key] = jnp.concatenate(
        [params[b_key], params[b_key].mean(keepdims=True)])
    return p


def _drop_pool_axis(kind: str, params: Dict, idx: int) -> Dict:
    """Remove member ``idx``'s column from a non-pool-free output head."""
    if PREDICTORS[kind].pool_free:
        return params
    p = dict(params)
    if kind == "attn":
        w_key, b_key = "wo", "bo"
    elif kind == "reg":
        w_key, b_key = "w", "b"
    elif kind == "attn-ens":
        p["wo"] = jnp.delete(params["wo"], idx, axis=2)
        p["bo"] = jnp.delete(params["bo"], idx, axis=1)
        return p
    elif kind in ("2fcn", "3fcn"):
        last = f"layer{len(params) - 1}"
        inner = dict(params[last])
        inner["w"] = jnp.delete(inner["w"], idx, axis=1)
        inner["b"] = jnp.delete(inner["b"], idx, axis=0)
        p[last] = inner
        return p
    else:  # pragma: no cover
        raise ValueError(f"no pool-removal rule for predictor {kind!r}")
    p[w_key] = jnp.delete(params[w_key], idx, axis=1)
    p[b_key] = jnp.delete(params[b_key], idx, axis=0)
    return p


@dataclasses.dataclass
class PredictiveRouter:
    quality_kind: str
    cost_kind: str
    quality_params: Dict
    cost_params: Dict
    model_emb: np.ndarray            # (K, C)
    reward: str = "R2"
    cost_scaler: Optional[Dict] = None   # {"mu","sd"} from the cost trainer
    # Online-adaptation state: params are versioned so the serving engine
    # can swap whole routers atomically and reject stale publishes, and the
    # k-means centroids behind the model embeddings ride along so members
    # added at runtime can be embedded per-cluster from live outcomes.
    version: int = 0
    centroids: Optional[np.ndarray] = None   # (C, d_query) from clustering

    @property
    def n_members(self) -> int:
        return int(np.asarray(self.model_emb).shape[0])

    def with_updates(
        self,
        quality_params: Optional[Dict] = None,
        cost_params: Optional[Dict] = None,
        model_emb: Optional[np.ndarray] = None,
    ) -> "PredictiveRouter":
        """Next router version with some state replaced (never mutated).

        The returned object shares unreplaced leaves with ``self`` — safe
        because routers are treated as immutable; publishing is a single
        reference swap on the engine (see ``RoutedEngine.swap_router``).
        """
        return dataclasses.replace(
            self,
            quality_params=(self.quality_params if quality_params is None
                            else quality_params),
            cost_params=self.cost_params if cost_params is None else cost_params,
            model_emb=self.model_emb if model_emb is None else model_emb,
            version=self.version + 1,
        )

    def add_member(self, emb_row: Optional[np.ndarray] = None) -> "PredictiveRouter":
        """Grow the pool by one member (hot membership).

        ``emb_row`` (C,) is the new member's model embedding; defaults to
        the mean of the existing rows (a maximally non-committal prior —
        the online membership tracker replaces it with per-cluster observed
        quality as outcomes arrive). Non-pool-free predictor heads grow a
        cold-started output column.
        """
        memb = np.asarray(self.model_emb)
        if emb_row is None:
            emb_row = memb.mean(axis=0)
        emb_row = np.asarray(emb_row, memb.dtype).reshape(1, -1)
        scaler = self.cost_scaler
        if scaler is not None and np.ndim(scaler["mu"]) == 1:
            scaler = {
                "mu": np.append(scaler["mu"], scaler["mu"].mean()),
                "sd": np.append(scaler["sd"], scaler["sd"].mean()),
            }
        return dataclasses.replace(
            self,
            quality_params=_expand_pool_axis(self.quality_kind,
                                             self.quality_params),
            cost_params=_expand_pool_axis(self.cost_kind, self.cost_params),
            model_emb=np.concatenate([memb, emb_row], axis=0),
            cost_scaler=scaler,
            version=self.version + 1,
        )

    def remove_member(self, idx: int) -> "PredictiveRouter":
        """Shrink the pool: drop member ``idx`` (members above shift down)."""
        memb = np.asarray(self.model_emb)
        if not 0 <= idx < memb.shape[0]:
            raise IndexError(f"member {idx} out of range 0..{memb.shape[0]-1}")
        if memb.shape[0] <= 1:
            raise ValueError("cannot remove the last pool member")
        scaler = self.cost_scaler
        if scaler is not None and np.ndim(scaler["mu"]) == 1:
            scaler = {
                "mu": np.delete(scaler["mu"], idx),
                "sd": np.delete(scaler["sd"], idx),
            }
        return dataclasses.replace(
            self,
            quality_params=_drop_pool_axis(self.quality_kind,
                                           self.quality_params, idx),
            cost_params=_drop_pool_axis(self.cost_kind, self.cost_params, idx),
            model_emb=np.delete(memb, idx, axis=0),
            cost_scaler=scaler,
            version=self.version + 1,
        )

    def denormalize_cost(self, c_hat: np.ndarray) -> np.ndarray:
        """Undo the cost trainer's target normalization and clamp at zero.

        The single place this happens — every scoring path (predict here,
        the serving engine's fused Pallas path) must route through it so the
        two cannot drift.
        """
        c_hat = np.asarray(c_hat)
        if self.cost_scaler is not None:
            c_hat = c_hat * self.cost_scaler["sd"] + self.cost_scaler["mu"]
        return np.maximum(c_hat, 0.0)

    def predict(self, q_emb: np.ndarray):
        m = jnp.asarray(self.model_emb)
        q = jnp.asarray(q_emb)
        s_hat = PREDICTORS[self.quality_kind].apply(self.quality_params, q, m)
        c_hat = PREDICTORS[self.cost_kind].apply(self.cost_params, q, m)
        return np.asarray(s_hat), self.denormalize_cost(c_hat)

    def predict_with_uncertainty(self, q_emb: np.ndarray):
        """(s_mean, s_std, c_hat), each (B, K).

        For ensemble quality kinds ``s_std`` is the per-head disagreement
        (epistemic uncertainty of the quality estimate — the signal the
        cascade escalation policy consumes); non-ensemble kinds report
        zero std, so callers degrade gracefully to mean-only decisions.
        """
        heads_apply = ENSEMBLE_KINDS.get(self.quality_kind)
        if heads_apply is None:
            s_hat, c_hat = self.predict(q_emb)
            return s_hat, np.zeros_like(s_hat), c_hat
        m = jnp.asarray(self.model_emb)
        q = jnp.asarray(q_emb)
        per_head = np.asarray(heads_apply(self.quality_params, q, m))
        c_hat = PREDICTORS[self.cost_kind].apply(self.cost_params, q, m)
        return (per_head.mean(axis=0), per_head.std(axis=0),
                self.denormalize_cost(c_hat))

    def route(self, q_emb: np.ndarray, lam: float) -> np.ndarray:
        s_hat, c_hat = self.predict(q_emb)
        return np.asarray(rewards_mod.route(self.reward, s_hat, c_hat, lam))

    def sweep(self, q_emb: np.ndarray, lams: Sequence[float]) -> np.ndarray:
        """(L, B) routed indices across the lambda grid (one predict pass)."""
        s_hat, c_hat = self.predict(q_emb)
        out = []
        for lam in lams:
            out.append(np.asarray(rewards_mod.route(self.reward, s_hat, c_hat, lam)))
        return np.stack(out)


def oracle_sweep(
    quality: np.ndarray, cost: np.ndarray, lams: Sequence[float], reward: str
) -> np.ndarray:
    """Oracle router choices (true s, c) across the lambda grid: (L, B)."""
    out = []
    for lam in lams:
        out.append(np.asarray(rewards_mod.route(reward, quality, cost, lam)))
    return np.stack(out)


def evaluate_sweep(
    choices: np.ndarray,
    quality: np.ndarray,
    cost: np.ndarray,
    lams: Optional[np.ndarray] = None,
    expensive_idx: Optional[int] = None,
) -> Dict[str, float]:
    lams = DEFAULT_LAMBDA_GRID if lams is None else lams
    if expensive_idx is None:
        expensive_idx = int(np.argmax(cost.mean(axis=0)))
    return evaluate_router(choices, quality, cost, lams, expensive_idx)
