"""Predictor architectures for the dual-predictor routing framework.

One predictor estimates response *quality* of every pool member for a query,
a second (same family) estimates generation *cost*. Variants (paper §3 +
Appendix C):

  reg        linear map   q_emb -> K scores
  2fcn/3fcn  MLPs         q_emb -> K scores (params shared across models)
  reg-emb / 2fcn-emb / 3fcn-emb
             per-model input concat [q_emb ; m_emb_k] -> 1 score
  attn       single-head cross-attention: q_emb as query, model embeddings
             as keys/values (THE paper contribution)
  attn-dot   same attention core with a pool-size-free scoring head
             (preserves dynamic add/remove of models; see DESIGN.md §1)
  attn-ens   the attention core with a small deep ensemble of output heads
             (shared trunk, H cheap (latent -> K) heads). ``apply`` returns
             the ensemble mean, so it drops into every existing scoring
             path; :data:`ENSEMBLE_KINDS` maps the kind to a heads-apply
             returning the per-head (H, B, K) scores whose spread is the
             epistemic uncertainty the cascade escalation policy consumes.

All are functional: ``init(key, dims) -> params``, ``apply(params, q, m) ->
(B, K)``. Model embeddings ``m`` are (K, C) built by
:mod:`repro.core.model_repr` and passed at call time — decoupled from
training, so the pool can change without retraining (emb/attn variants).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class PredictorDef(NamedTuple):
    init: Callable          # (key, d_query, n_models, d_model_emb) -> params
    apply: Callable         # (params, q (B,dq), m (K,dm)) -> (B,K)
    pool_free: bool         # True if params are independent of K


# ---------------------------------------------------------------------------
# Regression
# ---------------------------------------------------------------------------

def _init_reg(key, dq, k, dm):
    return {"w": dense_init(key, dq, k), "b": jnp.zeros((k,))}


def _apply_reg(p, q, m):
    return q @ p["w"] + p["b"]


def _init_reg_emb(key, dq, k, dm):
    return {"w": dense_init(key, dq + dm, 1), "b": jnp.zeros(())}


def _apply_reg_emb(p, q, m):
    b, k = q.shape[0], m.shape[0]
    qq = jnp.broadcast_to(q[:, None, :], (b, k, q.shape[1]))
    mm = jnp.broadcast_to(m[None, :, :], (b, k, m.shape[1]))
    x = jnp.concatenate([qq, mm], axis=-1)
    return (x @ p["w"])[..., 0] + p["b"]


# ---------------------------------------------------------------------------
# MLPs (2-layer and 3-layer FCNs)
# ---------------------------------------------------------------------------

MLP_HIDDEN = 256


def _init_fcn(key, d_in, d_out, n_hidden):
    dims = [d_in] + [MLP_HIDDEN] * n_hidden + [d_out]
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"layer{i}": {
            "w": dense_init(ks[i], dims[i], dims[i + 1]),
            "b": jnp.zeros((dims[i + 1],)),
        }
        for i in range(len(dims) - 1)
    }


def _apply_fcn(p, x):
    n = len(p)
    for i in range(n):
        x = x @ p[f"layer{i}"]["w"] + p[f"layer{i}"]["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def _make_fcn(n_hidden):
    def init(key, dq, k, dm):
        return _init_fcn(key, dq, k, n_hidden)

    def apply(p, q, m):
        return _apply_fcn(p, q)

    return init, apply


def _make_fcn_emb(n_hidden):
    def init(key, dq, k, dm):
        return _init_fcn(key, dq + dm, 1, n_hidden)

    def apply(p, q, m):
        b, k = q.shape[0], m.shape[0]
        qq = jnp.broadcast_to(q[:, None, :], (b, k, q.shape[1]))
        mm = jnp.broadcast_to(m[None, :, :], (b, k, m.shape[1]))
        x = jnp.concatenate([qq, mm], axis=-1)
        return _apply_fcn(p, x)[..., 0]

    return init, apply


# ---------------------------------------------------------------------------
# Single-head cross-attention (the paper's router head)
# ---------------------------------------------------------------------------

ATTN_LATENT = 20  # internal dimension (paper §5: cost predictor maps to 20)


def _init_attn(key, dq, k, dm, latent=ATTN_LATENT):
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], dq, latent),
        "wk": dense_init(ks[1], dm, latent),
        "wv": dense_init(ks[2], dm, latent),
        "wo": dense_init(ks[3], latent, k),
        "bo": jnp.zeros((k,)),
    }


def attention_scores(p, q, m):
    """Core single-head cross-attention (paper Fig. 2).

    q (B, dq) -> queries; m (K, dm) -> keys & values. Returns the attended
    context (B, latent) and the attention weights (B, K).
    """
    qp = q @ p["wq"]                                   # (B, d)
    kp = m @ p["wk"]                                   # (K, d)
    vp = m @ p["wv"]                                   # (K, d)
    d_v = vp.shape[-1]
    logits = (qp @ kp.T) / math.sqrt(d_v)              # (B, K)
    alpha = jax.nn.softmax(logits, axis=-1)
    ctx = alpha @ vp                                   # (B, d)
    return ctx, alpha


def _apply_attn(p, q, m):
    ctx, _ = attention_scores(p, q, m)
    return ctx @ p["wo"] + p["bo"]


def _init_attn_dot(key, dq, k, dm, latent=ATTN_LATENT):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], dq, latent),
        "wk": dense_init(ks[1], dm, latent),
        "wv": dense_init(ks[2], dm, latent),
        "scale": jnp.ones(()),
        "bias": jnp.zeros(()),
    }


def _apply_attn_dot(p, q, m):
    """Pool-size-free head: score_m = (ctx + q~) . v~_m (dynamic pools)."""
    ctx, _ = attention_scores(p, q, m)
    qp = q @ p["wq"]
    vp = m @ p["wv"]
    return p["scale"] * ((ctx + qp) @ vp.T) + p["bias"]


# ---------------------------------------------------------------------------
# Deep-ensemble cross-attention (shared trunk, H cheap output heads)
# ---------------------------------------------------------------------------

ENSEMBLE_HEADS = 4  # H: output heads sharing one cross-attention trunk


def _init_attn_ens(key, dq, k, dm, latent=ATTN_LATENT, n_heads=ENSEMBLE_HEADS):
    ks = jax.random.split(key, 3 + n_heads)
    return {
        "wq": dense_init(ks[0], dq, latent),
        "wk": dense_init(ks[1], dm, latent),
        "wv": dense_init(ks[2], dm, latent),
        # Per-head output maps, stacked on a leading head axis. Heads differ
        # through init + bootstrap-resampled training data (predictor_trainer
        # make_ensemble_predictor_step); the trunk is shared, so an extra
        # head costs one (latent, K) matmul — negligible next to the trunk.
        "wo": jnp.stack([dense_init(ks[3 + h], latent, k)
                         for h in range(n_heads)]),
        "bo": jnp.zeros((n_heads, k)),
    }


def _apply_attn_ens_heads(p, q, m):
    """Per-head scores (H, B, K) — the ensemble's full predictive spread."""
    ctx, _ = attention_scores(p, q, m)
    return jnp.einsum("bd,hdk->hbk", ctx, p["wo"]) + p["bo"][:, None, :]


def _apply_attn_ens(p, q, m):
    return _apply_attn_ens_heads(p, q, m).mean(axis=0)


# kind -> heads-apply ``(params, q, m) -> (H, B, K)``. Scoring paths that
# need epistemic uncertainty (PredictiveRouter.predict_with_uncertainty)
# look the kind up here; everything else uses the mean via PREDICTORS.
ENSEMBLE_KINDS: Dict[str, Callable] = {
    "attn-ens": _apply_attn_ens_heads,
}


_fcn2_init, _fcn2_apply = _make_fcn(1)
_fcn3_init, _fcn3_apply = _make_fcn(2)
_fcn2e_init, _fcn2e_apply = _make_fcn_emb(1)
_fcn3e_init, _fcn3e_apply = _make_fcn_emb(2)

PREDICTORS: Dict[str, PredictorDef] = {
    "reg": PredictorDef(_init_reg, _apply_reg, pool_free=False),
    "2fcn": PredictorDef(_fcn2_init, _fcn2_apply, pool_free=False),
    "3fcn": PredictorDef(_fcn3_init, _fcn3_apply, pool_free=False),
    "reg-emb": PredictorDef(_init_reg_emb, _apply_reg_emb, pool_free=True),
    "2fcn-emb": PredictorDef(_fcn2e_init, _fcn2e_apply, pool_free=True),
    "3fcn-emb": PredictorDef(_fcn3e_init, _fcn3e_apply, pool_free=True),
    "attn": PredictorDef(_init_attn, _apply_attn, pool_free=False),
    "attn-dot": PredictorDef(_init_attn_dot, _apply_attn_dot, pool_free=True),
    "attn-ens": PredictorDef(_init_attn_ens, _apply_attn_ens, pool_free=False),
}
