"""Training-free LLM representations (paper §5, inspired by Universal Routing).

Training prompts are clustered with k-means (C=20 from an elbow test in the
paper); 20% of prompts are sampled uniformly at random from each cluster as
representatives. A model's embedding is its mean observed quality on the
representatives of each cluster: I_m in R^C.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.clustering import assign_clusters, kmeans

N_CLUSTERS = 20
SAMPLE_FRACTION = 0.20


def build_model_embeddings(
    query_emb: np.ndarray,        # (N, d) training prompt embeddings
    quality: np.ndarray,          # (N, K) observed quality per (prompt, model)
    *,
    n_clusters: int = N_CLUSTERS,
    sample_fraction: float = SAMPLE_FRACTION,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (model_embeddings (K, C), centroids (C, d))."""
    n, k = quality.shape
    n_clusters = min(n_clusters, n)
    centers, assign = kmeans(query_emb, n_clusters, seed=seed)
    rng = np.random.default_rng(seed)

    emb = np.zeros((k, n_clusters), dtype=np.float32)
    overall = quality.mean(axis=0)
    for c in range(n_clusters):
        members = np.flatnonzero(assign == c)
        if len(members) == 0:
            emb[:, c] = overall
            continue
        n_rep = max(1, int(round(sample_fraction * len(members))))
        reps = rng.choice(members, size=n_rep, replace=False)
        emb[:, c] = quality[reps].mean(axis=0)
    return emb, centers


def embed_new_model(
    centroids: np.ndarray,
    query_emb: np.ndarray,
    quality_one: np.ndarray,      # (N,) observed quality of the new model
) -> np.ndarray:
    """Embed a model added to the pool after training (dynamic pools):
    mean quality per existing cluster — no predictor retraining needed."""
    assign = assign_clusters(query_emb, centroids)
    c = centroids.shape[0]
    emb = np.zeros((c,), dtype=np.float32)
    overall = float(quality_one.mean())
    for ci in range(c):
        members = np.flatnonzero(assign == ci)
        emb[ci] = quality_one[members].mean() if len(members) else overall
    return emb
