"""Reward functions combining predicted quality and cost (paper Eq. 3).

    R1(s, c; lam) = s - c / lam              (traditional linear trade-off)
    R2(s, c; lam) = s * exp(-c / lam)        (proposed exponential trade-off)

``lam`` ("lambda") is the user's willingness to pay. R2 is bounded on
s in [0,1], c >= 0 — the paper attributes its drastically lower
lambda-sensitivity to this boundedness.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp


def reward_linear(s, c, lam):
    """R1 = s - c/lam."""
    return s - c / lam


def reward_exponential(s, c, lam):
    """R2 = s * exp(-c/lam)."""
    return s * jnp.exp(-c / lam)


REWARDS: Dict[str, Callable] = {
    "R1": reward_linear,
    "R2": reward_exponential,
}


def route(reward_name: str, s_hat, c_hat, lam):
    """argmax_m Reward(s_hat[:, m], c_hat[:, m]; lam) -> (B,) model indices."""
    r = REWARDS[reward_name](jnp.asarray(s_hat), jnp.asarray(c_hat), lam)
    return jnp.argmax(r, axis=-1)
