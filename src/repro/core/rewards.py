"""Reward functions combining predicted quality and cost (paper Eq. 3).

    R1(s, c; lam) = s - c / lam              (traditional linear trade-off)
    R2(s, c; lam) = s * exp(-c / lam)        (proposed exponential trade-off)

``lam`` ("lambda") is the user's willingness to pay. R2 is bounded on
s in [0,1], c >= 0 — the paper attributes its drastically lower
lambda-sensitivity to this boundedness.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp


def reward_linear(s, c, lam):
    """R1 = s - c/lam."""
    return s - c / lam


def reward_exponential(s, c, lam):
    """R2 = s * exp(-c/lam)."""
    return s * jnp.exp(-c / lam)


REWARDS: Dict[str, Callable] = {
    "R1": reward_linear,
    "R2": reward_exponential,
}


def route(reward_name: str, s_hat, c_hat, lam):
    """argmax_m Reward(s_hat[:, m], c_hat[:, m]; lam) -> (B,) model indices."""
    r = REWARDS[reward_name](jnp.asarray(s_hat), jnp.asarray(c_hat), lam)
    return jnp.argmax(r, axis=-1)


# ---------------------------------------------------------------------------
# Cascade (multi-leg) reward accounting
# ---------------------------------------------------------------------------

def cascade_outcome(leg_quality, leg_cost, keep_best: bool = True):
    """(final_quality, cumulative_cost) of one escalation sequence.

    The cost of a cascade is the SUM of every leg it ran — charging only
    the final leg would let escalation look free and silently blow any
    $/window ledger. Quality is the best answer in hand under keep-best
    semantics (the serving plane never discards a served response), or the
    last leg's answer when ``keep_best=False`` (strict replace-on-escalate,
    the RouteLLM framing).
    """
    if len(leg_quality) == 0 or len(leg_quality) != len(leg_cost):
        raise ValueError("leg_quality and leg_cost must be equal, nonzero "
                         f"length (got {len(leg_quality)}/{len(leg_cost)})")
    q = max(leg_quality) if keep_best else leg_quality[-1]
    return float(q), float(sum(leg_cost))


def cascade_reward(reward_name: str, leg_quality, leg_cost, lam,
                   keep_best: bool = True):
    """Realized reward of a full cascade: R(final quality, SUM leg costs)."""
    q, c = cascade_outcome(leg_quality, leg_cost, keep_best=keep_best)
    return float(REWARDS[reward_name](q, c, lam))
