"""Message transports for the distributed serving plane.

The plane's components (:class:`~repro.distributed.plane.ServingPlane`,
:class:`~repro.distributed.coordinator.Coordinator`, follower-side proxies)
talk only in :class:`~repro.distributed.messages.Message` values through
this interface:

  * ``bind(wid, handler)`` — register ``handler(msg) -> payload | None``
    as endpoint ``wid``;
  * ``send(msg)`` — one-way, no reply;
  * ``request(msg, timeout)`` — deliver and return the reply ``Message``.

Implementations:

  * :class:`LocalTransport` — deterministic in-process loopback. Delivery
    is a synchronous handler call and the ``Message`` (payload included)
    is passed **by reference**: no serialization, object identity
    preserved, seeded replays byte-identical. This is the default and
    carries the whole existing single-process plane.
  * :class:`SocketTransport` — length-prefixed TCP between real OS
    processes (``u32`` big-endian frame length + codec bytes), with
    connect retry/backoff, per-message timeouts, and nested-RPC
    servicing: while a side waits for its reply it services interleaved
    inbound *requests* (a follower mid-``STEP`` can call back into the
    controller's ledger, or route a generate to a peer, without
    deadlock). Endpoints whose ``dst`` is not locally bound are routed
    through the controller, which forwards to the owning connection.
  * :class:`FaultyTransport` — a seeded fault-injection wrapper (drop /
    duplicate / reorder applied to one-way ``send`` traffic) for testing
    the protocol's loss tolerance; ``request`` stays reliable, mirroring
    a retried RPC.

Failure surface: every delivery problem raises :class:`TransportError`.
Callers treat an unreachable endpoint as a (possibly transient)
partition — the coordinator skips it for the round, the plane lets the
crash/rejoin machinery reconcile.
"""
from __future__ import annotations

import random
import socket
import struct
import time
from typing import Callable, Dict, Optional

from repro.distributed import messages as M
from repro.distributed.messages import Message


class TransportError(RuntimeError):
    """Endpoint unreachable / timed out / connection lost."""


Handler = Callable[[Message], Optional[dict]]


class RpcStats:
    """RPC accounting for one transport endpoint.

    Request counters and byte totals are deterministic for a seeded
    ``LocalTransport`` run (the message sequence IS the replay contract);
    the per-kind latency histograms are wall-measured and must be
    registered as wall metrics, excluded from deterministic snapshots
    (see :func:`repro.obs.wiring.register_transport_metrics`).
    """

    def __init__(self):
        self.requests: Dict[str, int] = {}       # kind -> completed RPCs
        self.peer_requests: Dict[int, int] = {}  # peer wid -> completed RPCs
        self.bytes_out: Dict[int, int] = {}      # peer wid -> frame bytes
        self.bytes_in: Dict[int, int] = {}
        self.latency: Dict[str, object] = {}     # kind -> wall-s Histogram
        self.retries = 0        # connect() re-dials
        self.timeouts = 0
        self.unreachable = 0
        self.errors = 0         # remote handler failures (ERROR replies)
        self.in_flight = 0

    def note_request(self, peer: int, kind: str, wall_s: float) -> None:
        self.requests[kind] = self.requests.get(kind, 0) + 1
        peer = int(peer)
        self.peer_requests[peer] = self.peer_requests.get(peer, 0) + 1
        h = self.latency.get(kind)
        if h is None:
            from repro.serving.telemetry import Histogram

            h = self.latency[kind] = Histogram()
        h.record(wall_s)

    def note_io(self, peer: int, *, out: int = 0, inb: int = 0) -> None:
        peer = int(peer)
        if out:
            self.bytes_out[peer] = self.bytes_out.get(peer, 0) + out
        if inb:
            self.bytes_in[peer] = self.bytes_in.get(peer, 0) + inb

    def note_failure(self, exc: Exception) -> None:
        s = str(exc)
        if "timed out" in s:
            self.timeouts += 1
        elif "remote handler failed" in s:
            self.errors += 1
        else:
            self.unreachable += 1

    def merged_latency(self):
        """One histogram folding every kind's wall latency (for export)."""
        from repro.serving.telemetry import Histogram

        out = Histogram()
        for h in self.latency.values():
            out.merge(h)
        return out


class Transport:
    # Fleet observability hooks — attached by the driver / follower host;
    # all default off, so a bare transport does zero extra work.
    tracer = None        # TraceRecorder: client ``rpc`` spans when set
    trace_wid = 0        # pid the client spans render under
    now = 0.0            # virtual clock, stamped by the driving loop
    now_fn = None        # live virtual-clock read (follower worker clock)
    stats: Optional[RpcStats] = None

    def _tnow(self) -> float:
        fn = self.now_fn
        return self.now if fn is None else float(fn())

    def _trace_client(self, msg: Message, t0: float) -> None:
        """Client-side ``rpc`` span, emitted after a successful reply (so
        a client span's existence implies the server handled the call —
        the span-tree validator relies on that pairing)."""
        tr = self.tracer
        if tr is not None and msg.kind in M.RPC_SPAN_KINDS:
            tr.span("rpc", "rpc", t0, self._tnow(), wid=self.trace_wid,
                    args={"rpc": msg.seq, "kind": msg.kind,
                          "side": "client", "peer": int(msg.dst)})

    def bind(self, wid: int, handler: Handler) -> None:
        raise NotImplementedError

    def send(self, msg: Message) -> None:
        raise NotImplementedError

    def request(self, msg: Message, timeout: Optional[float] = None
                ) -> Message:
        raise NotImplementedError

    def close(self) -> None:
        pass


def _dispatch(handler: Handler, msg: Message) -> Message:
    """Run a handler and wrap its return payload as the reply message."""
    try:
        payload = handler(msg)
    except TransportError:
        raise
    except Exception as exc:  # surfaced to the requester, not swallowed
        return Message(kind=M.ERROR, dst=msg.src, src=msg.dst,
                       reply_to=msg.seq,
                       payload={"error": f"{type(exc).__name__}: {exc}"})
    return Message(kind=M.ACK, dst=msg.src, src=msg.dst, reply_to=msg.seq,
                   payload=payload if payload is not None else {})


def _check_reply(rep: Message) -> Message:
    if rep.kind == M.ERROR:
        raise TransportError(
            f"remote handler failed: {rep.payload.get('error')}")
    return rep


class LocalTransport(Transport):
    """In-process loopback bus: synchronous, by-reference, deterministic.

    Delivery order is the caller's call order — exactly the shared-object
    call sequence the plane executed before the message-passing refactor,
    which is what keeps seeded runs bit-identical across the change.
    """

    def __init__(self):
        self._handlers: Dict[int, Handler] = {}
        self._seq = 0
        self.stats = RpcStats()

    def bind(self, wid: int, handler: Handler) -> None:
        self._handlers[int(wid)] = handler

    def _deliver(self, msg: Message) -> Message:
        handler = self._handlers.get(msg.dst)
        if handler is None:
            raise TransportError(f"no endpoint bound for wid {msg.dst}")
        # Handler exceptions propagate raw: in-process, a crash is a crash
        # (tests want the traceback, not an ERROR frame).
        payload = handler(msg)
        return Message(kind=M.ACK, dst=msg.src, src=msg.dst,
                       reply_to=msg.seq,
                       payload=payload if payload is not None else {})

    def send(self, msg: Message) -> None:
        self._deliver(msg)

    def request(self, msg: Message, timeout: Optional[float] = None
                ) -> Message:
        self._seq += 1
        msg.seq = self._seq
        msg.expect_reply = True
        t0 = self._tnow()
        wall0 = time.perf_counter()
        s = self.stats
        try:
            rep = _check_reply(self._deliver(msg))
        except TransportError as exc:
            if s is not None:
                s.note_failure(exc)
            raise
        if s is not None:
            s.note_request(msg.dst, msg.kind, time.perf_counter() - wall0)
        self._trace_client(msg, t0)
        return rep


class FaultyTransport(Transport):
    """Seeded drop/duplicate/reorder wrapper over another transport.

    Faults apply to one-way ``send`` traffic only (broadcast-shaped
    messages, where the protocol must tolerate loss); ``request`` passes
    through reliably. Reordering holds a message back and flushes held
    messages *after* later sends — a bounded, seeded shuffle.
    """

    def __init__(self, inner: Transport, *, seed: int = 0,
                 drop: float = 0.0, dup: float = 0.0, reorder: float = 0.0):
        self.inner = inner
        self.rng = random.Random(seed)
        self.drop, self.dup, self.reorder = drop, dup, reorder
        self._held: list = []
        self.stats = {"sent": 0, "dropped": 0, "duplicated": 0, "held": 0}

    def bind(self, wid: int, handler: Handler) -> None:
        self.inner.bind(wid, handler)

    def send(self, msg: Message) -> None:
        self.stats["sent"] += 1
        if self.rng.random() < self.drop:
            self.stats["dropped"] += 1
            return
        copies = [msg]
        if self.rng.random() < self.dup:
            self.stats["duplicated"] += 1
            copies.append(msg)
        if self.rng.random() < self.reorder:
            self.stats["held"] += 1
            self._held.extend(copies)
            return
        for m in copies:
            self.inner.send(m)
        self.flush()

    def flush(self) -> None:
        """Deliver held (reordered) messages in seeded shuffled order."""
        held, self._held = self._held, []
        self.rng.shuffle(held)
        for m in held:
            self.inner.send(m)

    def request(self, msg: Message, timeout: Optional[float] = None
                ) -> Message:
        return self.inner.request(msg, timeout)

    def close(self) -> None:
        self.flush()
        self.inner.close()


# -- socket transport --------------------------------------------------------

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 30


def _send_frame(conn: socket.socket, body: bytes) -> None:
    try:
        conn.sendall(_LEN.pack(len(body)) + body)
    except OSError as exc:
        raise TransportError(f"send failed: {exc}") from exc


def _recv_exact(conn: socket.socket, n: int) -> bytes:
    chunks = []
    while n > 0:
        try:
            part = conn.recv(min(n, 1 << 20))
        except socket.timeout as exc:
            raise TransportError("recv timed out") from exc
        except OSError as exc:
            raise TransportError(f"recv failed: {exc}") from exc
        if not part:
            raise TransportError("connection closed by peer")
        chunks.append(part)
        n -= len(part)
    return b"".join(chunks)


def _recv_frame(conn: socket.socket) -> bytes:
    n = _LEN.unpack(_recv_exact(conn, 4))[0]
    if n > MAX_FRAME:
        raise TransportError(f"oversized frame ({n} bytes)")
    return _recv_exact(conn, n)


class SocketTransport(Transport):
    """Length-prefixed TCP transport between real OS processes.

    One process is the **controller** (``wid 0``): it owns the listening
    socket and one accepted connection per follower. Followers each hold
    a single connection to the controller; messages between followers are
    routed through it (the controller forwards frames whose ``dst`` is
    neither itself nor the sender).

    The protocol is strictly synchronous lockstep (the plane's event loop
    drives every exchange), so each side is single-threaded: after
    writing a request it reads frames until one carries its
    ``reply_to``; any *request* frame that arrives meanwhile is a nested
    call from the peer (e.g. the follower asking the controller's ledger
    mid-``STEP``) and is serviced inline.
    """

    CONNECT_RETRIES = 40
    CONNECT_BACKOFF_S = 0.25

    def __init__(self, wid: int, *, timeout: Optional[float] = 120.0):
        self.wid = int(wid)
        self.timeout = timeout
        self._handlers: Dict[int, Handler] = {}
        self._conns: Dict[int, socket.socket] = {}
        self._seq = self.wid * 1_000_000  # per-endpoint disjoint seq space
        self._listener: Optional[socket.socket] = None
        self.is_controller = self.wid == 0
        self.stats = RpcStats()

    # -- wiring --------------------------------------------------------------

    def bind(self, wid: int, handler: Handler) -> None:
        self._handlers[int(wid)] = handler

    def listen(self, port: int = 0, host: str = "127.0.0.1") -> int:
        """Controller: open the accept socket; returns the bound port."""
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, port))
        srv.listen(16)
        self._listener = srv
        return srv.getsockname()[1]

    def accept(self, n_followers: int, timeout: float = 60.0) -> Dict[int, dict]:
        """Controller: accept ``n_followers`` HELLOs; returns wid -> hello
        payload (pid etc.)."""
        assert self._listener is not None, "listen() first"
        self._listener.settimeout(timeout)
        hellos: Dict[int, dict] = {}
        while len(hellos) < n_followers:
            try:
                conn, _ = self._listener.accept()
            except socket.timeout as exc:
                raise TransportError(
                    f"only {len(hellos)}/{n_followers} followers "
                    f"connected") from exc
            conn.settimeout(self.timeout)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            hello = M.decode(_recv_frame(conn))
            if hello.kind != M.HELLO:
                conn.close()
                continue
            wid = int(hello.payload["wid"])
            self._conns[wid] = conn
            hellos[wid] = dict(hello.payload)
            _send_frame(conn, M.encode(Message(
                kind=M.ACK, dst=wid, src=self.wid, reply_to=hello.seq)))
        return hellos

    def connect(self, port: int, host: str = "127.0.0.1", *,
                hello_payload: Optional[dict] = None) -> None:
        """Follower: dial the controller with retry/backoff, say HELLO."""
        last: Optional[Exception] = None
        for attempt in range(self.CONNECT_RETRIES):
            try:
                conn = socket.create_connection((host, port), timeout=10.0)
                break
            except OSError as exc:
                last = exc
                self.stats.retries += 1
                time.sleep(self.CONNECT_BACKOFF_S * min(attempt + 1, 8))
        else:
            raise TransportError(
                f"could not reach controller at {host}:{port}: {last}")
        conn.settimeout(self.timeout)
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._conns[0] = conn
        payload = {"wid": self.wid}
        payload.update(hello_payload or {})
        _send_frame(conn, M.encode(Message(
            kind=M.HELLO, dst=0, src=self.wid, seq=self._next_seq(),
            payload=payload)))
        ack = M.decode(_recv_frame(conn))
        if ack.kind != M.ACK:
            raise TransportError(f"bad HELLO ack: {ack.kind}")

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _conn_for(self, dst: int) -> socket.socket:
        if dst in self._conns:
            return self._conns[dst]
        if not self.is_controller and 0 in self._conns:
            return self._conns[0]      # follower: everything via controller
        raise TransportError(f"no route to wid {dst}")

    def _peer_for(self, dst: int) -> int:
        """The wid on the other end of the conn frames to ``dst`` ride."""
        return int(dst) if dst in self._conns else 0

    # -- delivery ------------------------------------------------------------

    def _send_msg(self, conn: socket.socket, msg: Message,
                  peer: int) -> None:
        body = M.encode(msg)
        if self.stats is not None:
            self.stats.note_io(peer, out=len(body) + 4)
        _send_frame(conn, body)

    def _recv_msg(self, conn: socket.socket, peer: int) -> Message:
        buf = _recv_frame(conn)
        if self.stats is not None:
            self.stats.note_io(peer, inb=len(buf) + 4)
        return M.decode(buf)

    def _service(self, msg: Message) -> None:
        """Handle an inbound request/one-way frame (possibly forwarding)."""
        if msg.dst != self.wid and self.is_controller:
            # Route follower->follower traffic through our connections.
            try:
                if msg.expect_reply:
                    rep = self._roundtrip(self._conn_for(msg.dst), msg)
                else:
                    self._send_msg(self._conn_for(msg.dst), msg, msg.dst)
                    return
            except TransportError as exc:
                rep = Message(kind=M.ERROR, dst=msg.src, src=self.wid,
                              reply_to=msg.seq,
                              payload={"error": str(exc)})
            self._send_msg(self._conn_for(msg.src), rep, msg.src)
            return
        handler = self._handlers.get(msg.dst)
        if handler is None:
            rep = Message(kind=M.ERROR, dst=msg.src, src=self.wid,
                          reply_to=msg.seq,
                          payload={"error": f"no endpoint {msg.dst}"})
        else:
            rep = _dispatch(handler, msg)
        if msg.expect_reply:
            self._send_msg(self._conn_for(msg.src), rep,
                           self._peer_for(msg.src))

    def _roundtrip(self, conn: socket.socket, msg: Message) -> Message:
        peer = self._peer_for(msg.dst)
        self._send_msg(conn, msg, peer)
        while True:
            rep = self._recv_msg(conn, peer)
            if rep.reply_to == msg.seq:
                return rep
            # Nested inbound call while we wait: service it inline.
            self._service(rep)

    def send(self, msg: Message) -> None:
        msg.src = self.wid
        if msg.dst in self._handlers:   # local endpoint: loop back
            _dispatch(self._handlers[msg.dst], msg)
            return
        self._send_msg(self._conn_for(msg.dst), msg,
                       self._peer_for(msg.dst))

    def request(self, msg: Message, timeout: Optional[float] = None
                ) -> Message:
        msg.src = self.wid
        msg.seq = self._next_seq()
        msg.expect_reply = True
        t0 = self._tnow()
        wall0 = time.perf_counter()
        s = self.stats
        if msg.dst in self._handlers:   # local endpoint: loop back
            try:
                rep = _check_reply(_dispatch(self._handlers[msg.dst], msg))
            except TransportError as exc:
                if s is not None:
                    s.note_failure(exc)
                raise
            if s is not None:
                s.note_request(msg.dst, msg.kind,
                               time.perf_counter() - wall0)
            self._trace_client(msg, t0)
            return rep
        if s is not None:
            s.in_flight += 1
        try:
            conn = self._conn_for(msg.dst)
            if timeout is not None:
                conn.settimeout(timeout)
            try:
                rep = _check_reply(self._roundtrip(conn, msg))
            finally:
                if timeout is not None:
                    conn.settimeout(self.timeout)
        except TransportError as exc:
            if s is not None:
                s.note_failure(exc)
            raise
        finally:
            if s is not None:
                s.in_flight -= 1
        if s is not None:
            s.note_request(msg.dst, msg.kind, time.perf_counter() - wall0)
        self._trace_client(msg, t0)
        return rep

    # -- follower serve loop -------------------------------------------------

    def serve_forever(self) -> None:
        """Follower: service controller frames until SHUTDOWN / EOF.

        Raises :class:`TransportError` when the controller connection
        dies — the caller (``repro.distributed.host``) degrades to
        follower-local serving instead of crashing.
        """
        conn = self._conns[0]
        conn.settimeout(None)           # idle between rounds is normal
        while True:
            msg = self._recv_msg(conn, 0)
            if msg.kind == M.SHUTDOWN:
                if msg.expect_reply:
                    _send_frame(conn, M.encode(Message(
                        kind=M.ACK, dst=msg.src, src=self.wid,
                        reply_to=msg.seq)))
                return
            self._service(msg)

    def drop_connection(self, wid: int) -> None:
        conn = self._conns.pop(wid, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        for wid in list(self._conns):
            self.drop_connection(wid)
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None
