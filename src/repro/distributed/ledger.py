"""Shared budget ledger: one $/window budget across N scheduler workers.

With per-worker :class:`~repro.serving.budget.BudgetGovernor` instances,
"at most $B per window" silently becomes "$N*B per window" — each worker
only sees its own spend. The ledger is a single governor every worker
records into, so utilization and the effective lambda reflect the *global*
spend.

Two multi-worker wrinkles:

  * **controller cadence** — each worker calls ``update()`` once per
    dispatch round; N workers would apply N proportional controller steps
    per window and oscillate. The ledger throttles the controller to at
    most one step per ``update_min_interval_s`` of virtual time; throttled
    calls return the current lambda unchanged (workers still *read* a
    fresh effective lambda every round).
  * **clock skew** — workers advance independent virtual clocks, so spend
    events arrive slightly out of time order. The ledger clamps to a
    monotone high-water time, keeping the rolling-window deque sorted;
    the distortion is bounded by the worker skew, which the plane keeps
    well under the window length.
"""
from __future__ import annotations

from repro.serving.budget import BudgetGovernor


class SharedBudgetLedger(BudgetGovernor):
    def __init__(self, budget: float, window_s: float = 10.0, *,
                 update_min_interval_s: float = None, **kwargs):
        super().__init__(budget, window_s, **kwargs)
        self.update_min_interval_s = (
            window_s / 20.0 if update_min_interval_s is None
            else update_min_interval_s)
        self._now_hwm = 0.0
        self._last_ctrl = float("-inf")
        self.throttled = 0

    def _monotone(self, now: float) -> float:
        self._now_hwm = max(self._now_hwm, float(now))
        return self._now_hwm

    def record(self, cost: float, now: float) -> None:
        super().record(cost, self._monotone(now))

    def utilization(self, now: float) -> float:
        return super().utilization(self._monotone(now))

    def update(self, now: float) -> float:
        t = self._monotone(now)
        if t - self._last_ctrl < self.update_min_interval_s:
            self.throttled += 1
            self.last_action = "throttled"
            self.last_utilization = self.utilization(t)
            return self.lam
        self._last_ctrl = t
        return super().update(t)
