"""Shared budget ledger: one $/window budget across N scheduler workers.

With per-worker :class:`~repro.serving.budget.BudgetGovernor` instances,
"at most $B per window" silently becomes "$N*B per window" — each worker
only sees its own spend. The ledger is a single governor every worker
records into, so utilization and the effective lambda reflect the *global*
spend.

Two multi-worker wrinkles:

  * **controller cadence** — each worker calls ``update()`` once per
    dispatch round; N workers would apply N proportional controller steps
    per window and oscillate. The ledger throttles the controller to at
    most one step per ``update_min_interval_s`` of virtual time; throttled
    calls return the current lambda unchanged (workers still *read* a
    fresh effective lambda every round).
  * **clock skew** — workers advance independent virtual clocks, so spend
    events arrive slightly out of time order. The ledger clamps to a
    monotone high-water time, keeping the rolling-window deque sorted;
    the distortion is bounded by the worker skew, which the plane keeps
    well under the window length.
"""
from __future__ import annotations

from repro.distributed import messages as M
from repro.distributed.messages import Message
from repro.serving.budget import BudgetGovernor


class SharedBudgetLedger(BudgetGovernor):
    def __init__(self, budget: float, window_s: float = 10.0, *,
                 update_min_interval_s: float = None, **kwargs):
        super().__init__(budget, window_s, **kwargs)
        self.update_min_interval_s = (
            window_s / 20.0 if update_min_interval_s is None
            else update_min_interval_s)
        self._now_hwm = 0.0
        self._last_ctrl = float("-inf")
        self.throttled = 0

    def _monotone(self, now: float) -> float:
        self._now_hwm = max(self._now_hwm, float(now))
        return self._now_hwm

    def record(self, cost: float, now: float) -> None:
        super().record(cost, self._monotone(now))

    def utilization(self, now: float) -> float:
        return super().utilization(self._monotone(now))

    def update(self, now: float) -> float:
        t = self._monotone(now)
        if t - self._last_ctrl < self.update_min_interval_s:
            self.throttled += 1
            self.last_action = "throttled"
            self.last_utilization = self.utilization(t)
            return self.lam
        self._last_ctrl = t
        return super().update(t)


class LedgerClient:
    """Remote-scheduler facade for a controller-side shared ledger.

    In socket mode the real :class:`SharedBudgetLedger` lives in the
    controller process; each follower's scheduler gets one of these as
    its ``governor``. Every call is one ``LEDGER_OP`` message to the
    ledger-owning endpoint, so "at most $B per window" stays a *global*
    property — N processes record into one rolling window, exactly like
    the in-process plane's shared object.

    The reply piggybacks the ledger's ``lam`` / ``last_action`` /
    ``last_utilization``, which the client caches: scheduler tracing and
    cascade headroom reads see fresh values without extra round trips.

    When the ledger endpoint becomes unreachable (controller loss) the
    client degrades permanently to its cached values instead of raising:
    a follower draining its queue solo keeps serving under the last
    effective lambda rather than crashing mid-request. Global budget
    enforcement is necessarily suspended while degraded — the spend a
    degraded follower records is lost to the window — which matches the
    plane's follower-local degradation semantics.
    """

    _UNREACHABLE = object()

    def __init__(self, transport, dst: int = 0):
        self.transport = transport
        self.dst = int(dst)
        self._lam = 0.0
        self.last_action = "init"
        self.last_utilization = 0.0
        self.last_headroom = 1.0
        self.degraded = False

    def _op(self, op: str, *args):
        from repro.distributed.transport import TransportError

        if self.degraded:
            return self._UNREACHABLE
        try:
            rep = self.transport.request(Message(
                kind=M.LEDGER_OP, dst=self.dst,
                payload={"op": op, "args": list(args)}))
        except TransportError:
            self.degraded = True
            self.last_action = "degraded"
            return self._UNREACHABLE
        p = rep.payload
        self._lam = float(p.get("lam", self._lam))
        if p.get("last_action") is not None:
            self.last_action = p["last_action"]
        if p.get("last_utilization") is not None:
            self.last_utilization = p["last_utilization"]
        return p.get("result")

    @property
    def lam(self) -> float:
        return self._lam

    def update(self, now: float) -> float:
        r = self._op("update", now)
        return self._lam if r is self._UNREACHABLE else float(r)

    def record(self, cost: float, now: float) -> None:
        self._op("record", float(cost), now)

    def utilization(self, now: float) -> float:
        r = self._op("utilization", now)
        return self.last_utilization if r is self._UNREACHABLE else float(r)

    def headroom(self, now: float) -> float:
        r = self._op("headroom", now)
        if r is self._UNREACHABLE:
            return self.last_headroom
        self.last_headroom = float(r)
        return self.last_headroom

    def window_spend(self, now: float) -> float:
        r = self._op("window_spend", now)
        return 0.0 if r is self._UNREACHABLE else float(r)

    def summary(self, now: float) -> dict:
        r = self._op("summary", now)
        return {} if r is self._UNREACHABLE else r
