"""Multi-worker serving plane: the serve->learn loop across N workers.

Converts every in-process singleton of the single-worker online loop into
an explicitly synchronized, worker-replicated component:

  * :mod:`worker` — :class:`WorkerNode`: engine replica + scheduler +
    local replay, with crash/rejoin semantics;
  * :mod:`coordinator` — :class:`Coordinator`: seeded deterministic replay
    merge onto the leader, bounded leader updates, versioned router
    broadcast with stale-publish rejection, lowest-id leader election;
  * :mod:`ledger` — :class:`SharedBudgetLedger`: one global $/window
    budget across all workers' governors;
  * :mod:`plane` — :class:`ServingPlane`: the deterministic multi-clock
    event loop, round-robin request assignment, scenario (crash/rejoin)
    events, and per-worker telemetry rollup.

Driver: ``python -m repro.launch.serve --workers N`` (see README
"Multi-worker serving"); parity benchmark:
``benchmarks/distributed_bench.py``.
"""
from repro.distributed.coordinator import Coordinator, SyncConfig
from repro.distributed.ledger import SharedBudgetLedger
from repro.distributed.plane import PlaneEvent, ServingPlane
from repro.distributed.worker import WorkerNode

__all__ = [
    "Coordinator", "PlaneEvent", "ServingPlane", "SharedBudgetLedger",
    "SyncConfig", "WorkerNode",
]
