"""Multi-worker serving plane: the serve->learn loop across N workers.

Converts every in-process singleton of the single-worker online loop into
an explicitly synchronized, worker-replicated component, communicating
through typed messages over a pluggable transport:

  * :mod:`messages` — the typed, versioned message vocabulary and the
    lossless binary codec for the socket wire;
  * :mod:`transport` — :class:`Transport` with
    :class:`LocalTransport` (deterministic in-process loopback,
    by-reference delivery), :class:`SocketTransport` (length-prefixed
    TCP between real OS processes), and :class:`FaultyTransport`
    (seeded drop/dup/reorder fault injection for tests);
  * :mod:`worker` — :class:`WorkerNode`: engine replica + scheduler +
    local replay, a transport endpoint with crash/rejoin semantics;
  * :mod:`coordinator` — :class:`Coordinator`: seeded deterministic replay
    merge onto the leader, bounded leader updates, versioned router
    broadcast with stale-publish rejection, lowest-id leader election;
  * :mod:`ledger` — :class:`SharedBudgetLedger`: one global $/window
    budget across all workers' governors; :class:`LedgerClient`: the
    remote-process facade for it;
  * :mod:`plane` — :class:`ServingPlane`: the deterministic multi-clock
    event loop, round-robin request assignment, scenario (crash/rejoin)
    events, and per-worker telemetry rollup;
  * :mod:`shard` — pool-member ownership across workers and the
    scheduler-side dispatcher that routes generate legs to the owner;
  * :mod:`host` — the follower process entry point
    (``python -m repro.distributed.host``) and the controller-side
    :class:`RemoteWorkerProxy`.

Driver: ``python -m repro.launch.serve --workers N --transport
{local,socket}`` (see README "Multi-host serving"); parity benchmark:
``benchmarks/distributed_bench.py``; socket smoke:
``tools/distributed_smoke.py``.
"""
from repro.distributed.coordinator import Coordinator, SyncConfig
from repro.distributed.ledger import LedgerClient, SharedBudgetLedger
from repro.distributed.messages import Message, decode, encode
from repro.distributed.plane import PlaneEvent, ServingPlane
from repro.distributed.shard import PoolDispatcher, owner_of
from repro.distributed.transport import (
    FaultyTransport,
    LocalTransport,
    SocketTransport,
    Transport,
    TransportError,
)
from repro.distributed.worker import WorkerNode

__all__ = [
    "Coordinator", "FaultyTransport", "LedgerClient", "LocalTransport",
    "Message", "PlaneEvent", "PoolDispatcher", "ServingPlane",
    "SharedBudgetLedger", "SocketTransport", "SyncConfig", "Transport",
    "TransportError", "WorkerNode", "decode", "encode", "owner_of",
]
