"""One serving-plane worker: engine replica + scheduler + local online state.

A worker is the unit of replication in the multi-worker plane: it owns its
own :class:`~repro.serving.engine.RoutedEngine` instance (so router swaps
are per-worker and atomic), its own admission queue / micro-batching
scheduler / virtual clock (workers run concurrently in real deployments —
their virtual clocks advance independently), and — in online mode — a
follower :class:`~repro.online.loop.OnlineAdapter` whose replay buffer is
the worker's local outcome log.

Since the message-passing refactor the worker is also a **transport
endpoint**: :meth:`bind` registers :meth:`handle` on a
:class:`~repro.distributed.transport.Transport`, and every protocol
interaction (sync status, replay gather, router broadcast, plane step,
crash/rejoin, sharded generate, ledger ops, telemetry/trace dumps)
arrives as a :class:`~repro.distributed.messages.Message`. The plain
methods below remain the implementation the handlers dispatch to — and
stay directly callable, which is what the in-process tests and benches
do through :class:`~repro.distributed.transport.LocalTransport`'s
by-reference delivery.

Crash/rejoin models a worker process dying: queued and future requests must
be reassigned by the plane, and the in-memory online state (replay, staged
feedback) does not survive — a rejoining worker comes back empty and
catches up to the current router version from the leader.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from collections import deque

from repro.distributed import messages as M
from repro.distributed.messages import Message


class WorkerNode:
    def __init__(self, wid: int, engine, scheduler, adapter=None):
        self.wid = int(wid)
        self.engine = engine
        self.scheduler = scheduler
        self.adapter = adapter
        self.alive = True
        self.arrivals = deque()      # assigned, not-yet-arrived requests
        self.served: List = []       # completed requests, dispatch order
        self.swaps_accepted = 0
        self.swaps_rejected = 0
        self.crashes = 0
        self.transport = None
        # Socket mode: the controller-side worker fronts the real shared
        # budget ledger for follower LEDGER_OP messages. None = the
        # scheduler's own governor answers them.
        self.ledger = None
        # Socket mode: the follower's process-local TraceRecorder, drained
        # to the controller incrementally via TRACE_REQ (local mode shares
        # one recorder through the scheduler's scoped tracer instead).
        self.recorder = None
        # Socket mode: the follower's process-local MetricsRegistry,
        # scraped by the controller via METRICS_REQ (federated /metrics).
        self.registry = None

    # -- transport endpoint --------------------------------------------------

    def bind(self, transport) -> None:
        self.transport = transport
        transport.bind(self.wid, self.handle)

    def handle(self, msg: Message) -> Optional[dict]:
        """Service one protocol message; returns the reply payload.

        Kinds in :data:`~repro.distributed.messages.RPC_SPAN_KINDS` emit a
        server-side ``rpc`` span around the handler (virtual-clock
        timestamps, so STEP spans carry their real virtual duration); the
        span's ``rpc`` arg is the request's seq — the same link id the
        transport stamps on the matching client span.
        """
        tracer = getattr(self.scheduler, "tracer", None)
        if tracer is None or msg.kind not in M.RPC_SPAN_KINDS:
            return self._handle(msg)
        t0 = self.clock.now
        out = self._handle(msg)
        tracer.span("rpc", "rpc", t0, self.clock.now,
                    args={"rpc": msg.seq, "kind": msg.kind,
                          "side": "server", "peer": int(msg.src)})
        return out

    def _handle(self, msg: Message) -> Optional[dict]:
        p = msg.payload
        kind = msg.kind
        if kind == M.SYNC_STATUS:
            return self.sync_status()
        if kind == M.REPLAY_SAMPLE:
            if self.adapter is None:
                return {"batch": None}
            return {"batch": self.adapter.replay.sample(
                int(p["n"]), recent_frac=float(p["recent_frac"]))}
        if kind == M.ROUTER_BCAST:
            return {"accepted": self.publish(p["router"]),
                    "version": self.router_version}
        if kind == M.CLEAR_BURST:
            if self.adapter is not None:
                self.adapter.pending_burst = False
            return None
        if kind == M.CACHE_INVAL:
            semcache = getattr(self.scheduler, "semcache", None)
            if semcache is not None:
                semcache.on_drift_alarm(float(p.get("now", 0.0)))
            return None
        if kind == M.ASSIGN:
            self.assign(p["reqs"])
            return {"n": len(p["reqs"])}
        if kind == M.NEXT_ACTION:
            return {"t": self.next_action_s()}
        if kind == M.STEP:
            served = self.step(float(p["t"]))
            return {"n_served": len(served), "now": self.clock.now}
        if kind == M.CRASH:
            return {"orphans": self.crash(float(p["t"]))}
        if kind == M.REJOIN:
            self.rejoin(float(p["t"]), p.get("router"),
                        p.get("replay_seed"))
            return {"version": self.router_version}
        if kind == M.TICK:
            if self.adapter is not None:
                self.adapter.tick(float(p["t"]))
            return None
        if kind == M.FINALIZE:
            return self.finalize(float(p["t"]),
                                 check_slo=bool(p.get("check_slo", True)))
        if kind == M.GENERATE:
            per_req = p.get("max_new_per_req")
            if per_req is not None:
                outs, costs = self.engine.generate_member(
                    int(p["member"]), p["prompts"],
                    max_new=int(p["max_new"]), max_new_per_req=per_req)
            else:
                outs, costs = self.engine.generate_member(
                    int(p["member"]), p["prompts"],
                    max_new=int(p["max_new"]))
            return {"outs": list(outs), "costs": costs}
        if kind == M.LEDGER_OP:
            return self.ledger_op(str(p["op"]), list(p.get("args", ())))
        if kind == M.TELEMETRY_REQ:
            return {"telemetry": self.telemetry,
                    "completed": self.telemetry.completed,
                    "served": len(self.served),
                    "swaps_accepted": self.swaps_accepted,
                    "swaps_rejected": self.swaps_rejected,
                    "crashes": self.crashes,
                    "version": self.router_version,
                    "now": self.clock.now}
        if kind == M.TRACE_REQ:
            # Incremental drain: flushable events (runtime scope + closed,
            # sampled request trees) leave this process now; ``force``
            # (end of run) also drains open trees.
            rec = self.recorder
            if rec is None:
                return {"events": [], "next_key": 0}
            return {"events": rec.drain(force=bool(p.get("force"))),
                    "next_key": rec._next_key}
        if kind == M.METRICS_REQ:
            if self.registry is None:
                return {"prom": ""}
            return {"prom": self.registry.prometheus(deterministic=False)}
        if kind == M.HELLO:
            return {"wid": self.wid}
        raise ValueError(f"worker {self.wid}: unknown message kind {kind!r}")

    # -- handler implementations ---------------------------------------------

    def sync_status(self) -> Dict:
        has_adapter = self.adapter is not None
        return {
            "wid": self.wid,
            "alive": self.alive,
            "version": self.router_version,
            "has_adapter": has_adapter,
            "pending_burst": bool(self.adapter.pending_burst)
            if has_adapter else False,
            "added": self.adapter.replay.added if has_adapter else 0,
            "distinct": len(self.adapter.replay) if has_adapter else 0,
            "now": self.clock.now,
        }

    def assign(self, reqs) -> None:
        """Merge newly assigned requests into the arrival backlog."""
        merged = sorted(list(self.arrivals) + list(reqs),
                        key=lambda r: (r.arrival_s, r.rid))
        self.arrivals = deque(merged)

    def finalize(self, t_end: float, *, check_slo: bool = True) -> Dict:
        """End-of-run bookkeeping: forced SLO evaluation + queue-level
        reject/expire counts folded into the telemetry snapshot."""
        slo = getattr(self.scheduler, "slo", None)
        if check_slo and slo is not None:
            slo.check(t_end, force=True)
        self.telemetry.rejected = self.queue.rejected
        self.telemetry.expired = self.queue.expired
        self.telemetry.shed = self.queue.shed
        return {"completed": self.telemetry.completed}

    def ledger_op(self, op: str, args: List) -> Dict:
        """Apply one budget-ledger operation for a remote scheduler.

        In socket mode the real :class:`SharedBudgetLedger` lives in the
        controller process (``self.ledger``); followers' ``LedgerClient``
        governors forward their update/record/read calls here so the
        $/window budget stays global.
        """
        gov = self.ledger if self.ledger is not None \
            else self.scheduler.governor
        if gov is None:
            raise ValueError(f"worker {self.wid}: no ledger to apply "
                             f"{op!r} to")
        allowed = {"update", "record", "utilization", "headroom",
                   "window_spend", "summary"}
        if op not in allowed:
            raise ValueError(f"unknown ledger op {op!r}")
        result = getattr(gov, op)(*args)
        return {"result": result, "lam": gov.lam,
                "last_action": getattr(gov, "last_action", None),
                "last_utilization": getattr(gov, "last_utilization", None)}

    # -- convenience ---------------------------------------------------------

    @property
    def clock(self):
        return self.scheduler.clock

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def telemetry(self):
        return self.scheduler.telemetry

    @property
    def router_version(self) -> int:
        return self.engine.router.version

    # -- router broadcast ----------------------------------------------------

    def publish(self, router) -> bool:
        """Atomically swap a broadcast router in; stale publishes rejected.

        The engine's ``swap_router`` enforces the version ordering — a
        worker that missed a broadcast can later accept a newer version,
        but a delayed older broadcast can never roll this worker back.
        """
        try:
            self.engine.swap_router(router)
        except ValueError:
            self.swaps_rejected += 1
            return False
        self.swaps_accepted += 1
        return True

    # -- plane event loop ----------------------------------------------------

    def next_action_s(self) -> float:
        """Earliest virtual time this worker can act (inf = nothing to do).

        Delegates to the scheduler's ``next_dispatch_s`` so the dispatch
        wake-time policy lives in one place for the solo and multi-worker
        paths alike.
        """
        if not self.alive:
            return float("inf")
        return self.scheduler.next_dispatch_s(
            self.arrivals[0].arrival_s if self.arrivals else None)

    def step(self, t: float) -> List:
        """Advance to ``t``, inject due arrivals, dispatch if ready."""
        self.clock.advance_to(t)
        while self.arrivals and self.arrivals[0].arrival_s <= self.clock.now:
            self.queue.offer(self.arrivals.popleft(), self.clock.now)
        self.scheduler.note_queue_depth()
        served = []
        if self.scheduler.should_dispatch(flush=not self.arrivals):
            served = self.scheduler.dispatch()
            self.served.extend(served)
        return served

    # -- crash / rejoin ------------------------------------------------------

    def crash(self, now: float) -> List:
        """Kill the worker; returns orphaned (queued + future) requests
        the plane must reassign. In-memory online state is lost."""
        self.alive = False
        self.crashes += 1
        orphans = list(self.queue.pop(self.queue.depth)) + list(self.arrivals)
        self.arrivals.clear()
        tracer = getattr(self.scheduler, "tracer", None)
        if tracer is not None:
            tracer.instant("worker_crash", "plane", now,
                           args={"orphans": len(orphans)})
        return orphans

    def rejoin(self, now: float, router=None,
               replay_seed: Optional[int] = None) -> None:
        """Restart after a crash: empty queue, fresh replay (nothing
        survived the process), catch-up swap to the current router."""
        self.alive = True
        self.clock.advance_to(now)
        if self.adapter is not None:
            seed = (self.wid + 1) * 7919 + self.crashes if replay_seed is None \
                else replay_seed
            self.adapter.reset_outcome_state(seed)
        if router is not None and router.version > self.engine.router.version:
            self.publish(router)
        tracer = getattr(self.scheduler, "tracer", None)
        if tracer is not None:
            tracer.instant("worker_rejoin", "plane", now,
                           args={"router_version": self.engine.router.version})
