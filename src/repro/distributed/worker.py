"""One serving-plane worker: engine replica + scheduler + local online state.

A worker is the unit of replication in the multi-worker plane: it owns its
own :class:`~repro.serving.engine.RoutedEngine` instance (so router swaps
are per-worker and atomic), its own admission queue / micro-batching
scheduler / virtual clock (workers run concurrently in real deployments —
their virtual clocks advance independently), and — in online mode — a
follower :class:`~repro.online.loop.OnlineAdapter` whose replay buffer is
the worker's local outcome log. Pool member *parameters* are shared across
workers (one copy of the weights per host in the simulated deployment).

Crash/rejoin models a worker process dying: queued and future requests must
be reassigned by the plane, and the in-memory online state (replay, staged
feedback) does not survive — a rejoining worker comes back empty and
catches up to the current router version from the leader.
"""
from __future__ import annotations

from typing import List, Optional

from collections import deque


class WorkerNode:
    def __init__(self, wid: int, engine, scheduler, adapter=None):
        self.wid = int(wid)
        self.engine = engine
        self.scheduler = scheduler
        self.adapter = adapter
        self.alive = True
        self.arrivals = deque()      # assigned, not-yet-arrived requests
        self.served: List = []       # completed requests, dispatch order
        self.swaps_accepted = 0
        self.swaps_rejected = 0
        self.crashes = 0

    # -- convenience ---------------------------------------------------------

    @property
    def clock(self):
        return self.scheduler.clock

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def telemetry(self):
        return self.scheduler.telemetry

    @property
    def router_version(self) -> int:
        return self.engine.router.version

    # -- router broadcast ----------------------------------------------------

    def publish(self, router) -> bool:
        """Atomically swap a broadcast router in; stale publishes rejected.

        The engine's ``swap_router`` enforces the version ordering — a
        worker that missed a broadcast can later accept a newer version,
        but a delayed older broadcast can never roll this worker back.
        """
        try:
            self.engine.swap_router(router)
        except ValueError:
            self.swaps_rejected += 1
            return False
        self.swaps_accepted += 1
        return True

    # -- plane event loop ----------------------------------------------------

    def next_action_s(self) -> float:
        """Earliest virtual time this worker can act (inf = nothing to do).

        Delegates to the scheduler's ``next_dispatch_s`` so the dispatch
        wake-time policy lives in one place for the solo and multi-worker
        paths alike.
        """
        if not self.alive:
            return float("inf")
        return self.scheduler.next_dispatch_s(
            self.arrivals[0].arrival_s if self.arrivals else None)

    def step(self, t: float) -> List:
        """Advance to ``t``, inject due arrivals, dispatch if ready."""
        self.clock.advance_to(t)
        while self.arrivals and self.arrivals[0].arrival_s <= self.clock.now:
            self.queue.offer(self.arrivals.popleft(), self.clock.now)
        self.scheduler.note_queue_depth()
        served = []
        if self.scheduler.should_dispatch(flush=not self.arrivals):
            served = self.scheduler.dispatch()
            self.served.extend(served)
        return served

    # -- crash / rejoin ------------------------------------------------------

    def crash(self, now: float) -> List:
        """Kill the worker; returns orphaned (queued + future) requests
        the plane must reassign. In-memory online state is lost."""
        self.alive = False
        self.crashes += 1
        orphans = list(self.queue.pop(self.queue.depth)) + list(self.arrivals)
        self.arrivals.clear()
        tracer = getattr(self.scheduler, "tracer", None)
        if tracer is not None:
            tracer.instant("worker_crash", "plane", now,
                           args={"orphans": len(orphans)})
        return orphans

    def rejoin(self, now: float, router=None,
               replay_seed: Optional[int] = None) -> None:
        """Restart after a crash: empty queue, fresh replay (nothing
        survived the process), catch-up swap to the current router."""
        self.alive = True
        self.clock.advance_to(now)
        if self.adapter is not None:
            seed = (self.wid + 1) * 7919 + self.crashes if replay_seed is None \
                else replay_seed
            self.adapter.reset_outcome_state(seed)
        if router is not None and router.version > self.engine.router.version:
            self.publish(router)
        tracer = getattr(self.scheduler, "tracer", None)
        if tracer is not None:
            tracer.instant("worker_rejoin", "plane", now,
                           args={"router_version": self.engine.router.version})
