"""Follower process entry point + controller-side worker proxy.

``python -m repro.distributed.host --wid W --port P --serve-argv JSON``
is what ``repro.launch.serve --transport socket`` launches for workers
1..N-1. The follower:

  1. dials the controller **first** (connect/retry/backoff) — the
     controller's ``accept`` returns as soon as the TCP handshakes land,
     and protocol frames simply queue in the socket buffers while step 2
     runs;
  2. re-parses the controller's forwarded serve argv and rebuilds the
     identical seeded serving context (pool init, predictor training,
     corpus split — every RNG derives from ``--seed``, so no parameters
     cross the wire);
  3. claims its pool shard (:func:`repro.distributed.shard.shard_pool`:
     mesh-sharded params for owned members, evicted otherwise) and
     installs a :class:`~repro.distributed.shard.PoolDispatcher` so legs
     for non-owned members hop to their owners;
  4. services protocol messages (``serve_forever``) until ``SHUTDOWN``.

Budget ops go through a :class:`~repro.distributed.ledger.LedgerClient`
to the controller's shared ledger; traces land in a process-local
recorder the controller collects via ``TRACE_REQ`` at end of run.

**Graceful degradation**: if the controller connection dies mid-run the
follower does not crash — it drains its remaining queued work locally
(:func:`drain_local`) under the last known router version and effective
lambda, stopping only if a leg needs an unreachable peer's pool shard.

:class:`RemoteWorkerProxy` is the other side: the controller's in-memory
stand-in for a follower, satisfying the plane/coordinator reporting
surface (``telemetry`` / ``router_version`` / ``clock`` / ``alive``) by
``TELEMETRY_REQ`` RPC with cached fallback, and mirroring step results
via ``observe_step`` so mid-run reads don't need extra round trips. It
deliberately has NO ``bind`` or ``scheduler`` attribute: the coordinator
then never binds it as a local endpoint, and the plane's SLO dedup
always forces the remote tracker's end-of-run check.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import types
from typing import Optional

from repro.distributed import messages as M
from repro.distributed.messages import Message
from repro.distributed.transport import SocketTransport, TransportError
from repro.serving.telemetry import Telemetry


class RemoteWorkerProxy:
    """Controller-side mirror of a follower-process worker."""

    def __init__(self, wid: int, transport, *, member_names=(),
                 pid: int = -1):
        self.wid = int(wid)
        self.transport = transport
        self.pid = int(pid)
        self.alive = True
        self.clock = types.SimpleNamespace(now=0.0)
        self.served_count = 0
        # Cached fallbacks for a partitioned follower: reporting degrades
        # to the last mirrored values instead of raising mid-summary.
        self._telemetry = Telemetry(list(member_names))
        self._version = 0
        self.swaps_accepted = 0
        self.swaps_rejected = 0
        self.crashes = 0

    def observe_step(self, rep: dict) -> None:
        """Mirror a STEP reply — keeps clock/served fresh without RPC."""
        self.clock.now = max(self.clock.now, float(rep["now"]))
        self.served_count += int(rep["n_served"])

    def _refresh(self) -> None:
        try:
            rep = self.transport.request(
                Message(kind=M.TELEMETRY_REQ, dst=self.wid))
        except TransportError:
            return
        p = rep.payload
        self._telemetry = p["telemetry"]
        self._version = int(p["version"])
        self.swaps_accepted = int(p["swaps_accepted"])
        self.swaps_rejected = int(p["swaps_rejected"])
        self.crashes = int(p["crashes"])
        self.served_count = int(p["served"])
        self.clock.now = max(self.clock.now, float(p["now"]))

    @property
    def telemetry(self) -> Telemetry:
        self._refresh()
        return self._telemetry

    @property
    def router_version(self) -> int:
        self._refresh()
        return self._version


def drain_local(worker) -> int:
    """Follower-local degradation: serve out the backlog without a plane.

    Runs the worker's own step loop (arrivals -> queue -> dispatch) under
    the last broadcast router version; the LedgerClient governor has
    already degraded to its cached lambda. Stops early if a generate leg
    needs a pool shard owned by an unreachable peer. Returns requests
    served while degraded.
    """
    served = 0
    while True:
        t = worker.next_action_s()
        if t == float("inf"):
            break
        try:
            served += len(worker.step(t))
        except TransportError:
            break           # a leg needs an unreachable peer's shard
    return served


def run_follower(wid: int, port: int, serve_argv: list,
                 host: str = "127.0.0.1") -> int:
    """Build worker ``wid`` from the forwarded argv and serve the plane."""
    # Import here, not at module top: serve imports this module back for
    # RemoteWorkerProxy, and the follower only needs the heavy serving
    # stack after the connection is up anyway.
    from repro.distributed.ledger import LedgerClient
    from repro.distributed.shard import PoolDispatcher, shard_pool
    from repro.launch import serve

    args = serve.make_parser().parse_args(serve_argv)

    transport = SocketTransport(wid, timeout=600.0)
    transport.connect(port, host, hello_payload={"pid": os.getpid()})
    print(f"[w{wid}] pid {os.getpid()} connected to controller "
          f"{host}:{port}; building serving context", flush=True)

    ctx = serve.build_context(args)
    recorder = None
    if args.trace_out or args.trace_profile \
            or serve._streaming_requested(args):
        from repro.obs import TraceRecorder, TraceSampler

        sampler = None
        if args.trace_sample is not None:
            sampler = TraceSampler(args.trace_sample, seed=args.seed,
                                   head=args.trace_head)
        # key_base partitions the trace-key space per process so the
        # controller can absorb drained follower events verbatim.
        recorder = TraceRecorder(
            label=f"serve-{args.trace}-seed{args.seed}-w{wid}",
            sampler=sampler, max_buffered_per_worker=args.trace_cap,
            key_base=wid * 1_000_000)
    governor = None
    if args.budget > 0:
        governor = LedgerClient(transport, dst=0)
    slo = serve._make_slo(args, tracer=recorder)
    drift_proto = serve.build_drift_proto(args, ctx)
    worker = serve.build_plane_worker(args, ctx, wid, governor,
                                     drift_proto, recorder, slo)
    worker.recorder = recorder
    owned = shard_pool(worker.engine.pool, wid, args.workers)
    worker.scheduler.dispatcher = PoolDispatcher(
        wid, args.workers, worker.engine, transport)
    worker.bind(transport)
    # Fleet RPC observability: this follower's outbound RPCs (GENERATE
    # hops to shard owners, ledger ops) emit client-side rpc spans into
    # the local recorder, timestamped on the worker's virtual clock.
    if recorder is not None:
        transport.tracer = recorder
        transport.trace_wid = wid
    transport.now_fn = lambda: worker.clock.now
    # Federated metrics: a process-local registry (series labelled with
    # this wid) the controller scrapes via METRICS_REQ and merges into
    # its /metrics. The shared budget ledger is NOT registered here — it
    # lives in the controller's registry exactly once.
    if args.metrics_out or args.metrics_port is not None \
            or serve._streaming_requested(args):
        from repro.obs import (MetricsRegistry, register_scheduler_metrics,
                               register_slo_metrics,
                               register_transport_metrics)

        registry = MetricsRegistry()
        labels = (("worker", wid),)
        register_scheduler_metrics(registry, worker.scheduler, labels=labels)
        if slo is not None:
            register_slo_metrics(registry, slo,
                                 lambda: worker.clock.now, labels=labels)
        register_transport_metrics(registry, transport, labels=labels)
        worker.registry = registry
    print(f"[w{wid}] ready: router v{worker.router_version}, owns pool "
          f"members {owned}", flush=True)

    degraded_served = 0
    clean = True
    try:
        transport.serve_forever()
    except TransportError as exc:
        clean = False
        print(f"[w{wid}] controller lost ({exc}); degrading to "
              f"follower-local serving", flush=True)
        degraded_served = drain_local(worker)
        print(f"[w{wid}] degraded drain served {degraded_served} "
              f"requests", flush=True)
    finally:
        transport.close()

    disp = worker.scheduler.dispatcher
    print(f"[w{wid}] done: served {len(worker.served)} "
          f"(v{worker.router_version}, generate local/remote "
          f"{disp.stats['local']}/{disp.stats['remote']})", flush=True)
    if worker.scheduler.cascade is not None:
        print(f"[w{wid}] {worker.scheduler.cascade.report()}", flush=True)
    if worker.scheduler.semcache is not None:
        rep = worker.scheduler.semcache.report()
        print(f"[w{wid}] semcache: {rep['served']}/{rep['lookups']} served "
              f"(hit rate {rep['hit_rate']:.2f})  {rep['entries']} entries",
              flush=True)
    if worker.adapter is not None:
        print(f"[w{wid}] {worker.adapter.report()}", flush=True)
    return 0 if clean else 1


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--wid", type=int, required=True)
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--serve-argv", required=True,
                    help="JSON list: the controller's serve argv, "
                         "re-parsed to rebuild identical seeded state")
    a = ap.parse_args(argv)
    serve_argv = json.loads(a.serve_argv)
    if not isinstance(serve_argv, list):
        ap.error("--serve-argv must be a JSON list of strings")
    return run_follower(a.wid, a.port, [str(s) for s in serve_argv],
                        host=a.host)


if __name__ == "__main__":
    sys.exit(main())
