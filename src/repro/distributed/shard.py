"""Pool-member sharding: each worker owns a shard of the LM pool.

The paper's setting (and RouterBench's) is a pool of heterogeneous LLMs
too large to co-host: the router is tiny, the members are not. This
module splits pool ownership across the worker fleet:

  * :func:`owner_of` — deterministic member -> worker placement
    (round-robin by index, stable under worker count);
  * :func:`shard_pool` — on a worker process, lay out the *owned*
    members' parameters with the repo's per-config mesh sharding specs
    (:func:`repro.launch.sharding.param_shardings` over a
    :func:`repro.launch.mesh.make_debug_mesh` by default — the same
    spec tables production meshes use), and evict the parameters of
    members this worker does not own (scoring never reads them; only
    ``PoolMember.generate`` does);
  * :class:`PoolDispatcher` — the scheduler-side indirection: a generate
    micro-batch for a member this worker owns runs locally, any other
    member's batch becomes a ``GENERATE`` message to the owning worker.

The dispatcher preserves ``RoutedEngine.generate_member``'s exact
signature and return contract (per-request output token rows + $ costs),
so the scheduler's delivered-work pricing and telemetry are oblivious to
where the member actually ran.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.distributed import messages as M
from repro.distributed.messages import Message


def owner_of(member_idx: int, n_workers: int) -> int:
    """Which worker owns pool member ``member_idx`` (round-robin)."""
    if n_workers <= 0:
        raise ValueError("n_workers must be positive")
    return int(member_idx) % int(n_workers)


def owned_members(wid: int, n_members: int, n_workers: int) -> List[int]:
    return [mi for mi in range(n_members)
            if owner_of(mi, n_workers) == int(wid)]


def shard_pool(pool, wid: int, n_workers: int, *, mesh=None,
               evict: bool = True) -> List[int]:
    """Apply mesh sharding to this worker's owned members; evict the rest.

    Returns the owned member indices. ``mesh=None`` uses the single-host
    debug mesh — the sharding *specs* are identical to what a production
    mesh would get, only the device set differs. With ``evict=True`` the
    non-owned members' parameters are dropped (the memory win that makes
    this sharding real); their generates must go through a
    :class:`PoolDispatcher`.
    """
    import jax

    from repro.launch.mesh import make_debug_mesh
    from repro.launch.sharding import param_shardings

    if mesh is None:
        mesh = make_debug_mesh(1, 1)
    owned = []
    for mi, member in enumerate(pool):
        if owner_of(mi, n_workers) == int(wid):
            shardings = param_shardings(member.cfg, mesh, member.params)
            member.params = jax.device_put(member.params, shardings)
            owned.append(mi)
        elif evict:
            member.params = None
    return owned


class PoolDispatcher:
    """Routes generate micro-batches to the member's owning worker.

    Installed as the scheduler's ``dispatcher``: the scheduler calls
    :meth:`generate_member` exactly where it would call the engine's, and
    the dispatcher either runs the batch on the local engine (owned
    member) or ships it as one ``GENERATE`` request to the owner over the
    transport. Remote costs come back as the owner priced them — the
    member's per-token rate is placement-independent, so the budget
    ledger sees identical $ either way.
    """

    def __init__(self, wid: int, n_workers: int, engine, transport):
        self.wid = int(wid)
        self.n_workers = int(n_workers)
        self.engine = engine
        self.transport = transport
        self.stats = {"local": 0, "remote": 0}
        # Trace context for the NEXT generate (set by the scheduler per
        # micro-batch): stamped onto the GENERATE frame so the owner's
        # server span joins the requesting request's causal chain.
        self.trace_key = None
        self.parent_span = None
        # After each call: the remote GENERATE's rpc link id (the request
        # seq, echoed as the reply's reply_to) — None for a local run. The
        # scheduler attaches it to the leg/generate spans as the `rpc` arg.
        self.last_rpc = None

    def owns(self, member_idx: int) -> bool:
        return owner_of(member_idx, self.n_workers) == self.wid

    def generate_member(self, member_idx: int, prompts,
                        max_new: int = 8,
                        max_new_per_req: Optional[List[int]] = None):
        if self.owns(member_idx):
            self.stats["local"] += 1
            self.last_rpc = None
            return self.engine.generate_member(
                member_idx, prompts, max_new=max_new,
                max_new_per_req=max_new_per_req)
        self.stats["remote"] += 1
        owner = owner_of(member_idx, self.n_workers)
        rep = self.transport.request(Message(
            kind=M.GENERATE, dst=owner,
            trace_key=self.trace_key, parent_span=self.parent_span,
            payload={"member": int(member_idx),
                     "prompts": [np.asarray(p) for p in prompts],
                     "max_new": int(max_new),
                     "max_new_per_req": (None if max_new_per_req is None
                                         else [int(m)
                                               for m in max_new_per_req])}))
        self.last_rpc = rep.reply_to
        outs = [np.asarray(o) for o in rep.payload["outs"]]
        costs = np.asarray(rep.payload["costs"], np.float64)
        return outs, costs
