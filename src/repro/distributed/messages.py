"""Typed, versioned message codec for the distributed serving plane.

Every protocol interaction between the plane's components — replay-merge
gathers, router broadcasts with version fencing, ledger spend reports,
drift-alarm cache invalidations, crash/rejoin, leader catch-up — is a
:class:`Message`: a ``kind`` from the closed vocabulary below, source and
destination worker ids, a sequence number (for request/reply pairing),
and a payload dict.

Two delivery regimes share the type:

  * :class:`~repro.distributed.transport.LocalTransport` passes the
    ``Message`` object **by reference** — payload objects (``Request``
    instances, routers, replay batches) keep their identity, which the
    in-process plane relies on (served-request mutations must land on
    the trace's original objects) and which makes seeded replays
    byte-identical by construction.
  * :class:`~repro.distributed.transport.SocketTransport` frames
    ``encode(msg)`` bytes over TCP. The codec is a small self-contained
    tagged binary format (no pickle): scalars, strings, bytes,
    containers, and ndarrays (dtype + shape + raw C-order buffer —
    lossless, including float NaN/inf), plus adapters for the domain
    objects that cross process boundaries (``PredictiveRouter``,
    ``Request``, ``Telemetry`` and its ``Histogram``/``BoundedSeries``
    internals).

The frame starts with ``MAGIC`` + ``PROTOCOL_VERSION``; a receiver on a
different protocol version rejects the frame outright instead of
misparsing it.

Version 2 added the optional trace context ``(trace_key, parent_span)``
to every frame (fleet-wide RPC tracing) and the ``METRICS_REQ`` kind
(federated metrics scrape). Both ride the same field dict the codec has
always encoded, so a v2 decoder accepts frames with or without them;
v1 decoders reject v2 frames at the version byte.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any, Dict, Optional

import numpy as np

MAGIC = b"RMSG"
PROTOCOL_VERSION = 2

# -- message kinds -----------------------------------------------------------
# Session / control
HELLO = "hello"                  # follower -> controller: wid, pid
ACK = "ack"                      # generic reply envelope
ERROR = "error"                  # handler raised: payload {"error": str}
SHUTDOWN = "shutdown"            # controller -> follower: exit serve loop
# Coordinator sync protocol
SYNC_STATUS = "sync_status"      # -> {alive, version, has_adapter,
#                                      pending_burst, added, distinct}
REPLAY_SAMPLE = "replay_sample"  # {n, recent_frac} -> {batch}
ROUTER_BCAST = "router_bcast"    # {router} -> {accepted, version}
CLEAR_BURST = "clear_burst"      # leader ran the concentrated burst
CACHE_INVAL = "cache_inval"      # {mode, now}: fleet-wide semcache inval
# Plane event loop
ASSIGN = "assign"                # {reqs}: merge into worker arrivals
NEXT_ACTION = "next_action"      # -> {t}
STEP = "step"                    # {t} -> {n_served, now}
CRASH = "crash"                  # {t} -> {orphans}
REJOIN = "rejoin"                # {t, router, replay_seed}
TICK = "tick"                    # {t}: final staged-feedback flush
FINALIZE = "finalize"            # {t, check_slo}: end-of-run bookkeeping
# Sharded-pool dispatch and shared services
GENERATE = "generate"            # {member, prompts, max_new,
#                                   max_new_per_req} -> {outs, costs}
LEDGER_OP = "ledger_op"          # {op, args} -> {result, lam, ...}
TELEMETRY_REQ = "telemetry_req"  # -> {telemetry, served, queue}
TRACE_REQ = "trace_req"          # -> {events, next_key} recorder drain
METRICS_REQ = "metrics_req"      # -> {prom}: follower registry scrape

KINDS = frozenset(v for k, v in list(globals().items())
                  if k.isupper() and isinstance(v, str))

# Kinds that emit client/server ``rpc`` trace spans. Deliberately
# excluded: NEXT_ACTION (per-iteration polling noise), session control
# (HELLO/ACK/ERROR/SHUTDOWN), one-way broadcasts (CLEAR_BURST /
# CACHE_INVAL — loss-tolerant, a client span would imply a handled
# request), and the obs drain traffic itself (TELEMETRY_REQ / TRACE_REQ /
# METRICS_REQ — wall-driven, must not perturb deterministic traces).
RPC_SPAN_KINDS = frozenset({
    SYNC_STATUS, REPLAY_SAMPLE, ROUTER_BCAST, ASSIGN, STEP, CRASH,
    REJOIN, TICK, FINALIZE, GENERATE, LEDGER_OP,
})


@dataclasses.dataclass
class Message:
    kind: str
    dst: int
    src: int = -1
    seq: int = -1
    reply_to: Optional[int] = None
    expect_reply: bool = False
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # Trace context (protocol v2): the request-tree key and parent span
    # link id this frame does work for, so the receiving worker's spans
    # join the sender's causal chain across process boundaries.
    trace_key: Optional[int] = None
    parent_span: Optional[int] = None


# -- domain-object adapters --------------------------------------------------

def _tree_to_np(tree):
    """Materialize a params pytree (dicts/lists/tuples of arrays) to numpy."""
    if isinstance(tree, dict):
        return {k: _tree_to_np(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_tree_to_np(v) for v in tree)
    if tree is None or isinstance(tree, (bool, int, float, str)):
        return tree
    return np.asarray(tree)


def router_to_state(router) -> Dict[str, Any]:
    return {
        "quality_kind": router.quality_kind,
        "cost_kind": router.cost_kind,
        "quality_params": _tree_to_np(router.quality_params),
        "cost_params": _tree_to_np(router.cost_params),
        "model_emb": np.asarray(router.model_emb),
        "reward": router.reward,
        "cost_scaler": _tree_to_np(router.cost_scaler),
        "version": int(router.version),
        "centroids": (None if router.centroids is None
                      else np.asarray(router.centroids)),
    }


def router_from_state(state: Dict[str, Any]):
    from repro.core.router import PredictiveRouter
    return PredictiveRouter(**state)


def request_to_state(req) -> Dict[str, Any]:
    return {f.name: getattr(req, f.name) for f in dataclasses.fields(req)}


def request_from_state(state: Dict[str, Any]):
    from repro.serving.queue import Request
    return Request(**state)


def telemetry_to_state(tel) -> Dict[str, Any]:
    return dict(vars(tel))


def telemetry_from_state(state: Dict[str, Any]):
    from repro.serving.telemetry import Telemetry
    tel = Telemetry(state["member_names"])
    for k, v in state.items():
        setattr(tel, k, v)
    return tel


def _histogram_to_state(h) -> Dict[str, Any]:
    return {"edges": h.edges, "counts": h.counts, "count": h.count,
            "total": h.total, "min": h.min, "max": h.max,
            "exemplars": {k: tuple(v) for k, v in h.exemplars.items()}}


def _histogram_from_state(state: Dict[str, Any]):
    from repro.serving.telemetry import Histogram
    h = Histogram()
    h.edges = np.asarray(state["edges"])
    h.counts = np.asarray(state["counts"])
    h.count = int(state["count"])
    h.total = float(state["total"])
    h.min = float(state["min"])
    h.max = float(state["max"])
    # Pre-exemplar peers omit the field; tolerate its absence.
    h.exemplars = {int(k): tuple(v)
                   for k, v in state.get("exemplars", {}).items()}
    return h


def _series_to_state(s) -> Dict[str, Any]:
    return {"cap": s.cap, "stride": s.stride, "n_seen": s.n_seen,
            "points": [list(p) for p in s._points]}


def _series_from_state(state: Dict[str, Any]):
    from repro.serving.telemetry import BoundedSeries
    s = BoundedSeries(cap=int(state["cap"]))
    s.stride = int(state["stride"])
    s.n_seen = int(state["n_seen"])
    s._points = [tuple(p) for p in state["points"]]
    return s


# -- tagged binary codec -----------------------------------------------------
#
# One tag byte per value. Lengths/counts are u32 big-endian; ints i64;
# floats f64. Objects are encoded as (tag, state-dict) through the
# adapters above — the adapters, not the codec, own the field lists.

_T_NONE, _T_TRUE, _T_FALSE = b"N", b"T", b"F"
_T_INT, _T_BIGINT, _T_FLOAT = b"i", b"Z", b"f"
_T_STR, _T_BYTES = b"s", b"b"
_T_LIST, _T_TUPLE, _T_SET, _T_DICT = b"l", b"t", b"e", b"d"
_T_NDARRAY = b"a"
_T_ROUTER, _T_REQUEST, _T_TELEMETRY = b"R", b"Q", b"Y"
_T_HISTOGRAM, _T_SERIES = b"H", b"G"


def _enc(obj, out: list) -> None:
    if obj is None:
        out.append(_T_NONE)
    elif obj is True:
        out.append(_T_TRUE)
    elif obj is False:
        out.append(_T_FALSE)
    elif isinstance(obj, np.bool_):
        out.append(_T_TRUE if bool(obj) else _T_FALSE)
    elif isinstance(obj, (int, np.integer)):
        try:
            out.append(_T_INT + struct.pack(">q", int(obj)))
        except struct.error:
            raw = str(int(obj)).encode()
            out.append(_T_BIGINT + struct.pack(">I", len(raw)) + raw)
    elif isinstance(obj, (float, np.floating)):
        out.append(_T_FLOAT + struct.pack(">d", float(obj)))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.append(_T_STR + struct.pack(">I", len(raw)) + raw)
    elif isinstance(obj, (bytes, bytearray)):
        out.append(_T_BYTES + struct.pack(">I", len(obj)) + bytes(obj))
    elif isinstance(obj, np.ndarray):
        if obj.dtype.hasobject:
            raise TypeError(
                "unencodable message value: object-dtype ndarray")
        arr = np.ascontiguousarray(obj)
        dt = arr.dtype.str.encode()
        raw = arr.tobytes()
        out.append(_T_NDARRAY + struct.pack(">B", len(dt)) + dt
                   + struct.pack(">B", arr.ndim)
                   + b"".join(struct.pack(">I", d) for d in arr.shape)
                   + struct.pack(">I", len(raw)) + raw)
    elif isinstance(obj, (list, tuple, set, frozenset)):
        tag = (_T_LIST if isinstance(obj, list)
               else _T_TUPLE if isinstance(obj, tuple) else _T_SET)
        items = sorted(obj, key=repr) if tag == _T_SET else obj
        out.append(tag + struct.pack(">I", len(obj)))
        for v in items:
            _enc(v, out)
    elif isinstance(obj, dict):
        out.append(_T_DICT + struct.pack(">I", len(obj)))
        for k, v in obj.items():
            _enc(k, out)
            _enc(v, out)
    else:
        name = type(obj).__name__
        adapters = {
            "PredictiveRouter": (_T_ROUTER, router_to_state),
            "Request": (_T_REQUEST, request_to_state),
            "Telemetry": (_T_TELEMETRY, telemetry_to_state),
            "Histogram": (_T_HISTOGRAM, _histogram_to_state),
            "BoundedSeries": (_T_SERIES, _series_to_state),
        }
        if name in adapters:
            tag, to_state = adapters[name]
            out.append(tag)
            _enc(to_state(obj), out)
            return
        # jax arrays (or anything array-like) degrade to a numpy snapshot.
        try:
            arr = np.asarray(obj)
        except Exception:
            raise TypeError(
                f"unencodable message value of type {type(obj)!r}")
        if arr.dtype == object:
            raise TypeError(
                f"unencodable message value of type {type(obj)!r}")
        _enc(arr, out)


class _Reader:
    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise ValueError("truncated message frame")
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]


def _dec(r: _Reader):
    tag = r.take(1)
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_INT:
        return struct.unpack(">q", r.take(8))[0]
    if tag == _T_BIGINT:
        return int(r.take(r.u32()).decode())
    if tag == _T_FLOAT:
        return struct.unpack(">d", r.take(8))[0]
    if tag == _T_STR:
        return r.take(r.u32()).decode("utf-8")
    if tag == _T_BYTES:
        return r.take(r.u32())
    if tag == _T_NDARRAY:
        dt = np.dtype(r.take(struct.unpack(">B", r.take(1))[0]).decode())
        ndim = struct.unpack(">B", r.take(1))[0]
        shape = tuple(r.u32() for _ in range(ndim))
        raw = r.take(r.u32())
        return np.frombuffer(raw, dtype=dt).reshape(shape).copy()
    if tag in (_T_LIST, _T_TUPLE, _T_SET):
        n = r.u32()
        items = [_dec(r) for _ in range(n)]
        if tag == _T_TUPLE:
            return tuple(items)
        if tag == _T_SET:
            return set(items)
        return items
    if tag == _T_DICT:
        n = r.u32()
        return {_dec(r): _dec(r) for _ in range(n)}
    if tag == _T_ROUTER:
        return router_from_state(_dec(r))
    if tag == _T_REQUEST:
        return request_from_state(_dec(r))
    if tag == _T_TELEMETRY:
        return telemetry_from_state(_dec(r))
    if tag == _T_HISTOGRAM:
        return _histogram_from_state(_dec(r))
    if tag == _T_SERIES:
        return _series_from_state(_dec(r))
    raise ValueError(f"unknown codec tag {tag!r}")


def encode(msg: Message) -> bytes:
    """Message -> length-independent frame body (transport adds framing)."""
    out = [MAGIC, struct.pack(">B", PROTOCOL_VERSION)]
    _enc({
        "kind": msg.kind, "dst": msg.dst, "src": msg.src, "seq": msg.seq,
        "reply_to": msg.reply_to, "expect_reply": msg.expect_reply,
        "payload": msg.payload, "trace_key": msg.trace_key,
        "parent_span": msg.parent_span,
    }, out)
    return b"".join(out)


def decode(buf: bytes) -> Message:
    if buf[:4] != MAGIC:
        raise ValueError("bad message magic")
    ver = struct.unpack(">B", buf[4:5])[0]
    if ver != PROTOCOL_VERSION:
        raise ValueError(f"protocol version mismatch: "
                         f"got {ver}, want {PROTOCOL_VERSION}")
    fields = _dec(_Reader(buf[5:]))
    return Message(kind=fields["kind"], dst=fields["dst"], src=fields["src"],
                   seq=fields["seq"], reply_to=fields["reply_to"],
                   expect_reply=fields["expect_reply"],
                   payload=fields["payload"],
                   trace_key=fields.get("trace_key"),
                   parent_span=fields.get("parent_span"))
