"""Leader/follower router sync: replay merge -> leader update -> broadcast.

The serve->learn loop of the single-worker adapter has four in-process
singletons: the replay buffer, the incremental updater, the drift burst,
and the router version. Replicating the scheduler across N workers
requires each to become an explicitly synchronized component:

  * **replay merge** — every sync round, each alive worker contributes a
    recency-stratified sample of its *local* replay (its own seeded
    generator), gathered in ascending worker-id order into the leader's
    merge buffer. The merge order and every sample are seeded, so two
    planes fed the same traffic produce bit-identical merged streams.
  * **leader update** — only the leader runs the bounded Adam steps
    (:class:`~repro.online.updater.IncrementalUpdater`), on the merged
    buffer, anchored to the leader's live router.
  * **broadcast** — the resulting versioned router is swapped on every
    alive worker through ``RoutedEngine.swap_router``; its stale-publish
    rejection means a worker that missed a version can accept any newer
    broadcast but can never be rolled back by a delayed older one.
  * **leader election** — deterministic, state-free: the lowest-id alive
    worker leads. When the leader crashes, the next worker's router (kept
    current by the broadcasts) anchors a fresh updater; Adam moments reset,
    exactly like the hot-membership warm-start path.

Since the message-passing refactor, every worker interaction above is a
:class:`~repro.distributed.messages.Message` over a
:class:`~repro.distributed.transport.Transport` — ``SYNC_STATUS`` /
``REPLAY_SAMPLE`` / ``ROUTER_BCAST`` / ``CLEAR_BURST`` / ``CACHE_INVAL``
— so the same coordinator drives in-process workers (LocalTransport,
by-reference, bit-identical to the pre-refactor plane) and real remote
processes (SocketTransport). The one deliberate exception: the
coordinator is **co-located with the leader** — the updater reads
``leader.engine`` / ``leader.adapter`` directly (gathering gradients over
a wire buys nothing when the update runs on exactly one node), which in
socket mode pins the controller process to worker 0.

An unreachable worker (socket partition) is skipped for the round and
counted in ``stats["unreachable"]``; version fencing makes the eventual
``converge()`` catch-up safe regardless of what it missed.

Follower drift alarms don't burst locally (that would fork router
lineages); they raise ``pending_burst``, and the next sync round runs one
concentrated burst on the leader instead — and, since the burst signals
the query distribution moved, broadcasts a ``CACHE_INVAL`` so every
worker's semantic cache invalidates together instead of drifting apart.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.distributed import messages as M
from repro.distributed.messages import Message
from repro.distributed.transport import LocalTransport, TransportError
from repro.online.replay import ReplayBuffer
from repro.online.updater import IncrementalUpdater, OnlineUpdateConfig


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    sync_every_s: float = 0.25     # virtual seconds between sync rounds
    merge_per_worker: int = 48     # stratified sample size gathered per worker
    merge_capacity: int = 4096     # leader-side merge buffer capacity
    merge_recent_frac: float = 0.5
    steps_per_sync: int = 8        # bounded leader Adam steps per round
    burst_steps: int = 48          # when a follower raised pending_burst
    min_buffer: int = 32           # don't update on a near-empty merge buffer
    seed: int = 0
    update: OnlineUpdateConfig = OnlineUpdateConfig()


class Coordinator:
    def __init__(self, workers: List, config: Optional[SyncConfig] = None,
                 *, transport=None):
        self.workers = list(workers)
        self.config = config or SyncConfig()
        self.transport = transport if transport is not None \
            else LocalTransport()
        for w in self.workers:
            if hasattr(w, "bind") and getattr(w, "transport", None) is None:
                w.bind(self.transport)
        self.merge_replay = ReplayBuffer(self.config.merge_capacity,
                                         seed=self.config.seed)
        self._updater: Optional[IncrementalUpdater] = None
        self._anchor_wid: Optional[int] = None
        self._last_outcome_snap: dict = {}
        # Observability hook (repro.obs): the shared TraceRecorder (not a
        # worker-scoped view) — sync events are stamped with the leader's
        # wid at emission time. Installed by the plane.
        self.tracer = None
        self.stats = {
            "syncs": 0, "merged": 0, "updates": 0, "update_steps": 0,
            "bursts": 0, "broadcasts": 0, "stale_rejected": 0,
            "leader_changes": 0, "unreachable": 0, "cache_invals": 0,
        }

    # -- transport helpers ---------------------------------------------------

    def _request(self, wid: int, kind: str,
                 payload: Optional[dict] = None) -> Optional[dict]:
        """One RPC to a worker endpoint; None = unreachable this round."""
        try:
            rep = self.transport.request(
                Message(kind=kind, dst=wid, payload=payload or {}))
        except TransportError:
            self.stats["unreachable"] += 1
            return None
        return rep.payload

    def _send(self, wid: int, kind: str,
              payload: Optional[dict] = None) -> None:
        try:
            self.transport.send(
                Message(kind=kind, dst=wid, payload=payload or {}))
        except TransportError:
            self.stats["unreachable"] += 1

    # -- membership ----------------------------------------------------------

    @property
    def alive(self) -> List:
        return [w for w in sorted(self.workers, key=lambda w: w.wid)
                if w.alive]

    @property
    def leader(self):
        """Lowest-id alive worker — deterministic, no consensus state."""
        alive = self.alive
        return alive[0] if alive else None

    def _ensure_updater(self, leader) -> IncrementalUpdater:
        if self._updater is None or self._anchor_wid != leader.wid:
            if self._anchor_wid is not None and self._anchor_wid != leader.wid:
                self.stats["leader_changes"] += 1
            # Anchor on the new leader's live router (kept current by the
            # broadcasts); optimizer moments reset, like warm_start.
            self._updater = IncrementalUpdater(leader.engine.router,
                                               self.config.update)
            self._anchor_wid = leader.wid
        return self._updater

    # -- sync protocol -------------------------------------------------------

    def merge_round(self, now: float) -> int:
        """Gather stratified replay samples from every alive worker, in
        ascending worker-id order (deterministic merge order)."""
        n = 0
        for w in self.alive:
            rep = self._request(w.wid, M.REPLAY_SAMPLE, {
                "n": self.config.merge_per_worker,
                "recent_frac": self.config.merge_recent_frac})
            batch = None if rep is None else rep.get("batch")
            if batch is None:
                continue
            for q, m, s, c, t in zip(batch["q_emb"], batch["member"],
                                     batch["s"], batch["c"], batch["t"]):
                self.merge_replay.add(q, int(m), float(s), float(c), float(t))
                n += 1
        self.stats["merged"] += n
        return n

    def _statuses(self) -> Dict[int, dict]:
        """SYNC_STATUS from every alive worker (ascending wid); workers
        unreachable this round are simply absent from the map."""
        out: Dict[int, dict] = {}
        for w in self.alive:
            st = self._request(w.wid, M.SYNC_STATUS)
            if st is not None:
                out[w.wid] = st
        return out

    def sync_round(self, now: float):
        """One leader/follower cycle: merge -> bounded update -> broadcast.

        Returns the newly published router, or None when no update ran
        (empty merge buffer, no leader, or zero effective steps).
        """
        leader = self.leader
        if leader is None:
            return None
        self.transport.now = now   # virtual timestamp for rpc spans
        updater = self._ensure_updater(leader)
        self.stats["syncs"] += 1

        statuses = self._statuses()
        # Read (don't clear) escalated follower bursts: if this round can't
        # run steps yet (empty merge buffer), the flags must survive to the
        # round that can — the drift detector already re-anchored, so a
        # dropped flag would mean the burst never happens at all.
        burst = any(st["has_adapter"] and st["pending_burst"]
                    for st in statuses.values())
        # Idle guard: if no worker observed anything since the last round
        # (long traffic gaps fire many sync boundaries), don't re-gather
        # and re-train on the same stale samples. Compared per worker id
        # (not as a sum): a crash removes a worker's count and a rejoin
        # resets it, either of which could make an aggregate alias.
        snap = {wid: st["added"] for wid, st in statuses.items()
                if st["has_adapter"]}
        if snap == self._last_outcome_snap and not burst:
            return None
        self._last_outcome_snap = snap
        # Like the solo adapter's min_buffer, counted over DISTINCT held
        # outcomes — the merge buffer itself is inflated by with-replacement
        # sampling, so its length would pass on a near-empty fleet.
        distinct = sum(st["distinct"] for st in statuses.values()
                       if st["has_adapter"])
        if distinct < self.config.min_buffer:
            return None
        self.merge_round(now)
        if len(self.merge_replay) < self.config.min_buffer:
            return None
        steps = self.config.burst_steps if burst else self.config.steps_per_sync
        # Leader co-location: the update runs against the leader's live
        # engine/adapter in this process — the one shared-object access
        # the transport abstraction deliberately keeps.
        model_emb = (leader.adapter.membership.model_emb
                     if leader.adapter is not None
                     else leader.engine.router.model_emb)
        res = updater.run_steps(self.merge_replay, model_emb, steps)
        if res["steps"] == 0:
            return None
        if burst:
            for w in self.alive:
                st = statuses.get(w.wid)
                if st is not None and st["has_adapter"]:
                    self._send(w.wid, M.CLEAR_BURST)
            self.stats["bursts"] += 1
            # The burst means the query distribution moved: invalidate
            # every worker's semantic cache in the same round, so no
            # worker keeps serving answers its peers already dropped.
            for w in self.alive:
                self._send(w.wid, M.CACHE_INVAL,
                           {"mode": "probe", "now": now})
                self.stats["cache_invals"] += 1
        new_router = updater.publish(leader.engine, model_emb)
        leader.swaps_accepted += 1
        self.stats["updates"] += 1
        self.stats["update_steps"] += res["steps"]
        accepted = self.broadcast(new_router, exclude=leader)
        if self.tracer is not None:
            self.tracer.instant(
                "sync_round", "plane", now, wid=leader.wid,
                args={"version": new_router.version,
                      "steps": int(res["steps"]), "burst": bool(burst),
                      "broadcast_accepted": accepted})
        return new_router

    def broadcast(self, router, exclude=None) -> int:
        """Swap ``router`` onto every alive worker; returns acceptances."""
        ok = 0
        for w in self.alive:
            if w is exclude:
                continue
            self.stats["broadcasts"] += 1
            rep = self._request(w.wid, M.ROUTER_BCAST, {"router": router})
            if rep is None:
                continue            # partitioned: converge() repairs later
            if rep["accepted"]:
                ok += 1
            else:
                self.stats["stale_rejected"] += 1
        return ok

    def catch_up(self, worker) -> None:
        """Bring a (re)joined worker to the current canonical version."""
        leader = self.leader
        if leader is None or worker is leader:
            return
        router = leader.engine.router
        st = self._request(worker.wid, M.SYNC_STATUS)
        if st is None:
            return
        if router.version > st["version"]:
            self._request(worker.wid, M.ROUTER_BCAST, {"router": router})

    def converge(self) -> None:
        """Ensure every alive worker holds the leader's router version."""
        for w in self.alive:
            self.catch_up(w)

    # -- reporting -----------------------------------------------------------

    def report(self) -> str:
        s = self.stats
        leader = self.leader
        unreachable = (f"  unreachable {s['unreachable']}"
                       if s["unreachable"] else "")
        return (
            f"coordinator: leader w{leader.wid if leader else '-'}  "
            f"syncs {s['syncs']}  merged {s['merged']} outcomes  "
            f"updates {s['updates']} ({s['update_steps']} steps, "
            f"{s['bursts']} bursts)  broadcasts {s['broadcasts']} "
            f"(stale rejected {s['stale_rejected']})  "
            f"leader changes {s['leader_changes']}{unreachable}"
        )
