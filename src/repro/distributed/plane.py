"""Multi-worker serving plane: N micro-batching workers, one leader.

Requests are assigned round-robin across workers (a front-door load
balancer), every worker runs the continuous micro-batching loop from
:mod:`repro.serving.scheduler` on its own virtual clock, and the
:class:`~repro.distributed.coordinator.Coordinator` periodically runs the
replay-merge -> leader-update -> broadcast cycle.

Since the message-passing refactor the plane drives workers exclusively
through :class:`~repro.distributed.messages.Message` traffic on the
coordinator's :class:`~repro.distributed.transport.Transport` —
``ASSIGN`` / ``NEXT_ACTION`` / ``STEP`` / ``CRASH`` / ``REJOIN`` /
``TICK`` / ``FINALIZE``. Over
:class:`~repro.distributed.transport.LocalTransport` the messages are
delivered by reference to in-process :class:`WorkerNode` endpoints —
the event sequence (and therefore every seeded replay) is bit-identical
to the pre-refactor shared-object plane. Over
:class:`~repro.distributed.transport.SocketTransport` the same loop
drives real OS processes (see :mod:`repro.distributed.host`); the
``workers`` list then holds :class:`~repro.distributed.host.
RemoteWorkerProxy` mirrors that satisfy the reporting surface
(``telemetry``, ``router_version``, ``clock``) by RPC.

The event loop is deterministic: it always advances the worker with the
earliest next-action time (ties by worker id), fires sync rounds at fixed
virtual-time boundaries, and applies crash/rejoin scenario events in
timestamp order. A crashed worker's queued and future requests are
reassigned to the survivors; a rejoining worker comes back with empty
online state and catch-up swaps to the current router version. A worker
whose transport endpoint is unreachable (socket partition) is treated as
unable to act until the crash/rejoin machinery reconciles it.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence

from repro.distributed import messages as M
from repro.distributed.messages import Message
from repro.distributed.transport import TransportError
from repro.serving.telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class PlaneEvent:
    """A scenario event: ``kind`` is "crash" or "rejoin"."""
    t: float
    kind: str
    wid: int


class ServingPlane:
    def __init__(self, workers: List, coordinator, *,
                 sync_every_s: Optional[float] = None,
                 events: Sequence[PlaneEvent] = (), tracer=None,
                 flusher=None, fleet_drain=None):
        self.workers = {w.wid: w for w in workers}
        self.coordinator = coordinator
        self.transport = coordinator.transport
        self.sync_every_s = (coordinator.config.sync_every_s
                             if sync_every_s is None else sync_every_s)
        self.events = sorted(
            events, key=lambda e: (e.t, e.kind != "crash", e.wid))
        self.reassigned = 0
        self.ignored_events: List[PlaneEvent] = []
        self._stash: List = []   # orphans while no worker is alive
        # Observability (repro.obs): the plane's tracer is the SHARED
        # TraceRecorder — workers hold worker-scoped views of it, the
        # coordinator stamps its events with the leader's wid, and
        # scenario events land here. One recorder means a request that
        # migrates between workers (crash reassignment) keeps one span
        # tree across pids. (Socket mode has per-process recorders
        # instead, merged by the driver at end of run.)
        self.tracer = tracer
        if tracer is not None and getattr(coordinator, "tracer", None) \
                is None:
            coordinator.tracer = tracer
        # RPC tracing: the transport emits client-side `rpc` spans for the
        # plane/coordinator protocol traffic. The event loop stamps
        # `transport.now` with the fleet's virtual time at every decision
        # point, so span timestamps are a pure function of the seeded
        # schedule (wall latency goes to `transport.stats`, not the trace).
        if tracer is not None and self.transport.tracer is None:
            self.transport.tracer = tracer
        # Socket mode: called at every sync boundary with the fleet
        # high-water virtual time — drains follower trace segments and
        # refreshes the federated /metrics snapshot between rounds.
        self.fleet_drain = fleet_drain
        # Streaming flusher (repro.obs.stream.ObsFlusher): ticked at the
        # event loop's deterministic decision points on the fleet's
        # high-water virtual time — flush boundaries are a pure function
        # of the seeded schedule, so segment contents replay bit-identical.
        self.flusher = flusher

    # -- transport helpers ---------------------------------------------------

    def _request(self, wid: int, kind: str,
                 payload: Optional[dict] = None) -> Optional[dict]:
        try:
            rep = self.transport.request(
                Message(kind=kind, dst=wid, payload=payload or {}))
        except TransportError:
            return None
        return rep.payload

    # -- request assignment --------------------------------------------------

    def _alive(self) -> List:
        return [w for w in sorted(self.workers.values(), key=lambda w: w.wid)
                if w.alive]

    def _assign(self, reqs: Sequence) -> None:
        """Round-robin a time-sorted request list over alive workers."""
        alive = self._alive()
        if not alive:
            self._stash.extend(reqs)
            return
        buckets: Dict[int, List] = {w.wid: [] for w in alive}
        for i, r in enumerate(sorted(reqs, key=lambda r: (r.arrival_s, r.rid))):
            w = alive[i % len(alive)]
            buckets[w.wid].append(r)
        for w in alive:
            if buckets[w.wid]:
                rep = self._request(w.wid, M.ASSIGN,
                                    {"reqs": buckets[w.wid]})
                if rep is None:     # unreachable: hold for a rejoin
                    self._stash.extend(buckets[w.wid])

    # -- scenario events -----------------------------------------------------

    def _apply_event(self, e: PlaneEvent) -> None:
        w = self.workers[e.wid]
        self.transport.now = e.t
        if self.tracer is not None:
            self.tracer.instant("plane_event", "plane", e.t, wid=e.wid,
                                args={"kind": e.kind})
        if e.kind == "crash" and w.alive:
            rep = self._request(e.wid, M.CRASH, {"t": e.t})
            orphans = rep["orphans"] if rep is not None else []
            w.alive = False
            self.reassigned += len(orphans)
            self._assign(orphans)
        elif e.kind == "rejoin" and not w.alive:
            leader = self.coordinator.leader
            router = leader.engine.router if leader is not None else None
            rep = self._request(e.wid, M.REJOIN,
                                {"t": e.t, "router": router,
                                 "replay_seed": None})
            if rep is None:
                return              # still unreachable: stays down
            w.alive = True
            if self._stash:
                stash, self._stash = self._stash, []
                self._assign(stash)
        elif e.kind in ("crash", "rejoin"):
            # Crash of a dead worker / rejoin of a live one: the protocol
            # treats these as idempotent no-ops, but record them — a
            # misordered scenario (rejoin scheduled before its crash)
            # surfaces here instead of disappearing silently.
            self.ignored_events.append(e)
        else:
            raise ValueError(f"unknown plane event kind {e.kind!r}")

    # -- the deterministic event loop ----------------------------------------

    def _next_action(self, w) -> float:
        rep = self._request(w.wid, M.NEXT_ACTION)
        return float("inf") if rep is None else float(rep["t"])

    def run_trace(self, trace: Sequence) -> Dict:
        """Serve an open-loop trace across the worker fleet to completion."""
        ev = deque(self.events)
        t_start = min((w.clock.now for w in self.workers.values()),
                      default=0.0)
        self.transport.now = t_start
        self._assign(list(trace))
        next_sync = t_start + self.sync_every_s
        t_hi = t_start                  # fleet high-water virtual time
        while True:
            acts = [(self._next_action(w), w.wid) for w in self._alive()]
            acts = [a for a in acts if a[0] != float("inf")]
            t_next, wid = min(acts) if acts else (float("inf"), -1)
            t_ev = ev[0].t if ev else float("inf")
            if t_next == float("inf"):
                if ev:              # drain remaining scenario events
                    self._apply_event(ev.popleft())
                    continue
                break
            if t_ev <= t_next and t_ev <= next_sync:
                self._apply_event(ev.popleft())
                continue
            if next_sync <= t_next:
                self.coordinator.sync_round(next_sync)
                t_hi = max(t_hi, next_sync)
                next_sync += self.sync_every_s
                if self.fleet_drain is not None:
                    self.fleet_drain(t_hi)
                if self.flusher is not None:
                    self.flusher.maybe_flush(t_hi)
                continue
            self.transport.now = t_next
            rep = self._request(wid, M.STEP, {"t": t_next})
            w = self.workers[wid]
            if rep is not None and hasattr(w, "observe_step"):
                w.observe_step(rep)     # proxy mirrors clock/served counts
            t_hi = max(t_hi, t_next)
            if self.flusher is not None:
                self.flusher.maybe_flush(t_hi)

        t_end = max(w.clock.now for w in self.workers.values())
        self.transport.now = t_end
        for w in self._alive():
            self._request(w.wid, M.TICK, {"t": t_end})
        self.coordinator.sync_round(t_end)
        self.coordinator.converge()
        # Forced end-of-run SLO evaluation. In-process workers may SHARE
        # one tracker (the fleet-wide SLO view) — dedup by object id so a
        # run shorter than the check throttle still surfaces each alert
        # exactly once; remote proxies own per-process trackers and always
        # check.
        seen_slos: set = set()
        for w in self.workers.values():
            check_slo = True
            sched = getattr(w, "scheduler", None)
            if sched is not None:
                slo = getattr(sched, "slo", None)
                if slo is None or id(slo) in seen_slos:
                    check_slo = False
                else:
                    seen_slos.add(id(slo))
            self._request(w.wid, M.FINALIZE,
                          {"t": t_end, "check_slo": check_slo})
        return self.summary(t_end - t_start)

    # -- reporting -----------------------------------------------------------

    def rollup(self) -> Telemetry:
        return Telemetry.rollup(
            [w.telemetry for w in sorted(self.workers.values(),
                                         key=lambda w: w.wid)])

    def summary(self, duration_s: Optional[float] = None) -> Dict:
        merged = self.rollup()
        out = merged.summary(duration_s)
        out["n_workers"] = len(self.workers)
        out["alive_workers"] = len(self._alive())
        out["reassigned"] = self.reassigned
        out["ignored_events"] = [dataclasses.asdict(e)
                                 for e in self.ignored_events]
        out["router_versions"] = {
            w.wid: w.router_version for w in self.workers.values()}
        out["per_worker_completed"] = {
            w.wid: w.telemetry.completed for w in self.workers.values()}
        out["coordinator"] = dict(self.coordinator.stats)
        return out

    def report(self, duration_s: Optional[float] = None) -> str:
        merged = self.rollup()
        lines = [merged.report(duration_s)]
        versions = " ".join(
            f"w{w.wid}:v{w.router_version}{'' if w.alive else '(down)'}"
            for w in sorted(self.workers.values(), key=lambda w: w.wid))
        ignored = (f"  ignored events {len(self.ignored_events)}"
                   if self.ignored_events else "")
        lines.append(
            f"plane: {len(self._alive())}/{len(self.workers)} workers up  "
            f"versions {versions}  reassigned {self.reassigned}{ignored}")
        lines.append(self.coordinator.report())
        return "\n".join(lines)
