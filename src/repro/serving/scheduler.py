"""Continuous micro-batching scheduler for the routed serving runtime.

The streaming pipeline the paper's router needs in deployment:

    traffic -> AdmissionQueue -> [score batch] -> per-member micro-batches
                                 (fused Pallas       (coalesced generate
                                  router_xattn)       calls per pool member)

Each dispatch round drains up to ``score_batch`` requests from the queue,
scores them in ONE pass through the router (the fused cross-attention path
reuses the pool-side K~/V~ projections across rounds), then coalesces
same-member requests into generate micro-batches of at most ``max_batch``.
A round fires when the queue holds a full score batch, when the head
request has waited ``max_wait_s`` (latency bound under light load), or on
final flush — the standard continuous-batching trade-off.

Time is a first-class input: the scheduler runs against a :class:`SimClock`
so open-loop traces replay deterministically on CPU. Service time defaults
to measured wall time (real compute cost of the reduced-config pool) but
can be overridden with a model for fully deterministic tests.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.budget import BudgetGovernor
from repro.serving.queue import DONE, AdmissionQueue, Request
from repro.serving.telemetry import Telemetry


class SimClock:
    """Monotone virtual clock; the runtime never reads wall time directly."""

    def __init__(self, start: float = 0.0):
        self.now = start

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, t)

    def advance(self, dt: float) -> None:
        self.now += max(dt, 0.0)


def default_service_model(score_us_per_req: float = 200.0,
                          generate_base_ms: float = 2.0,
                          generate_ms_per_req: float = 1.0):
    """Deterministic virtual service-time model for the simulator.

    On this CPU container the reduced-config pool generates in wall-seconds,
    which would stretch the virtual timeline far past any realistic budget
    window; this model gives the simulated deployment production-shaped
    service times (scoring ~us/request, generation ~ms/micro-batch) so
    budget windows, deadlines, and arrival rates compose sensibly. Pass
    ``service_time=None`` to the scheduler to use measured wall time instead.
    """
    def model(kind: str, n: int, wall_s: float) -> float:
        if kind == "score":
            return n * score_us_per_req * 1e-6
        return (generate_base_ms + n * generate_ms_per_req) * 1e-3
    return model


@dataclasses.dataclass
class SchedulerConfig:
    score_batch: int = 64      # max requests scored per dispatch round
    max_batch: int = 8         # max requests per member generate micro-batch
    max_wait_s: float = 0.02   # dispatch when head-of-line waited this long
    queue_capacity: int = 256


class MicroBatchScheduler:
    """Drives a stateless :class:`~repro.serving.engine.RoutedEngine`.

    ``service_time(kind, n_requests, wall_s) -> virtual seconds`` (kind is
    ``"score"`` or ``"generate"``) lets tests and the simulator replace
    measured wall time with a deterministic model.
    """

    def __init__(self, engine, config: Optional[SchedulerConfig] = None,
                 *, governor: Optional[BudgetGovernor] = None,
                 queue: Optional[AdmissionQueue] = None,
                 telemetry: Optional[Telemetry] = None,
                 clock: Optional[SimClock] = None,
                 service_time: Optional[Callable[[str, int, float], float]]
                 = None,
                 adapter=None, cascade=None, tracer=None, slo=None,
                 flusher=None, semcache=None, dispatcher=None):
        self.engine = engine
        self.config = config or SchedulerConfig()
        self.queue = queue or AdmissionQueue(self.config.queue_capacity)
        self.telemetry = telemetry or Telemetry(
            [m.name for m in engine.pool])
        self.governor = governor
        self.clock = clock or SimClock()
        self.service_time = service_time
        # Observability (repro.obs): one tracer fans out to every hook the
        # scheduler owns — the queue's admission events, the cascade's
        # decision instants, the adapter's observe/update events, and the
        # engine's router-swap notifications. All emission sites are
        # ``if tracer is not None`` branches: with no tracer the runtime
        # does zero extra work.
        self.tracer = tracer
        if tracer is not None:
            self.queue.tracer = tracer
            if cascade is not None and getattr(cascade, "tracer", None) \
                    is None:
                cascade.tracer = tracer
            if adapter is not None and getattr(adapter, "tracer", None) \
                    is None:
                adapter.tracer = tracer
            if getattr(engine, "on_swap", None) is None:
                engine.on_swap = lambda version: tracer.instant(
                    "router_swap", "online", self.clock.now,
                    args={"version": version})
        # SLO monitors (repro.obs.slo.SLOTracker): every finalized request
        # is observed once, and burn rates are re-evaluated at the end of
        # each dispatch round (the tracker throttles itself).
        self.slo = slo
        if slo is not None and slo.tracer is None:
            slo.tracer = tracer
        # SLO-class admission enforcement: when on, a firing burn-rate
        # alert sheds the queue's lowest slo_class at dispatch start
        # (opt-in via serve's --slo-class; off = identical behavior).
        self.slo_enforce = False
        # Streaming flusher (repro.obs.stream.ObsFlusher): run_trace ticks
        # it on the virtual clock; the multi-worker plane drives its own.
        self.flusher = flusher
        # Perfetto counter tracks are emitted on value *change* only —
        # a flat series costs one event, not one per tick.
        self._ctr_depth: Optional[int] = None
        self._ctr_lam: Optional[float] = None
        # Online adaptation (repro.online.OnlineAdapter): overrides the
        # scoring-step argmax with the exploration policy and consumes
        # served outcomes after every dispatch round.
        self.adapter = adapter
        # Cascade escalation (repro.cascade.CascadeCoordinator): turns
        # completed legs into stop-vs-escalate decisions; escalated
        # requests are re-admitted at the queue head instead of finalized.
        self.cascade = cascade
        # Semantic answer cache (repro.serving.semcache.SemanticCache):
        # rung 0 of the cascade ladder, consulted on the scoring pass's
        # shared q_emb before any scoring/generation. With a cascade
        # installed the cache borrows its policy (stop-vs-escalate on the
        # same reward math) and, when the adapter owns a drift detector,
        # registers its invalidation on that detector's alarm hooks.
        self.semcache = semcache
        if semcache is not None:
            if cascade is not None and semcache.policy is None:
                semcache.policy = cascade.policy
            adrift = getattr(adapter, "drift", None)
            if (adrift is not None and semcache.drift is None
                    and semcache.on_drift_alarm not in adrift.alarm_hooks):
                adrift.alarm_hooks.append(semcache.on_drift_alarm)
        # Sharded-pool dispatch (repro.distributed.shard.PoolDispatcher):
        # when set, generate micro-batches go through ``dispatcher.
        # generate_member`` — members this worker owns run on the local
        # engine, any other member's batch is routed to its owning worker
        # over the plane's transport. None = every member is local.
        self.dispatcher = dispatcher
        # Engines that predate per-request cost accounting (test/bench
        # stubs) return one scalar $ per generate call and take no
        # ``max_new_per_req``; detect once and split evenly for them.
        gen = (engine.generate_member if dispatcher is None
               else dispatcher.generate_member)
        try:
            sig = inspect.signature(gen)
            self._gen_per_req = "max_new_per_req" in sig.parameters
        except (TypeError, ValueError):
            self._gen_per_req = False

    # -- one scheduling round -----------------------------------------------

    def should_dispatch(self, flush: bool = False) -> bool:
        if self.queue.depth == 0:
            return False
        if flush or self.queue.depth >= self.config.score_batch:
            return True
        # 1ns slack: admitted + max_wait can round to exactly `now`, making
        # the computed wait one ulp short of max_wait forever (livelock).
        return (self.queue.oldest_wait(self.clock.now)
                >= self.config.max_wait_s - 1e-9)

    def next_dispatch_s(self, next_arrival_s: Optional[float] = None) -> float:
        """Earliest virtual time a dispatch could be warranted.

        The wake-time counterpart of :meth:`should_dispatch` (same policy
        as :meth:`run_trace`'s inline wait computation — keep the three in
        step): dispatch immediately when a full score batch is queued or
        there is nothing left to wait for (flush); otherwise wake at the
        head-of-line wait bound or the next known arrival, whichever comes
        first. Returns inf when the queue is empty and no arrival is
        scheduled. Used by the multi-worker plane's event loop
        (``repro.distributed.worker``).
        """
        if self.queue.depth and (
                next_arrival_s is None
                or self.queue.depth >= self.config.score_batch):
            return self.clock.now
        cands = []
        if self.queue.depth:
            head = self.queue.peek_all()[0]
            cands.append(head.admitted_s + self.config.max_wait_s)
        if next_arrival_s is not None:
            cands.append(next_arrival_s)
        if not cands:
            return float("inf")
        return max(self.clock.now, min(cands))

    def _virtual_dt(self, kind: str, n: int, wall_s: float) -> float:
        if self.service_time is None:
            return wall_s
        return self.service_time(kind, n, wall_s)

    def note_queue_depth(self) -> None:
        """Sample queue depth into telemetry (+ a Perfetto counter track on
        change). The single depth-sampling entry point for every host loop
        (run_trace, the plane's worker steps)."""
        depth = self.queue.depth
        self.telemetry.record_queue_depth(self.clock.now, depth)
        if self.tracer is not None and depth != self._ctr_depth:
            self._ctr_depth = depth
            self.tracer.counter("queue_depth", self.clock.now, depth)

    def _cache_rung(self, batch, q_emb, lam, outcomes):
        """Cascade rung 0: serve eligible requests from the semantic cache.

        Consulted on the shared embedding pass before any scoring or
        generation. A *stop* verdict serves the cached answer at zero
        marginal cost and finalizes the request on the spot; a
        *fallthrough* (the policy expects a real rung to beat the cached
        answer) carries the cached answer as best-so-far into the ladder
        — keep-best semantics, escalating can only add cost, never lose
        the answer in hand. Returns (remaining batch, their q_emb rows,
        cache-served requests); cache-served outcome snapshots (charged
        the entry's ORIGINAL generation cost, so the adapter's cost head
        keeps training on real economics) are appended to ``outcomes``.
        """
        now = self.clock.now
        tracer = self.tracer
        cache = self.semcache
        # Cache-owned drift detector watches the full arrival stream
        # (no-op when invalidation rides the adapter's detector).
        cache.observe_queries(q_emb, now)
        for r, e in zip(batch, q_emb):
            r.q_emb = e
        names = [m.name for m in self.engine.pool]
        headroom = (self.cascade.headroom(now) if self.cascade is not None
                    else 1.0)
        eligible = [i for i, r in enumerate(batch)
                    if r.leg == 0 and r.forced_member < 0]
        hits = cache.match(q_emb[eligible]) if eligible else []
        hit_of = dict(zip(eligible, hits))
        keep, cache_served = [], []
        record_cache = self.telemetry.record_cache
        for i, r in enumerate(batch):
            if i not in hit_of:
                keep.append(i)
                continue
            if hit_of[i] is None:  # miss fast path: no verdict object
                cache.note_miss()
                record_cache("miss")
                keep.append(i)
                continue
            v = cache.decide(hit_of[i], lam, headroom=headroom)
            if not v.serve:
                if v.reason == "stale":
                    self.telemetry.record_cache("stale")
                    if tracer is not None:
                        tracer.instant(
                            "cache_stale", "cache", now, key=r.trace_key,
                            args={"dist": v.dist,
                                  "member": v.entry.member_name})
                else:
                    self.telemetry.record_cache("miss")
                if v.reason == "fallthrough" and self.cascade is not None:
                    mi = (names.index(v.entry.member_name)
                          if v.entry.member_name in names else -1)
                    if mi >= 0:
                        r.best_q = v.entry.quality
                        r.best_q_std = v.sigma
                        r.best_member = mi
                        r.best_observed = False
                        r.best_output = np.asarray(
                            v.entry.output)[: r.max_new]
                keep.append(i)
                continue
            entry = v.entry
            mi = (names.index(entry.member_name)
                  if entry.member_name in names else -1)
            r.service_start_s = now
            r.queued_s = now - r.arrival_s
            r.finish_s = now
            r.status = DONE
            r.member = mi
            r.output = np.asarray(entry.output)[: r.max_new]
            r.cost = 0.0
            r.best_q = entry.quality
            r.best_q_std = v.sigma
            r.best_member = mi
            r.best_observed = False
            r.best_output = r.output
            self.telemetry.finalize_request(r)
            self.telemetry.record_cache("hit")
            if tracer is not None:
                tracer.span("queue_wait", "queue", r.admitted_s, now,
                            key=r.trace_key, args={"leg": 0})
                tracer.instant(
                    "cache_hit", "cache", now, key=r.trace_key,
                    args={"dist": v.dist, "member": entry.member_name,
                          "q": entry.quality})
                tracer.span(
                    "request", "request", r.arrival_s, r.finish_s,
                    key=r.trace_key,
                    args={"status": "done", "legs": 0, "cached": True,
                          "member": entry.member_name,
                          "cum_cost": r.cum_cost})
            if self.slo is not None:
                self._observe_slo(r, missed=False)
            if self.cascade is not None:
                self.cascade.on_cache_served(r)
            if self.adapter is not None and mi >= 0:
                snap = r.snapshot_leg()
                snap.member = mi
                snap.cost = entry.cost
                outcomes.append(snap)
            cache_served.append(r)
        return [batch[i] for i in keep], q_emb[keep], cache_served

    def _cache_admit(self, r: Request) -> None:
        """Offer a finalized outcome to the semantic cache."""
        if (self.semcache is None or r.q_emb is None
                or not 0 <= r.member < len(self.engine.pool)):
            return
        quality = r.best_q
        if math.isnan(quality):
            if r.leg_quality:
                quality = r.leg_quality[-1]
            elif r.s_pred is not None:
                quality = float(r.s_pred[r.member])
            else:
                return
        # $ the delivered answer cost to produce: its own leg's charge
        # (future hits replay this on the adapter's cost axis).
        cost = r.cost
        if r.member in r.tried and r.leg_costs:
            i = len(r.tried) - 1 - r.tried[::-1].index(r.member)
            if i < len(r.leg_costs):
                cost = r.leg_costs[i]
        self.semcache.admit(
            r.q_emb, output=r.output,
            member_name=self.engine.pool[r.member].name,
            quality=float(quality), cost=float(cost), s_pred=r.s_pred,
            s_std_pred=r.s_std_pred, c_pred=r.c_pred)

    def _observe_slo(self, r: Request, *, missed: bool) -> None:
        quality = None
        if not math.isnan(r.best_q):
            quality = r.best_q
        elif r.leg_quality:
            quality = r.leg_quality[-1]
        self.slo.observe_request(
            r.finish_s, e2e_s=r.e2e_latency_s, missed=missed,
            quality=quality, cost=r.cum_cost if r.cum_cost else r.cost)

    def dispatch(self) -> List[Request]:
        """Expire, score once, coalesce, generate. Returns served requests.

        With a cascade coordinator installed, a completed generate is a
        *leg*, not necessarily the end of the request: the coordinator may
        re-admit the request at the queue head with a forced next member
        (escalation), and only stop decisions finalize. Every leg's cost
        is charged to the budget governor as it happens, so the ledger
        sees the cascade's cumulative spend.
        """
        served: List[Request] = []
        tracer = self.tracer
        if self.slo_enforce and self.slo is not None and self.queue.depth:
            # SLO-class enforcement: a firing burn-rate alert means the
            # error budget is burning too fast — shed the lowest service
            # class queued before spending capacity on it. Shed requests
            # are NOT observed into the tracker (they never consumed an
            # error budget; feeding them back would self-amplify).
            firing = self.slo.firing()
            if firing:
                self.queue.shed_lowest(self.clock.now, alerts=firing)
        for r in self.queue.expire(self.clock.now):
            if r.best_output is not None:
                # Deadline hit mid-cascade: the request already holds a
                # served answer — deliver best-so-far instead of expiring
                # work that was paid for. The queue already classified it
                # as rescued (no expire instant, no expired count).
                r.status = DONE
                r.output = r.best_output
                r.member = r.best_member
                # Close out queued time: the request sat in queue from its
                # last (re)admission until the deadline fired.
                wait_from = r.arrival_s if r.leg == 0 else r.admitted_s
                r.queued_s = ((0.0 if math.isnan(r.queued_s) else r.queued_s)
                              + (r.finish_s - wait_from))
                self.telemetry.finalize_request(r)
                if self.cascade is not None:
                    self.cascade.on_rescued(r)
                if tracer is not None:
                    args = {"status": "done", "legs": r.leg,
                            "rescued": True, "cum_cost": r.cum_cost}
                    if r.leg == 0:
                        # Zero-leg rescue: the best-so-far answer came
                        # from a cache fallthrough, not a served leg.
                        args["cached"] = True
                    tracer.span("request", "request", r.arrival_s,
                                r.finish_s, key=r.trace_key, args=args)
                if self.slo is not None:
                    self._observe_slo(r, missed=True)
                served.append(r)
            else:
                if tracer is not None:
                    tracer.span("request", "request", r.arrival_s,
                                r.finish_s, key=r.trace_key,
                                args={"status": "expired", "legs": r.leg})
                if self.slo is not None:
                    self._observe_slo(r, missed=True)
        # Hot pool membership can mutate the pool between rounds: re-sync
        # the telemetry member axis and re-derive the cascade's cost
        # ladder (a stale ladder can't escalate to a new member and may
        # still rank a removed one).
        self.telemetry.sync_members([m.name for m in self.engine.pool])
        if self.cascade is not None:
            router = getattr(self.engine, "router", None)
            if router is not None:
                self.cascade.policy.refresh(router)
        batch = self.queue.pop(self.config.score_batch)
        if not batch:
            if self.slo is not None:
                self.slo.check(self.clock.now)
            return served

        lam = self.engine.lam
        if self.governor is not None:
            lam = self.governor.update(self.clock.now)
            if tracer is not None:
                tracer.instant(
                    "governor", "budget", self.clock.now,
                    args={"lam": lam,
                          "action": self.governor.last_action,
                          "utilization": self.governor.last_utilization})
        if tracer is not None and lam != self._ctr_lam:
            self._ctr_lam = lam
            tracer.counter("budget_lam", self.clock.now, lam)
        self.telemetry.record_lambda(self.clock.now, lam)

        outcomes: List[Request] = []   # per-leg outcomes for the adapter
        t_score0 = self.clock.now
        t0 = time.perf_counter()
        q_emb = None
        if (self.semcache is not None or self.adapter is not None
                or self.cascade is not None):
            # One embedding pass shared between the cache rung, scoring,
            # and the outcome loop (replay / drift want the same q_emb
            # the router saw).
            q_emb = np.asarray(self.engine.embed([r.text for r in batch]))
        if self.semcache is not None:
            # Cascade rung 0: the semantic cache short-circuits eligible
            # requests *before* any scoring or generation.
            batch, q_emb, cache_served = self._cache_rung(
                batch, q_emb, lam, outcomes)
            served.extend(cache_served)
            if not batch:
                if self.adapter is not None:
                    if outcomes:
                        self.adapter.observe(outcomes, self.clock.now)
                    else:
                        self.adapter.tick(self.clock.now)
                if self.slo is not None:
                    self.slo.check(self.clock.now)
                return served
        if q_emb is not None:
            if self.cascade is not None:
                s_hat, s_std, c_hat = self.engine.score_emb_uncertainty(q_emb)
                self.cascade.note_scores(batch, s_hat, s_std, c_hat)
            else:
                s_hat, c_hat = self.engine.score_emb(q_emb)
            if self.adapter is not None:
                choices = self.adapter.choose(s_hat, c_hat, lam,
                                              self.clock.now)
                for r, e, ex in zip(batch, q_emb, self.adapter.last_explored):
                    r.q_emb = e
                    r.explored = bool(ex)
            else:
                choices = self.engine.choose(s_hat, c_hat, lam)
        else:
            s_hat, c_hat = self.engine.score_texts([r.text for r in batch])
            choices = self.engine.choose(s_hat, c_hat, lam)
        if self.semcache is not None and self.cascade is None:
            # Pin the belief rows cache admissions fall back on for entry
            # quality when there is no cascade to pin them (note_scores).
            for r, s, c in zip(batch, s_hat, c_hat):
                if r.s_pred is None:
                    r.s_pred = np.asarray(s)
                    r.c_pred = np.asarray(c)
        choices = np.asarray(choices)
        names = [m.name for m in self.engine.pool]
        for i, r in enumerate(batch):
            if r.forced_member >= 0:
                # Escalated leg: the cascade policy already picked the
                # ladder rung; the argmax/exploration choice is overridden.
                # The rung is resolved by member NAME only (hot pool
                # mutations shift indices — a positional lookup would
                # silently dispatch a different member); a rung whose name
                # is gone falls back to free routing — the request must
                # not be lost, and must not run an arbitrary member.
                if r.forced_member_name and r.forced_member_name in names:
                    choices[i] = names.index(r.forced_member_name)
                r.forced_member = -1
                r.forced_member_name = ""
        score_wall = time.perf_counter() - t0
        self.telemetry.record_score_batch(len(batch), score_wall)
        self.clock.advance(self._virtual_dt("score", len(batch), score_wall))
        if tracer is not None:
            # Stub engines in tests/smokes may have no versioned router.
            version = getattr(getattr(self.engine, "router", None),
                              "version", None)
            tracer.span("score_batch", "sched", t_score0, self.clock.now,
                        args={"n": len(batch), "router_version": version})
        for r in batch:
            r.service_start_s = self.clock.now
            # True queued time accumulates per leg: arrival -> first
            # service, then admitted -> service for every re-admitted leg
            # — earlier legs' *generation* time never counts as queueing.
            wait_from = r.arrival_s if r.leg == 0 else r.admitted_s
            r.queued_s = ((0.0 if math.isnan(r.queued_s) else r.queued_s)
                          + (self.clock.now - wait_from))
            if tracer is not None:
                tracer.span("queue_wait", "queue", r.admitted_s,
                            self.clock.now, key=r.trace_key,
                            args={"leg": r.leg + 1})

        for mi in range(len(self.engine.pool)):
            idx = [i for i, c in enumerate(choices) if int(c) == mi]
            for lo in range(0, len(idx), self.config.max_batch):
                chunk = [batch[i] for i in idx[lo:lo + self.config.max_batch]]
                max_new = max(r.max_new for r in chunk)
                t_gen0 = self.clock.now
                t0 = time.perf_counter()
                gen = (self.engine.generate_member
                       if self.dispatcher is None
                       else self.dispatcher.generate_member)
                if self.dispatcher is not None:
                    # Trace context for a possible remote hop: the frame
                    # carries the chunk head's request-tree key and the
                    # generate link id this micro-batch will record under.
                    self.dispatcher.trace_key = (
                        chunk[0].trace_key if chunk[0].trace_key >= 0
                        else None)
                    self.dispatcher.parent_span = (
                        self.telemetry.generate_calls + 1)
                if self._gen_per_req:
                    outs, cost = gen(
                        mi, [r.prompt for r in chunk], max_new=max_new,
                        max_new_per_req=[r.max_new for r in chunk])
                else:
                    outs, cost = gen(
                        mi, [r.prompt for r in chunk], max_new=max_new)
                gen_wall = time.perf_counter() - t0
                self.clock.advance(
                    self._virtual_dt("generate", len(chunk), gen_wall))
                # Per-request $ charges: engines price delivered work per
                # request (prefill + each request's own new tokens); legacy
                # scalar-cost engines (test/bench stubs) split evenly.
                cost_arr = np.asarray(cost, np.float64)
                if cost_arr.ndim == 0:
                    per_req = np.full(len(chunk),
                                      float(cost_arr) / len(chunk))
                else:
                    per_req = cost_arr
                cost = float(per_req.sum())
                if self.governor is not None:
                    self.governor.record(cost, self.clock.now)
                delivered = sum(min(len(o), r.max_new)
                                for o, r in zip(outs, chunk))
                self.telemetry.record_generate(mi, len(chunk), delivered, cost)
                # Span-link id: this worker's generate micro-batch sequence
                # number (unique per pid — telemetry is per-worker). Leg
                # spans carry the same id so tooling can jump from a
                # request's leg to the micro-batch that served it.
                gen_id = self.telemetry.generate_calls
                # Remote hop: the dispatcher exposes the GENERATE RPC's
                # link id (request seq) — attached to the generate and leg
                # spans so tooling can jump from a request's leg to the
                # client/server rpc span pair across pids.
                rpc_id = (None if self.dispatcher is None
                          else getattr(self.dispatcher, "last_rpc", None))
                if tracer is not None:
                    gargs = {"member": self.engine.pool[mi].name,
                             "n": len(chunk), "cost": cost, "gen": gen_id}
                    if rpc_id is not None:
                        gargs["rpc"] = rpc_id
                    tracer.span("generate", "sched", t_gen0, self.clock.now,
                                args=gargs)
                for r, o, per_req_cost in zip(chunk, outs, per_req):
                    per_req_cost = float(per_req_cost)
                    r.member = mi
                    r.output = np.asarray(o)[: r.max_new]
                    r.cost = per_req_cost
                    r.cum_cost += per_req_cost
                    r.leg += 1
                    r.tried.append(mi)
                    r.leg_costs.append(per_req_cost)
                    r.finish_s = self.clock.now
                    if tracer is not None:
                        largs = {"leg": r.leg,
                                 "member": self.engine.pool[mi].name,
                                 "cost": per_req_cost, "gen": gen_id}
                        if rpc_id is not None:
                            largs["rpc"] = rpc_id
                        tracer.span(
                            "leg", "request", r.service_start_s, r.finish_s,
                            key=r.trace_key, args=largs)
                    if self.cascade is None:
                        r.status = DONE
                        self._cache_admit(r)
                        self.telemetry.finalize_request(r)
                        if tracer is not None:
                            tracer.span(
                                "request", "request", r.arrival_s,
                                r.finish_s, key=r.trace_key,
                                args={"status": "done", "legs": r.leg,
                                      "member": self.engine.pool[mi].name,
                                      "cum_cost": r.cum_cost})
                        if self.slo is not None:
                            self._observe_slo(r, missed=False)
                        served.append(r)
                        outcomes.append(r)
                        continue
                    nxt = self.cascade.on_leg_complete(r, lam,
                                                       self.clock.now)
                    self.telemetry.record_leg(
                        r.leg, per_req_cost, r.leg_quality[-1],
                        r.e2e_latency_s)
                    # The adapter trains on each leg's true attribution
                    # (member/cost of the leg that ran), which the live
                    # request object won't keep: snapshot it. (Only the
                    # adapter consumes outcomes — skip the copies without
                    # one.)
                    if self.adapter is not None:
                        outcomes.append(r.snapshot_leg())
                    if nxt is not None:
                        self.telemetry.record_escalation()
                        r.forced_member = nxt
                        r.forced_member_name = self.engine.pool[nxt].name
                        self.queue.offer_front(r, self.clock.now)
                        continue
                    r.status = DONE
                    if r.best_output is not None:
                        # Keep-best semantics: deliver the best leg's
                        # answer; cum_cost still charges every leg.
                        r.output = r.best_output
                        r.member = r.best_member
                    self._cache_admit(r)
                    self.telemetry.finalize_request(r)
                    if tracer is not None:
                        name = (self.engine.pool[r.member].name
                                if 0 <= r.member < len(self.engine.pool)
                                else str(r.member))
                        tracer.span(
                            "request", "request", r.arrival_s, r.finish_s,
                            key=r.trace_key,
                            args={"status": "done", "legs": r.leg,
                                  "member": name, "cum_cost": r.cum_cost})
                    if self.slo is not None:
                        self._observe_slo(r, missed=False)
                    served.append(r)
        if self.adapter is not None:
            if outcomes:
                # observe() also ticks: staged (delayed-feedback) outcomes
                # whose scores have landed flush on the same round.
                self.adapter.observe(outcomes, self.clock.now)
            else:
                self.adapter.tick(self.clock.now)
        if self.slo is not None:
            self.slo.check(self.clock.now)
        return served

    # -- open-loop trace replay ---------------------------------------------

    def run_trace(self, trace: Sequence[Request]) -> Dict:
        """Replay an open-loop arrival trace to completion.

        Arrivals are injected at their trace times regardless of service
        progress (open loop); the virtual clock jumps between arrival,
        wait-deadline, and service events. Returns the telemetry summary.
        """
        pending = deque(sorted(trace, key=lambda r: r.arrival_s))
        t_start = self.clock.now
        while pending or self.queue.depth:
            while pending and pending[0].arrival_s <= self.clock.now:
                self.queue.offer(pending.popleft(), self.clock.now)
            self.note_queue_depth()
            if self.flusher is not None:
                self.flusher.maybe_flush(self.clock.now)
            if self.should_dispatch(flush=not pending):
                self.dispatch()
                continue
            nxt = []
            if pending:
                nxt.append(pending[0].arrival_s)
            if self.queue.depth:
                head = self.queue.peek_all()[0]
                nxt.append(head.admitted_s + self.config.max_wait_s)
            nxt_t = min(nxt)
            if nxt_t <= self.clock.now:
                # No future event to wait for (float rounding): the only way
                # this happens is a queued head at its wait bound — serve it.
                self.dispatch()
                continue
            self.clock.advance_to(nxt_t)
        if self.adapter is not None:
            # Final flush: staged outcomes whose feedback landed by the end
            # of the trace still commit (later ones expire when the stage
            # has a timeout configured, else stay pending).
            self.adapter.tick(self.clock.now)
        if self.slo is not None:
            # Forced end-of-trace evaluation: a run shorter than the check
            # throttle must still surface its alert transitions.
            self.slo.check(self.clock.now, force=True)
        self.telemetry.rejected = self.queue.rejected
        self.telemetry.expired = self.queue.expired
        self.telemetry.shed = self.queue.shed
        return self.telemetry.summary(self.clock.now - t_start)
