"""Routed serving engine: the paper's router fronting the architecture pool.

Flow per request batch:
    text -> featurizer -> dual predictors (quality, cost) -> reward argmax
         -> dispatch to the chosen pool member's generate loop.

The pool members are the assigned architectures (reduced configs on CPU,
full configs under the production mesh). Each member's $ cost rate derives
from its *active* parameter count — 2*N_active FLOPs/token at a fixed
$/FLOP — so the router's cost axis is grounded in real model economics
rather than API price tables.

The router's scoring hot path runs through the fused Pallas kernel
(``repro.kernels.ops.router_xattn``) when the quality predictor is the
attention variant on TPU; elsewhere it falls back to the jnp reference path
(identical math, see kernels/ref.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictors import PREDICTORS
from repro.core.rewards import REWARDS
from repro.core.router import PredictiveRouter
from repro.data.featurizer import embed_texts
from repro.kernels import ops as kops
from repro.models import lm as lm_mod

# $ per 1e12 FLOPs — anchors active-param FLOPs to an API-like price axis.
DOLLARS_PER_TFLOP = 2.2e-4


def arch_cost_rate(cfg, tokens_out: int = 256) -> float:
    """$ per request: 2 * N_active FLOPs/token * tokens * $/FLOP."""
    flops = 2.0 * cfg.active_param_count() * tokens_out
    return flops / 1e12 * DOLLARS_PER_TFLOP


@dataclasses.dataclass
class PoolMember:
    name: str
    cfg: object
    params: Dict
    quality_profile: Callable[[np.ndarray], np.ndarray]  # emb -> quality sim
    cost_rate: float

    def generate(self, prompts: jax.Array, max_new: int = 8):
        return lm_mod.greedy_generate(self.cfg, self.params, prompts, max_new)


@dataclasses.dataclass
class RoutedEngine:
    router: PredictiveRouter
    pool: List[PoolMember]
    lam: float = 1.0
    use_pallas: bool = False

    def _scores(self, q_emb: np.ndarray):
        if self.use_pallas and self.router.quality_kind == "attn":
            qp = self.router.quality_params
            s_hat = np.asarray(kops.router_xattn(
                jnp.asarray(q_emb), qp["wq"], qp["wk"], qp["wv"],
                qp["wo"], qp["bo"], jnp.asarray(self.router.model_emb),
            ))
            cp = self.router.cost_params
            c_hat = np.asarray(PREDICTORS[self.router.cost_kind].apply(
                cp, jnp.asarray(q_emb), jnp.asarray(self.router.model_emb)))
            if self.router.cost_scaler is not None:
                c_hat = c_hat * self.router.cost_scaler["sd"] + self.router.cost_scaler["mu"]
            return s_hat, np.maximum(c_hat, 0.0)
        return self.router.predict(q_emb)

    def route_texts(self, texts: Sequence[str]) -> np.ndarray:
        emb = embed_texts(texts)
        s_hat, c_hat = self._scores(emb)
        r = REWARDS[self.router.reward](s_hat, c_hat, self.lam)
        return np.argmax(np.asarray(r), axis=-1)

    def serve(self, texts: Sequence[str], prompts: jax.Array,
              max_new: int = 8) -> Dict:
        """Route a batch and run generation on each chosen member.

        ``prompts`` are the token ids (same order as texts). Requests routed
        to the same member are batched into one generate call.
        """
        t0 = time.time()
        choices = self.route_texts(texts)
        out_tokens = [None] * len(texts)
        total_cost = 0.0
        for mi, member in enumerate(self.pool):
            idx = np.flatnonzero(choices == mi)
            if len(idx) == 0:
                continue
            toks = member.generate(prompts[idx], max_new=max_new)
            for j, ii in enumerate(idx):
                out_tokens[ii] = np.asarray(toks[j])
            total_cost += member.cost_rate * len(idx)
        return {
            "choices": choices,
            "outputs": out_tokens,
            "total_cost": total_cost,
            "latency_s": time.time() - t0,
            "per_member_counts": np.bincount(choices, minlength=len(self.pool)),
        }
