"""Routed serving engine: the paper's router fronting the architecture pool.

Flow per score batch:
    text -> featurizer -> dual predictors (quality, cost) -> reward argmax
         -> dispatch to the chosen pool member's generate loop.

The pool members are the assigned architectures (reduced configs on CPU,
full configs under the production mesh). Each member's $ cost rate derives
from its *active* parameter count — 2*N_active FLOPs/token at a fixed
$/FLOP — so the router's cost axis is grounded in real model economics
rather than API price tables.

:class:`RoutedEngine` is the *stateless* scoring/dispatch core: it owns no
queue, no clock, and no budget — the streaming scheduler
(:mod:`repro.serving.scheduler`) drives it. The router's scoring hot path
runs through the fused Pallas kernel (``repro.kernels.ops.router_xattn_pool``)
when the quality predictor is the attention variant, with the pool-side
K~/V~ projections computed once per pool and reused across every score
batch; elsewhere it falls back to the jnp reference path (identical math,
see kernels/ref.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictors import PREDICTORS
from repro.core.rewards import REWARDS
from repro.core.router import PredictiveRouter
from repro.data.featurizer import embed_texts
from repro.kernels import ops as kops
from repro.models import lm as lm_mod

# $ per 1e12 FLOPs — anchors active-param FLOPs to an API-like price axis.
DOLLARS_PER_TFLOP = 2.2e-4

# Nominal generation length the per-request $ rate is quoted at. The
# router's cost axis trains on this flat rate (a stable, request-agnostic
# per-member price that keeps the ladder ordering deterministic), while
# the actual ledger charge is per *delivered* token (see
# ``generate_member``): ``cost_rate / REF_TOKENS_OUT`` $ per token.
REF_TOKENS_OUT = 256


def arch_cost_per_token(cfg) -> float:
    """$ per token processed: 2 * N_active FLOPs/token * $/FLOP."""
    return 2.0 * cfg.active_param_count() / 1e12 * DOLLARS_PER_TFLOP


def arch_cost_rate(cfg, tokens_out: int = REF_TOKENS_OUT) -> float:
    """Nominal $ per request at the reference generation length."""
    return arch_cost_per_token(cfg) * tokens_out


@dataclasses.dataclass
class PoolMember:
    name: str
    cfg: object
    params: Dict
    quality_profile: Callable[[np.ndarray], np.ndarray]  # emb -> quality sim
    cost_rate: float

    def generate(self, prompts: jax.Array, max_new: int = 8, attn_mask=None):
        return lm_mod.greedy_generate(self.cfg, self.params, prompts, max_new,
                                      attn_mask=attn_mask)


def pad_prompts(prompts: Sequence[np.ndarray], pad_id: int = 0) -> jax.Array:
    """Left-pad variable-length token rows into one (B, S_max) int32 batch.

    Left padding keeps the *last* prompt position real, which is what the
    greedy prefill conditions the first generated token on. Pass the
    matching :func:`prompt_pad_mask` into generate so every mixer family
    ignores pad positions — attention masks pad keys, SSM/xLSTM scans
    treat pads as identity updates, MoE excludes pads from capacity
    accounting — making each request's output invariant to its micro-batch
    neighbors (pinned by tests/test_masked_prefill.py).
    """
    s_max = max(int(len(p)) for p in prompts)
    out = np.full((len(prompts), s_max), pad_id, np.int32)
    for i, p in enumerate(prompts):
        p = np.asarray(p, np.int32)
        out[i, s_max - len(p):] = p
    return jnp.asarray(out)


def prompt_pad_mask(prompts: Sequence[np.ndarray]) -> jax.Array:
    """(B, S_max) bool, True at real (right-aligned) token positions."""
    s_max = max(int(len(p)) for p in prompts)
    mask = np.zeros((len(prompts), s_max), bool)
    for i, p in enumerate(prompts):
        mask[i, s_max - len(p):] = True
    return jnp.asarray(mask)


@dataclasses.dataclass
class RoutedEngine:
    """Stateless scoring/dispatch core driven by the streaming scheduler.

    Holds only the trained router and the model pool; every method is a pure
    function of its arguments (plus the lazily cached per-pool K~/V~
    projections, invalidated via :meth:`refresh_pool`).
    """

    router: PredictiveRouter
    pool: List[PoolMember]
    lam: float = 1.0
    use_pallas: bool = False
    # Observability hook: called with the new router version after every
    # successful swap (the scheduler wires this to the trace recorder).
    on_swap: Optional[Callable[[int], None]] = dataclasses.field(
        default=None, repr=False)
    _pool_proj: Optional[Tuple[jax.Array, jax.Array]] = dataclasses.field(
        default=None, repr=False)

    # -- scoring ------------------------------------------------------------

    def pool_projections(self) -> Tuple[jax.Array, jax.Array]:
        """Cached pool-side K~/V~ for the fused scoring path (once per pool)."""
        if self._pool_proj is None:
            qp = self.router.quality_params
            self._pool_proj = kops.pool_projections(
                qp["wk"], qp["wv"], jnp.asarray(self.router.model_emb))
        return self._pool_proj

    def refresh_pool(self) -> None:
        """Invalidate cached projections after the pool/router changes."""
        self._pool_proj = None

    def _scores(self, q_emb: np.ndarray):
        if self.use_pallas and self.router.quality_kind == "attn":
            qp = self.router.quality_params
            kt, vt = self.pool_projections()
            # Bucket the batch dim to multiples of 64 *outside* the jit
            # boundary: scheduler batches vary per round, and jit keys on
            # the raw shape — without bucketing every distinct batch size
            # would retrace and recompile the kernel.
            b = q_emb.shape[0]
            b_pad = -(-b // 64) * 64
            q = jnp.asarray(np.pad(np.asarray(q_emb, np.float32),
                                   ((0, b_pad - b), (0, 0))))
            s_hat = np.asarray(kops.router_xattn_pool(
                q, qp["wq"], kt, vt, qp["wo"], qp["bo"]))[:b]
            cp = self.router.cost_params
            c_hat = self.router.denormalize_cost(
                PREDICTORS[self.router.cost_kind].apply(
                    cp, jnp.asarray(q_emb), jnp.asarray(self.router.model_emb)))
            return s_hat, c_hat
        return self.router.predict(q_emb)

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        """Query embeddings (B, dq) — exposed so the online adapter can
        reuse the scoring pass's embeddings for replay/drift without a
        second featurizer pass."""
        return embed_texts(texts)

    def score_emb(self, q_emb: np.ndarray):
        """(s_hat, c_hat), both (B, K), from precomputed embeddings."""
        return self._scores(q_emb)

    def score_emb_uncertainty(self, q_emb: np.ndarray):
        """(s_mean, s_std, c_hat), each (B, K) — the cascade scoring path.

        Ensemble quality kinds report per-head disagreement as epistemic
        std; everything else degrades to zero std (the cascade policy then
        runs on means alone). This path stays on the jnp reference scorer:
        the fused Pallas kernel computes a single output head, and the
        per-head spread is exactly what it would fuse away.
        """
        return self.router.predict_with_uncertainty(q_emb)

    def score_texts(self, texts: Sequence[str]):
        """(s_hat, c_hat), both (B, K) — one fused pass over the batch."""
        return self._scores(embed_texts(texts))

    # -- online adaptation ---------------------------------------------------

    def swap_router(self, new_router) -> None:
        """Atomically publish a new router version.

        The swap is a single reference assignment of a fully-built router
        (the updater constructs the whole param tree before calling this),
        so a concurrent scorer sees either the old or the new router —
        never a partially-written tree. Stale publishes (version <= live
        version with the same object identity contract) are rejected so a
        slow updater can't roll back a newer router.
        """
        if new_router is self.router:
            raise ValueError("swap_router needs a new router object "
                             "(routers are immutable; use with_updates)")
        if new_router.version <= self.router.version:
            raise ValueError(
                f"stale router publish: v{new_router.version} <= "
                f"live v{self.router.version}")
        self.router = new_router
        self.refresh_pool()
        if self.on_swap is not None:
            self.on_swap(new_router.version)

    def choose(self, s_hat: np.ndarray, c_hat: np.ndarray,
               lam: Optional[float] = None) -> np.ndarray:
        """Reward argmax over the pool at willingness-to-pay ``lam``."""
        lam = self.lam if lam is None else lam
        r = REWARDS[self.router.reward](s_hat, c_hat, lam)
        return np.argmax(np.asarray(r), axis=-1)

    def route_texts(self, texts: Sequence[str],
                    lam: Optional[float] = None) -> np.ndarray:
        s_hat, c_hat = self.score_texts(texts)
        return self.choose(s_hat, c_hat, lam)

    # -- dispatch -----------------------------------------------------------

    def generate_member(self, member_idx: int, prompts: Sequence[np.ndarray],
                        max_new: int = 8,
                        max_new_per_req: Optional[Sequence[int]] = None,
                        ) -> Tuple[List[np.ndarray], np.ndarray]:
        """Run one generate micro-batch on a pool member.

        ``prompts`` are variable-length token rows; they are left-padded
        into one batch. Returns ``(per-request output tokens, per-request
        $ costs)``. The charge is *delivered work* — prefill (prompt
        tokens) plus the new tokens each request actually receives (capped
        by its own ``max_new_per_req`` entry when given, so chunk-mates
        with different caps pay different $ even though the micro-batch
        generates to the chunk max) — at the member's per-token rate,
        never a flat per-request price.
        """
        member = self.pool[member_idx]
        toks = member.generate(pad_prompts(prompts), max_new=max_new,
                               attn_mask=prompt_pad_mask(prompts))
        outs = [np.asarray(toks[i]) for i in range(len(prompts))]
        per_tok = member.cost_rate / REF_TOKENS_OUT
        caps = (max_new_per_req if max_new_per_req is not None
                else [max_new] * len(prompts))
        costs = np.asarray(
            [per_tok * (len(np.asarray(p)) + min(len(o), int(cap)))
             for p, o, cap in zip(prompts, outs, caps)], np.float64)
        return outs, costs

    def serve(self, texts: Sequence[str], prompts: jax.Array,
              max_new: int = 8) -> Dict:
        """One-shot batch serving (no queue): route, then generate.

        Requests routed to the same member are coalesced into one generate
        call. The streaming scheduler supersedes this for sustained traffic;
        it remains the simple synchronous entry point.
        """
        t0 = time.time()
        choices = self.route_texts(texts)
        out_tokens = [None] * len(texts)
        total_cost = 0.0
        prompts = np.asarray(prompts)
        for mi in range(len(self.pool)):
            idx = np.flatnonzero(choices == mi)
            if len(idx) == 0:
                continue
            outs, cost = self.generate_member(
                mi, [prompts[i] for i in idx], max_new=max_new)
            for j, ii in enumerate(idx):
                out_tokens[ii] = outs[j]
            total_cost += float(np.sum(cost))
        return {
            "choices": choices,
            "outputs": out_tokens,
            "total_cost": total_cost,
            "latency_s": time.time() - t0,
            "per_member_counts": np.bincount(choices, minlength=len(self.pool)),
        }
