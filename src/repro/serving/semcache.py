"""Semantic answer cache: rung 0 of the cascade ladder.

At millions-of-users traffic many queries are near-duplicates. This cache
keys *answers* by query embedding: when a new query lands within a
calibrated radius of a cached entry, the cached answer can be served at
zero marginal cost. Crucially the decision to serve it is NOT a bare
threshold — the cache is wired as the cheapest rung of the cascade
ladder, so stop-vs-escalate reasons about cache confidence with the same
expected-marginal-reward math as every other leg
(:meth:`repro.cascade.policy.CascadePolicy.decide_rung0`):

  * **stop value** — the reward of keeping the cached answer at $0:
    ``R(q_entry - gamma * sigma(d), 0)`` where ``sigma(d)`` is a
    distance-derived confidence spread (``conf_slope * d / radius`` —
    an exact hit has no spread, a hit at the radius edge is discounted
    like an answer the ensemble disagrees about).
  * **escalation value** — for each real rung, the optimistic reward at
    that rung's predicted cost, using the belief rows pinned when the
    *cached* answer was originally scored.

A stop serves the cached answer; an escalate falls through to the real
ladder (the request is scored and routed as if the cache missed).

Distances run through the existing Pallas :func:`repro.kernels.ops.
pairwise_l2` entry point on the scoring pass's shared ``q_emb`` — no
second embedding pass, and the entry matrix is a fixed ``(cap, d)``
buffer with query batches bucketed to a fixed granularity so jit traces
once per bucket, not once per batch size.

Admission is bounded: LRU eviction at ``cap`` entries plus a per-entry
quality floor (never cache an answer worth repeating only by accident).
Invalidation is driven by the online drift detector
(:class:`repro.online.drift.DriftDetector` alarm hooks): under domain
shift a stale cache is a quality cliff, so an alarm either flushes the
cache or marks every entry stale for re-probing ("probe" mode — a stale
hit is never served, and the fresh outcome that replaces it re-arms the
region).

Everything is a pure function of admitted state + query embeddings (LRU
ticks use a deterministic counter, never wall time), so cached runs
replay byte-identically under the virtual clock.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import ops as kops

INVALIDATION_MODES = ("probe", "flush")

# Below this many query x entry cells the batched lookup runs as one
# fused numpy expression (cached per-slot norms, same math as the
# admission-path dedup check): the Pallas kernel's per-call dispatch
# overhead dominates tiny problems, and a busy scheduler loop pays that
# dispatch cache-cold. At-scale lookups (big caps / wide buckets, TPU)
# still go through the kernel.
_KERNEL_MIN_CELLS = 1 << 15


@dataclasses.dataclass
class CacheEntry:
    """One cached answer keyed by the embedding of the query that made it."""

    emb: np.ndarray                    # (d,) fp32 query embedding
    output: np.ndarray                 # generated tokens served on a hit
    member_name: str                   # pool member that produced the answer
    quality: float                     # quality credited to the answer
    cost: float                        # $ the answer originally cost to make
    # Router belief rows of the originating query (cascade rung-0 inputs).
    s_pred: Optional[np.ndarray] = None
    s_std_pred: Optional[np.ndarray] = None
    c_pred: Optional[np.ndarray] = None
    stale: bool = False                # drift-invalidated; never served
    last_used: int = 0                 # LRU tick (deterministic counter)


class CacheVerdict:
    """Outcome of one rung-0 lookup (returned by :meth:`SemanticCache.decide`)."""

    __slots__ = ("serve", "entry", "dist", "sigma", "reason")

    def __init__(self, serve: bool, entry: Optional[CacheEntry],
                 dist: float, sigma: float, reason: str):
        self.serve = serve
        self.entry = entry
        self.dist = dist
        self.sigma = sigma
        self.reason = reason  # "hit" | "stale" | "fallthrough" | "miss"


def calibrate_radius(emb: np.ndarray, quantile: float = 0.05,
                     sample: int = 512) -> float:
    """Serving radius from the reference corpus's own geometry.

    Takes the ``quantile`` of nearest-neighbor distances among (a
    deterministic prefix sample of) the reference embeddings: queries
    closer than most in-distribution neighbor pairs are near-duplicates.
    """
    emb = np.asarray(emb, np.float32)
    s = emb[: min(sample, len(emb))]
    if len(s) < 2:
        return 1e-6
    d2 = np.asarray(kops.pairwise_l2(s, s), np.float64)
    np.fill_diagonal(d2, np.inf)
    nn = np.sqrt(np.maximum(d2.min(axis=1), 0.0))
    nn = nn[np.isfinite(nn)]
    return float(max(np.quantile(nn, quantile), 1e-6))


class SemanticCache:
    """Embedding-keyed answer cache serving as cascade rung 0.

    ``policy`` (a :class:`repro.cascade.policy.CascadePolicy`) makes a hit
    a real stop-vs-escalate decision; without one the cache degrades to a
    radius threshold (the quality floor was enforced at admission).
    ``drift`` optionally attaches a detector the cache owns — its alarms
    invalidate via :meth:`on_drift_alarm`, which is also registered as an
    ``alarm_hooks`` callback so an adapter-owned detector can drive the
    same invalidation.
    """

    def __init__(self, radius: float, cap: int = 256, *,
                 quality_floor: float = 0.25, conf_slope: float = 0.25,
                 invalidate: str = "probe", policy=None, drift=None,
                 query_bucket: int = 64):
        if radius <= 0.0:
            raise ValueError("radius must be > 0")
        if cap < 1:
            raise ValueError("cap must be >= 1")
        if invalidate not in INVALIDATION_MODES:
            raise ValueError(
                f"invalidate must be one of {INVALIDATION_MODES}")
        self.radius = float(radius)
        self.cap = int(cap)
        self.quality_floor = float(quality_floor)
        self.conf_slope = float(conf_slope)
        self.invalidate = invalidate
        self.policy = policy
        self.drift = drift
        self.query_bucket = int(query_bucket)
        self._entries: List[CacheEntry] = []
        self._emb_buf: Optional[np.ndarray] = None  # fixed (cap, d) fp32
        self._used_buf = np.zeros(self.cap, np.int64)  # LRU ticks, slot-major
        self._norm_buf = np.zeros(self.cap, np.float32)  # ||emb||^2 per slot
        self._q_scratch: Optional[np.ndarray] = None   # padded query buffer
        self._seq = 0                               # deterministic LRU tick
        self.stats = {
            "lookups": 0, "hits": 0, "misses": 0, "stale_hits": 0,
            "fallthroughs": 0, "served": 0, "admitted": 0, "refreshed": 0,
            "evicted": 0, "invalidations": 0, "flushes": 0,
        }
        if drift is not None:
            drift.alarm_hooks.append(self.on_drift_alarm)

    def __len__(self) -> int:
        return len(self._entries)

    # -- lookup ---------------------------------------------------------------

    def _tick(self) -> int:
        self._seq += 1
        return self._seq

    def match(self, q_emb: np.ndarray) -> List[Optional[Tuple[int, float]]]:
        """Nearest cached entry within radius per query row.

        Returns ``(entry_index, distance)`` per row, or ``None`` on a miss.
        Stale entries still match (the caller decides what a stale hit
        means); batched through the Pallas pairwise-L2 kernel against the
        fixed-capacity entry buffer, with the query batch bucketed so jit
        retraces once per bucket size, not once per batch size. Problems
        under ``_KERNEL_MIN_CELLS`` cells short-circuit to a fused numpy
        norm expansion — the kernel's dispatch overhead dominates there.
        """
        q_emb = np.asarray(q_emb, np.float32)
        if q_emb.ndim == 1:
            q_emb = q_emb[None]
        b = q_emb.shape[0]
        n = len(self._entries)
        if n == 0 or b == 0:
            return [None] * b
        bucket = self.query_bucket
        b_pad = -(-b // bucket) * bucket
        d = q_emb.shape[1]
        if b_pad * n < _KERNEL_MIN_CELLS:
            d2 = (self._norm_buf[:n][None, :]
                  - 2.0 * (q_emb @ self._emb_buf[:n].T)
                  + np.einsum("ij,ij->i", q_emb, q_emb)[:, None])
        else:
            if self._q_scratch is None or self._q_scratch.shape[0] < b_pad \
                    or self._q_scratch.shape[1] != d:
                self._q_scratch = np.zeros((b_pad, d), np.float32)
            q = self._q_scratch[:b_pad]
            q[:b] = q_emb
            q[b:] = 0.0
            d2 = np.asarray(kops.pairwise_l2(q, self._emb_buf))[:b, :n]
        nn = np.argmin(d2, axis=1)
        r2 = self.radius * self.radius
        out: List[Optional[Tuple[int, float]]] = []
        for i in range(b):
            j = int(nn[i])
            v = float(d2[i, j])
            out.append((j, math.sqrt(v) if v > 0.0 else 0.0)
                       if v <= r2 else None)
        return out

    def decide(self, hit: Optional[Tuple[int, float]], lam: float, *,
               headroom: float = 1.0) -> CacheVerdict:
        """Rung-0 stop-vs-escalate for one lookup result.

        A hit is a zero-marginal-cost leg whose quality confidence
        degrades with distance; with a cascade policy installed, serving
        it is exactly the policy's stop decision at ``cum_cost=0``.
        """
        self.stats["lookups"] += 1
        if hit is None:
            self.stats["misses"] += 1
            return CacheVerdict(False, None, float("inf"), 0.0, "miss")
        return self._decide_hit(hit, lam, headroom)

    def note_miss(self) -> None:
        """Account a lookup miss without building a verdict (hot path)."""
        self.stats["lookups"] += 1
        self.stats["misses"] += 1

    def _decide_hit(self, hit: Tuple[int, float], lam: float,
                    headroom: float) -> CacheVerdict:
        j, dist = hit
        entry = self._entries[j]
        if entry.stale:
            self.stats["stale_hits"] += 1
            return CacheVerdict(False, entry, dist, 0.0, "stale")
        sigma = self.conf_slope * dist / self.radius
        if self.policy is not None and entry.s_pred is not None:
            d = self.policy.decide_rung0(
                q_cache=entry.quality, sigma_cache=sigma,
                s_hat=entry.s_pred, s_std=entry.s_std_pred,
                c_hat=entry.c_pred, lam=lam, headroom=headroom)
            if d.escalate:
                self.stats["fallthroughs"] += 1
                return CacheVerdict(False, entry, dist, sigma, "fallthrough")
        self.stats["hits"] += 1
        self.stats["served"] += 1
        entry.last_used = self._tick()
        self._used_buf[j] = entry.last_used
        return CacheVerdict(True, entry, dist, sigma, "hit")

    def _nearest_np(self, emb: np.ndarray) -> Optional[Tuple[int, float]]:
        """Single-row nearest-within-radius in plain numpy.

        The admission-time duplicate check runs once per finalized
        request — off the batched lookup path, so it skips the kernel
        dispatch overhead pairwise_l2 amortizes over query batches."""
        n = len(self._entries)
        if n == 0:
            return None
        # ||x - e||^2 = ||x||^2 - 2 x.e + ||e||^2 with per-slot norms
        # cached at write time: one BLAS matvec instead of a full
        # (n, d) difference materialization per admission.
        d2 = (self._norm_buf[:n] - 2.0 * (self._emb_buf[:n] @ emb)
              + float(emb @ emb))
        j = int(np.argmin(d2))
        v = float(d2[j])
        if v > self.radius * self.radius:
            return None
        return (j, math.sqrt(v) if v > 0.0 else 0.0)

    # -- admission / eviction -------------------------------------------------

    def admit(self, emb: np.ndarray, *, output, member_name: str,
              quality: float, cost: float, s_pred=None, s_std_pred=None,
              c_pred=None) -> bool:
        """Admit a served outcome; returns True when it entered the cache.

        An outcome within radius of an existing entry *refreshes* that
        entry in place (clearing any stale mark — this is how "probe"
        invalidation re-arms a region); otherwise LRU-evict at capacity.
        Quality below the floor (or non-finite) never enters.
        """
        quality = float(quality)
        if not np.isfinite(quality) or quality < self.quality_floor:
            return False
        emb = np.asarray(emb, np.float32).reshape(-1)
        entry = CacheEntry(
            emb=emb, output=np.asarray(output), member_name=str(member_name),
            quality=quality, cost=float(cost),
            s_pred=None if s_pred is None else np.asarray(s_pred, np.float64),
            s_std_pred=(None if s_std_pred is None
                        else np.asarray(s_std_pred, np.float64)),
            c_pred=None if c_pred is None else np.asarray(c_pred, np.float64),
            last_used=self._tick())
        if self._emb_buf is None:
            self._emb_buf = np.zeros((self.cap, emb.shape[0]), np.float32)
        hit = self._nearest_np(emb)
        if hit is not None:
            slot = hit[0]
            self._entries[slot] = entry
            self._write_slot(slot, emb, entry.last_used)
            self.stats["refreshed"] += 1
            return True
        if len(self._entries) >= self.cap:
            slot = int(np.argmin(self._used_buf[: len(self._entries)]))
            self._entries[slot] = entry
            self._write_slot(slot, emb, entry.last_used)
            self.stats["evicted"] += 1
        else:
            self._entries.append(entry)
            self._write_slot(len(self._entries) - 1, emb, entry.last_used)
        self.stats["admitted"] += 1
        return True

    def _write_slot(self, slot: int, emb: np.ndarray, tick: int) -> None:
        self._emb_buf[slot] = emb
        self._used_buf[slot] = tick
        self._norm_buf[slot] = float(emb @ emb)

    # -- invalidation ---------------------------------------------------------

    def on_drift_alarm(self, now: float = 0.0) -> None:
        """Drift alarm: the query distribution moved, cached answers may be
        stale. "flush" drops everything; "probe" marks entries stale so
        they stop being served but their regions re-arm when a fresh
        outcome lands within radius."""
        n = len(self._entries)
        if n == 0:
            return
        self.stats["invalidations"] += n
        if self.invalidate == "flush":
            self._entries.clear()
            if self._emb_buf is not None:
                self._emb_buf[:] = 0.0
            self._used_buf[:] = 0
            self._norm_buf[:] = 0.0
            self.stats["flushes"] += 1
        else:
            for e in self._entries:
                e.stale = True

    def observe_queries(self, q_emb: np.ndarray, now: float = 0.0) -> bool:
        """Feed the scoring pass's embeddings to a cache-owned drift
        detector (no-op when invalidation rides an adapter's detector).
        The alarm hook registered at construction does the invalidation;
        refit re-anchors so the detector watches for the *next* shift."""
        if self.drift is None or self.drift.ref_mean is None:
            return False
        fired = self.drift.observe(q_emb, now)
        if fired:
            self.drift.refit()
        return fired

    def report(self) -> dict:
        out = dict(self.stats)
        out["entries"] = len(self._entries)
        out["stale_entries"] = sum(1 for e in self._entries if e.stale)
        out["radius"] = self.radius
        out["hit_rate"] = (self.stats["served"] / self.stats["lookups"]
                           if self.stats["lookups"] else 0.0)
        return out
