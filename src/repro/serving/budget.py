"""Rolling cost-budget governor.

RouteLLM-style deployments route under a *spend* constraint, not a fixed
lambda: the operator states "at most $B per window" and the router's
willingness-to-pay must adapt to traffic. The governor tracks realized
spend over a rolling window and steers the effective lambda of the
exponential reward R2 = s * exp(-c / lam):

  * over budget  -> shrink lambda (cost penalty grows, traffic shifts to
    cheaper pool members);
  * under budget -> relax lambda back toward the operator's nominal value
    (never beyond it — the budget is a cap, not a quota to burn).

The controller is proportional in log-space: one update scales lambda by
``(high_water / utilization) ** gain`` (floored at ``min_step`` per update),
because lambda spans orders of magnitude (see the paper's lambda grids) and
a fixed decay would need dozens of updates to cross a decade. Relaxation is
a gentler fixed step — tighten fast, recover slowly. The governor is purely
a function of the recorded spend events + the supplied clock, so it is
deterministic and unit-testable without wall time.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Tuple


class BudgetGovernor:
    """Steers the effective lambda to hold spend at/below a $/window budget.

    Args:
      budget: $ allowed per rolling window.
      window_s: rolling window length in (virtual) seconds.
      lam0: operator's nominal willingness-to-pay (upper bound for lam).
      lam_min: floor — even fully over budget the router keeps routing
        (to the cheapest member) instead of dividing by zero.
      gain: log-space proportional gain; 1.0 means a 10x overspend shrinks
        lambda 10x in one update.
      min_step: floor on the per-update shrink factor (limits how violently
        a single window can move lambda).
      decay: relaxation step (0 < decay < 1): when under budget, lambda
        recovers by 1/decay per update, never above lam0.
      high_water / low_water: utilization thresholds (spend / budget) that
        trigger tightening / relaxing.
    """

    def __init__(self, budget: float, window_s: float = 10.0, *,
                 lam0: float = 1.0, lam_min: float = 1e-9,
                 gain: float = 1.0, min_step: float = 0.05,
                 decay: float = 0.7, high_water: float = 1.0,
                 low_water: float = 0.7):
        if budget <= 0:
            raise ValueError("budget must be positive")
        if not 0.0 < decay < 1.0:
            raise ValueError("decay must be in (0, 1)")
        self.budget = budget
        self.window_s = window_s
        self.lam0 = lam0
        self.lam_min = lam_min
        self.gain = gain
        self.min_step = min_step
        self.decay = decay
        self.high_water = high_water
        self.low_water = low_water

        self._events: Deque[Tuple[float, float]] = deque()  # (t, $)
        self._scale = 1.0
        self.total_spend = 0.0
        self.tightened = 0   # adjustment counters (telemetry)
        self.relaxed = 0
        # Last controller verdict (observability: the scheduler's trace
        # emits one "governor" instant per update).
        self.last_action = "hold"
        self.last_utilization = 0.0

    # -- spend accounting ---------------------------------------------------

    def record(self, cost: float, now: float) -> None:
        self._events.append((now, cost))
        self.total_spend += cost

    def _trim(self, now: float) -> None:
        lo = now - self.window_s
        while self._events and self._events[0][0] < lo:
            self._events.popleft()

    def window_spend(self, now: float) -> float:
        self._trim(now)
        return sum(c for _, c in self._events)

    def utilization(self, now: float) -> float:
        return self.window_spend(now) / self.budget

    def headroom(self, now: float) -> float:
        """Budget slack in [0, 1]: 1 = window untouched, 0 = at/over cap.

        The one definition every consumer shares — exploration annealing
        (`OnlineAdapter`) and cascade escalation gating
        (`CascadeCoordinator`) must read the same slack or their
        spend-shedding behaviours drift apart.
        """
        return float(min(max(1.0 - self.utilization(now), 0.0), 1.0))

    # -- control ------------------------------------------------------------

    @property
    def lam(self) -> float:
        return max(self.lam0 * self._scale, self.lam_min)

    def update(self, now: float) -> float:
        """One controller step; call once per scheduler dispatch."""
        u = self.utilization(now)
        self.last_utilization = u
        if u > self.high_water:
            step = (self.high_water / u) ** self.gain
            self._scale *= max(step, self.min_step)
            self.tightened += 1
            self.last_action = "tighten"
        elif u < self.low_water and self._scale < 1.0:
            self._scale = min(self._scale / self.decay, 1.0)
            self.relaxed += 1
            self.last_action = "relax"
        else:
            self.last_action = "hold"
        return self.lam

    def summary(self, now: float) -> Dict[str, float]:
        return {
            "lam": self.lam,
            "lam0": self.lam0,
            "budget_per_window": self.budget,
            "window_spend": self.window_spend(now),
            "utilization": self.utilization(now),
            "total_spend": self.total_spend,
            "tightened": self.tightened,
            "relaxed": self.relaxed,
        }
