"""Routed serving: the paper's router as a first-class serving feature."""
from repro.serving.engine import (
    DOLLARS_PER_TFLOP,
    PoolMember,
    RoutedEngine,
    arch_cost_rate,
)

__all__ = ["DOLLARS_PER_TFLOP", "PoolMember", "RoutedEngine", "arch_cost_rate"]
