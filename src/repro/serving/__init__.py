"""Routed serving: the paper's router as a first-class streaming runtime.

Layers (bottom up): :mod:`engine` — stateless scoring/dispatch core;
:mod:`queue` — bounded admission with deadlines/backpressure;
:mod:`budget` — rolling $/window governor steering effective lambda;
:mod:`scheduler` — continuous micro-batching over the queue;
:mod:`traffic` — open-loop scenario traces; :mod:`telemetry` — metrics.
"""
from repro.serving.budget import BudgetGovernor
from repro.serving.engine import (
    DOLLARS_PER_TFLOP,
    REF_TOKENS_OUT,
    PoolMember,
    RoutedEngine,
    arch_cost_per_token,
    arch_cost_rate,
    pad_prompts,
    prompt_pad_mask,
)
from repro.serving.queue import (
    DONE,
    EXPIRED,
    PENDING,
    REJECTED,
    SHED,
    AdmissionQueue,
    Request,
)
from repro.serving.scheduler import (
    MicroBatchScheduler,
    SchedulerConfig,
    SimClock,
    default_service_model,
)
from repro.serving.semcache import SemanticCache, calibrate_radius
from repro.serving.telemetry import Histogram, Telemetry
from repro.serving.traffic import TRACE_KINDS, TraceConfig, make_trace

__all__ = [
    "DOLLARS_PER_TFLOP", "REF_TOKENS_OUT", "PoolMember", "RoutedEngine",
    "arch_cost_per_token", "arch_cost_rate",
    "pad_prompts", "prompt_pad_mask",
    "AdmissionQueue", "Request", "PENDING", "DONE", "REJECTED",
    "EXPIRED", "SHED",
    "BudgetGovernor", "MicroBatchScheduler", "SchedulerConfig",
    "SimClock", "default_service_model", "Histogram", "Telemetry",
    "SemanticCache", "calibrate_radius",
    "TRACE_KINDS", "TraceConfig", "make_trace",
]
