"""Serving telemetry: counters, latency histograms, spend, queue depth.

Everything the acceptance report needs — per-member routed counts and spend,
p50/p99 routing + end-to-end latency, queue-depth snapshots — collected with
plain counters and fixed log-spaced histogram buckets (no per-request lists,
so memory stays O(buckets) at any traffic volume).
"""
from __future__ import annotations

import hashlib
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def exemplar_score(trace_key: int) -> int:
    """Deterministic min-hash rank of a trace key.

    The bucket exemplar kept is the key with the SMALLEST score — a pure
    function of the key itself, so which exemplar survives is independent
    of arrival order and of how per-worker histograms are merged, and a
    seeded replay reproduces the exact same exemplars.
    """
    digest = hashlib.blake2b(str(int(trace_key)).encode(),
                             digest_size=8).digest()
    return int.from_bytes(digest, "big")


class BoundedSeries:
    """Bounded (t, value) series with deterministic stride decimation.

    Keeps every ``stride``-th appended sample; when the kept set reaches
    ``cap`` points, every other point is dropped and the stride doubles.
    Unlike a ring buffer (the old ``deque(maxlen=...)``), coverage always
    spans the *whole* run — the head is thinned, never discarded — at
    resolution uniform in append index. The kept set is a pure function of
    the append sequence: replay-deterministic, no RNG, no wall clock.
    Memory is O(cap) at any traffic volume.
    """

    def __init__(self, cap: int = 4096):
        if cap < 2:
            raise ValueError("cap must be >= 2")
        self.cap = int(cap)
        self.stride = 1
        self.n_seen = 0
        self._points: List[Tuple[float, float]] = []

    def append(self, t: float, value: float) -> None:
        if self.n_seen % self.stride == 0:
            self._points.append((t, value))
            if len(self._points) >= self.cap:
                self._points = self._points[::2]
                self.stride *= 2
        self.n_seen += 1

    def merge(self, other: "BoundedSeries") -> None:
        """Fold another series in: union sorted by time, re-decimated to
        this series' cap (multi-worker rollup keeps whole-run coverage)."""
        pts = sorted(self._points + list(other._points))
        stride = max(self.stride, other.stride)
        while len(pts) >= self.cap:
            pts = pts[::2]
            stride *= 2
        self._points = pts
        self.stride = stride
        self.n_seen += other.n_seen

    def __len__(self) -> int:
        return len(self._points)

    def __iter__(self):
        return iter(self._points)

    def __getitem__(self, i):
        return self._points[i]

    def __bool__(self) -> bool:
        return bool(self._points)


class Histogram:
    """Log-bucketed latency histogram with interpolated percentiles."""

    def __init__(self, lo: float = 1e-6, hi: float = 1e3, n_buckets: int = 90):
        self.edges = np.logspace(math.log10(lo), math.log10(hi), n_buckets + 1)
        self.counts = np.zeros(n_buckets + 2, np.int64)  # +under/overflow
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        # Prometheus-style exemplars: raw bucket index -> (min-hash score,
        # trace_key, observed value). One per bucket, O(buckets) memory.
        self.exemplars: Dict[int, Tuple[int, int, float]] = {}

    def record(self, value: float, *, exemplar: Optional[int] = None) -> None:
        idx = int(np.searchsorted(self.edges, value, side="right"))
        self.counts[idx] += 1
        self.count += 1
        self.total += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        if exemplar is not None:
            # Lexicographic min over (score, key, value): the score picks
            # the surviving key, the full tuple breaks same-key ties so
            # the table is a pure function of the recorded set.
            cand = (exemplar_score(exemplar), int(exemplar), float(value))
            cur = self.exemplars.get(idx)
            if cur is None or cand < cur:
                self.exemplars[idx] = cand

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram in (identical bucket edges required)."""
        if not np.array_equal(self.edges, other.edges):
            raise ValueError("cannot merge histograms with different edges")
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        for idx, ex in other.exemplars.items():
            cur = self.exemplars.get(idx)
            if cur is None or tuple(ex) < cur:
                self.exemplars[idx] = tuple(ex)

    def percentile(self, p: float) -> float:
        """Approximate percentile (log-interpolated inside the bucket).

        The under/overflow buckets have no fixed outer edge, so they
        interpolate against the observed min/max instead of collapsing to
        a single point — a histogram whose every value landed below
        ``edges[0]`` still reports percentile(100) == max, not min.
        Interpolation falls back to linear when a bucket bound is
        non-positive (only reachable through min/max in the under/overflow
        buckets; the interior edges are strictly positive).
        """
        if self.count == 0:
            return float("nan")
        target = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c > 0:
                frac = (target - seen) / c
                if i == 0:
                    lo, hi = self.min, min(self.edges[0], self.max)
                elif i >= len(self.edges):
                    lo, hi = max(self.edges[-1], self.min), self.max
                else:
                    lo, hi = self.edges[i - 1], self.edges[i]
                if lo > 0 and hi > 0:
                    est = lo * (hi / lo) ** frac
                else:
                    est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            seen += c
        return self.max


class Telemetry:
    """Aggregated serving-runtime metrics for one run."""

    def __init__(self, member_names: Sequence[str]):
        self.member_names = list(member_names)
        k = len(self.member_names)
        self.member_counts = np.zeros(k, np.int64)
        self.member_spend = np.zeros(k, np.float64)
        self.member_tokens = np.zeros(k, np.int64)
        self.generate_calls = 0
        self.score_batches = 0
        self.scored_requests = 0
        self.completed = 0
        self.rejected = 0
        self.expired = 0
        self.shed = 0            # SLO-class load shedding (queue.shed)
        self.routing_latency = Histogram()    # wall s per score batch
        self.queue_wait = Histogram()         # virtual s, true queued time
        #                                       (sum of per-leg waits, never
        #                                       earlier legs' service time)
        self.e2e_latency = Histogram()        # virtual s, arrival -> finish
        self.batch_size_sum = 0               # generate micro-batch sizes
        self.max_queue_depth = 0
        self.depth_samples = 0
        # Cascade (multi-leg) accounting, indexed by leg number - 1. Lists
        # grow on demand (max_legs is small and operator-bounded).
        self.leg_served: list = []            # legs served at leg n
        self.leg_spend: list = []             # $ spent on leg n
        self.leg_quality_sum: list = []       # observed/estimated quality
        self.leg_latency: list = []           # Histogram per leg (e2e at
        #                                       that leg's completion)
        self.escalations = 0
        self.finalized_by_leg: list = []      # requests finalized after leg n
        self.double_finalize_blocked = 0      # idempotence guard trips
        # Semantic cache (cascade rung 0) counters: hits served at zero
        # marginal cost, misses (no entry in radius OR policy fell
        # through to the ladder), and stale hits (drift-invalidated
        # entries that were NOT served).
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_stale = 0
        # Bounded whole-run time series: effective lambda per dispatch
        # round and queue depth per loop tick. Deterministically thinned,
        # never ring-truncated — the start of the run stays inspectable.
        self.lam_trace = BoundedSeries(cap=4096)
        self.depth_trace = BoundedSeries(cap=4096)

    def sync_members(self, names: Sequence[str]) -> None:
        """Re-align per-member counters with the (hot-mutated) pool.

        Columns follow member *names*: a hot-added member gets fresh
        zeroed counters, a surviving member keeps its history, and a
        removed member's history is dropped (its index would otherwise be
        silently re-attributed to whichever member shifted into it).
        """
        names = list(names)
        if names == self.member_names:
            return
        # Each old column is consumed at most once, so duplicate member
        # names map first-come and extras start zeroed instead of cloning
        # one member's history into every same-named column.
        pools: Dict[str, list] = {}
        for i, n in enumerate(self.member_names):
            pools.setdefault(n, []).append(i)
        src = [pools[n].pop(0) if pools.get(n) else None for n in names]

        def realign(arr, dtype):
            out = np.zeros(len(names), dtype)
            for i, j in enumerate(src):
                if j is not None:
                    out[i] = arr[j]
            return out

        self.member_counts = realign(self.member_counts, np.int64)
        self.member_spend = realign(self.member_spend, np.float64)
        self.member_tokens = realign(self.member_tokens, np.int64)
        self.member_names = names

    def merge(self, other: "Telemetry") -> None:
        """Fold another run's telemetry in (multi-worker rollup).

        Member columns are matched by *name*; the other run's members must
        be a subset-compatible view of the same pool (workers of one
        serving plane share the pool, so this is the common case).
        """
        if other.member_names != self.member_names:
            self.sync_members(list(dict.fromkeys(
                self.member_names + other.member_names)))
        col = {n: i for i, n in enumerate(self.member_names)}
        for j, name in enumerate(other.member_names):
            i = col[name]
            self.member_counts[i] += other.member_counts[j]
            self.member_spend[i] += other.member_spend[j]
            self.member_tokens[i] += other.member_tokens[j]
        self.generate_calls += other.generate_calls
        self.score_batches += other.score_batches
        self.scored_requests += other.scored_requests
        self.completed += other.completed
        self.rejected += other.rejected
        self.expired += other.expired
        self.shed += other.shed
        self.batch_size_sum += other.batch_size_sum
        self.max_queue_depth = max(self.max_queue_depth,
                                   other.max_queue_depth)
        self.depth_samples += other.depth_samples
        self.escalations += other.escalations
        self.double_finalize_blocked += other.double_finalize_blocked
        self.cache_hits += other.cache_hits
        self.cache_misses += other.cache_misses
        self.cache_stale += other.cache_stale
        self._grow_legs(len(other.leg_served))
        for i in range(len(other.leg_served)):
            self.leg_served[i] += other.leg_served[i]
            self.leg_spend[i] += other.leg_spend[i]
            self.leg_quality_sum[i] += other.leg_quality_sum[i]
            self.leg_latency[i].merge(other.leg_latency[i])
            self.finalized_by_leg[i] += other.finalized_by_leg[i]
        self.routing_latency.merge(other.routing_latency)
        self.queue_wait.merge(other.queue_wait)
        self.e2e_latency.merge(other.e2e_latency)
        self.lam_trace.merge(other.lam_trace)
        self.depth_trace.merge(other.depth_trace)

    @classmethod
    def rollup(cls, parts: Sequence["Telemetry"]) -> "Telemetry":
        """Aggregate per-worker telemetry into one plane-level view."""
        if not parts:
            return cls([])
        out = cls(parts[0].member_names)
        for t in parts:
            out.merge(t)
        return out

    # -- recording ----------------------------------------------------------

    def record_score_batch(self, n_requests: int, wall_s: float) -> None:
        self.score_batches += 1
        self.scored_requests += n_requests
        self.routing_latency.record(wall_s)

    def record_generate(self, member: int, n_requests: int, tokens: int,
                        cost: float) -> None:
        self.generate_calls += 1
        self.batch_size_sum += n_requests
        self.member_counts[member] += n_requests
        self.member_tokens[member] += tokens
        self.member_spend[member] += cost

    def record_cache(self, outcome: str) -> None:
        """Count one semantic-cache lookup outcome: hit | miss | stale."""
        if outcome == "hit":
            self.cache_hits += 1
        elif outcome == "stale":
            self.cache_stale += 1
        else:
            self.cache_misses += 1

    def record_completion(self, queue_wait_s: float, e2e_s: float,
                          exemplar: Optional[int] = None) -> None:
        self.completed += 1
        self.queue_wait.record(queue_wait_s, exemplar=exemplar)
        self.e2e_latency.record(e2e_s, exemplar=exemplar)

    def finalize_request(self, req) -> bool:
        """Idempotent completion accounting for one request.

        A re-admitted cascade leg flows through the completion path again;
        this is the single guard making sure a request can never be counted
        twice in the completion counters / latency histograms, no matter
        how many legs it ran or how a buggy caller double-drives the
        finalize path. Returns False (and counts the block) on a repeat.
        """
        if req.finalized:
            self.double_finalize_blocked += 1
            return False
        req.finalized = True
        self.record_completion(
            req.queue_wait_s, req.e2e_latency_s,
            exemplar=req.trace_key if req.trace_key >= 0 else None)
        # Per-leg attribution only once cascade accounting is live (a
        # record_leg call or a multi-leg request) — plain single-shot runs
        # keep their summary free of cascade keys.
        if self.leg_served or req.leg > 1:
            leg = max(int(req.leg), 1)
            self._grow_legs(leg)
            self.finalized_by_leg[leg - 1] += 1
        return True

    # -- cascade (multi-leg) accounting --------------------------------------

    def _grow_legs(self, n_legs: int) -> None:
        while len(self.leg_served) < n_legs:
            self.leg_served.append(0)
            self.leg_spend.append(0.0)
            self.leg_quality_sum.append(0.0)
            self.leg_latency.append(Histogram())
            self.finalized_by_leg.append(0)

    def record_leg(self, leg: int, cost: float, quality: float,
                   latency_s: float) -> None:
        """One completed cascade leg (leg numbering starts at 1)."""
        self._grow_legs(leg)
        i = leg - 1
        self.leg_served[i] += 1
        self.leg_spend[i] += cost
        self.leg_quality_sum[i] += quality
        self.leg_latency[i].record(latency_s)

    def record_escalation(self) -> None:
        self.escalations += 1

    def record_queue_depth(self, now: float, depth: int) -> None:
        self.max_queue_depth = max(self.max_queue_depth, depth)
        self.depth_samples += 1
        self.depth_trace.append(now, float(depth))

    def record_lambda(self, now: float, lam: float) -> None:
        self.lam_trace.append(now, float(lam))

    # -- reporting ----------------------------------------------------------

    @property
    def total_spend(self) -> float:
        return float(self.member_spend.sum())

    def summary(self, duration_s: Optional[float] = None) -> Dict:
        out = {
            "completed": self.completed,
            "rejected": self.rejected,
            "expired": self.expired,
            "shed": self.shed,
            "per_member_counts": dict(
                zip(self.member_names, self.member_counts.tolist())),
            "per_member_spend": dict(
                zip(self.member_names, self.member_spend.tolist())),
            "total_spend": self.total_spend,
            "generate_calls": self.generate_calls,
            "score_batches": self.score_batches,
            "mean_generate_batch": (self.batch_size_sum / self.generate_calls
                                    if self.generate_calls else 0.0),
            "routing_p50_ms": self.routing_latency.percentile(50) * 1e3,
            "routing_p99_ms": self.routing_latency.percentile(99) * 1e3,
            "queue_wait_p50_ms": self.queue_wait.percentile(50) * 1e3,
            "queue_wait_p99_ms": self.queue_wait.percentile(99) * 1e3,
            "e2e_p50_ms": self.e2e_latency.percentile(50) * 1e3,
            "e2e_p99_ms": self.e2e_latency.percentile(99) * 1e3,
            "max_queue_depth": self.max_queue_depth,
        }
        if self.leg_served:
            out["legs_served"] = list(self.leg_served)
            out["leg_spend"] = list(self.leg_spend)
            out["leg_mean_quality"] = [
                (qs / n if n else float("nan"))
                for qs, n in zip(self.leg_quality_sum, self.leg_served)]
            out["leg_e2e_p50_ms"] = [
                h.percentile(50) * 1e3 for h in self.leg_latency]
            out["finalized_by_leg"] = list(self.finalized_by_leg)
            out["escalations"] = self.escalations
            out["escalation_rate"] = (self.escalations / self.completed
                                      if self.completed else 0.0)
            out["double_finalize_blocked"] = self.double_finalize_blocked
        lookups = self.cache_hits + self.cache_misses + self.cache_stale
        if lookups:
            out["cache_hits"] = self.cache_hits
            out["cache_misses"] = self.cache_misses
            out["cache_stale"] = self.cache_stale
            out["cache_hit_rate"] = self.cache_hits / lookups
        if duration_s:
            out["duration_s"] = duration_s
            out["requests_per_s"] = self.completed / duration_s
        return out

    def report(self, duration_s: Optional[float] = None) -> str:
        s = self.summary(duration_s)
        shed = f"  shed {s['shed']}" if s["shed"] else ""
        lines = [
            f"completed {s['completed']}  rejected {s['rejected']}  "
            f"expired {s['expired']}{shed}",
            "per-member counts: " + "  ".join(
                f"{n}={c}" for n, c in s["per_member_counts"].items()),
            "per-member spend:  " + "  ".join(
                f"{n}=${v:.6f}" for n, v in s["per_member_spend"].items()),
            f"total spend ${s['total_spend']:.6f}   "
            f"generate calls {s['generate_calls']} "
            f"(mean batch {s['mean_generate_batch']:.1f})",
            f"routing latency p50 {s['routing_p50_ms']:.2f}ms  "
            f"p99 {s['routing_p99_ms']:.2f}ms  "
            f"({s['score_batches']} score batches)",
            f"queue wait p50 {s['queue_wait_p50_ms']:.1f}ms  "
            f"p99 {s['queue_wait_p99_ms']:.1f}ms   "
            f"e2e p50 {s['e2e_p50_ms']:.1f}ms  p99 {s['e2e_p99_ms']:.1f}ms",
            f"max queue depth {s['max_queue_depth']}",
        ]
        if self.leg_served:
            per_leg = "  ".join(
                f"L{i + 1}: n={n} ${sp:.6f} q={mq:.3f} p50={p50:.1f}ms"
                for i, (n, sp, mq, p50) in enumerate(zip(
                    s["legs_served"], s["leg_spend"],
                    s["leg_mean_quality"], s["leg_e2e_p50_ms"])))
            lines.append(f"cascade legs: {per_leg}")
            lines.append(
                f"escalations {s['escalations']} "
                f"(rate {s['escalation_rate']:.3f})  finalized by leg "
                + "/".join(str(n) for n in s["finalized_by_leg"]))
        if duration_s:
            lines.append(f"duration {s['duration_s']:.2f}s  "
                         f"throughput {s['requests_per_s']:.1f} req/s")
        return "\n".join(lines)
