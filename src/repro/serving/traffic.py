"""Scenario-diverse open-loop traffic simulator.

Builds request traces on top of the synthetic RouterBench generator
(:func:`repro.data.generate`): arrival processes model *when* queries land,
the RouterBench texts model *what* they ask. Scenarios:

  poisson   memoryless arrivals at a constant mean rate — the steady-state
            baseline every serving paper starts from;
  bursty    ON-OFF modulated Poisson (exponential ON/OFF holding times,
            ON rate = burst_factor * base rate) — flash crowds that stress
            admission control and the budget governor;
  drift     Poisson arrivals whose *content* shifts over the trace from one
            benchmark mixture to another (e.g. commonsense -> math+code) —
            domain shift that moves the router's quality estimates.
  neardup   Poisson arrivals where most queries repeat a small hot set of
            texts (Zipf-weighted) — the millions-of-users regime where
            near-duplicate queries make a semantic answer cache pay.

Prompt lengths are heavy-tailed (Pareto, truncated) — the long-prompt tail
is what makes naive fixed-batch serving stall, and what micro-batching is
for. All randomness flows from one ``numpy`` Generator seeded by the trace
config, so identical configs give identical traces.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.queue import Request

TRACE_KINDS = ("poisson", "bursty", "drift", "neardup")


@dataclasses.dataclass
class TraceConfig:
    kind: str = "poisson"
    n_requests: int = 200
    rate: float = 200.0            # mean arrivals per (virtual) second
    seed: int = 0
    # bursty (ON-OFF) shape
    burst_factor: float = 8.0      # ON-phase rate multiplier
    on_mean_s: float = 0.25        # mean ON holding time
    off_mean_s: float = 0.75       # mean OFF holding time
    # heavy-tail prompt lengths
    prompt_len_min: int = 8
    prompt_len_max: int = 96
    pareto_alpha: float = 1.3
    # neardup (hot-set repetition) shape
    hot_set: int = 32              # number of hot texts arrivals repeat
    dup_frac: float = 0.7          # P(arrival repeats a hot text)
    # request shape
    max_new: int = 4
    deadline_s: Optional[float] = None  # relative to arrival; None = none
    vocab: int = 256


def _arrival_times(cfg: TraceConfig, rng: np.random.Generator) -> np.ndarray:
    n = cfg.n_requests
    if cfg.kind in ("poisson", "drift", "neardup"):
        return np.cumsum(rng.exponential(1.0 / cfg.rate, size=n))
    if cfg.kind == "bursty":
        times, t, on = [], 0.0, True
        on_rate = cfg.rate * cfg.burst_factor
        while len(times) < n:
            hold = rng.exponential(cfg.on_mean_s if on else cfg.off_mean_s)
            if on:
                tt = t + np.cumsum(rng.exponential(
                    1.0 / on_rate, size=max(int(on_rate * hold * 2), 8)))
                times.extend(tt[tt < t + hold].tolist())
            t += hold
            on = not on
        return np.asarray(times[:n])
    raise ValueError(f"unknown trace kind {cfg.kind!r}; "
                     f"choose from {TRACE_KINDS}")


def _prompt_lengths(cfg: TraceConfig, rng: np.random.Generator) -> np.ndarray:
    tail = rng.pareto(cfg.pareto_alpha, size=cfg.n_requests) + 1.0
    lens = (cfg.prompt_len_min * tail).astype(np.int64)
    return np.clip(lens, cfg.prompt_len_min, cfg.prompt_len_max)


def _drift_order(benchmarks: Sequence[str],
                 rng: np.random.Generator, n: int) -> np.ndarray:
    """Sample indices whose benchmark mixture drifts across the trace.

    Early requests are drawn mostly from the first half of the benchmark
    alphabet, late requests mostly from the second half, with a linear
    crossfade — a controlled distribution shift, not a hard switch.
    """
    benchmarks = np.asarray(benchmarks)
    names = sorted(set(benchmarks.tolist()))
    group_b = np.isin(benchmarks, names[len(names) // 2:])
    idx_a, idx_b = np.flatnonzero(~group_b), np.flatnonzero(group_b)
    if len(idx_a) == 0 or len(idx_b) == 0:   # degenerate: one benchmark
        return rng.integers(0, len(benchmarks), size=n)
    out = np.empty(n, np.int64)
    for i in range(n):
        p_b = 0.1 + 0.8 * (i / max(n - 1, 1))    # 10% -> 90% group B
        src = idx_b if rng.random() < p_b else idx_a
        out[i] = src[rng.integers(len(src))]
    return out


def _neardup_picks(cfg: TraceConfig, rng: np.random.Generator,
                   n_texts: int) -> np.ndarray:
    """Hot-set repetition: with probability ``dup_frac`` an arrival repeats
    one of ``hot_set`` hot texts (Zipf-weighted, so a few queries dominate
    — the shape real duplicate traffic has), else samples uniformly."""
    hot = rng.choice(n_texts, size=min(cfg.hot_set, n_texts), replace=False)
    w = 1.0 / np.arange(1, len(hot) + 1)          # Zipf s=1 over the hot set
    w /= w.sum()
    out = np.empty(cfg.n_requests, np.int64)
    for i in range(cfg.n_requests):
        if rng.random() < cfg.dup_frac:
            out[i] = hot[rng.choice(len(hot), p=w)]
        else:
            out[i] = rng.integers(n_texts)
    return out


def make_trace(cfg: TraceConfig, texts: Sequence[str],
               benchmarks: Optional[Sequence[str]] = None) -> List[Request]:
    """Build an open-loop request trace over the given prompt corpus.

    ``texts`` is the corpus to sample from (typically the held-out split of
    the synthetic RouterBench data); ``benchmarks`` (aligned with texts) is
    required for the drift scenario.
    """
    rng = np.random.default_rng(cfg.seed)
    arrivals = _arrival_times(cfg, rng)
    lens = _prompt_lengths(cfg, rng)
    if cfg.kind == "drift":
        if benchmarks is None:
            raise ValueError("drift trace needs per-text benchmark labels")
        picks = _drift_order(benchmarks, rng, cfg.n_requests)
    elif cfg.kind == "neardup":
        picks = _neardup_picks(cfg, rng, len(texts))
    else:
        picks = rng.integers(0, len(texts), size=cfg.n_requests)
    reqs = []
    for i in range(cfg.n_requests):
        t = float(arrivals[i])
        reqs.append(Request(
            text=texts[int(picks[i])],
            prompt=rng.integers(0, cfg.vocab, size=int(lens[i])).astype(
                np.int32),
            max_new=cfg.max_new,
            arrival_s=t,
            deadline_s=None if cfg.deadline_s is None else t + cfg.deadline_s,
        ))
    return reqs
