"""Admission queue for the streaming serving runtime.

Open-loop traffic lands here before the micro-batching scheduler drains it.
Admission control is explicit: a bounded queue exerts *backpressure* by
rejecting arrivals when full (the client-visible 429), and per-request
*deadlines* expire requests that waited too long to be worth serving
(routing latency budgets in the RouterBench setting are milliseconds; a
request that missed its deadline only wastes pool capacity).

Everything is driven by an externally supplied clock value ``now`` — the
queue itself never reads wall time, which keeps the runtime deterministic
under the simulator's virtual clock and testable without sleeps.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, List, Optional, Sequence

import numpy as np

_REQUEST_IDS = itertools.count()

# Request lifecycle states.
PENDING = "pending"    # admitted, waiting in queue
DONE = "done"          # served; ``output`` holds the generated tokens
REJECTED = "rejected"  # backpressure: queue was full at arrival
EXPIRED = "expired"    # deadline passed before service started
SHED = "shed"          # dropped by SLO-class load shedding


@dataclasses.dataclass
class Request:
    """One routed generation request flowing through the runtime."""

    text: str                          # prompt text (what the router scores)
    prompt: np.ndarray                 # token ids for the chosen member
    max_new: int = 8
    arrival_s: float = 0.0             # trace arrival time (virtual clock)
    deadline_s: Optional[float] = None # absolute; None = never expires
    rid: int = dataclasses.field(default_factory=lambda: next(_REQUEST_IDS))
    # Observability identity: assigned by the TraceRecorder in admission
    # order (dense, deterministic across replays — unlike ``rid``, whose
    # process-global counter shifts between in-process runs). -1 = no
    # tracer has seen this request.
    trace_key: int = -1
    # Service class for SLO-aware load shedding: higher = more important.
    # When a burn-rate alert fires, the scheduler sheds queued requests of
    # the LOWEST class present first (0 = best-effort default).
    slo_class: int = 0

    # Filled in by the runtime.
    status: str = PENDING
    member: int = -1                   # routed pool member index (last leg)
    admitted_s: float = float("nan")
    service_start_s: float = float("nan")
    finish_s: float = float("nan")
    # True accumulated queue time: the scheduler adds each leg's wait
    # (arrival -> first service, then admitted -> service per re-leg) at
    # service start. ``nan`` = never served by the scheduler.
    queued_s: float = float("nan")
    cost: float = 0.0                  # $ of the LAST leg served
    output: Optional[np.ndarray] = None
    # Online-adaptation bookkeeping: the scoring-pass embedding (reused by
    # the replay buffer / drift detector) and whether exploration overrode
    # the reward argmax for this request.
    q_emb: Optional[np.ndarray] = None
    explored: bool = False
    # Multi-leg cascade lifecycle (repro.cascade). A request completing a
    # leg whose outcome triggers escalation is re-admitted at elevated
    # priority instead of finalized; these fields carry the cascade state
    # across legs. ``cum_cost`` is what cascade-aware reward accounting
    # charges — the SUM of every leg's cost, never just the last one.
    leg: int = 0                       # completed legs
    cum_cost: float = 0.0              # $ across ALL legs
    tried: List[int] = dataclasses.field(default_factory=list)
    leg_costs: List[float] = dataclasses.field(default_factory=list)
    leg_quality: List[float] = dataclasses.field(default_factory=list)
    forced_member: int = -1            # escalation target (-1 = route freely)
    forced_member_name: str = ""       # resolves the target across hot pool
    #                                    mutations (index shifts); "" = by index
    finalized: bool = False            # telemetry completion guard
    # Best-answer-so-far under keep-best escalation semantics.
    best_q: float = float("nan")
    best_q_std: float = 0.0
    best_member: int = -1
    best_observed: bool = False        # best_q is feedback, not an estimate
    best_output: Optional[np.ndarray] = None
    # Router belief rows pinned at the last scoring pass (cascade policy
    # inputs): per-member quality mean / ensemble std / predicted cost.
    s_pred: Optional[np.ndarray] = None
    s_std_pred: Optional[np.ndarray] = None
    c_pred: Optional[np.ndarray] = None

    @property
    def queue_wait_s(self) -> float:
        """Total time spent *queued*, summed across legs.

        The scheduler accumulates each leg's wait into ``queued_s`` at
        service start; earlier legs' generation time never counts as
        queueing (it used to: arrival -> final-leg service start folded
        every prior leg's service into "queue wait"). Requests that never
        went through the scheduler (hand-built telemetry inputs) fall
        back to the single-leg definition.
        """
        if not np.isnan(self.queued_s):
            return self.queued_s
        return self.service_start_s - self.arrival_s

    @property
    def e2e_latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    def snapshot_leg(self) -> "Request":
        """Frozen per-leg outcome copy with a fresh rid.

        The online loop observes every *leg* as its own outcome (the
        adapter learns from both the cheap try and the escalation), but the
        request object itself stays in flight and its ``member``/``cost``
        mutate on the next leg — and staged delayed feedback is keyed by
        rid, which must be unique per outcome. The per-leg lists are
        copied (the live request keeps appending to them); array fields
        are shared (never mutated in place).
        """
        return dataclasses.replace(
            self, rid=next(_REQUEST_IDS), status=DONE,
            tried=list(self.tried), leg_costs=list(self.leg_costs),
            leg_quality=list(self.leg_quality))


class AdmissionQueue:
    """Bounded FIFO with deadline expiry and admission counters."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._items: Deque[Request] = deque()
        self.admitted = 0
        self.rejected = 0
        self.expired = 0
        self.readmitted = 0
        self.shed = 0
        # Optional trace hook (repro.obs): admission/rejection/expiry are
        # queue-owned lifecycle transitions, so their events are emitted
        # here. The scheduler installs the tracer.
        self.tracer = None

    @property
    def depth(self) -> int:
        return len(self._items)

    def offer(self, req: Request, now: float) -> bool:
        """Admit ``req`` if there is room; reject (backpressure) otherwise."""
        if len(self._items) >= self.capacity:
            req.status = REJECTED
            self.rejected += 1
            if self.tracer is not None:
                self.tracer.instant("reject", "queue", now,
                                    key=self.tracer.ensure_key(req),
                                    args={"depth": len(self._items)})
            return False
        req.admitted_s = now
        self._items.append(req)
        self.admitted += 1
        if self.tracer is not None:
            self.tracer.instant("admit", "queue", now,
                                key=self.tracer.ensure_key(req),
                                args={"depth": len(self._items)})
        return True

    def offer_front(self, req: Request, now: float) -> None:
        """Re-admit an escalated leg at the HEAD of the queue.

        Escalated requests are in-flight work with sunk cost: making them
        queue behind fresh arrivals would stack a second full queue wait
        onto their latency, and rejecting them under backpressure would
        throw away a served answer. They therefore jump the FIFO and are
        exempt from the capacity bound (the request was already admitted
        once; re-admission never grows the number of live requests).
        """
        req.status = PENDING
        req.admitted_s = now
        self._items.appendleft(req)
        self.readmitted += 1
        if self.tracer is not None:
            self.tracer.instant("readmit", "queue", now,
                                key=self.tracer.ensure_key(req),
                                args={"leg": req.leg,
                                      "member": req.forced_member_name
                                      or str(req.forced_member)})

    def expire(self, now: float) -> List[Request]:
        """Drop queued requests whose deadline has passed.

        Rescue-aware: a request holding a best-so-far answer
        (``best_output``, mid-cascade) is *rescued*, not expired — the
        scheduler will finalize it with the answer in hand. It leaves the
        queue through the same returned list but keeps ``PENDING`` status,
        emits a ``rescued`` instant (not ``expire``), and never touches
        the ``expired`` counter — so traces and counters agree with the
        request's actual fate instead of flip-flopping through an expiry
        the scheduler immediately rewrites.
        """
        survivors: Deque[Request] = deque()
        dropped: List[Request] = []
        for req in self._items:
            if req.deadline_s is not None and req.deadline_s < now:
                req.finish_s = now
                dropped.append(req)
                if req.best_output is not None:
                    if self.tracer is not None:
                        self.tracer.instant(
                            "rescued", "queue", now,
                            key=self.tracer.ensure_key(req),
                            args={"leg": req.leg,
                                  "deadline_s": req.deadline_s})
                else:
                    req.status = EXPIRED
                    self.expired += 1
                    if self.tracer is not None:
                        self.tracer.instant(
                            "expire", "queue", now,
                            key=self.tracer.ensure_key(req),
                            args={"deadline_s": req.deadline_s})
            else:
                survivors.append(req)
        self._items = survivors
        return dropped

    def shed_lowest(self, now: float,
                    alerts: Sequence[str] = ()) -> List[Request]:
        """SLO-class-aware load shedding: drop every queued request of the
        LOWEST ``slo_class`` present.

        Called by the scheduler when a burn-rate alert fires: best-effort
        load is sacrificed first so higher classes keep their error
        budget. Escalated requests holding a best-so-far answer are never
        shed — they carry sunk cost and a servable answer (same rationale
        as deadline rescue). Each shed emits a ``shed`` trace instant and
        counts once; returns the dropped requests.
        """
        sheddable = [r for r in self._items if r.best_output is None]
        if not sheddable:
            return []
        lo = min(r.slo_class for r in sheddable)
        survivors: Deque[Request] = deque()
        dropped: List[Request] = []
        for req in self._items:
            if req.best_output is None and req.slo_class == lo:
                req.status = SHED
                req.finish_s = now
                self.shed += 1
                dropped.append(req)
                if self.tracer is not None:
                    self.tracer.instant("shed", "queue", now,
                                        key=self.tracer.ensure_key(req),
                                        args={"slo_class": lo,
                                              "alerts": list(alerts)})
            else:
                survivors.append(req)
        self._items = survivors
        return dropped

    def pop(self, n: int) -> List[Request]:
        """Dequeue up to ``n`` requests in arrival order."""
        out = []
        while self._items and len(out) < n:
            out.append(self._items.popleft())
        return out

    def oldest_wait(self, now: float) -> float:
        """Seconds the head-of-line request has waited (0 when empty)."""
        if not self._items:
            return 0.0
        return now - self._items[0].admitted_s

    def peek_all(self) -> Sequence[Request]:
        return tuple(self._items)
