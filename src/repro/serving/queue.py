"""Admission queue for the streaming serving runtime.

Open-loop traffic lands here before the micro-batching scheduler drains it.
Admission control is explicit: a bounded queue exerts *backpressure* by
rejecting arrivals when full (the client-visible 429), and per-request
*deadlines* expire requests that waited too long to be worth serving
(routing latency budgets in the RouterBench setting are milliseconds; a
request that missed its deadline only wastes pool capacity).

Everything is driven by an externally supplied clock value ``now`` — the
queue itself never reads wall time, which keeps the runtime deterministic
under the simulator's virtual clock and testable without sleeps.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, List, Optional, Sequence

import numpy as np

_REQUEST_IDS = itertools.count()

# Request lifecycle states.
PENDING = "pending"    # admitted, waiting in queue
DONE = "done"          # served; ``output`` holds the generated tokens
REJECTED = "rejected"  # backpressure: queue was full at arrival
EXPIRED = "expired"    # deadline passed before service started


@dataclasses.dataclass
class Request:
    """One routed generation request flowing through the runtime."""

    text: str                          # prompt text (what the router scores)
    prompt: np.ndarray                 # token ids for the chosen member
    max_new: int = 8
    arrival_s: float = 0.0             # trace arrival time (virtual clock)
    deadline_s: Optional[float] = None # absolute; None = never expires
    rid: int = dataclasses.field(default_factory=lambda: next(_REQUEST_IDS))

    # Filled in by the runtime.
    status: str = PENDING
    member: int = -1                   # routed pool member index
    admitted_s: float = float("nan")
    service_start_s: float = float("nan")
    finish_s: float = float("nan")
    cost: float = 0.0
    output: Optional[np.ndarray] = None
    # Online-adaptation bookkeeping: the scoring-pass embedding (reused by
    # the replay buffer / drift detector) and whether exploration overrode
    # the reward argmax for this request.
    q_emb: Optional[np.ndarray] = None
    explored: bool = False

    @property
    def queue_wait_s(self) -> float:
        return self.service_start_s - self.arrival_s

    @property
    def e2e_latency_s(self) -> float:
        return self.finish_s - self.arrival_s


class AdmissionQueue:
    """Bounded FIFO with deadline expiry and admission counters."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._items: Deque[Request] = deque()
        self.admitted = 0
        self.rejected = 0
        self.expired = 0

    @property
    def depth(self) -> int:
        return len(self._items)

    def offer(self, req: Request, now: float) -> bool:
        """Admit ``req`` if there is room; reject (backpressure) otherwise."""
        if len(self._items) >= self.capacity:
            req.status = REJECTED
            self.rejected += 1
            return False
        req.admitted_s = now
        self._items.append(req)
        self.admitted += 1
        return True

    def expire(self, now: float) -> List[Request]:
        """Drop queued requests whose deadline has passed."""
        survivors: Deque[Request] = deque()
        dropped: List[Request] = []
        for req in self._items:
            if req.deadline_s is not None and req.deadline_s < now:
                req.status = EXPIRED
                req.finish_s = now
                dropped.append(req)
            else:
                survivors.append(req)
        self._items = survivors
        self.expired += len(dropped)
        return dropped

    def pop(self, n: int) -> List[Request]:
        """Dequeue up to ``n`` requests in arrival order."""
        out = []
        while self._items and len(out) < n:
            out.append(self._items.popleft())
        return out

    def oldest_wait(self, now: float) -> float:
        """Seconds the head-of-line request has waited (0 when empty)."""
        if not self._items:
            return 0.0
        return now - self._items[0].admitted_s

    def peek_all(self) -> Sequence[Request]:
        return tuple(self._items)
