"""Cascade routing: uncertainty-aware multi-leg escalation.

Turns the paper's one-shot routing decision into a sequential one: run a
cheap pool member first, then — if the answer in hand looks inadequate
relative to what a stronger member is predicted to deliver at the extra
cost — escalate up a deterministic cost ladder. Three pieces:

  :mod:`policy`       — stop-vs-escalate expected-marginal-reward rule over
                        quality mean + ensemble std + predicted cost;
  :mod:`coordinator`  — scheduler hook owning per-request cascade state and
                        telemetry-facing stats;
  serving integration — ``MicroBatchScheduler(cascade=...)`` re-admits
                        escalated legs at elevated priority, charges each
                        leg to the budget governor, and finalizes exactly
                        once (see :mod:`repro.serving.scheduler`).
"""
from repro.cascade.coordinator import CascadeCoordinator
from repro.cascade.policy import (
    CascadeConfig,
    CascadeDecision,
    CascadePolicy,
    cost_ladder,
)

__all__ = [
    "CascadeConfig", "CascadeCoordinator", "CascadeDecision",
    "CascadePolicy", "cost_ladder",
]
