"""Uncertainty-aware escalation policy: stop vs. escalate, one leg at a time.

RouterBench (arXiv:2403.12031) shows *cascading* — try a cheap model,
escalate only when the response looks inadequate — dominates parts of the
cost-quality frontier no single-shot policy can reach, and RouteLLM
(arXiv:2406.18665) frames routing as exactly this strong/weak escalation
decision under a confidence threshold. The paper's router already predicts
per-model quality AND cost; with the deep-ensemble quality head
(``attn-ens``) it also reports *epistemic* uncertainty. That triple is what
a principled escalation rule needs:

  * **ladder** — a deterministic member ordering cheapest -> strongest,
    derived from the router's cost scaler (the per-member mean cost the
    offline cost trainer normalized against). Escalation only ever climbs
    the ladder, so a cascade terminates in at most K legs.
  * **stop value** — the reward of keeping the best answer so far at the
    cascade's *cumulative* cost. When the current leg's quality is only
    estimated (no observed feedback), ensemble disagreement discounts it:
    an answer the heads disagree about is a weaker reason to stop.
  * **escalation value** — for each untried rung above the current one,
    the reward of the optimistic (mean + beta * std) quality at cumulative
    cost + that rung's predicted cost. Optimism in the face of epistemic
    uncertainty makes the policy explore rungs the router is unsure about,
    exactly where a second opinion is worth buying.

Escalate when the best rung's expected *marginal* reward clears ``margin``
(and the budget governor still has headroom); otherwise stop. The rule is
reward-shape generic — both ``R1 = s - c/lam`` (linear) and
``R2 = s * exp(-c/lam)`` (exponential) plug in — and is a pure function of
its inputs, so decisions replay deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core.rewards import REWARDS


def cost_ladder(router, c_hat: Optional[np.ndarray] = None) -> np.ndarray:
    """Member indices cheapest -> strongest (ascending expected cost).

    The ladder comes from the router's cost scaler: ``mu`` is each member's
    mean training cost, the stable, lambda-free ordering the offline cost
    trainer already established. Routers without a per-member scaler (e.g.
    hand-built test stubs) fall back to the mean of a predicted cost matrix
    ``c_hat`` (B, K) when supplied.
    """
    scaler = getattr(router, "cost_scaler", None)
    if scaler is not None and np.ndim(scaler["mu"]) == 1:
        mu = np.asarray(scaler["mu"], np.float64)
    elif c_hat is not None:
        mu = np.asarray(c_hat, np.float64).mean(axis=0)
    else:
        raise ValueError(
            "cost_ladder needs a per-member cost scaler on the router "
            "or a predicted cost matrix to derive the ladder from")
    return np.argsort(mu, kind="stable")


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    max_legs: int = 3          # hard cap on legs per request (>= 1)
    beta: float = 1.0          # optimism width on untried rungs (UCB)
    gamma: float = 1.0         # disagreement discount on the stop value
    margin: float = 0.0        # required expected marginal reward to escalate
    min_headroom: float = 0.0  # below this budget headroom, never escalate


class CascadeDecision(NamedTuple):
    escalate: bool
    next_member: int           # ladder rung to run next (-1 when stopping)
    expected_gain: float       # best rung's expected marginal reward


class CascadePolicy:
    """Expected-marginal-reward stop-vs-escalate rule over a cost ladder."""

    def __init__(self, ladder: Sequence[int],
                 config: Optional[CascadeConfig] = None,
                 reward: str = "R2"):
        self.ladder = [int(m) for m in ladder]
        self.config = config or CascadeConfig()
        if reward not in REWARDS:
            raise ValueError(f"unknown reward {reward!r}")
        self.reward = reward
        self._rank = {m: i for i, m in enumerate(self.ladder)}

    def refresh(self, router) -> bool:
        """Re-derive the ladder after a hot pool mutation.

        ``add_member`` / ``remove_member`` change the pool's member-index
        space while the policy's ladder still ranks the *old* members —
        a freshly added member could never be escalated to, and a removed
        member's stale rung could be selected. Called by the scheduler
        every dispatch round (next to the telemetry member re-sync); a
        no-op unless the router's member count disagrees with the ladder
        length, so unmutated pools pay one integer compare. Routers
        without a per-member cost scaler (hand-built stubs) are left
        alone. Returns True when the ladder was rebuilt.
        """
        scaler = getattr(router, "cost_scaler", None)
        if scaler is None or np.ndim(scaler.get("mu")) != 1:
            return False
        if len(scaler["mu"]) == len(self.ladder):
            return False
        try:
            ladder = cost_ladder(router)
        except ValueError:
            return False
        self.ladder = [int(m) for m in ladder]
        self._rank = {m: i for i, m in enumerate(self.ladder)}
        return True

    def _reward(self, s: float, c: float, lam: float) -> float:
        return float(REWARDS[self.reward](np.float64(s), np.float64(c), lam))

    def candidates(self, tried: Sequence[int]) -> list:
        """Untried rungs strictly above the highest rung already run."""
        if not tried:
            return list(self.ladder)
        top = max(self._rank.get(int(m), -1) for m in tried)
        return [m for m in self.ladder[top + 1:] if m not in set(tried)]

    def decide(self, *, s_cur: float, s_std_cur: float,
               s_hat: np.ndarray, s_std: np.ndarray, c_hat: np.ndarray,
               cum_cost: float, tried: Sequence[int], lam: float,
               observed: bool = False,
               headroom: float = 1.0) -> CascadeDecision:
        """One stop-vs-escalate decision after a completed leg.

        Args:
          s_cur: quality of the best answer so far — observed feedback when
            available (``observed=True``), else the router's mean estimate.
          s_std_cur: ensemble disagreement on ``s_cur`` (ignored when
            observed — ground truth has no epistemic spread).
          s_hat / s_std / c_hat: per-member (K,) mean quality, quality std,
            and predicted cost rows for this query.
          cum_cost: $ already spent on this request across all legs.
          tried: member indices already run (leg order irrelevant).
          lam: effective willingness-to-pay (post-governor).
          headroom: budget-governor slack in [0, 1]; under
            ``min_headroom`` the cascade never escalates (spend-shedding
            composes with the governor's lambda tightening).
        """
        cfg = self.config
        if len(tried) >= cfg.max_legs or headroom < cfg.min_headroom:
            return CascadeDecision(False, -1, 0.0)
        s_keep = float(s_cur)
        if not observed:
            s_keep -= cfg.gamma * float(s_std_cur)
        v_stop = self._reward(s_keep, cum_cost, lam)
        best_gain, best_m = -np.inf, -1
        for m in self.candidates(tried):
            s_up = min(float(s_hat[m]) + cfg.beta * float(s_std[m]), 1.0)
            # Keep-best semantics: escalating can only add cost, never
            # lose the answer already in hand.
            v_esc = self._reward(max(s_keep, s_up),
                                 cum_cost + max(float(c_hat[m]), 0.0), lam)
            gain = v_esc - v_stop
            if gain > best_gain:
                best_gain, best_m = gain, m
        if best_m < 0 or best_gain <= cfg.margin:
            return CascadeDecision(False, -1,
                                   best_gain if np.isfinite(best_gain)
                                   else 0.0)
        return CascadeDecision(True, best_m, best_gain)

    def decide_rung0(self, *, q_cache: float, sigma_cache: float,
                     s_hat: np.ndarray, s_std: np.ndarray,
                     c_hat: np.ndarray, lam: float,
                     headroom: float = 1.0) -> CascadeDecision:
        """Semantic-cache rung 0: keep the cached answer or enter the ladder.

        A cache hit is a zero-marginal-cost leg with nothing tried yet:
        the stop value is the reward of the cached answer's quality
        (discounted by the distance-derived confidence spread
        ``sigma_cache``, exactly like ensemble disagreement on an
        estimated leg) at ``cum_cost = 0``; escalation candidates are the
        whole ladder at their predicted costs. Escalating "falls through"
        the cache — the request is then scored and routed normally.
        """
        return self.decide(
            s_cur=q_cache, s_std_cur=sigma_cache, s_hat=s_hat,
            s_std=s_std, c_hat=c_hat, cum_cost=0.0, tried=(),
            lam=lam, observed=False, headroom=headroom)
