"""Cascade coordinator: the scheduler-side hook driving multi-leg requests.

The micro-batching scheduler stays a single-leg machine; this object owns
everything cascade-specific around it:

  * at the **scoring step** it pins each request's predicted quality mean /
    ensemble std / cost rows onto the request (``note_scores``), so the
    escalation decision at leg completion replays against exactly what the
    router believed when the leg was dispatched — no re-scoring race with
    online router swaps;
  * at **leg completion** (``on_leg_complete``) it resolves the leg's
    quality — observed feedback when the deployment has it (RouterBench
    logs responses; the simulator's truth tables stand in), the router's
    estimate otherwise — maintains the request's best-answer-so-far under
    keep-best semantics, and asks the :class:`CascadePolicy` whether the
    expected marginal reward of the next ladder rung justifies another
    leg. Returns the rung to escalate to, or ``None`` to finalize.

The scheduler charges every leg's generate call to the budget governor as
it happens, so a cascade's *cumulative* cost hits the $/window ledger leg
by leg — a cascade can tighten lambda mid-flight, and the policy sees the
tightened lambda (and shrinking headroom) on its next decision.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from repro.cascade.policy import CascadeDecision, CascadePolicy


class CascadeCoordinator:
    """Per-run cascade state machine over :class:`CascadePolicy`.

    ``observed_quality(request) -> float | None`` supplies post-hoc quality
    feedback for a completed leg when the deployment has one (user rating,
    auto-eval, simulator truth); ``None`` falls back to the router's
    predicted mean for that member — the policy then discounts it by the
    ensemble disagreement.
    """

    def __init__(self, policy: CascadePolicy, *,
                 observed_quality: Optional[Callable] = None,
                 governor=None):
        self.policy = policy
        self.observed_quality = observed_quality
        self.governor = governor
        # Observability hook (repro.obs): one "cascade_decision" instant
        # per completed leg, carrying the policy's expected-marginal-reward
        # inputs. Installed by the scheduler.
        self.tracer = None
        self.stats: Dict[str, float] = {
            "legs": 0, "escalations": 0, "finalized": 0,
            "observed_legs": 0, "estimated_legs": 0,
            "headroom_blocked": 0, "cache_stops": 0,
        }
        # Escalation counts indexed by the leg that triggered them
        # (leg 1 -> leg 2 escalations live at index 0, etc.).
        self.escalations_by_leg: List[int] = []

    def headroom(self, now: float) -> float:
        if self.governor is None:
            return 1.0
        return self.governor.headroom(now)

    # -- scoring-step hook ---------------------------------------------------

    def note_scores(self, batch, s_hat: np.ndarray, s_std: np.ndarray,
                    c_hat: np.ndarray) -> None:
        """Pin this round's per-request prediction rows onto the requests."""
        for r, s, sd, c in zip(batch, s_hat, s_std, c_hat):
            r.s_pred = np.asarray(s)
            r.s_std_pred = np.asarray(sd)
            r.c_pred = np.asarray(c)

    # -- leg-completion hook -------------------------------------------------

    def on_leg_complete(self, r, lam: float, now: float) -> Optional[int]:
        """Decide the completed leg's fate; returns the next member or None.

        The scheduler has already appended the leg to ``r.tried`` /
        ``r.leg_costs`` and accumulated ``r.cum_cost`` before calling this.
        """
        self.stats["legs"] += 1
        member = int(r.member)
        s_obs = (self.observed_quality(r)
                 if self.observed_quality is not None else None)
        observed = s_obs is not None
        self.stats["observed_legs" if observed else "estimated_legs"] += 1
        s_cur = float(s_obs) if observed else float(r.s_pred[member])
        s_std_cur = 0.0 if observed else float(r.s_std_pred[member])
        r.leg_quality.append(s_cur)
        # Keep-best: the answer in hand is the best leg seen so far,
        # compared on disagreement-discounted value (an estimate's value
        # is its mean minus gamma * ensemble std; observed feedback has no
        # epistemic spread) — so a verified 0.7 beats a 0.75 the heads
        # can't agree on, and legs with mixed feedback compare fairly.
        gamma = self.policy.config.gamma
        cur_eff = s_cur - gamma * s_std_cur
        best_eff = r.best_q - gamma * r.best_q_std
        if not np.isfinite(r.best_q) or cur_eff >= best_eff:
            r.best_q = s_cur
            r.best_q_std = s_std_cur
            r.best_member = member
            r.best_observed = observed
            r.best_output = r.output

        hr = self.headroom(now)
        decision: CascadeDecision = self.policy.decide(
            s_cur=r.best_q, s_std_cur=r.best_q_std,
            s_hat=r.s_pred, s_std=r.s_std_pred, c_hat=r.c_pred,
            cum_cost=r.cum_cost, tried=r.tried, lam=lam,
            observed=r.best_observed, headroom=hr,
        )
        if (not decision.escalate and hr < self.policy.config.min_headroom
                and len(r.tried) < self.policy.config.max_legs):
            # Attribute the stop to the budget gate only when the policy
            # WOULD have escalated with full headroom — a leg that would
            # have stopped anyway (answer already good enough) is not a
            # budget-suppressed escalation.
            ungated = self.policy.decide(
                s_cur=r.best_q, s_std_cur=r.best_q_std,
                s_hat=r.s_pred, s_std=r.s_std_pred, c_hat=r.c_pred,
                cum_cost=r.cum_cost, tried=r.tried, lam=lam,
                observed=r.best_observed, headroom=1.0,
            )
            if ungated.escalate:
                self.stats["headroom_blocked"] += 1
        if self.tracer is not None:
            self.tracer.instant(
                "cascade_decision", "cascade", now, key=r.trace_key,
                args={"leg": len(r.tried),
                      "escalate": bool(decision.escalate),
                      "next_member": (int(decision.next_member)
                                      if decision.escalate else None),
                      "expected_gain": float(decision.expected_gain),
                      "best_q": float(r.best_q),
                      "best_q_std": float(r.best_q_std),
                      "observed": bool(r.best_observed),
                      "cum_cost": float(r.cum_cost),
                      "lam": float(lam), "headroom": float(hr)})
        if not decision.escalate:
            self.stats["finalized"] += 1
            return None
        leg_idx = len(r.tried) - 1
        while len(self.escalations_by_leg) <= leg_idx:
            self.escalations_by_leg.append(0)
        self.escalations_by_leg[leg_idx] += 1
        self.stats["escalations"] += 1
        return int(decision.next_member)

    def on_rescued(self, r) -> None:
        """A deadline hit mid-cascade finalized the request with its
        best-so-far answer (scheduler rescue path) — account for it so
        ``finalized`` tracks the telemetry completion count and the
        escalation rate stays honest."""
        self.stats["finalized"] += 1

    def on_cache_served(self, r) -> None:
        """Rung 0 stopped: a semantic-cache hit finalized the request
        without entering the real ladder. Counted as a finalization (the
        request is done) but not as a leg — no pool member ran."""
        self.stats["finalized"] += 1
        self.stats["cache_stops"] += 1

    # -- reporting -----------------------------------------------------------

    @property
    def escalation_rate(self) -> float:
        """Escalations per finalized request (0 when nothing finalized)."""
        done = self.stats["finalized"]
        return float(self.stats["escalations"] / done) if done else 0.0

    def report(self) -> str:
        s = self.stats
        by_leg = " ".join(f"L{i + 1}->L{i + 2}:{n}"
                          for i, n in enumerate(self.escalations_by_leg))
        return (
            f"cascade: legs {int(s['legs'])}  "
            f"escalations {int(s['escalations'])} ({by_leg or 'none'})  "
            f"finalized {int(s['finalized'])}  "
            f"rate {self.escalation_rate:.3f}  "
            f"quality signal observed/estimated "
            f"{int(s['observed_legs'])}/{int(s['estimated_legs'])}  "
            f"headroom-blocked {int(s['headroom_blocked'])}  "
            f"cache-stops {int(s['cache_stops'])}"
        )
