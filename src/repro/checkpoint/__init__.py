"""Flattened-pytree checkpointing to .npz (orbax is unavailable offline).

Stores every leaf under its tree path plus a small JSON metadata blob.
Restoration validates structure + shapes against a template tree (so silent
config drift fails loudly).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.common.tree import flatten_with_paths, unflatten_from_paths

_META_KEY = "__repro_meta__"


def save_checkpoint(path: str, tree: Any, meta: Optional[Dict] = None) -> None:
    flat = flatten_with_paths(tree)
    arrays = {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype.kind == "V":  # ml_dtypes (bf16/fp8): not npz-serializable
            a = a.astype(np.float32)
        arrays[k] = a
    arrays[_META_KEY] = np.frombuffer(
        json.dumps(meta or {}).encode("utf-8"), dtype=np.uint8
    )
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def load_checkpoint(path: str, template: Any):
    """Returns (tree_like_template, meta)."""
    with np.load(path) as data:
        meta = json.loads(bytes(data[_META_KEY]).decode("utf-8"))
        flat = {k: data[k] for k in data.files if k != _META_KEY}
    tree = unflatten_from_paths(template, flat)
    # Restore original dtypes from the template (np.savez keeps them, but
    # weak-typed scalars can drift).
    tree = jax.tree.map(
        lambda t, x: x.astype(t.dtype) if hasattr(t, "dtype") else x, template, tree
    )
    return tree, meta
